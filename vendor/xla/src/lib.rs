//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The real `xla` crate wraps the native `xla_extension` closure, which
//! is not present in this offline build environment.  This stub keeps
//! the workspace compiling and unit-testable without it:
//!
//! - [`Literal`] is a real host-side buffer (dtype + dims + bytes), so
//!   the literal round-trip helpers and their tests work unchanged.
//! - Everything that would touch PJRT ([`PjRtClient::cpu`],
//!   [`PjRtLoadedExecutable::execute`], HLO loading) returns an error
//!   with a clear message.  All real-compute tests in the workspace
//!   check for AOT artifacts first and skip cleanly, so the stub never
//!   turns a green test red — it only gates the real-compute paths.
//!
//! Swap this path dependency for the real crate (same API subset) to
//! run the PJRT paths.

// API-compatibility shim: keep lints out of the way of matching the
// upstream surface.
#![allow(clippy::all)]

use std::borrow::Borrow;
use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} unavailable in this offline build (vendor/xla); \
         install the real xla crate + xla_extension to run PJRT paths"
    )))
}

/// Element dtypes the workspace marshals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host-native element types a [`Literal`] can decode to.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(b: [u8; 4]) -> f32 {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(b: [u8; 4]) -> i32 {
        i32::from_le_bytes(b)
    }
}

/// Host-side literal: dtype + dims + raw (little-endian) bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product::<usize>().max(1);
        if data.len() != numel * 4 {
            return Err(Error(format!(
                "literal byte length {} does not match shape {dims:?}",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn scalar(v: f32) -> Literal {
        Literal { ty: ElementType::F32, dims: Vec::new(), bytes: v.to_le_bytes().to_vec() }
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error(format!(
                "literal dtype mismatch: holds {:?}, asked for {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        if self.bytes.len() < 4 {
            return Err(Error("empty literal".into()));
        }
        self.to_vec::<T>().map(|v| v[0])
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("tuple literals (PJRT execution output)")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HLO parsing")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PJRT compilation")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PJRT execution")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PJRT buffer fetch")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let data = [1.0f32, -2.5, 3.0];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
            .is_err());
    }

    #[test]
    fn pjrt_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::scalar(1.0).decompose_tuple().is_err());
    }
}
