//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment for this workspace is offline (no crates.io
//! access — see `rust/src/util/mod.rs`), so the error-handling subset
//! the workspace actually uses is vendored here: an [`Error`] carrying
//! a context chain, the [`Result`] alias, the `anyhow!` / `bail!` /
//! `ensure!` macros, and the [`Context`] extension trait for `Result`
//! and `Option`.
//!
//! Display semantics match upstream closely enough for this workspace:
//! `{}` prints the outermost message, `{:#}` prints the full chain as
//! `outer: cause: root`, and `{:?}` prints the message plus a
//! "Caused by:" list.

// API-compatibility shim: keep lints out of the way of matching the
// upstream surface.
#![allow(clippy::all)]

use std::error::Error as StdError;
use std::fmt;

/// An error with an optional chain of causes (outermost first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The root cause's message (the innermost error in the chain).
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(s) = &cur.source {
            cur = s;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = &self.source;
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = &e.source;
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion (what makes `?` work on std errors) does not
// overlap with the reflexive `From<Error> for Error` in core.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our textual chain.
        let msg = e.to_string();
        let mut causes: Vec<String> = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            causes.push(s.to_string());
            src = s.source();
        }
        let mut inner = None;
        for m in causes.into_iter().rev() {
            inner = Some(Box::new(Error { msg: m, source: inner }));
        }
        Error { msg, source: inner }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "boom 7");
    }

    #[test]
    fn context_chain_alternate_display() {
        let e: Result<()> = fails().context("outer");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: boom 7");
        assert_eq!(e.root_cause(), "boom 7");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn f() -> Result<i32> {
            let v: i32 = "12".parse()?;
            let _bad: std::result::Result<i32, _> = "x".parse::<i32>();
            Ok(v)
        }
        assert_eq!(f().unwrap(), 12);
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn ensure_forms() {
        fn f(x: i32) -> Result<()> {
            ensure!(x > 0);
            ensure!(x < 10, "too big: {x}");
            Ok(())
        }
        assert!(f(5).is_ok());
        assert!(format!("{}", f(0).unwrap_err()).contains("condition failed"));
        assert_eq!(format!("{}", f(50).unwrap_err()), "too big: 50");
    }
}
