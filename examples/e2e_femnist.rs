//! End-to-end validation driver (EXPERIMENTS.md §E2E): train the
//! FEMNIST-analog MLP with FedAvg for a few hundred rounds of real FL —
//! full Parrot stack (scheduling + hierarchical aggregation + PJRT
//! compute) — and log the loss/accuracy curve to results/e2e_femnist.csv.
//!
//!     cargo run --release --example e2e_femnist             # full (200 rounds)
//!     cargo run --release --example e2e_femnist -- --rounds 40
//!
//! Proves all layers compose: L1 Pallas kernels inside the L2 train-step
//! HLO, replayed by the L3 coordinator over K simulated devices, with
//! the loss going down and accuracy climbing far above chance.

// Wallclock here is reporting-only (progress lines), not simulation
// state; exempt from the ambient-clock ban.
#![allow(clippy::disallowed_methods)]

use parrot::config::RunConfig;
use parrot::coordinator::run_simulation;
use parrot::util::cli::Args;

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    let args = Args::from_env()?;
    let rounds = args.usize_or("rounds", 200)?;
    let cfg = RunConfig {
        algorithm: args.get_or("algorithm", "fedavg").to_string(),
        model: "mlp".into(),
        n_clients: args.usize_or("clients", 300)?,
        clients_per_round: args.usize_or("per-round", 30)?,
        n_devices: args.usize_or("devices", 4)?,
        rounds,
        local_epochs: 1,
        lr: 0.05,
        mean_client_size: 60,
        eval_every: 5,
        eval_batches: 16,
        seed: args.u64_or("seed", 2024)?,
        cluster: parrot::cluster::ClusterProfile::homogeneous(args.usize_or("devices", 4)?),
        ..Default::default()
    };
    println!(
        "e2e: {} | M={} M_p={} K={} R={} | params go through the full \
         Pallas→JAX→HLO→PJRT→coordinator stack",
        cfg.algorithm, cfg.n_clients, cfg.clients_per_round, cfg.n_devices, cfg.rounds
    );

    let t0 = std::time::Instant::now();
    let summary = run_simulation(cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("round,wall_secs,train_loss,eval_loss,eval_acc,utilization\n");
    for r in &summary.metrics.rounds {
        csv.push_str(&format!(
            "{},{:.4},{:.5},{},{},{:.4}\n",
            r.round,
            r.wall_secs,
            r.train_loss,
            r.eval_loss.map(|x| format!("{x:.5}")).unwrap_or_default(),
            r.eval_acc.map(|x| format!("{x:.5}")).unwrap_or_default(),
            r.utilization
        ));
    }
    std::fs::write("results/e2e_femnist.csv", csv)?;

    // Console curve (sparse).
    println!("\nround   train-loss   eval-loss   eval-acc");
    for r in summary.metrics.rounds.iter().filter(|r| r.eval_acc.is_some()) {
        println!(
            "{:>5}   {:>10.4}   {:>9.4}   {:>7.2}%",
            r.round,
            r.train_loss,
            r.eval_loss.unwrap(),
            100.0 * r.eval_acc.unwrap()
        );
    }
    let first_loss = summary
        .metrics
        .rounds
        .iter()
        .find_map(|r| r.eval_loss)
        .unwrap_or(f64::NAN);
    let (final_loss, final_acc) =
        (summary.final_loss.unwrap_or(f64::NAN), summary.final_acc.unwrap_or(0.0));
    println!(
        "\ndone in {wall:.1}s: eval loss {first_loss:.3} → {final_loss:.3}, \
         final accuracy {:.1}% — curve in results/e2e_femnist.csv",
        100.0 * final_acc
    );
    anyhow::ensure!(final_loss < first_loss, "loss must decrease");
    anyhow::ensure!(final_acc > 0.2, "accuracy should be far above 1/62 chance");
    println!("e2e OK");
    Ok(())
}
