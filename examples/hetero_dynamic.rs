//! Heterogeneous + unstable devices (paper Appendix A, Figs. 9/11):
//! the same real-compute FedAvg run on (a) homogeneous, (b) simulated
//! heterogeneous-GPU, and (c) dynamically unstable clusters, with and
//! without Time-Window scheduling — showing the scheduler absorbing the
//! heterogeneity.
//!
//!     cargo run --release --example hetero_dynamic -- --rounds 5

use parrot::cluster::ClusterProfile;
use parrot::config::{RunConfig, SchedulerKind};
use parrot::coordinator::run_simulation;
use parrot::util::cli::Args;

fn run(
    tag: &str,
    cluster: ClusterProfile,
    sched: SchedulerKind,
    rounds: usize,
) -> anyhow::Result<f64> {
    let k = cluster.n_devices();
    let cfg = RunConfig {
        algorithm: "fedavg".into(),
        n_clients: 64,
        clients_per_round: 16,
        n_devices: k,
        rounds,
        mean_client_size: 50,
        eval_every: 0, // timing-focused
        warmup_rounds: 2,
        scheduler: sched,
        seed: 5,
        cluster,
        state_dir: std::env::temp_dir()
            .join("parrot_hetero_example")
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    };
    let summary = run_simulation(cfg)?;
    // Steady-state rounds only (post-warmup).
    let t = summary.metrics.mean_round_secs_after(2);
    println!("{tag:<28} mean steady round {t:>6.2}s");
    Ok(t)
}

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    let args = Args::from_env()?;
    let rounds = args.usize_or("rounds", 6)?;
    let k = 4;
    println!("hetero_dynamic: real compute, K={k}, R={rounds} (sleep-injected heterogeneity)\n");

    let homo = run("homo / greedy", ClusterProfile::homogeneous(k), SchedulerKind::Greedy, rounds)?;
    let hete_u = run(
        "hete / uniform (no sched)",
        ClusterProfile::heterogeneous(k),
        SchedulerKind::Uniform,
        rounds,
    )?;
    let hete_g = run(
        "hete / greedy",
        ClusterProfile::heterogeneous(k),
        SchedulerKind::Greedy,
        rounds,
    )?;
    let dyn_g = run(
        "dynamic / time-window(3)",
        ClusterProfile::dynamic(k, 8.0),
        SchedulerKind::TimeWindow(3),
        rounds,
    )?;

    println!(
        "\nheterogeneity slows the unscheduled run by {:.2}x; scheduling claws back {:.2}x",
        hete_u / homo,
        hete_u / hete_g
    );
    anyhow::ensure!(hete_u > homo, "heterogeneity must cost time");
    anyhow::ensure!(
        hete_g < hete_u * 1.05,
        "scheduling must not be slower than uniform under heterogeneity"
    );
    let _ = dyn_g;
    println!("hetero_dynamic OK");
    Ok(())
}
