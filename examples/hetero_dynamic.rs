//! Heterogeneous + unstable devices (paper Appendix A, Figs. 9/11):
//! the same real-compute FedAvg run on (a) homogeneous, (b) simulated
//! heterogeneous-GPU, and (c) dynamically unstable clusters, with and
//! without Time-Window scheduling — showing the scheduler absorbing the
//! heterogeneity.
//!
//! # Dynamic scenarios
//!
//! Part 2 drives the discrete-event virtual-time engine through the
//! §4.4 scenarios the old per-scheme loops could not represent:
//!
//! - **client availability < 1** — a Bernoulli(0.8) participation
//!   model; unavailable clients are never scheduled;
//! - **mid-round device departure + later rejoin** — the departing
//!   device's in-flight and queued tasks are orphaned and re-placed on
//!   the survivors through the scheduler's greedy step
//!   (`DeviceLeave`/`DeviceJoin` events), and its history records are
//!   pruned so a replacement device re-learns its workload model;
//! - **injected stragglers and mid-task client drops** — 10% of tasks
//!   run 4x slower; 2% of clients vanish mid-task
//!   (`ClientUnavailable`), wasting the partial compute.
//!
//! The real-compute part needs AOT artifacts (`make artifacts`) and the
//! PJRT runtime; without them it is skipped and only the virtual part
//! runs.
//!
//!     cargo run --release --example hetero_dynamic -- --rounds 5

use parrot::cluster::{ClusterProfile, WorkloadCost};
use parrot::config::{RunConfig, Scheme, SchedulerKind};
use parrot::coordinator::run_simulation;
use parrot::data::{Partition, PartitionKind};
use parrot::simulation::{
    run_virtual, AvailabilityModel, ChurnEvent, ChurnKind, ChurnSpec, CommModel, DynamicsSpec,
    SlowdownLaw, StragglerSpec, VirtualSim,
};
use parrot::util::cli::Args;

fn run(
    tag: &str,
    cluster: ClusterProfile,
    sched: SchedulerKind,
    rounds: usize,
) -> anyhow::Result<f64> {
    let k = cluster.n_devices();
    let cfg = RunConfig {
        algorithm: "fedavg".into(),
        n_clients: 64,
        clients_per_round: 16,
        n_devices: k,
        rounds,
        mean_client_size: 50,
        eval_every: 0, // timing-focused
        warmup_rounds: 2,
        scheduler: sched,
        seed: 5,
        cluster,
        state_dir: std::env::temp_dir()
            .join("parrot_hetero_example")
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    };
    let summary = run_simulation(cfg)?;
    // Steady-state rounds only (post-warmup).
    let t = summary.metrics.mean_round_secs_after(2);
    println!("{tag:<28} mean steady round {t:>6.2}s");
    Ok(t)
}

fn real_compute_part(rounds: usize) -> anyhow::Result<()> {
    let k = 4;
    println!("part 1: real compute, K={k}, R={rounds} (sleep-injected heterogeneity)\n");
    let homo = run("homo / greedy", ClusterProfile::homogeneous(k), SchedulerKind::Greedy, rounds)?;
    let hete_u = run(
        "hete / uniform (no sched)",
        ClusterProfile::heterogeneous(k),
        SchedulerKind::Uniform,
        rounds,
    )?;
    let hete_g = run(
        "hete / greedy",
        ClusterProfile::heterogeneous(k),
        SchedulerKind::Greedy,
        rounds,
    )?;
    let dyn_g = run(
        "dynamic / time-window(3)",
        ClusterProfile::dynamic(k, 8.0),
        SchedulerKind::TimeWindow(3),
        rounds,
    )?;

    println!(
        "\nheterogeneity slows the unscheduled run by {:.2}x; scheduling claws back {:.2}x",
        hete_u / homo,
        hete_u / hete_g
    );
    anyhow::ensure!(hete_u > homo, "heterogeneity must cost time");
    anyhow::ensure!(
        hete_g < hete_u * 1.05,
        "scheduling must not be slower than uniform under heterogeneity"
    );
    let _ = dyn_g;
    Ok(())
}

fn dynamic_scenarios() -> anyhow::Result<()> {
    let (m, m_p, k, rounds, seed) = (500usize, 100usize, 8usize, 8usize, 5u64);
    println!("\npart 2: dynamic scenarios on the discrete-event engine");
    println!("        (M={m}, M_p={m_p}, K={k}: availability 0.8, leave@r2 + join@r5, stragglers)\n");
    let dynamics = DynamicsSpec {
        availability: AvailabilityModel::Bernoulli(0.8),
        churn: ChurnSpec {
            events: vec![
                ChurnEvent { round: 2, device: 1, secs: 1.0, kind: ChurnKind::Leave },
                ChurnEvent { round: 5, device: 1, secs: 0.0, kind: ChurnKind::Join },
            ],
            leave_prob: 0.0,
            join_prob: 0.0,
        },
        straggler: StragglerSpec { prob: 0.1, law: SlowdownLaw::Fixed(4.0), drop_prob: 0.02 },
    };
    let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
    let mut results = Vec::new();
    for (scheme, sched, tag) in [
        (Scheme::SdDist, SchedulerKind::Uniform, "SD Dist."),
        (Scheme::FaDist, SchedulerKind::Uniform, "FA Dist."),
        (Scheme::Parrot, SchedulerKind::TimeWindow(3), "Parrot"),
    ] {
        let mut sim = VirtualSim::new(
            scheme,
            ClusterProfile::heterogeneous(k),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            sched,
            2,
            partition.clone(),
            1,
            seed,
        )
        .with_dynamics(dynamics.clone());
        let rs = run_virtual(&mut sim, rounds, m_p, seed ^ 0xD1);
        let t = rs.iter().skip(2).map(|r| r.total_secs).sum::<f64>() / (rounds - 2) as f64;
        let util = rs.iter().map(|r| r.utilization()).sum::<f64>() / rs.len() as f64;
        let departures: usize = rs.iter().map(|r| r.departures).sum();
        let dropped: usize = rs.iter().map(|r| r.dropped_clients).sum();
        let unavailable: usize = rs.iter().map(|r| r.unavailable_clients).sum();
        println!(
            "{tag:<10} round {t:>7.2}s  util {:>5.1}%  unavailable {unavailable:>3}  \
             dropped {dropped:>3}  departures {departures}",
            100.0 * util
        );
        anyhow::ensure!(departures >= 1, "{tag}: the scripted departure must fire");
        anyhow::ensure!(util > 0.0 && util < 1.0, "{tag}: utilization must be non-degenerate");
        results.push((tag, t));
    }
    // Parrot's scheduler absorbs the injected dynamics best.
    let fa = results.iter().find(|(t, _)| *t == "FA Dist.").unwrap().1;
    let parrot = results.iter().find(|(t, _)| *t == "Parrot").unwrap().1;
    anyhow::ensure!(
        parrot < fa,
        "Parrot ({parrot:.2}s) must beat FA ({fa:.2}s) under dynamics"
    );
    Ok(())
}

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts").join("mlp_train.hlo.txt").exists()
}

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    let args = Args::from_env()?;
    let rounds = args.usize_or("rounds", 6)?;
    if artifacts_ready() {
        // With artifacts present, a failing assertion here is a real
        // regression and must fail the example.
        real_compute_part(rounds)?;
    } else {
        println!("part 1 (real compute) skipped: artifacts/ not built (run `make artifacts`)");
    }
    dynamic_scenarios()?;
    println!("\nhetero_dynamic OK");
    Ok(())
}
