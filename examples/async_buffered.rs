//! Asynchronous buffered execution (`--scheme async`) vs synchronous
//! Parrot, on the virtual-time engine.
//!
//! The demo runs the identical client stream three ways under straggler
//! injection on a heterogeneous cluster:
//!
//! 1. **sync Parrot** — every round ends at a barrier; one straggler
//!    idles the whole cluster until the hierarchical tail ships;
//! 2. **async degenerate** — `buffer = M_p`, `max_staleness = 0`: the
//!    admission gate closes after each cohort, so the work-conserving
//!    dispatcher reproduces the sync timeline *exactly* (asserted);
//! 3. **async buffered** — `buffer = M_p/4`, `max_staleness = 3`,
//!    `poly:0.5` staleness discounts: executors keep pulling cohorts
//!    inside the staleness window, the server flushes every K updates,
//!    and the straggler only delays its own flush.
//!
//! Prints the per-flush table (interval, updates, staleness histogram)
//! and the end-to-end makespans.  Entirely virtual — no AOT artifacts
//! needed.
//!
//!     cargo run --release --example async_buffered -- --rounds 8

use parrot::aggregation::StalenessWeight;
use parrot::cluster::{ClusterProfile, WorkloadCost};
use parrot::config::{Scheme, SchedulerKind};
use parrot::data::{Partition, PartitionKind};
use parrot::simulation::{
    run_virtual, AsyncSpec, CommModel, DynamicsSpec, SlowdownLaw, StragglerSpec, VirtualSim,
};
use parrot::util::cli::Args;

fn sim(scheme: Scheme, partition: &Partition, k: usize) -> VirtualSim {
    VirtualSim::new(
        scheme,
        ClusterProfile::heterogeneous(k),
        WorkloadCost::femnist(),
        CommModel::femnist(),
        SchedulerKind::Greedy,
        2,
        partition.clone(),
        1,
        11,
    )
    .with_dynamics(DynamicsSpec {
        straggler: StragglerSpec { prob: 0.2, law: SlowdownLaw::Fixed(6.0), drop_prob: 0.0 },
        ..Default::default()
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let rounds = args.usize_or("rounds", 8)?;
    let (m, m_p, k) = (400usize, 64usize, 8usize);
    let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, 3);
    println!(
        "async_buffered: M={m}, M_p={m_p}, K={k}, {rounds} cohorts, stragglers 0.2:x6\n"
    );

    let mut sync = sim(Scheme::Parrot, &partition, k);
    let rs_sync = run_virtual(&mut sync, rounds, m_p, 77);
    let sync_total: f64 = rs_sync.iter().map(|r| r.total_secs).sum();
    println!("sync Parrot: {sync_total:8.2}s total ({rounds} barrier rounds)");

    let mut deg = sim(Scheme::Async, &partition, k);
    deg.async_spec = AsyncSpec { buffer: 0, max_staleness: 0, weight: StalenessWeight::Const };
    let rs_deg = run_virtual(&mut deg, rounds, m_p, 77);
    let deg_total: f64 = rs_deg.iter().map(|r| r.total_secs).sum();
    println!("async degenerate (b=M_p, S=0): {deg_total:8.2}s total");
    assert!(
        (deg_total - sync_total).abs() < 1e-6 * sync_total,
        "degenerate async must equal the sync timeline"
    );

    let mut asy = sim(Scheme::Async, &partition, k);
    asy.async_spec =
        AsyncSpec { buffer: m_p / 4, max_staleness: 3, weight: StalenessWeight::Poly(0.5) };
    let rs = run_virtual(&mut asy, rounds, m_p, 77);
    let async_total: f64 = rs.iter().map(|r| r.total_secs).sum();
    println!("async buffered (b={}, S=3, poly:0.5): {async_total:8.2}s total\n", m_p / 4);

    println!(
        "{:>6} {:>10} {:>8} {:>6} {:>9}  staleness histogram",
        "flush", "interval", "applied", "stale", "chain(s)"
    );
    for r in &rs {
        println!(
            "{:>6} {:>9.2}s {:>8} {:>6} {:>8.3}s  {:?}",
            r.round, r.total_secs, r.flush_updates, r.stale_dropped, r.comm_secs,
            r.staleness_hist
        );
    }
    println!(
        "\nspeedup vs sync barrier: {:.2}x (work-conserving dispatch + staleness-weighted \
         buffered flushes)",
        sync_total / async_total.max(1e-9)
    );
    assert!(async_total < sync_total, "buffered async must beat the barrier here");
    Ok(())
}
