//! Simulation → deployment with zero code change (paper §3.2), with
//! update compression live on the real sockets.
//!
//! Runs the *identical* RunConfig twice:
//!   1. in-process simulation (`LocalEndpoint` transport), and
//!   2. a real TCP deployment — server thread + one OS process per
//!      worker (spawned via `parrot worker`), talking over sockets —
//! and asserts the two produce the same final parameters: the
//! coordinator code is transport-generic, so nothing changes between
//! simulation and deployment except the Transport implementation.
//! Both runs negotiate `--compress qint8`, so the device aggregates
//! crossing the real sockets are quantized wire frames; the codecs are
//! deterministic, so simulation and deployment still agree exactly.
//!
//! The server binds port 0 and hands workers the ephemeral port the OS
//! picked — no hardcoded ports.
//!
//!     cargo build --release && cargo run --release --example deploy_tcp

use parrot::compress::Codec;
use parrot::config::RunConfig;
use parrot::coordinator::{run_simulation, Server};
use parrot::transport::TcpListenerHandle;
use std::process::{Child, Command};

fn cfg(state_tag: &str) -> RunConfig {
    RunConfig {
        algorithm: "fedavg".into(),
        n_clients: 24,
        clients_per_round: 6,
        n_devices: 2,
        rounds: 3,
        mean_client_size: 30,
        eval_every: 0,
        seed: 99,
        cluster: parrot::cluster::ClusterProfile::homogeneous(2),
        compress: Codec::QInt8,
        state_dir: std::env::temp_dir()
            .join(format!("parrot_deploy_{state_tag}"))
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    }
}

fn spawn_worker(addr: &str, id: usize) -> anyhow::Result<Child> {
    // The launcher binary doubles as the worker process image.
    let exe = std::env::current_exe()?;
    let parrot = exe
        .parent()
        .and_then(|p| p.parent())
        .map(|p| p.join("parrot"))
        .filter(|p| p.exists())
        .ok_or_else(|| anyhow::anyhow!("build the launcher first: cargo build --release"))?;
    Ok(Command::new(parrot)
        .args([
            "worker",
            "--addr",
            addr,
            "--id",
            &id.to_string(),
            "--clients",
            "24",
            "--per-round",
            "6",
            "--devices",
            "2",
            "--rounds",
            "3",
            "--mean-size",
            "30",
            "--eval-every",
            "0",
            "--seed",
            "99",
            "--compress",
            "qint8",
            "--state-dir",
            &cfg("tcp").state_dir,
        ])
        .spawn()?)
}

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    println!("deploy_tcp: simulation vs real-socket deployment, same config, qint8 uploads\n");

    // 1) In-process simulation.
    println!("[1/2] local simulation (--compress qint8)...");
    let sim = run_simulation(cfg("local"))?;
    println!(
        "      done, mean round {:.2}s, {:.2} MB comm",
        sim.metrics.mean_round_secs(),
        sim.metrics.total_bytes() as f64 / (1 << 20) as f64
    );

    // 2) TCP deployment: bind port 0, read the ephemeral port, spawn 2
    //    worker processes against it, serve in this thread.
    let handle = TcpListenerHandle::listen("127.0.0.1:0")?;
    let addr = handle.local_addr()?.to_string();
    println!("[2/2] TCP deployment on {addr} (2 worker processes, qint8 over sockets)...");
    let mut w1 = spawn_worker(&addr, 1)?;
    let mut w2 = spawn_worker(&addr, 2)?;
    let transport = handle.accept(2)?;
    let dep = Server::new(transport, cfg("tcp"))?.run()?;
    w1.wait()?;
    w2.wait()?;
    println!(
        "      done, mean round {:.2}s, {:.2} MB comm, {} trips",
        dep.metrics.mean_round_secs(),
        dep.metrics.total_bytes() as f64 / (1 << 20) as f64,
        dep.metrics.total_trips()
    );

    let d = sim.final_params.max_abs_diff(&dep.final_params);
    println!("\nmax |param diff| simulation vs deployment: {d:e}");
    anyhow::ensure!(d < 1e-5, "deployment must match simulation bit-for-bit-ish");
    println!("deploy_tcp OK — zero-code-change migration verified, compressed on the wire");
    Ok(())
}
