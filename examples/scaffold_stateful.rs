//! Stateful FL at scale: SCAFFOLD over 1,000 clients on 4 devices,
//! with the distributed client-state store.
//!
//! The point of this example is the paper's §3.4 claim scaled out:
//! stateful algorithms at large M are only feasible with the client
//! state manager — 1,000 control variates never sit in memory at once;
//! they live on disk and stream through bounded write-back LRU caches.
//! With `--state-shards` each worker owns a consistent-hash shard of
//! the clients in its own directory: state never leans on a shared
//! filesystem, non-owned state rides the coordinator transport
//! (plan-driven prefetch ahead of each round, write-back returns after
//! it), and the example prints the per-shard residue to make the
//! ownership split visible.
//!
//!     cargo run --release --example scaffold_stateful -- --rounds 6
//!     cargo run --release --example scaffold_stateful -- --shards 0   # legacy local store

use parrot::config::RunConfig;
use parrot::coordinator::run_simulation;
use parrot::state::StateManager;
use parrot::statestore::ShardMap;
use parrot::util::cli::Args;

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    let args = Args::from_env()?;
    let state_dir = std::env::temp_dir().join("parrot_scaffold_example");
    let _ = std::fs::remove_dir_all(&state_dir);
    let n_devices = 4usize;
    let shards = args.usize_or("shards", n_devices)?.min(n_devices);
    let cfg = RunConfig {
        algorithm: "scaffold".into(),
        n_clients: args.usize_or("clients", 1000)?,
        clients_per_round: args.usize_or("per-round", 50)?,
        n_devices,
        rounds: args.usize_or("rounds", 6)?,
        mean_client_size: 40,
        eval_every: 2,
        eval_batches: 8,
        seed: 11,
        cluster: parrot::cluster::ClusterProfile::homogeneous(n_devices),
        state_dir: state_dir.to_string_lossy().into_owned(),
        state_shards: shards,
        state_writeback: shards > 0,
        ..Default::default()
    };
    let seed = cfg.seed;
    println!(
        "scaffold_stateful: M={} (stateful!) M_p={} K={} R={} state-shards={}",
        cfg.n_clients, cfg.clients_per_round, cfg.n_devices, cfg.rounds, cfg.state_shards
    );

    let summary = run_simulation(cfg)?;
    for r in &summary.metrics.rounds {
        print!("round {:>2}  wall {:>6.2}s  loss {:>7.4}", r.round, r.wall_secs, r.train_loss);
        if r.state_bytes > 0 {
            print!("  state {:>6.1} KB", r.state_bytes as f64 / 1024.0);
        }
        if let Some(a) = r.eval_acc {
            print!("  acc {:.1}%", 100.0 * a);
        }
        println!();
    }

    // Inspect the state the run left behind, shard by shard.
    let run_dir = state_dir.join(format!("run_{seed}"));
    let shard_dirs: Vec<std::path::PathBuf> = if shards > 0 {
        (0..n_devices).map(|w| run_dir.join(format!("shard_{w}"))).collect()
    } else {
        vec![run_dir.clone()]
    };
    let mut total_count = 0u64;
    let mut total_disk = 0u64;
    let mut populated_shards = 0usize;
    for (i, d) in shard_dirs.iter().enumerate() {
        if !d.exists() {
            continue;
        }
        let sm = StateManager::new(d, 0)?;
        let mut count = 0u64;
        for e in std::fs::read_dir(d)? {
            if e?.file_name().to_string_lossy().ends_with(".state") {
                count += 1;
            }
        }
        println!(
            "shard {i}: {count} client states, {:.1} MB on disk",
            sm.disk_bytes() as f64 / (1 << 20) as f64
        );
        total_count += count;
        total_disk += sm.disk_bytes();
        if count > 0 {
            populated_shards += 1;
        }
    }
    println!(
        "\nstate store: {total_count} client control variates on disk, {:.1} MB total \
         (memory held only the in-flight ones)",
        total_disk as f64 / (1 << 20) as f64
    );

    // Round-trip integrity: reload a few states from their owner shard.
    let map = ShardMap::new(shards.max(1));
    let mut loaded = 0;
    for c in 0..(summary.metrics.rounds.len() * 50) as u64 {
        let dir = if shards > 0 {
            run_dir.join(format!("shard_{}", map.owner(c) as usize % n_devices))
        } else {
            run_dir.clone()
        };
        if !dir.exists() {
            continue;
        }
        let mut sm = StateManager::new(dir, 0)?;
        if sm.load_params(c)?.is_some() {
            loaded += 1;
            if loaded >= 3 {
                break;
            }
        }
    }
    anyhow::ensure!(loaded >= 1, "expected reloadable client state");
    anyhow::ensure!(total_count > 0, "expected persisted state files");
    if shards > 0 {
        // Shard dirs exist unconditionally (workers create them), so
        // count the shards that actually hold state files.
        anyhow::ensure!(
            populated_shards >= shards.min(2),
            "sharding must spread state across workers \
             (got {populated_shards} shards with state files)"
        );
        let state_traffic = summary.metrics.total_state_bytes();
        anyhow::ensure!(state_traffic > 0, "off-owner placements must move state");
        println!(
            "sharded traffic through the coordinator: {:.1} MB",
            state_traffic as f64 / (1 << 20) as f64
        );
    }
    println!("scaffold_stateful OK");
    Ok(())
}
