//! Stateful FL at scale: SCAFFOLD over 1,000 clients on 4 devices.
//!
//! The point of this example is the paper's §3.4 claim: stateful
//! algorithms at large M are only feasible with the client state
//! manager — 1,000 control variates never sit in memory at once; they
//! live on disk and stream through the bounded LRU cache.  The example
//! prints the state-manager traffic to make that visible.
//!
//!     cargo run --release --example scaffold_stateful -- --rounds 6

use parrot::config::RunConfig;
use parrot::coordinator::run_simulation;
use parrot::state::StateManager;
use parrot::util::cli::Args;

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    let args = Args::from_env()?;
    let state_dir = std::env::temp_dir().join("parrot_scaffold_example");
    let _ = std::fs::remove_dir_all(&state_dir);
    let cfg = RunConfig {
        algorithm: "scaffold".into(),
        n_clients: args.usize_or("clients", 1000)?,
        clients_per_round: args.usize_or("per-round", 50)?,
        n_devices: 4,
        rounds: args.usize_or("rounds", 6)?,
        mean_client_size: 40,
        eval_every: 2,
        eval_batches: 8,
        seed: 11,
        cluster: parrot::cluster::ClusterProfile::homogeneous(4),
        state_dir: state_dir.to_string_lossy().into_owned(),
        ..Default::default()
    };
    let seed = cfg.seed;
    println!(
        "scaffold_stateful: M={} (stateful!) M_p={} K={} R={}",
        cfg.n_clients, cfg.clients_per_round, cfg.n_devices, cfg.rounds
    );

    let summary = run_simulation(cfg)?;
    for r in &summary.metrics.rounds {
        print!("round {:>2}  wall {:>6.2}s  loss {:>7.4}", r.round, r.wall_secs, r.train_loss);
        if let Some(a) = r.eval_acc {
            print!("  acc {:.1}%", 100.0 * a);
        }
        println!();
    }

    // Inspect the state the run left behind.
    let mut sm = StateManager::new(state_dir.join(format!("run_{seed}")), 0)?;
    let disk = sm.disk_bytes()?;
    let mut count = 0u64;
    for e in std::fs::read_dir(state_dir.join(format!("run_{seed}")))? {
        if e?.file_name().to_string_lossy().ends_with(".state") {
            count += 1;
        }
    }
    println!(
        "\nstate manager: {count} client control variates on disk, {:.1} MB total \
         (memory held only the in-flight ones)",
        disk as f64 / (1 << 20) as f64
    );
    // A few loads to show round-trip integrity.
    let mut loaded = 0;
    for c in 0..summary.metrics.rounds.len() * 50 {
        if sm.load_params(c as u64)?.is_some() {
            loaded += 1;
            if loaded >= 3 {
                break;
            }
        }
    }
    anyhow::ensure!(loaded >= 1, "expected reloadable client state");
    anyhow::ensure!(count > 0, "expected persisted state files");
    println!("scaffold_stateful OK");
    Ok(())
}
