//! Quickstart: 10 rounds of FedAvg on a 60-client synthetic-FEMNIST
//! federation simulated on 2 devices — the 30-second "does everything
//! work" tour of the public API.
//!
//!     make artifacts && cargo run --release --example quickstart

use parrot::config::RunConfig;
use parrot::coordinator::run_simulation;

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    let cfg = RunConfig {
        algorithm: "fedavg".into(),
        model: "mlp".into(),
        n_clients: 60,
        clients_per_round: 12,
        n_devices: 2,
        rounds: 10,
        eval_every: 2,
        eval_batches: 8,
        seed: 7,
        cluster: parrot::cluster::ClusterProfile::homogeneous(2),
        ..Default::default()
    };
    println!(
        "quickstart: fedavg, M={} M_p={} K={} R={}",
        cfg.n_clients, cfg.clients_per_round, cfg.n_devices, cfg.rounds
    );

    let summary = run_simulation(cfg)?;

    println!("\nround  wall(s)  util%   train-loss   eval");
    for r in &summary.metrics.rounds {
        print!(
            "{:>5}  {:>7.2}  {:>5.1}  {:>10.4}",
            r.round,
            r.wall_secs,
            100.0 * r.utilization,
            r.train_loss
        );
        if let (Some(l), Some(a)) = (r.eval_loss, r.eval_acc) {
            print!("   loss {l:.4} acc {:.1}%", 100.0 * a);
        }
        println!();
    }
    let acc = summary.final_acc.unwrap_or(0.0);
    println!("\nfinal accuracy: {:.1}% (chance = {:.1}%)", 100.0 * acc, 100.0 / 62.0);
    anyhow::ensure!(acc > 0.10, "quickstart should comfortably beat chance");
    println!("quickstart OK");
    Ok(())
}
