//! Scheme benches: virtual-engine cost (simulated rounds/sec — this is
//! what lets the Fig-5/7/9/10/11 harnesses sweep paper-scale configs)
//! plus a reduced Table-1-shaped check that the engine's measured
//! bytes/trips match the analytic model.
//! Run: cargo bench --bench bench_schemes

use parrot::cluster::{ClusterProfile, WorkloadCost};
use parrot::config::{Scheme, SchedulerKind};
use parrot::coordinator::metrics::MemoryModel;
use parrot::data::{Partition, PartitionKind};
use parrot::simulation::{run_virtual, CommModel, VirtualSim};
use parrot::util::bench::{header, Bencher};

fn mk(scheme: Scheme, k: usize, m: usize, sched: SchedulerKind) -> VirtualSim {
    VirtualSim::new(
        scheme,
        ClusterProfile::homogeneous(k),
        WorkloadCost::femnist(),
        CommModel::femnist(),
        sched,
        2,
        Partition::generate(PartitionKind::Natural, m, 62, 100, 7),
        1,
        5,
    )
}

fn main() {
    header("schemes");
    let mut b = Bencher::new("schemes");

    for (scheme, name) in [
        (Scheme::SP, "sp"),
        (Scheme::SdDist, "sd"),
        (Scheme::FaDist, "fa"),
        (Scheme::Parrot, "parrot"),
    ] {
        let sched = if scheme == Scheme::Parrot {
            SchedulerKind::Greedy
        } else {
            SchedulerKind::Uniform
        };
        b.bench(&format!("virtual round {name} Mp=100 K=8"), || {
            let mut sim = mk(scheme, 8, 1000, sched);
            run_virtual(&mut sim, 5, 100, 3)
        });
    }

    b.bench("virtual round parrot Mp=1000 K=32 (paper scale)", || {
        let mut sim = mk(Scheme::Parrot, 32, 10_000, SchedulerKind::Greedy);
        run_virtual(&mut sim, 3, 1000, 3)
    });

    // Cross-check: engine-measured bytes == Table-1 analytic model.
    let comm = CommModel::femnist();
    let mut sim = mk(Scheme::Parrot, 8, 1000, SchedulerKind::Greedy);
    let r = &run_virtual(&mut sim, 1, 100, 3)[0];
    let model = 2 * MemoryModel::comm_size(Scheme::Parrot, comm.s_a, comm.s_e, 100, 8);
    println!(
        "\nparrot round bytes: engine {} vs 2x analytic {} ({})",
        r.bytes,
        model,
        if r.bytes == model { "MATCH" } else { "MISMATCH" }
    );
    assert_eq!(r.bytes, model);
    let mut fa = mk(Scheme::FaDist, 8, 1000, SchedulerKind::Uniform);
    let rf = &run_virtual(&mut fa, 1, 100, 3)[0];
    assert_eq!(rf.trips, 200);
    println!("fa trips 2*Mp = {} (MATCH)", rf.trips);
}
