//! Megascale admission benches: per-task heap allocation (one boxed
//! task object per queued client — the pre-SoA layout's allocation
//! profile) vs struct-of-arrays [`TaskTable`] admission at 100k queued
//! tasks, plus the column-scan read path the engine hot loops use.
//! Run: cargo bench --bench bench_megascale

use parrot::simulation::{SimTask, TaskTable};
use parrot::util::bench::{header, Bencher};

const N: usize = 100_000;

fn task(i: usize) -> SimTask {
    SimTask::new(i, 50 + (i * 13) % 300, 1.0 + (i % 7) as f64 * 0.01)
}

/// The old layout's allocation profile: one heap object per queued
/// task, plus per-device queue Vecs holding the indices.
fn admit_boxed(k: usize) -> usize {
    let mut tasks: Vec<Box<SimTask>> = Vec::with_capacity(N);
    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); k];
    for i in 0..N {
        tasks.push(Box::new(task(i)));
        assigned[i % k].push(i);
    }
    let mut acc = 0usize;
    for q in &assigned {
        for &t in q {
            acc = acc.wrapping_add(tasks[t].n_eff);
        }
    }
    acc
}

/// The SoA layout: six flat columns, one push per task, dense ids.
fn admit_soa(k: usize) -> usize {
    let mut tasks = TaskTable::with_capacity(N);
    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); k];
    for i in 0..N {
        let id = tasks.push(task(i));
        assigned[i % k].push(id);
    }
    let mut acc = 0usize;
    for q in &assigned {
        for &t in q {
            acc = acc.wrapping_add(tasks.n_eff[t]);
        }
    }
    acc
}

/// The engine's hot read path: a straight column scan (duration
/// model: n_eff × noise per task) over an already-admitted table.
fn scan_soa(tasks: &TaskTable) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..tasks.len() {
        acc += tasks.n_eff[i] as f64 * tasks.noise[i];
    }
    acc
}

fn scan_boxed(tasks: &[Box<SimTask>]) -> f64 {
    let mut acc = 0.0f64;
    for t in tasks {
        acc += t.n_eff as f64 * t.noise;
    }
    acc
}

fn main() {
    header("megascale admission (100k queued tasks)");
    let mut b = Bencher::new("megascale").with_iters(2, 10);

    b.bench_throughput("admit 100k boxed tasks, K=64 (tasks)", N, || admit_boxed(64));
    b.bench_throughput("admit 100k SoA tasks,   K=64 (tasks)", N, || admit_soa(64));

    let boxed: Vec<Box<SimTask>> = (0..N).map(|i| Box::new(task(i))).collect();
    let soa: TaskTable = (0..N).map(task).collect();
    b.bench_throughput("scan 100k boxed tasks (tasks)", N, || scan_boxed(&boxed));
    b.bench_throughput("scan 100k SoA columns (tasks)", N, || scan_soa(&soa));
}
