//! State-manager benches (§3.4): save/load latency with cache hits,
//! cache misses (disk), and the LRU eviction path — the costs the
//! Table-1 memory/disk trade is buying.
//! Run: cargo bench --bench bench_state

use parrot::model::ParamSet;
use parrot::state::StateManager;
use parrot::util::bench::{header, Bencher};

fn main() {
    header("state");
    let mut b = Bencher::new("state");
    let dir = std::env::temp_dir().join(format!("parrot_bench_state_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // SCAFFOLD-like control variate: mlp-shaped, ~1MB.
    let shapes = vec![
        vec![784usize, 256],
        vec![256],
        vec![256, 128],
        vec![128],
        vec![128, 62],
        vec![62],
    ];
    let state = ParamSet::init_he(&shapes, 1);
    let bytes = state.size_bytes();
    println!("client state size: {:.2} MB", bytes as f64 / (1 << 20) as f64);

    let mut sm = StateManager::new(&dir, 256 << 20).unwrap();
    let mut i = 0u64;
    b.bench_throughput("save (bytes)", bytes, || {
        i += 1;
        sm.save_params(i % 64, &state).unwrap();
    });

    // Warm-cache loads.
    sm.save_params(7, &state).unwrap();
    b.bench_throughput("load cache-hit (bytes)", bytes, || {
        sm.load_params(7).unwrap().unwrap()
    });

    // Cold loads: zero cache budget forces disk each time.
    let mut cold = StateManager::new(&dir, 0).unwrap();
    cold.save_params(9, &state).unwrap();
    b.bench_throughput("load disk (bytes)", bytes, || {
        cold.load_params(9).unwrap().unwrap()
    });

    // Eviction churn: budget for 4 states, rotate through 16.
    let mut churn = StateManager::new(&dir, 4 * bytes + 1024).unwrap();
    let mut j = 0u64;
    b.bench("save+evict rotate 16 clients", || {
        j += 1;
        churn.save_params(j % 16, &state).unwrap();
    });

    println!(
        "\ncache hits {} / loads {}, disk reads {}, peak cache {:.1} MB",
        sm.metrics.cache_hits,
        sm.metrics.loads,
        sm.metrics.disk_reads,
        sm.metrics.peak_cache_bytes as f64 / (1 << 20) as f64
    );
    let _ = std::fs::remove_dir_all(&dir);
}
