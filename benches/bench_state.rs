//! State-manager benches (§3.4): save/load latency with cache hits,
//! cache misses (disk), and the LRU eviction path — the costs the
//! Table-1 memory/disk trade is buying.
//! Run: cargo bench --bench bench_state

use parrot::model::ParamSet;
use parrot::state::StateManager;
use parrot::util::bench::{header, Bencher};

fn main() {
    header("state");
    let mut b = Bencher::new("state");
    let dir = std::env::temp_dir().join(format!("parrot_bench_state_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // SCAFFOLD-like control variate: mlp-shaped, ~1MB.
    let shapes = vec![
        vec![784usize, 256],
        vec![256],
        vec![256, 128],
        vec![128],
        vec![128, 62],
        vec![62],
    ];
    let state = ParamSet::init_he(&shapes, 1);
    let bytes = state.size_bytes();
    println!("client state size: {:.2} MB", bytes as f64 / (1 << 20) as f64);

    let mut sm = StateManager::new(&dir, 256 << 20).unwrap();
    let mut i = 0u64;
    b.bench_throughput("save (bytes)", bytes, || {
        i += 1;
        sm.save_params(i % 64, &state).unwrap();
    });

    // Warm-cache loads.
    sm.save_params(7, &state).unwrap();
    b.bench_throughput("load cache-hit (bytes)", bytes, || {
        sm.load_params(7).unwrap().unwrap()
    });

    // Cold loads: zero cache budget forces disk each time.
    let mut cold = StateManager::new(&dir, 0).unwrap();
    cold.save_params(9, &state).unwrap();
    b.bench_throughput("load disk (bytes)", bytes, || {
        cold.load_params(9).unwrap().unwrap()
    });

    // Eviction churn: budget for 4 states, rotate through 16.
    let mut churn = StateManager::new(&dir, 4 * bytes + 1024).unwrap();
    let mut j = 0u64;
    b.bench("save+evict rotate 16 clients", || {
        j += 1;
        churn.save_params(j % 16, &state).unwrap();
    });

    // Eviction storm at 10k resident clients: the old per-eviction
    // `min_by_key` scan over the whole cache made every insert O(n) —
    // O(n²) across a rotation.  The ordered LRU index makes the victim
    // pop O(log n): each benched save pays one constant-size dirty
    // spill (4 KB file) plus the index ops, not a 10k-entry scan.
    let small = ParamSet::init_he(&[vec![64usize, 16], vec![16]], 2); // ~4 KB
    let sb = small.size_bytes();
    let storm_dir = dir.join("storm");
    let mut storm = StateManager::new(&storm_dir, 10_000 * (sb + 64))
        .unwrap()
        .with_write_back(true);
    for c in 0..10_000u64 {
        storm.save_params(c, &small).unwrap(); // fill: 10k residents
    }
    let mut r = 0u64;
    b.bench("save+evict @10k resident clients", || {
        r += 1;
        // Fresh ids: every save displaces exactly one LRU victim.
        storm.save_params(10_000 + r, &small).unwrap();
    });
    println!(
        "storm: 10000 residents held, {} dirty spills, {:.1} MB spilled",
        storm.metrics.disk_writes,
        storm.metrics.bytes_written as f64 / (1 << 20) as f64
    );

    println!(
        "\ncache hits {} / loads {}, disk reads {}, peak cache {:.1} MB",
        sm.metrics.cache_hits,
        sm.metrics.loads,
        sm.metrics.disk_reads,
        sm.metrics.peak_cache_bytes as f64 / (1 << 20) as f64
    );
    let _ = std::fs::remove_dir_all(&dir);
}
