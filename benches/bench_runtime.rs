//! PJRT hot-path benches: the per-batch train step the whole simulation
//! multiplies, the TaskRun literal-reuse path vs the naive path, and
//! marshalling costs.  Skips cleanly without artifacts.
//! Run: make artifacts && cargo bench --bench bench_runtime

use parrot::data::{FederatedDataset, Partition, PartitionKind, SynthConfig};
use parrot::model::ParamSet;
use parrot::runtime::{lit_f32, Runtime};
use parrot::util::bench::{header, Bencher};
use std::path::Path;

fn main() {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("mlp_train.hlo.txt").exists() {
        println!("bench_runtime: artifacts not built, skipping (run `make artifacts`)");
        return;
    }
    header("runtime");
    let mut b = Bencher::new("runtime").with_iters(5, 30);

    let rt = Runtime::cpu(&dir).unwrap();
    let train = rt.load("mlp_train").unwrap();
    let eval = rt.load("mlp_eval").unwrap();
    let shapes = train.manifest.param_shapes();
    let params = ParamSet::init_he(&shapes, 1);
    let zeros = ParamSet::zeros(&shapes);
    let ds = FederatedDataset::new(
        SynthConfig::vision(3),
        Partition::generate(PartitionKind::Natural, 8, 62, 100, 3),
    );
    let batch = ds.batch(0, 0);
    let samples = parrot::model::BATCH;

    // Naive path: full ParamSet->literal marshalling every step.
    b.bench_throughput("train_once naive (samples)", samples, || {
        train
            .train_once(&params, &zeros, &zeros, &batch, 0.05, 0.0)
            .unwrap()
    });

    // Hot path: literals live across steps (one task, many batches).
    b.bench_throughput("task_run 8-step chain (samples)", samples * 8, || {
        let mut run = train.start_task(&params, &zeros, &zeros, 0.05, 0.0).unwrap();
        for j in 0..8 {
            run.step(&ds.batch(0, j % ds.n_batches(0))).unwrap();
        }
        run.finish().unwrap()
    });

    b.bench_throughput("eval step (samples)", samples, || {
        eval.eval(&params, &batch).unwrap()
    });

    // Marshalling microbenches.
    let flat: Vec<f32> = vec![1.0; 784 * 256];
    b.bench_throughput("lit_f32 784x256 (elems)", flat.len(), || {
        lit_f32(&flat, &[784, 256]).unwrap()
    });
    b.bench("params->literals mlp", || {
        params
            .shapes
            .iter()
            .zip(&params.tensors)
            .map(|(s, t)| lit_f32(t, s).unwrap())
            .collect::<Vec<_>>()
    });

    // Batch generation (must stay off the critical path).
    b.bench_throughput("synth batch gen (samples)", samples, || ds.batch(1, 0));
}
