//! Aggregation benches: the hierarchical local/global path on
//! realistically-sized parameter sets — the §4.2 server-cost claim
//! (server sums K aggregates instead of M_p updates).
//! Run: cargo bench --bench bench_aggregation

use parrot::aggregation::{AggOp, ClientUpdate, GlobalAgg, LocalAgg, Payload};
use parrot::model::ParamSet;
use parrot::util::bench::{header, Bencher};
use parrot::util::rng::Rng;

fn mk_params(shapes: &[Vec<usize>], seed: u64) -> ParamSet {
    let mut rng = Rng::new(seed);
    ParamSet {
        shapes: shapes.to_vec(),
        tensors: shapes
            .iter()
            .map(|s| {
                (0..s.iter().product::<usize>())
                    .map(|_| rng.normal_f32(0.0, 1.0))
                    .collect()
            })
            .collect(),
    }
}

fn mk_update(client: usize, shapes: &[Vec<usize>]) -> ClientUpdate {
    ClientUpdate {
        client,
        weight: 1.0 + client as f64,
        entries: vec![(
            "delta".into(),
            AggOp::WeightedAvg,
            Payload::Params(mk_params(shapes, client as u64)),
        )],
    }
}

fn main() {
    header("aggregation");
    let mut b = Bencher::new("aggregation");

    // mlp-sized tensors (≈240k params ≈ 1MB).
    let shapes = vec![
        vec![784usize, 256],
        vec![256],
        vec![256, 128],
        vec![128],
        vec![128, 62],
        vec![62],
    ];
    let numel: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();

    let updates: Vec<ClientUpdate> = (0..64).map(|c| mk_update(c, &shapes)).collect();

    b.bench_throughput("local_agg.add 64 clients (elems)", numel * 64, || {
        let mut la = LocalAgg::new(0);
        for u in &updates {
            la.add(u);
        }
        la.finish()
    });

    let mut la = LocalAgg::new(0);
    for u in &updates {
        la.add(u);
    }
    let dev = la.finish();
    let wire = dev.encoded().unwrap();
    println!("device aggregate wire size: {:.2} MB", wire.len() as f64 / (1 << 20) as f64);

    b.bench_throughput("device_agg.encode (bytes)", wire.len(), || dev.encoded().unwrap());
    b.bench_throughput("device_agg.decode (bytes)", wire.len(), || {
        parrot::aggregation::DeviceAggregate::decode(&wire).unwrap()
    });

    // Global merge of K=8 device aggregates vs flat 64-client fold —
    // the server-side work reduction of hierarchical aggregation.
    let per_dev: Vec<parrot::aggregation::DeviceAggregate> = (0..8)
        .map(|d| {
            let mut la = LocalAgg::new(d);
            for (i, u) in updates.iter().enumerate() {
                if i % 8 == d {
                    la.add(u);
                }
            }
            la.finish()
        })
        .collect();
    b.bench("global merge K=8 aggregates", || {
        let mut g = GlobalAgg::new();
        for d in &per_dev {
            g.merge(d.clone());
        }
        g.finish()
    });
    b.bench("flat fold Mp=64 updates (server-side)", || {
        let mut la = LocalAgg::new(0);
        for u in &updates {
            la.add(u);
        }
        let mut g = GlobalAgg::new();
        g.merge(la.finish());
        g.finish()
    });

    // ParamSet primitives.
    let a = mk_params(&shapes, 1);
    let c = mk_params(&shapes, 2);
    let mut acc = ParamSet::zeros(&shapes);
    b.bench_throughput("param add_scaled (elems)", numel, || {
        acc.add_scaled(&a, 0.5);
    });
    b.bench_throughput("param delta (elems)", numel, || a.delta(&c));
    b.bench_throughput("param to_bytes (elems)", numel, || a.to_bytes().unwrap());
}
