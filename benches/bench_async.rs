//! Async-dispatcher benches: the event-loop overhead of the
//! work-conserving dispatcher itself (claim, heap churn, flush
//! bookkeeping) at 10k queued tasks — the engine must stay simulation-
//! bound, not dispatcher-bound, at statescale client counts.
//! Run: cargo bench --bench bench_async

use parrot::aggregation::StalenessWeight;
use parrot::cluster::{ClusterProfile, WorkloadCost};
use parrot::config::SchedulerKind;
use parrot::scheduler::Scheduler;
use parrot::simulation::engine::{run_async, AsyncCohort, AsyncComm, AsyncSpec};
use parrot::simulation::{DynamicsSpec, SimTask, TaskTable};
use parrot::statestore::StatePlan;
use parrot::util::bench::{header, Bencher};

/// Drive `n_tasks` through the dispatcher in cohorts of `cohort_size`
/// on `k` executors; returns the completed-task count (black-boxed by
/// the bencher).
fn drive(n_tasks: usize, cohort_size: usize, k: usize, buffer: usize, stal: usize) -> usize {
    let cluster = ClusterProfile::heterogeneous(k);
    let cost = WorkloadCost::femnist();
    let dynamics = DynamicsSpec::default();
    let mut sched = Scheduler::new(SchedulerKind::Greedy, 1, k);
    let n_cohorts = n_tasks / cohort_size;
    let mut source = move |s: &mut Scheduler,
                           c: usize,
                           alive: &[bool],
                           base: &[f64]|
          -> Option<AsyncCohort> {
        if c >= n_cohorts {
            return None;
        }
        let clients: Vec<(usize, usize)> =
            (0..cohort_size).map(|i| (i, 50 + (i * 13) % 300)).collect();
        let schedule = s.schedule_from(c, &clients, alive, base);
        let mut tasks = TaskTable::with_capacity(cohort_size);
        let mut assigned = vec![Vec::new(); alive.len()];
        for (dev, cls) in schedule.assignment.iter().enumerate() {
            for &cl in cls {
                let id = tasks.push(SimTask::new(cl, 50 + (cl * 13) % 300, 1.0));
                assigned[dev].push(id);
            }
        }
        Some(AsyncCohort {
            tasks,
            assigned,
            state: StatePlan::default(),
            sched_secs: 0.0,
            unavailable: 0,
        })
    };
    let out = run_async(
        k,
        &cluster,
        &cost,
        &dynamics,
        7,
        AsyncSpec { buffer, max_staleness: stal, weight: StalenessWeight::Poly(0.5) },
        AsyncComm { s_a_down: 44_000_000, s_a_up: 44_000_000, s_e: 0, tier: None },
        &mut sched,
        &mut source,
        None,
    );
    out.completed
}

fn main() {
    header("async dispatcher");
    let mut b = Bencher::new("async").with_iters(2, 10);

    // The headline number: 10k tasks through 32 executors.
    b.bench_throughput("dispatch 10k tasks, K=32, b=100 S=2 (tasks)", 10_000, || {
        drive(10_000, 200, 32, 100, 2)
    });
    // Degenerate (barrier) mode: same stream, flush per cohort.
    b.bench_throughput("dispatch 10k tasks, K=32, degenerate (tasks)", 10_000, || {
        drive(10_000, 200, 32, 200, 0)
    });
    // Flush-heavy: tiny buffer maximizes ledger/chain churn.
    b.bench_throughput("dispatch 10k tasks, K=32, b=10 S=4 (tasks)", 10_000, || {
        drive(10_000, 200, 32, 10, 4)
    });
    // Small-cluster sanity point.
    b.bench_throughput("dispatch 10k tasks, K=4, b=50 S=2 (tasks)", 10_000, || {
        drive(10_000, 100, 4, 50, 2)
    });
}
