//! Scheduler benches: Alg. 3 assignment cost (the Fig. 8 claim that
//! scheduling is negligible, O(K·M_p)) and workload-estimation cost.
//! Run: cargo bench --bench bench_scheduler [-- --quick]

use parrot::scheduler::{greedy_assign, uniform_assign, DeviceEstimate, History, TaskRecord};
use parrot::util::bench::{header, Bencher};
use parrot::util::rng::Rng;

fn estimates(k: usize) -> Vec<DeviceEstimate> {
    (0..k)
        .map(|i| DeviceEstimate {
            t_sample: 0.002 * (1.0 + i as f64 * 0.1),
            b: 0.15,
            r2: 0.99,
            n_points: 50,
        })
        .collect()
}

fn clients(m: usize, seed: u64) -> Vec<(usize, usize)> {
    let mut rng = Rng::new(seed);
    (0..m).map(|i| (i, 20 + rng.below(400) as usize)).collect()
}

fn main() {
    header("scheduler");
    let mut b = Bencher::new("scheduler");

    for (k, m) in [(8usize, 100usize), (8, 1000), (32, 100), (32, 1000), (32, 10_000)] {
        let est = estimates(k);
        let cs = clients(m, 3);
        b.bench_throughput(&format!("greedy_assign K={k} Mp={m}"), m, || {
            greedy_assign(&cs, &est)
        });
    }

    let cs = clients(1000, 5);
    b.bench("uniform_assign K=32 Mp=1000", || uniform_assign(&cs, 32));

    // Estimation cost: OLS over r rounds of history (Fig. 8's other half).
    for rounds in [10usize, 100, 500] {
        let mut h = History::new();
        let mut rng = Rng::new(9);
        for r in 0..rounds {
            for d in 0..8 {
                for _ in 0..12 {
                    let n = 20 + rng.below(400) as usize;
                    h.push(TaskRecord {
                        round: r,
                        device: d,
                        n_samples: n,
                        secs: 0.002 * n as f64 + 0.15,
                    });
                }
            }
        }
        b.bench(&format!("estimate K=8 history={rounds}r"), || h.estimate(8, rounds, None));
        b.bench(&format!("estimate K=8 history={rounds}r window=5"), || {
            h.estimate(8, rounds, Some(5))
        });
    }

    // Sanity: scheduled makespan beats uniform on heterogeneous devices.
    let est = estimates(8);
    let cs = clients(100, 7);
    let sizes = parrot::scheduler::greedy::size_table(&cs);
    let (ga, _) = greedy_assign(&cs, &est);
    let ua = uniform_assign(&cs, 8);
    let gm = parrot::scheduler::greedy::makespan(&ga, &sizes, &est);
    let um = parrot::scheduler::greedy::makespan(&ua, &sizes, &est);
    println!("\nmakespan: greedy {gm:.2}s vs uniform {um:.2}s ({:.2}x)", um / gm);
    assert!(gm <= um);
}
