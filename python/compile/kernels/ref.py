"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Every kernel in this package has an oracle here with the identical
signature; ``python/tests/test_kernel.py`` sweeps shapes/dtypes with
hypothesis and asserts allclose between kernel and oracle, including
through ``jax.grad`` for the differentiable ones.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for kernels.matmul.matmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "relu") -> jax.Array:
    """Oracle for kernels.matmul.linear (fused bias + activation)."""
    z = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "none":
        return z
    raise ValueError(f"unknown activation {act!r}")


def fused_update_ref(
    w: jax.Array,
    g: jax.Array,
    anchor: jax.Array,
    corr: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
) -> jax.Array:
    """Oracle for kernels.update.fused_update."""
    return w - lr * (g + mu * (w - anchor) + corr)
