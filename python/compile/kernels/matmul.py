"""Layer-1 Pallas kernels: tiled matmul with fused bias + activation.

This is the compute hot-spot of the per-client train step (every dense
layer in the MLP / transformer forward AND backward pass goes through
here).  The kernel is written the TPU way:

- the grid tiles the output into ``(bm, bn)`` VMEM-resident blocks,
- the contraction dimension K is kept whole per block (for the layer
  sizes used by the Parrot models, an entire K-strip fits VMEM
  comfortably; see DESIGN.md §Perf for the footprint table),
- block sizes prefer MXU-shaped 128x128 tiles and fall back to the
  largest divisor of the dimension so no masking is needed,
- bias-add and the activation are fused into the same kernel so the
  pre-activation never round-trips through HBM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO through the Pallas
interpreter.  Real-TPU efficiency is estimated statically (DESIGN.md
§Perf), never from interpret-mode wallclock.

The public entry point :func:`linear` carries a custom VJP whose backward
pass reuses the same Pallas matmul for dx/dw, so the AOT-lowered HLO of
``jax.grad`` also runs through Layer 1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Upper bound on a block edge.  128 matches the MXU systolic array edge;
# see DESIGN.md §Hardware-Adaptation.
_MXU_EDGE = 128


def pick_block(dim: int, target: int = _MXU_EDGE) -> int:
    """Largest divisor of ``dim`` that is <= ``target``.

    Pallas blocks must tile the array exactly (we do not mask), so block
    edges are divisors.  Preferring the largest divisor keeps blocks as
    close to MXU-shaped as the layer geometry allows.
    """
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return 1


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output block: whole-K contraction in VMEM."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Tiled Pallas matmul ``x @ w`` for 2-D operands.

    Grid = (M/bm, N/bn); each program reads an (bm, K) strip of ``x`` and
    a (K, bn) strip of ``w`` and writes one (bm, bn) output block.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    bm, bn = pick_block(m), pick_block(n)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _linear_kernel_relu(x_ref, w_ref, b_ref, o_ref):
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(z + b_ref[...], 0.0)


def _linear_kernel_none(x_ref, w_ref, b_ref, o_ref):
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = z + b_ref[...]


_LINEAR_KERNELS = {"relu": _linear_kernel_relu, "none": _linear_kernel_none}


def _linear_impl(x: jax.Array, w: jax.Array, b: jax.Array, act: str) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    bm, bn = pick_block(m), pick_block(n)
    return pl.pallas_call(
        _LINEAR_KERNELS[act],
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b.reshape(1, n))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "relu") -> jax.Array:
    """Fused ``act(x @ w + b)`` — the Layer-1 hot path.

    Differentiable: the custom VJP routes dx / dw through the same Pallas
    matmul so the AOT backward pass is also kernel-backed.
    """
    return _linear_impl(x, w, b, act)


def _linear_fwd(x, w, b, act):
    y = _linear_impl(x, w, b, act)
    # Residuals: for relu, y itself encodes the activation mask (y > 0
    # iff pre-activation > 0), so we never save the pre-activation.
    return y, (x, w, y)


def _linear_bwd(act, res, dy):
    x, w, y = res
    if act == "relu":
        dz = jnp.where(y > 0.0, dy, 0.0)
    else:
        dz = dy
    # dx = dz @ w^T ; dw = x^T @ dz — both through the Pallas matmul.
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)
