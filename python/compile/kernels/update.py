"""Layer-1 Pallas kernel: the fused generalized FL update.

Every FL algorithm Parrot simulates (FedAvg, FedProx, FedNova, SCAFFOLD,
FedDyn, Mime — see DESIGN.md §3) applies the same elementwise local step

    w' = w - lr * ( g + mu * (w - anchor) + corr )

with algorithm-specific (mu, anchor, corr).  Fusing the four reads and
one write into a single kernel means each parameter tensor is streamed
through VMEM exactly once per step instead of materializing the three
intermediate terms in HBM.

The kernel is 1-D over the flattened parameter; the wrapper pads to a
block multiple so no masking is needed and slices the pad back off.
``lr`` and ``mu`` ride along as (1,)-shaped operands (broadcast per
block) because CPU-interpret Pallas has no scalar-prefetch path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 131072 f32 = 512 KiB per operand block; six refs -> ~3 MiB of VMEM per
# program, a safe margin under a TPU core's ~16 MiB budget.
#
# Perf note (EXPERIMENTS.md §Perf, iteration log): the block must be
# LARGE — each grid step of an interpret-mode Pallas kernel lowers to one
# iteration of an XLA while-loop with dynamic-slices, so the original
# 1024-wide block turned the 200k-element mlp.w1 update into a
# ~196-iteration serial loop that dominated the whole train step
# (~200 ms/batch). Measured sweep (train_once p50): 1024 -> 200.9 ms,
# 32768 -> 7.4 ms, 131072 -> 5.9 ms, 262144 -> 5.5 ms (+6%, but 6 MiB
# VMEM/program). 131072 is the roofline-elbow pick with TPU headroom.
_BLOCK = 131072


def _update_kernel(w_ref, g_ref, a_ref, c_ref, s_ref, o_ref):
    lr = s_ref[0]
    mu = s_ref[1]
    w = w_ref[...]
    o_ref[...] = w - lr * (g_ref[...] + mu * (w - a_ref[...]) + c_ref[...])


def fused_update(
    w: jax.Array,
    g: jax.Array,
    anchor: jax.Array,
    corr: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
) -> jax.Array:
    """Fused ``w - lr*(g + mu*(w-anchor) + corr)`` for any-shaped ``w``.

    ``lr`` / ``mu`` are 0-d f32 arrays (AOT scalar inputs).
    """
    shape = w.shape
    flat = [x.reshape(-1) for x in (w, g, anchor, corr)]
    n = flat[0].shape[0]
    pad = (-n) % _BLOCK
    if pad:
        flat = [jnp.pad(x, (0, pad)) for x in flat]
    total = n + pad
    scal = jnp.stack([lr.astype(jnp.float32), mu.astype(jnp.float32)])
    out = pl.pallas_call(
        _update_kernel,
        grid=(total // _BLOCK,),
        in_specs=[
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), jnp.float32),
        interpret=True,
    )(*flat, scal)
    if pad:
        out = out[:n]
    return out.reshape(shape)
