"""Layer-2: the per-client compute graph for every Parrot workload.

Defines the three model families the experiments use (DESIGN.md §2 maps
each to the paper's workload), the *generalized local train step* that
covers all six FL algorithms (DESIGN.md §3), and the eval / full-batch
gradient steps.  All dense compute routes through the Layer-1 Pallas
kernels (`kernels.matmul.linear`, `kernels.update.fused_update`) so the
AOT-lowered HLO contains the kernel schedule.

Build-time only: `aot.py` lowers the steps defined here to HLO text once;
the Rust coordinator replays them through PJRT with no Python anywhere on
the simulation path.

Parameter-ordering contract (what the Rust side relies on, encoded in the
manifest emitted by `aot.py`):

    train:  params..., anchors..., corrs..., x, y, lr, mu
            -> new_params..., loss, grad_sq
    eval:   params..., x, y            -> loss, n_correct
    grad:   params..., x, y            -> grads..., loss

`params`, `anchors`, `corrs` are parallel lists with identical
shapes/order (`ModelSpec.specs`).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.matmul import linear
from .kernels.update import fused_update

Params = List[jax.Array]

# Paper batch size (Table 4: batch size 20 for every workload).
BATCH = 20
# FEMNIST has 62 classes; the synthetic analogs keep that.
N_CLASSES = 62
# tinylm geometry (Reddit/Albert stand-in, DESIGN.md §2).
LM_VOCAB = 128
LM_SEQ = 32
LM_DIM = 64
LM_HEADS = 2
LM_FF = 256


def cross_entropy(logits: jax.Array, y: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy; ``y`` int32 class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - picked)


@dataclass
class ModelSpec:
    """One workload family: geometry + init + loss + metrics."""

    name: str
    x_shape: Tuple[int, ...]           # includes batch dim
    x_dtype: str                       # "f32" | "i32"
    y_shape: Tuple[int, ...]
    specs: List[Tuple[str, Tuple[int, ...]]]  # (param name, shape), in order
    loss: Callable[[Params, jax.Array, jax.Array], jax.Array] = field(repr=False, default=None)
    metrics: Callable[[Params, jax.Array, jax.Array], Tuple[jax.Array, jax.Array]] = field(repr=False, default=None)

    def param_count(self) -> int:
        return sum(int(math.prod(s)) for _, s in self.specs)

    def init(self, seed: int = 0) -> Params:
        """He-normal weights / zero biases / unit norm scales."""
        key = jax.random.PRNGKey(seed)
        out = []
        for pname, shape in self.specs:
            key, sub = jax.random.split(key)
            if pname.endswith("_s"):               # layernorm scale
                out.append(jnp.ones(shape, jnp.float32))
            elif len(shape) == 1:                  # bias / ln offset
                out.append(jnp.zeros(shape, jnp.float32))
            elif pname.startswith(("emb", "pos")):
                out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
            else:
                fan_in = int(math.prod(shape[:-1]))
                std = math.sqrt(2.0 / fan_in)
                out.append(std * jax.random.normal(sub, shape, jnp.float32))
        return out


# --------------------------------------------------------------------------
# mlp — FEMNIST-analog (ResNet-18 stand-in at matched relative FLOPs)
# --------------------------------------------------------------------------

def _mlp_logits(p: Params, x: jax.Array) -> jax.Array:
    h = linear(x, p[0], p[1], "relu")
    h = linear(h, p[2], p[3], "relu")
    return linear(h, p[4], p[5], "none")


def _mlp_loss(p: Params, x: jax.Array, y: jax.Array) -> jax.Array:
    return cross_entropy(_mlp_logits(p, x), y)


def _mlp_metrics(p, x, y):
    logits = _mlp_logits(p, x)
    loss = cross_entropy(logits, y)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, correct


MLP = ModelSpec(
    name="mlp",
    x_shape=(BATCH, 784), x_dtype="f32", y_shape=(BATCH,),
    specs=[
        ("w1", (784, 256)), ("b1", (256,)),
        ("w2", (256, 128)), ("b2", (128,)),
        ("w3", (128, N_CLASSES)), ("b3", (N_CLASSES,)),
    ],
    loss=_mlp_loss, metrics=_mlp_metrics,
)


# --------------------------------------------------------------------------
# cnn — second vision workload (ResNet-50 stand-in: ~2x the mlp FLOPs)
# --------------------------------------------------------------------------

def _cnn_logits(p: Params, x: jax.Array) -> jax.Array:
    x = x.reshape(-1, 28, 28, 1)
    h = jax.lax.conv_general_dilated(
        x, p[0], window_strides=(2, 2), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jnp.maximum(h + p[1], 0.0)
    h = jax.lax.conv_general_dilated(
        h, p[2], window_strides=(2, 2), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jnp.maximum(h + p[3], 0.0)
    h = h.reshape(h.shape[0], -1)  # (B, 7*7*16 = 784)
    return linear(h, p[4], p[5], "none")


def _cnn_loss(p, x, y):
    return cross_entropy(_cnn_logits(p, x), y)


def _cnn_metrics(p, x, y):
    logits = _cnn_logits(p, x)
    loss = cross_entropy(logits, y)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, correct


CNN = ModelSpec(
    name="cnn",
    x_shape=(BATCH, 784), x_dtype="f32", y_shape=(BATCH,),
    specs=[
        ("k1", (3, 3, 1, 8)), ("cb1", (8,)),
        ("k2", (3, 3, 8, 16)), ("cb2", (16,)),
        ("w3", (784, N_CLASSES)), ("b3", (N_CLASSES,)),
    ],
    loss=_cnn_loss, metrics=_cnn_metrics,
)


# --------------------------------------------------------------------------
# tinylm — Reddit/Albert stand-in: 1-block causal transformer LM
# --------------------------------------------------------------------------

def _ln(h: jax.Array, s: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    return (h - mu) * jax.lax.rsqrt(var + 1e-5) * s + b


def _lm_logits(p: Params, x: jax.Array) -> jax.Array:
    (emb, pos, wqkv, bqkv, wo, bo, ln1_s, ln1_b,
     w1, b1, w2, b2, ln2_s, ln2_b, lnf_s, lnf_b, head, bh) = p
    B, T = x.shape
    h = emb[x] + pos[None, :T, :]                      # (B, T, d)
    d = h.shape[-1]
    hd = d // LM_HEADS

    # --- attention block ---------------------------------------------------
    a_in = _ln(h, ln1_s, ln1_b).reshape(B * T, d)
    qkv = linear(a_in, wqkv, bqkv, "none").reshape(B, T, 3, LM_HEADS, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # (B, T, H, hd)
    att = jnp.einsum("bthd,bshd->bhts", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhts,bshd->bthd", att, v).reshape(B * T, d)
    h = h + linear(ctx, wo, bo, "none").reshape(B, T, d)

    # --- mlp block -----------------------------------------------------------
    m_in = _ln(h, ln2_s, ln2_b).reshape(B * T, d)
    m = linear(m_in, w1, b1, "relu")
    h = h + linear(m, w2, b2, "none").reshape(B, T, d)

    hf = _ln(h, lnf_s, lnf_b).reshape(B * T, d)
    return linear(hf, head, bh, "none").reshape(B, T, LM_VOCAB)


def _lm_loss(p, x, y):
    return cross_entropy(_lm_logits(p, x), y)


def _lm_metrics(p, x, y):
    logits = _lm_logits(p, x)
    loss = cross_entropy(logits, y)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, correct


TINYLM = ModelSpec(
    name="tinylm",
    x_shape=(BATCH, LM_SEQ), x_dtype="i32", y_shape=(BATCH, LM_SEQ),
    specs=[
        ("emb", (LM_VOCAB, LM_DIM)), ("pos", (LM_SEQ, LM_DIM)),
        ("wqkv", (LM_DIM, 3 * LM_DIM)), ("bqkv", (3 * LM_DIM,)),
        ("wo", (LM_DIM, LM_DIM)), ("bo", (LM_DIM,)),
        ("ln1_s", (LM_DIM,)), ("ln1_b", (LM_DIM,)),
        ("w1", (LM_DIM, LM_FF)), ("fb1", (LM_FF,)),
        ("w2", (LM_FF, LM_DIM)), ("fb2", (LM_DIM,)),
        ("ln2_s", (LM_DIM,)), ("ln2_b", (LM_DIM,)),
        ("lnf_s", (LM_DIM,)), ("lnf_b", (LM_DIM,)),
        ("head", (LM_DIM, LM_VOCAB)), ("bh", (LM_VOCAB,)),
    ],
    loss=_lm_loss, metrics=_lm_metrics,
)


MODELS = {m.name: m for m in (MLP, CNN, TINYLM)}


# --------------------------------------------------------------------------
# The three AOT-exported steps
# --------------------------------------------------------------------------

def make_train_step(spec: ModelSpec):
    """Generalized one-batch local step (DESIGN.md §3).

    FedAvg: mu=0, corr=0.  FedProx/FedDyn: mu>0, anchor=w_global.
    SCAFFOLD: corr = c - c_i.  Mime: corr = server momentum term.
    """

    def step(params: Params, anchors: Params, corrs: Params,
             x: jax.Array, y: jax.Array, lr: jax.Array, mu: jax.Array):
        loss, grads = jax.value_and_grad(spec.loss)(params, x, y)
        gsq = sum(jnp.vdot(g, g) for g in grads)
        new = [fused_update(w, g, a, c, lr, mu)
               for w, g, a, c in zip(params, grads, anchors, corrs)]
        return tuple(new) + (loss, gsq)

    return step


def make_eval_step(spec: ModelSpec):
    def step(params: Params, x: jax.Array, y: jax.Array):
        loss, correct = spec.metrics(params, x, y)
        return loss, correct

    return step


def make_grad_step(spec: ModelSpec):
    """Batch-gradient step (Mime's full-batch gradient; SCAFFOLD's c_i refresh)."""

    def step(params: Params, x: jax.Array, y: jax.Array):
        loss, grads = jax.value_and_grad(spec.loss)(params, x, y)
        return tuple(grads) + (loss,)

    return step


def example_args(spec: ModelSpec, kind: str):
    """ShapeDtypeStructs matching the manifest input order for ``kind``."""
    f32, i32 = jnp.float32, jnp.int32
    ps = [jax.ShapeDtypeStruct(s, f32) for _, s in spec.specs]
    x = jax.ShapeDtypeStruct(spec.x_shape, f32 if spec.x_dtype == "f32" else i32)
    y = jax.ShapeDtypeStruct(spec.y_shape, i32)
    if kind == "train":
        scalar = jax.ShapeDtypeStruct((), f32)
        return (ps, list(ps), list(ps), x, y, scalar, scalar)
    if kind in ("eval", "grad"):
        return (ps, x, y)
    raise ValueError(kind)


def make_step(spec: ModelSpec, kind: str):
    return {"train": make_train_step, "eval": make_eval_step,
            "grad": make_grad_step}[kind](spec)
