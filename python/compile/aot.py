"""AOT compile path: lower every (model, step) pair to HLO text + manifest.

This is the only place Python touches the system; it runs once at build
time (``make artifacts``).  For each model family in `model.MODELS` and
each step kind (train / eval / grad) it emits into ``artifacts/``:

- ``<model>_<kind>.hlo.txt``   — HLO **text**.  Text, not
  ``.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit
  instruction ids which the xla crate's xla_extension 0.5.1 rejects
  (``proto.id() <= INT_MAX``); the text parser reassigns ids and
  round-trips cleanly (see /opt/xla-example/README.md).
- ``<model>_<kind>.manifest.txt`` — plain-text description of the
  flattened input/output order, shapes and dtypes that the Rust
  ``model::manifest`` module parses.  The order is the jax pytree
  flattening order of the step signature and is the contract between
  Layers 2 and 3.

Additionally it emits numeric *test vectors* (``testvec_<artifact>``)
— concrete inputs plus expected outputs computed by the exact jitted
function — which the Rust integration tests replay through PJRT and
compare allclose, pinning the whole AOT bridge end to end.

Usage:  python -m compile.aot --out ../artifacts [--models mlp,cnn,tinylm]
"""

from __future__ import annotations

import argparse
import math
import os
import struct
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dt(x) -> str:
    return {"float32": "f32", "int32": "i32"}[str(x.dtype)]


def _shape_str(shape) -> str:
    return ",".join(str(d) for d in shape) if shape else "-"


def io_table(spec: M.ModelSpec, kind: str):
    """(inputs, outputs) as (name, role, dtype, shape) in flattened order."""
    f32 = "f32"
    params = [(n, "param", f32, s) for n, s in spec.specs]
    x = ("x", "batch", spec.x_dtype, spec.x_shape)
    y = ("y", "batch", "i32", spec.y_shape)
    if kind == "train":
        ins = (
            params
            + [(f"anchor.{n}", "anchor", f32, s) for n, s in spec.specs]
            + [(f"corr.{n}", "corr", f32, s) for n, s in spec.specs]
            + [x, y, ("lr", "scalar", f32, ()), ("mu", "scalar", f32, ())]
        )
        outs = [(f"new.{n}", "param", f32, s) for n, s in spec.specs] + [
            ("loss", "metric", f32, ()),
            ("gsq", "metric", f32, ()),
        ]
    elif kind == "eval":
        ins = params + [x, y]
        outs = [("loss", "metric", f32, ()), ("correct", "metric", f32, ())]
    elif kind == "grad":
        ins = params + [x, y]
        outs = [(f"grad.{n}", "param", f32, s) for n, s in spec.specs] + [
            ("loss", "metric", f32, ())
        ]
    else:
        raise ValueError(kind)
    return ins, outs


def write_manifest(path: str, spec: M.ModelSpec, kind: str) -> None:
    ins, outs = io_table(spec, kind)
    with open(path, "w") as f:
        f.write(f"artifact {spec.name}_{kind}\n")
        f.write(f"model {spec.name}\n")
        f.write(f"kind {kind}\n")
        f.write(f"batch {M.BATCH}\n")
        f.write(f"nparams {len(spec.specs)}\n")
        for name, role, dt, shape in ins:
            f.write(f"input {name} {role} {dt} {_shape_str(shape)}\n")
        for name, role, dt, shape in outs:
            f.write(f"output {name} {role} {dt} {_shape_str(shape)}\n")


def concrete_inputs(spec: M.ModelSpec, kind: str, seed: int = 7):
    """Deterministic concrete example inputs for the test vectors."""
    key = jax.random.PRNGKey(seed)
    params = spec.init(seed=1)
    kx, ky, ka, kc = jax.random.split(key, 4)
    if spec.x_dtype == "f32":
        x = jax.random.normal(kx, spec.x_shape, jnp.float32)
    else:
        x = jax.random.randint(kx, spec.x_shape, 0, M.LM_VOCAB, jnp.int32)
    ymax = M.LM_VOCAB if spec.name == "tinylm" else M.N_CLASSES
    y = jax.random.randint(ky, spec.y_shape, 0, ymax, jnp.int32)
    if kind == "train":
        anchors = [p + 0.01 for p in params]
        corrs = [0.001 * jax.random.normal(kc, p.shape, jnp.float32) for p in params]
        lr = jnp.float32(0.05)
        mu = jnp.float32(0.1)
        return (params, anchors, corrs, x, y, lr, mu)
    return (params, x, y)


def write_testvec(prefix: str, fn, args, spec: M.ModelSpec, kind: str) -> None:
    """Flatten concrete args + outputs to .idx (names/sizes) and .bin (LE bytes)."""
    flat_in, _ = jax.tree_util.tree_flatten(args)
    outs = fn(*args)
    flat_out, _ = jax.tree_util.tree_flatten(outs)
    ins, outdecl = io_table(spec, kind)
    assert len(flat_in) == len(ins), (len(flat_in), len(ins))
    assert len(flat_out) == len(outdecl), (len(flat_out), len(outdecl))
    import numpy as np

    with open(prefix + ".idx", "w") as idx, open(prefix + ".bin", "wb") as binf:
        for (name, _, dt, shape), arr in zip(ins, flat_in):
            a = np.asarray(arr)
            idx.write(f"in {name} {dt} {a.size} {_shape_str(shape)}\n")
            binf.write(a.astype("<f4" if dt == "f32" else "<i4").tobytes())
        for (name, _, dt, shape), arr in zip(outdecl, flat_out):
            a = np.asarray(arr)
            idx.write(f"out {name} {dt} {a.size} {_shape_str(shape)}\n")
            binf.write(a.astype("<f4" if dt == "f32" else "<i4").tobytes())


def kernel_report(out_dir: str) -> None:
    """Static L1 perf analysis: VMEM footprint + MXU-alignment per kernel.

    interpret=True gives CPU-numpy timings only, so TPU efficiency is
    *estimated* from the BlockSpec schedule (DESIGN.md §Perf): per-program
    VMEM bytes, arithmetic intensity, and MXU tile alignment.
    """
    from .kernels.matmul import pick_block

    lines = ["# Layer-1 kernel schedule report (static analysis)", ""]
    shapes = [
        ("mlp.l1 fwd", M.BATCH, 784, 256),
        ("mlp.l2 fwd", M.BATCH, 256, 128),
        ("mlp.l3 fwd", M.BATCH, 128, M.N_CLASSES),
        ("mlp.l1 dgrad", M.BATCH, 256, 784),
        ("mlp.l1 wgrad", 784, M.BATCH, 256),
        ("tinylm.qkv", M.BATCH * M.LM_SEQ, M.LM_DIM, 3 * M.LM_DIM),
        ("tinylm.ff1", M.BATCH * M.LM_SEQ, M.LM_DIM, M.LM_FF),
        ("tinylm.head", M.BATCH * M.LM_SEQ, M.LM_DIM, M.LM_VOCAB),
    ]
    lines.append(
        f"{'site':<16}{'M':>6}{'K':>6}{'N':>6}{'bm':>5}{'bn':>5}"
        f"{'VMEM/prog':>12}{'AI(flop/B)':>12}{'MXU-fit':>9}"
    )
    for site, m, k, n in shapes:
        bm, bn = pick_block(m), pick_block(n)
        vmem = 4 * (bm * k + k * bn + bm * bn)  # f32 operands resident per program
        flops = 2 * bm * k * bn
        ai = flops / vmem
        mxu = "full" if (bm % 128 == 0 and bn % 128 == 0) else (
            "partial" if (bn % 8 == 0) else "pad")
        lines.append(
            f"{site:<16}{m:>6}{k:>6}{n:>6}{bm:>5}{bn:>5}{vmem:>12,}{ai:>12.1f}{mxu:>9}"
        )
    lines += [
        "",
        "fused_update: 1-D BLOCK=131072 f32 (512 KiB/operand, ~3 MiB VMEM per",
        "program with 6 refs); purely bandwidth-bound (AI ~ 0.17 flop/B), so",
        "the fusion (4 reads 1 write, vs 10 reads 4 writes unfused) is the win.",
        "Block-size sweep (interpret-mode train_once p50, EXPERIMENTS.md §Perf):",
        "  1024 -> 200.9 ms   (196-iteration grid loop on mlp.w1)",
        " 32768 ->   7.4 ms",
        "131072 ->   5.9 ms   <- chosen (TPU VMEM headroom)",
        "262144 ->   5.5 ms   (+6%, 6 MiB/program)",
    ]
    with open(os.path.join(out_dir, "kernel_report.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="mlp,cnn,tinylm")
    ap.add_argument("--kinds", default="train,eval,grad")
    ap.add_argument("--skip-testvec", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for mname in args.models.split(","):
        spec = M.MODELS[mname]
        for kind in args.kinds.split(","):
            step = M.make_step(spec, kind)
            ex = M.example_args(spec, kind)
            lowered = jax.jit(step).lower(*ex)
            text = to_hlo_text(lowered)
            base = os.path.join(args.out, f"{mname}_{kind}")
            with open(base + ".hlo.txt", "w") as f:
                f.write(text)
            write_manifest(base + ".manifest.txt", spec, kind)
            print(f"[aot] {mname}_{kind}: {len(text)} chars of HLO")
            if not args.skip_testvec and mname == "mlp":
                jitted = jax.jit(step)
                write_testvec(
                    os.path.join(args.out, f"testvec_{mname}_{kind}"),
                    jitted, concrete_inputs(spec, kind), spec, kind,
                )
                print(f"[aot] testvec_{mname}_{kind} written")

    kernel_report(args.out)
    # Stamp for make's up-to-date check.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")
    print("[aot] done")


if __name__ == "__main__":
    main()
