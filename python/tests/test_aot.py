"""AOT pipeline checks: manifest/HLO/testvec emission contracts.

The Rust side parses these artifacts blindly, so the format assertions
here are effectively the L2<->L3 interface tests on the Python side.
"""

import os
import struct
import tempfile

import jax
import numpy as np
import pytest

from compile import model as M
from compile.aot import concrete_inputs, io_table, to_hlo_text, write_manifest, write_testvec

jax.config.update("jax_platform_name", "cpu")


class TestIoTable:
    def test_train_input_order(self):
        ins, outs = io_table(M.MLP, "train")
        n = len(M.MLP.specs)
        roles = [r for _, r, _, _ in ins]
        assert roles == ["param"] * n + ["anchor"] * n + ["corr"] * n + \
            ["batch", "batch", "scalar", "scalar"]
        assert [r for _, r, _, _ in outs] == ["param"] * n + ["metric", "metric"]

    def test_flattening_order_matches_io_table(self):
        """jax pytree flattening of the step args == manifest order (the contract)."""
        for kind in ("train", "eval", "grad"):
            ins, _ = io_table(M.MLP, kind)
            ex = M.example_args(M.MLP, kind)
            flat, _ = jax.tree_util.tree_flatten(ex)
            assert len(flat) == len(ins)
            for (name, _, dt, shape), leaf in zip(ins, flat):
                assert tuple(shape) == tuple(leaf.shape), name
                expect = {"f32": "float32", "i32": "int32"}[dt]
                assert str(leaf.dtype) == expect, name

    def test_eval_io(self):
        ins, outs = io_table(M.TINYLM, "eval")
        assert ins[-2][0] == "x" and ins[-1][0] == "y"
        assert [n for n, _, _, _ in outs] == ["loss", "correct"]


class TestManifest:
    def test_manifest_round_trip_fields(self, tmp_path):
        p = tmp_path / "m.txt"
        write_manifest(str(p), M.MLP, "train")
        lines = p.read_text().strip().split("\n")
        assert lines[0] == "artifact mlp_train"
        assert "model mlp" in lines and "kind train" in lines
        assert f"batch {M.BATCH}" in lines
        ins = [l for l in lines if l.startswith("input ")]
        outs = [l for l in lines if l.startswith("output ")]
        assert len(ins) == 3 * 6 + 4 and len(outs) == 6 + 2
        # scalar shapes serialize as "-"
        assert any(l == "input lr scalar f32 -" for l in ins)

    def test_manifest_shapes_parse(self, tmp_path):
        p = tmp_path / "m.txt"
        write_manifest(str(p), M.CNN, "grad")
        for line in p.read_text().strip().split("\n"):
            parts = line.split(" ")
            if parts[0] in ("input", "output"):
                assert len(parts) == 5
                if parts[4] != "-":
                    dims = [int(d) for d in parts[4].split(",")]
                    assert all(d > 0 for d in dims)


class TestHloText:
    def test_hlo_text_is_parseable_header(self):
        lowered = jax.jit(M.make_step(M.MLP, "eval")).lower(
            *M.example_args(M.MLP, "eval"))
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # return_tuple=True -> tuple-shaped root
        assert "->" in text

    def test_hlo_deterministic(self):
        lowered1 = jax.jit(M.make_step(M.MLP, "eval")).lower(
            *M.example_args(M.MLP, "eval"))
        lowered2 = jax.jit(M.make_step(M.MLP, "eval")).lower(
            *M.example_args(M.MLP, "eval"))
        assert to_hlo_text(lowered1) == to_hlo_text(lowered2)


class TestTestVec:
    def test_testvec_bin_size_matches_idx(self, tmp_path):
        spec, kind = M.MLP, "eval"
        fn = jax.jit(M.make_step(spec, kind))
        prefix = str(tmp_path / "tv")
        write_testvec(prefix, fn, concrete_inputs(spec, kind), spec, kind)
        total = 0
        for line in open(prefix + ".idx"):
            _, _, dt, size, _ = line.split(" ")
            total += 4 * int(size)
        assert os.path.getsize(prefix + ".bin") == total

    def test_testvec_outputs_replayable(self, tmp_path):
        """Reload the dumped inputs and re-run: outputs must match the dump."""
        spec, kind = M.MLP, "eval"
        fn = jax.jit(M.make_step(spec, kind))
        prefix = str(tmp_path / "tv")
        args = concrete_inputs(spec, kind)
        write_testvec(prefix, fn, args, spec, kind)
        blob = open(prefix + ".bin", "rb").read()
        off = 0
        arrays = []
        for line in open(prefix + ".idx"):
            io, name, dt, size, shape = line.split(" ")
            n = int(size)
            a = np.frombuffer(blob, dtype="<f4" if dt == "f32" else "<i4",
                              count=n, offset=off)
            off += 4 * n
            arrays.append((io, a))
        n_in = len([1 for io, _ in arrays if io == "in"])
        flat_args, treedef = jax.tree_util.tree_flatten(args)
        outs = fn(*args)
        flat_outs, _ = jax.tree_util.tree_flatten(outs)
        for (io, dumped), live in zip(arrays[n_in:], flat_outs):
            assert io == "out"
            np.testing.assert_allclose(dumped, np.asarray(live).reshape(-1),
                                       rtol=1e-6)
