"""Layer-2 correctness: model semantics of the generalized train step.

Checks the algorithm-covering semantics from DESIGN.md §3 — that the one
exported step really *is* FedAvg / FedProx / SCAFFOLD / FedDyn / Mime
depending on (mu, anchor, corr) — plus learning-progress sanity on every
model family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import concrete_inputs

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module", params=["mlp", "cnn", "tinylm"])
def spec(request):
    return M.MODELS[request.param]


def _zeros_like(ps):
    return [jnp.zeros_like(p) for p in ps]


class TestGeometry:
    def test_param_specs_match_init(self, spec):
        params = spec.init(0)
        assert len(params) == len(spec.specs)
        for (name, shape), p in zip(spec.specs, params):
            assert p.shape == shape, name
            assert p.dtype == jnp.float32

    def test_param_counts(self):
        assert M.MLP.param_count() == 784 * 256 + 256 + 256 * 128 + 128 + 128 * 62 + 62
        assert M.CNN.param_count() == 3 * 3 * 8 + 8 + 3 * 3 * 8 * 16 + 16 + 784 * 62 + 62
        assert M.TINYLM.param_count() > 50_000

    def test_init_deterministic(self, spec):
        a, b = spec.init(3), spec.init(3)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
        c = spec.init(4)
        assert any(not np.array_equal(x, y) for x, y in zip(a, c))


class TestTrainStepSemantics:
    def test_fedavg_is_plain_sgd(self, spec):
        """mu=0, corr=0 reduces to w - lr * grad."""
        params, _, _, x, y, lr, _ = concrete_inputs(spec, "train")
        step = M.make_step(spec, "train")
        z = _zeros_like(params)
        out = jax.jit(step)(params, z, z, x, y, lr, jnp.float32(0.0))
        new, loss = list(out[:-2]), out[-2]
        loss_ref, grads = jax.value_and_grad(spec.loss)(params, x, y)
        np.testing.assert_allclose(loss, loss_ref, rtol=1e-5)
        for w, g, w2 in zip(params, grads, new):
            np.testing.assert_allclose(w2, w - lr * g, rtol=1e-4, atol=1e-6)

    def test_fedprox_pulls_toward_anchor(self, spec):
        """mu>0 with anchor=w adds no force; anchor far away does."""
        params, _, _, x, y, lr, _ = concrete_inputs(spec, "train")
        step = jax.jit(M.make_step(spec, "train"))
        z = _zeros_like(params)
        mu = jnp.float32(10.0)
        # anchor == params: identical to fedavg
        out_self = step(params, params, z, x, y, lr, mu)
        out_avg = step(params, z, z, x, y, lr, jnp.float32(0.0))
        for a, b in zip(out_self[:-2], out_avg[:-2]):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
        # anchor at 0 with huge mu: pulls weights toward 0
        out_zero = step(params, z, z, x, y, lr, mu)
        shrunk = sum(float(jnp.vdot(w, w)) for w in out_zero[:-2])
        base = sum(float(jnp.vdot(w, w)) for w in out_avg[:-2])
        assert shrunk < base

    def test_scaffold_correction_shifts_update(self, spec):
        """corr enters additively: w' = w - lr*(g + corr)."""
        params, _, _, x, y, lr, _ = concrete_inputs(spec, "train")
        step = jax.jit(M.make_step(spec, "train"))
        z = _zeros_like(params)
        corr = [jnp.full_like(p, 0.01) for p in params]
        out_c = step(params, z, corr, x, y, lr, jnp.float32(0.0))
        out_0 = step(params, z, z, x, y, lr, jnp.float32(0.0))
        for wc, w0 in zip(out_c[:-2], out_0[:-2]):
            np.testing.assert_allclose(wc, w0 - lr * 0.01, rtol=1e-4, atol=1e-6)

    def test_gsq_is_grad_norm_sq(self, spec):
        params, _, _, x, y, lr, _ = concrete_inputs(spec, "train")
        step = jax.jit(M.make_step(spec, "train"))
        z = _zeros_like(params)
        out = step(params, z, z, x, y, lr, jnp.float32(0.0))
        _, grads = jax.value_and_grad(spec.loss)(params, x, y)
        gsq_ref = sum(float(jnp.vdot(g, g)) for g in grads)
        np.testing.assert_allclose(out[-1], gsq_ref, rtol=1e-3)

    def test_grad_step_matches_autodiff(self, spec):
        params, x, y = concrete_inputs(spec, "grad")
        out = jax.jit(M.make_step(spec, "grad"))(params, x, y)
        loss_ref, grads = jax.value_and_grad(spec.loss)(params, x, y)
        np.testing.assert_allclose(out[-1], loss_ref, rtol=1e-5)
        for g, gr in zip(out[:-1], grads):
            np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-6)


class TestLearning:
    def test_loss_decreases_over_sgd_steps(self, spec):
        """A few generalized steps on one batch must reduce the loss."""
        params, _, _, x, y, lr, _ = concrete_inputs(spec, "train")
        step = jax.jit(M.make_step(spec, "train"))
        z = _zeros_like(params)
        losses = []
        for _ in range(5):
            out = step(params, z, z, x, y, lr, jnp.float32(0.0))
            params = list(out[:-2])
            losses.append(float(out[-2]))
        assert losses[-1] < losses[0], losses

    def test_eval_correct_bounded_by_batch(self, spec):
        params, x, y = concrete_inputs(spec, "eval")
        loss, correct = jax.jit(M.make_step(spec, "eval"))(params, x, y)
        n_pred = int(np.prod(spec.y_shape))
        assert 0.0 <= float(correct) <= n_pred
        assert float(loss) > 0.0


class TestCrossEntropy:
    def test_perfect_logits_zero_loss(self):
        y = jnp.array([0, 1, 2], jnp.int32)
        logits = 1e4 * jax.nn.one_hot(y, 4)
        assert float(M.cross_entropy(logits, y)) < 1e-3

    def test_uniform_logits_log_c(self):
        y = jnp.array([0, 1], jnp.int32)
        logits = jnp.zeros((2, 62))
        np.testing.assert_allclose(M.cross_entropy(logits, y), np.log(62), rtol=1e-5)
