"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core numeric signal for the whole stack: the AOT-lowered HLO
that Rust executes contains exactly these kernels, so kernel==oracle here
plus the Rust-side testvec replay pins end-to-end numerics.

hypothesis sweeps shapes (including MXU-unaligned ones that exercise the
divisor-block fallback) and value magnitudes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import linear, matmul, pick_block
from compile.kernels.update import fused_update

jax.config.update("jax_platform_name", "cpu")

DIMS = st.sampled_from([1, 2, 3, 5, 8, 16, 20, 62, 100, 128, 130, 256])
SMALL_DIMS = st.sampled_from([1, 4, 20, 62, 128])


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------- pick_block

@given(dim=st.integers(1, 4096), target=st.sampled_from([8, 64, 128, 256]))
@settings(max_examples=200, deadline=None)
def test_pick_block_is_divisor_and_bounded(dim, target):
    b = pick_block(dim, target)
    assert 1 <= b <= min(dim, target)
    assert dim % b == 0


def test_pick_block_prefers_mxu_edge():
    assert pick_block(256) == 128
    assert pick_block(1024) == 128
    assert pick_block(62) == 62
    assert pick_block(784) == 112  # largest divisor of 784 under 128


# ------------------------------------------------------------------- matmul

@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_matmul_matches_ref(m, k, n, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x, w = _rand(kx, (m, k)), _rand(kw, (k, n))
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w),
                               rtol=1e-4, atol=1e-5)


def test_matmul_large_aligned():
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x, w = _rand(kx, (256, 512)), _rand(kw, (512, 384))
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w),
                               rtol=1e-4, atol=1e-4)


def test_matmul_shape_mismatch_raises():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 7))
    with pytest.raises(AssertionError):
        matmul(x, w)


# ------------------------------------------------------------------- linear

@pytest.mark.parametrize("act", ["relu", "none"])
@given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_linear_matches_ref(act, m, k, n, seed):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x, w, b = _rand(kx, (m, k)), _rand(kw, (k, n)), _rand(kb, (n,))
    np.testing.assert_allclose(linear(x, w, b, act), ref.linear_ref(x, w, b, act),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ["relu", "none"])
def test_linear_grads_match_ref(act):
    """The custom VJP (Pallas backward matmuls) == jax autodiff of the oracle."""
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(3), 3)
    x, w, b = _rand(kx, (20, 48)), _rand(kw, (48, 30)), _rand(kb, (30,))

    def f_kernel(x, w, b):
        return jnp.sum(jnp.sin(linear(x, w, b, act)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(ref.linear_ref(x, w, b, act)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-5)


def test_linear_relu_kills_negative_grads():
    """ReLU mask correctness: grads vanish where pre-activation < 0."""
    x = jnp.array([[1.0, 1.0]])
    w = jnp.array([[1.0, -1.0], [1.0, -1.0]])  # outputs: [2, -2] -> relu [2, 0]
    b = jnp.zeros((2,))
    y = linear(x, w, b, "relu")
    np.testing.assert_allclose(y, [[2.0, 0.0]])
    g = jax.grad(lambda w: jnp.sum(linear(x, w, b, "relu")))(w)
    # Column 1 (dead unit) must get zero gradient.
    np.testing.assert_allclose(g[:, 1], [0.0, 0.0])


# -------------------------------------------------------------- fused_update

@given(
    n=st.sampled_from([1, 7, 64, 1000, 1024, 1025, 4096, 200_000]),
    seed=st.integers(0, 2**31 - 1),
    lr=st.floats(0.0, 1.0),
    mu=st.floats(0.0, 1.0),
)
@settings(max_examples=20, deadline=None)
def test_fused_update_matches_ref_1d(n, seed, lr, mu):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    w, g, a, c = (_rand(k, (n,)) for k in ks)
    lr, mu = jnp.float32(lr), jnp.float32(mu)
    np.testing.assert_allclose(
        fused_update(w, g, a, c, lr, mu),
        ref.fused_update_ref(w, g, a, c, lr, mu),
        rtol=1e-6, atol=1e-6,
    )


@pytest.mark.parametrize("shape", [(784, 256), (3, 3, 8, 16), (62,), (1, 1)])
def test_fused_update_preserves_shape(shape):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    w, g, a = (_rand(k, shape) for k in ks)
    c = jnp.zeros(shape, jnp.float32)
    out = fused_update(w, g, a, c, jnp.float32(0.1), jnp.float32(0.0))
    assert out.shape == shape
    np.testing.assert_allclose(out, w - 0.1 * g, rtol=1e-6, atol=1e-6)


def test_fused_update_identities():
    """lr=0 -> no-op; mu=0,corr=0 -> plain SGD; g=0,corr=0,anchor=w -> no-op."""
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    w, g = _rand(ks[0], (100,)), _rand(ks[1], (100,))
    z = jnp.zeros_like(w)
    np.testing.assert_allclose(
        fused_update(w, g, z, z, jnp.float32(0.0), jnp.float32(0.5)), w)
    np.testing.assert_allclose(
        fused_update(w, g, w, z, jnp.float32(0.3), jnp.float32(0.7)),
        w - 0.3 * g, rtol=1e-6, atol=1e-6)


def test_fused_update_inside_jit_and_lowerable():
    """The kernel must survive jit + lowering (the AOT path)."""
    w = jnp.ones((130,))

    @jax.jit
    def f(w):
        # g=w, anchor=w (mu term vanishes), corr=w  ->  w - 0.1*(w + w) = 0.8w
        return fused_update(w, w, w, w, jnp.float32(0.1), jnp.float32(0.2))

    np.testing.assert_allclose(f(w), 0.8 * w, rtol=1e-6)
    hlo = jax.jit(f).lower(w).compiler_ir("stablehlo")
    assert "stablehlo" in str(hlo)
