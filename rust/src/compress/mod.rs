//! Pluggable update-compression codecs for the comm stack.
//!
//! Every parameter tensor that crosses a Transport (device aggregates,
//! FA task uploads) used to be raw little-endian f32 — 4 bytes/param in
//! the s_a·K upload term of Table 1.  This module provides the [`Codec`]
//! the coordinator negotiates at round start and the engine uses to
//! book *encoded* comm bytes:
//!
//! | codec        | wire bytes / tensor of n   | worst-case abs error        |
//! |--------------|----------------------------|-----------------------------|
//! | `none`       | 4·n                        | 0                           |
//! | `fp16`       | 2·n                        | max|x|·2⁻¹¹ + 2⁻²⁴ (+clamp) |
//! | `qint8`      | n + 8                      | (max−min)/510 (+f32 slop)   |
//! | `topk:f`     | 8·⌈f·n⌉ + 4                | (k+1)-th largest |x|        |
//!
//! "wire bytes" is the payload-only size; the self-describing tensor
//! stream adds a fixed 5-byte envelope (1 codec tag + 4 length prefix),
//! asserted equal to the measured encoding in `integration_schemes.rs`.
//!
//! Per-codec bounds, precisely:
//! - **Fp16**: values are clamped to ±65504 (the largest finite half)
//!   and rounded to nearest-even, so |x̂−x| ≤ |x|·2⁻¹¹ + 2⁻²⁴ plus the
//!   clamp overshoot max(|x|−65504, 0).
//! - **QInt8**: per-tensor affine quantization with zero-point `min`
//!   and `scale = (max−min)/255`; |x̂−x| ≤ scale/2 plus f32 rounding
//!   slop on the order of 10⁻⁶·(|min|+|max|+range).
//! - **TopK{frac}**: keeps the k = ⌈frac·n⌉ largest-magnitude entries
//!   exactly and zeroes the rest, so the per-element error is at most
//!   the largest dropped magnitude (the (k+1)-th largest |x|).
//!
//! `Collect` ("Special Params") entries are always forwarded verbatim —
//! the s_e·M_p term the paper says cannot be optimized — so only the
//! averaged-OP tensors are ever lossy on the wire.

// Determinism-critical module: re-enable the workspace-wide clippy
// bans on unordered collections and ambient clocks (see clippy.toml
// and the crate-root allow in lib.rs).
#![deny(clippy::disallowed_types, clippy::disallowed_methods)]

use crate::util::codec::{Decoder, Encoder};
use anyhow::{bail, ensure, Result};

/// Dense-length cap for sparse (TopK) tensors, whose element count is
/// not backed 1:1 by wire bytes: a corrupt length prefix must not
/// pre-allocate GBs.  16M elements covers every model this repo ships.
pub const MAX_DECODE_ELEMS: usize = 1 << 24;

/// An update-compression codec (negotiated per round by the server).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Codec {
    /// Raw little-endian f32 — lossless, 4 bytes/param.
    #[default]
    None,
    /// IEEE 754 half precision, round-to-nearest-even, ±65504 clamp.
    Fp16,
    /// Per-tensor affine 8-bit quantization (scale + zero-point).
    QInt8,
    /// Magnitude top-k sparsification: keep ⌈frac·n⌉ (index, value)
    /// pairs, zero the rest.
    TopK(f64),
}

impl Codec {
    /// Parse a `--compress` spec: `none|fp16|qint8|topk:<frac>`.
    pub fn parse(s: &str) -> Result<Codec> {
        match s {
            "none" | "off" => Ok(Codec::None),
            "fp16" => Ok(Codec::Fp16),
            "qint8" => Ok(Codec::QInt8),
            _ => {
                if let Some(frac) = s.strip_prefix("topk:") {
                    let f: f64 = frac
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad topk fraction {frac:?}"))?;
                    ensure!(
                        f > 0.0 && f <= 1.0,
                        "topk fraction must be in (0, 1], got {f}"
                    );
                    Ok(Codec::TopK(f))
                } else {
                    bail!("unknown codec {s:?} (none|fp16|qint8|topk:<frac>)")
                }
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            Codec::None => "none".into(),
            Codec::Fp16 => "fp16".into(),
            Codec::QInt8 => "qint8".into(),
            Codec::TopK(f) => format!("topk:{f}"),
        }
    }

    fn code(self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::Fp16 => 1,
            Codec::QInt8 => 2,
            Codec::TopK(_) => 3,
        }
    }

    /// Serialize the codec choice itself (round-start negotiation).
    /// The TopK fraction travels as f64 so server and workers compute
    /// the exact same k.
    pub fn encode_meta(&self, enc: &mut Encoder) {
        enc.put_u8(self.code());
        if let Codec::TopK(f) = self {
            enc.put_f64(*f);
        }
    }

    pub fn decode_meta(dec: &mut Decoder) -> Result<Codec> {
        Ok(match dec.u8()? {
            0 => Codec::None,
            1 => Codec::Fp16,
            2 => Codec::QInt8,
            3 => {
                let f = dec.f64()?;
                ensure!(
                    f > 0.0 && f <= 1.0,
                    "topk fraction must be in (0, 1], got {f}"
                );
                Codec::TopK(f)
            }
            t => bail!("unknown codec tag {t}"),
        })
    }

    /// Kept entries for an n-element tensor under TopK (0 for n = 0).
    pub fn top_k(&self, n: usize) -> usize {
        match self {
            Codec::TopK(f) => {
                if n == 0 {
                    0
                } else {
                    // ⌈f·n⌉ with a guard against binary-representation
                    // dust: 0.1 × 10000 is 1000.0000000000001 in f64
                    // and must keep 1000 entries, not 1001.
                    ((*f * n as f64 - 1e-9).ceil() as usize).clamp(1, n)
                }
            }
            _ => n,
        }
    }

    /// Payload-only wire bytes for an n-element tensor — what the
    /// virtual engine books per comm leg (the self-describing stream
    /// adds a fixed 5-byte tag+length envelope on top).
    pub fn wire_bytes(&self, n: usize) -> usize {
        match self {
            Codec::None => 4 * n,
            Codec::Fp16 => 2 * n,
            Codec::QInt8 => n + 8,
            Codec::TopK(_) => 4 + 8 * self.top_k(n),
        }
    }

    /// Documented worst-case absolute reconstruction error of
    /// `decode(encode(xs))` for this data (see module docs).
    pub fn bound(&self, xs: &[f32]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        match self {
            Codec::None => 0.0,
            Codec::Fp16 => {
                let maxabs = xs.iter().fold(0.0f64, |a, &x| a.max((x as f64).abs()));
                maxabs * (2.0f64).powi(-11)
                    + (maxabs - 65504.0).max(0.0)
                    + (2.0f64).powi(-24)
            }
            Codec::QInt8 => {
                let (min, scale) = qint8_params(xs);
                let (min, scale) = (min as f64, scale as f64);
                let max = min + 255.0 * scale;
                scale * 0.5 + 1e-6 * (min.abs() + max.abs() + 255.0 * scale)
            }
            Codec::TopK(_) => {
                let k = self.top_k(xs.len());
                if k >= xs.len() {
                    return 0.0;
                }
                let mut mags: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
                // Largest dropped magnitude = element at rank k when
                // sorted descending.
                mags.select_nth_unstable_by(k, |a, b| b.total_cmp(a));
                mags[k] as f64
            }
        }
    }
}

// ------------------------------------------------------------- fp16 ops

fn round_shift_rne(v: u32, shift: u32) -> u32 {
    let floor = v >> shift;
    let rem = v & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && floor & 1 == 1) {
        floor + 1
    } else {
        floor
    }
}

/// f32 → IEEE half bits, round-to-nearest-even; finite overflow clamps
/// to ±65504 (Inf/NaN propagate).  Bit-exact with numpy's float16 cast
/// on the non-overflow range.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xff) as i32;
    let man = b & 0x007f_ffff;
    if exp == 255 {
        // Inf / NaN pass through (quietened).
        return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e16 = exp - 112;
    if e16 >= 31 {
        return sign | 0x7bff; // clamp to largest finite half
    }
    let man24 = man | 0x0080_0000;
    let out = if e16 <= 0 {
        let shift = (14 - e16) as u32;
        if shift >= 32 {
            return sign; // underflows to signed zero
        }
        round_shift_rne(man24, shift)
    } else {
        (((e16 - 1) as u32) << 10) + round_shift_rne(man24, 13)
    };
    if out >= 0x7c00 {
        return sign | 0x7bff; // rounding carried into the Inf pattern
    }
    sign | out as u16
}

/// IEEE half bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mut man = (h & 0x03ff) as u32;
    let bits = if exp == 31 {
        let mut b = sign | 0x7f80_0000 | (man << 13);
        if man != 0 {
            b |= 0x0040_0000; // quiet NaN
        }
        b
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal half: normalize into an f32 exponent.
            let mut e = 113u32;
            while man & 0x400 == 0 {
                man <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((man & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// ------------------------------------------------------------ qint8 ops

/// (zero-point, scale) for per-tensor affine quantization.
fn qint8_params(xs: &[f32]) -> (f32, f32) {
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for &x in xs {
        min = min.min(x);
        max = max.max(x);
    }
    if !min.is_finite() || !max.is_finite() || max <= min {
        let zp = if min.is_finite() { min } else { 0.0 };
        return (zp, 0.0);
    }
    let scale = (max - min) / 255.0;
    if scale.is_finite() && scale > 0.0 {
        (min, scale)
    } else {
        (min, 0.0)
    }
}

// ------------------------------------------------------------- topk ops

/// Indices of the k largest-magnitude elements, ascending (ties break
/// toward the lower index, so the selection is deterministic).
fn top_k_indices(xs: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..xs.len()).map(|i| i as u32).collect();
    if k < xs.len() {
        idx.select_nth_unstable_by(k, |&a, &b| {
            xs[b as usize]
                .abs()
                .total_cmp(&xs[a as usize].abs())
                .then(a.cmp(&b))
        });
        idx.truncate(k);
    }
    idx.sort_unstable();
    idx
}

// ------------------------------------------------------ tensor encoding

/// Encode one tensor as a self-describing stream: codec tag, u32
/// length, codec payload.  Total length = `codec.wire_bytes(n) + 5`.
/// Errs only if a tensor's length exceeds the u32 wire prefix.
pub fn encode_f32s(enc: &mut Encoder, xs: &[f32], codec: Codec) -> Result<()> {
    enc.put_u8(codec.code());
    match codec {
        Codec::None => enc.put_f32s(xs)?,
        Codec::Fp16 => {
            let halves: Vec<u16> = xs.iter().map(|&x| f32_to_f16_bits(x)).collect();
            enc.put_u16s(&halves)?;
        }
        Codec::QInt8 => {
            enc.put_len(xs.len())?;
            let (min, scale) = qint8_params(xs);
            enc.put_f32(min);
            enc.put_f32(scale);
            if scale > 0.0 {
                for &x in xs {
                    enc.put_u8(((x - min) / scale).round().clamp(0.0, 255.0) as u8);
                }
            } else {
                for _ in xs {
                    enc.put_u8(0);
                }
            }
        }
        Codec::TopK(_) => {
            enc.put_len(xs.len())?;
            let k = codec.top_k(xs.len());
            enc.try_put_u32(k)?;
            for i in top_k_indices(xs, k) {
                enc.put_u32(i);
                enc.put_f32(xs[i as usize]);
            }
        }
    }
    Ok(())
}

/// Decode one self-describing tensor.  Every length prefix is
/// bounds-checked against the remaining buffer before allocation, so a
/// truncated or corrupted stream errors instead of panicking or
/// pre-allocating GBs.
pub fn decode_f32s(dec: &mut Decoder) -> Result<Vec<f32>> {
    match dec.u8()? {
        0 => dec.f32s(),
        1 => {
            let halves = dec.u16s()?;
            Ok(halves.into_iter().map(f16_bits_to_f32).collect())
        }
        2 => {
            let n = dec.count(1)?;
            let min = dec.f32()?;
            let scale = dec.f32()?;
            let raw = dec.raw(n)?;
            Ok(raw.iter().map(|&q| min + q as f32 * scale).collect())
        }
        3 => {
            let n = dec.u32()? as usize;
            ensure!(
                n <= MAX_DECODE_ELEMS,
                "top-k dense length {n} exceeds decode cap {MAX_DECODE_ELEMS}"
            );
            let k = dec.count(8)?;
            ensure!(k <= n, "top-k keeps {k} of only {n} elements");
            // The encoder always keeps ≥ 1 entry for a non-empty tensor.
            ensure!(n == 0 || k > 0, "top-k tensor of {n} elements keeps none");
            // The dense length is not backed by wire bytes — charge it
            // against the frame-wide budget so repeated hostile records
            // cannot amplify a small frame into GBs.
            dec.charge_dense(n)?;
            let mut out = vec![0.0f32; n];
            let mut prev: Option<usize> = None;
            for _ in 0..k {
                let i = dec.u32()? as usize;
                let v = dec.f32()?;
                ensure!(i < n, "top-k index {i} out of range {n}");
                if let Some(p) = prev {
                    ensure!(i > p, "top-k indices must be strictly ascending");
                }
                prev = Some(i);
                out[i] = v;
            }
            Ok(out)
        }
        t => bail!("unknown codec tag {t}"),
    }
}

/// Convenience: exact encoded size of one tensor under `codec`
/// (measured, so it is the ground truth `wire_bytes` is checked against).
pub fn encoded_len(xs: &[f32], codec: Codec) -> usize {
    let mut enc = Encoder::new();
    encode_f32s(&mut enc, xs, codec).expect("tensor exceeds wire limits");
    enc.len()
}

pub const ALL_CODECS: [Codec; 4] =
    [Codec::None, Codec::Fp16, Codec::QInt8, Codec::TopK(0.1)];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn round_trip(xs: &[f32], codec: Codec) -> Vec<f32> {
        let mut enc = Encoder::new();
        encode_f32s(&mut enc, xs, codec).unwrap();
        let buf = enc.finish();
        assert_eq!(buf.len(), codec.wire_bytes(xs.len()) + 5, "{codec:?}");
        let mut dec = Decoder::new(&buf);
        let out = decode_f32s(&mut dec).unwrap();
        assert!(dec.done());
        out
    }

    #[test]
    fn parse_and_name() {
        assert_eq!(Codec::parse("none").unwrap(), Codec::None);
        assert_eq!(Codec::parse("fp16").unwrap(), Codec::Fp16);
        assert_eq!(Codec::parse("qint8").unwrap(), Codec::QInt8);
        assert_eq!(Codec::parse("topk:0.1").unwrap(), Codec::TopK(0.1));
        assert!(Codec::parse("topk:0").is_err());
        assert!(Codec::parse("topk:1.5").is_err());
        assert!(Codec::parse("topk:x").is_err());
        assert!(Codec::parse("zstd").is_err());
        for c in ALL_CODECS {
            assert_eq!(Codec::parse(&c.name()).unwrap(), c);
        }
    }

    #[test]
    fn meta_round_trip() {
        for c in [Codec::None, Codec::Fp16, Codec::QInt8, Codec::TopK(0.25)] {
            let mut enc = Encoder::new();
            c.encode_meta(&mut enc);
            let buf = enc.finish();
            let mut dec = Decoder::new(&buf);
            let back = Codec::decode_meta(&mut dec).unwrap();
            assert_eq!(back, c, "meta round trip must be exact");
        }
        assert!(Codec::decode_meta(&mut Decoder::new(&[9])).is_err());
    }

    #[test]
    fn fp16_known_values() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (65504.0, 0x7bff),
            (65505.0, 0x7bff),  // clamp
            (1.0e6, 0x7bff),    // clamp
            (-1.0e6, 0xfbff),   // clamp
            (5.9604645e-8, 0x0001), // smallest subnormal half
            (1.0e-10, 0x0000),  // underflow
        ] {
            assert_eq!(f32_to_f16_bits(x), bits, "x={x}");
        }
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0);
        assert_eq!(f16_bits_to_f32(0x0001), 5.9604645e-8);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
    }

    #[test]
    fn prop_round_trip_within_documented_bound() {
        for codec in [Codec::Fp16, Codec::QInt8, Codec::TopK(0.3)] {
            prop::check(&format!("codec {codec:?} bound"), 60, |g| {
                let n = g.int(1, 400);
                let mag = 10.0f32.powi(g.int(0, 8) as i32 - 4);
                let mut rng = Rng::new(g.rng.next_u64());
                let xs: Vec<f32> =
                    (0..n).map(|_| rng.normal_f32(0.0, 1.0) * mag).collect();
                let back = round_trip(&xs, codec);
                if back.len() != n {
                    return Err(format!("length {} != {n}", back.len()));
                }
                let bound = codec.bound(&xs);
                for (i, (&a, &b)) in xs.iter().zip(&back).enumerate() {
                    let err = (a as f64 - b as f64).abs();
                    if err > bound {
                        return Err(format!(
                            "elem {i}: |{a} - {b}| = {err} > bound {bound}"
                        ));
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn none_is_lossless() {
        let mut rng = Rng::new(7);
        let xs: Vec<f32> = (0..257).map(|_| rng.normal_f32(0.0, 3.0)).collect();
        assert_eq!(round_trip(&xs, Codec::None), xs);
        assert_eq!(Codec::None.bound(&xs), 0.0);
    }

    #[test]
    fn qint8_constant_tensor_is_exact() {
        let xs = vec![2.5f32; 100];
        assert_eq!(round_trip(&xs, Codec::QInt8), xs);
    }

    #[test]
    fn topk_keeps_largest_magnitudes_exactly() {
        let xs = vec![0.1f32, -5.0, 0.0, 3.0, -0.2, 1.0];
        let back = round_trip(&xs, Codec::TopK(0.34)); // k = ceil(2.04) = 3
        assert_eq!(back, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
        // documented bound: largest dropped magnitude
        assert!((Codec::TopK(0.34).bound(&xs) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn topk_full_fraction_is_lossless() {
        let xs = vec![1.0f32, -2.0, 3.0];
        assert_eq!(round_trip(&xs, Codec::TopK(1.0)), xs);
        assert_eq!(Codec::TopK(1.0).bound(&xs), 0.0);
    }

    #[test]
    fn empty_tensors_round_trip() {
        for codec in ALL_CODECS {
            assert_eq!(round_trip(&[], codec), Vec::<f32>::new());
            assert_eq!(codec.bound(&[]), 0.0);
        }
    }

    #[test]
    fn wire_bytes_shrink() {
        let n = 10_000;
        assert_eq!(Codec::None.wire_bytes(n), 40_000);
        assert_eq!(Codec::Fp16.wire_bytes(n), 20_000);
        assert_eq!(Codec::QInt8.wire_bytes(n), 10_008);
        assert_eq!(Codec::TopK(0.1).wire_bytes(n), 4 + 8 * 1000);
        // ≥ 3.5× for the acceptance pair
        assert!(40_000.0 / Codec::QInt8.wire_bytes(n) as f64 >= 3.5);
        assert!(40_000.0 / Codec::TopK(0.1).wire_bytes(n) as f64 >= 3.5);
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        let xs: Vec<f32> = (0..50).map(|i| i as f32).collect();
        for codec in ALL_CODECS {
            let mut enc = Encoder::new();
            encode_f32s(&mut enc, &xs, codec).unwrap();
            let buf = enc.finish();
            for cut in 0..buf.len() {
                let _ = decode_f32s(&mut Decoder::new(&buf[..cut]));
            }
        }
        // hostile top-k headers
        let mut enc = Encoder::new();
        enc.put_u8(3);
        enc.put_u32(u32::MAX); // dense length way past the cap
        enc.put_u32(0);
        let buf = enc.finish();
        assert!(decode_f32s(&mut Decoder::new(&buf)).is_err());
        let mut enc = Encoder::new();
        enc.put_u8(3);
        enc.put_u32(4);
        enc.put_u32(2);
        enc.put_u32(9); // index out of range
        enc.put_f32(1.0);
        enc.put_u32(1);
        enc.put_f32(1.0);
        let buf = enc.finish();
        assert!(decode_f32s(&mut Decoder::new(&buf)).is_err());
    }
}
