//! The discrete-event core shared by every scheme timeline.
//!
//! One binary-heap event queue ordered by `(virtual_time, seq)` drives
//! the whole round; the scheme policies in [`super`] only decide *what*
//! to enqueue (initial placement, pull vs. assigned refill, comm
//! shape).  Event taxonomy:
//!
//! - `TaskStart`   — an executor begins a client task (straggler
//!   injection and mid-task drop decisions happen here).
//! - `TaskDone`    — compute finished; busy time booked, runtime record
//!   fed back to the scheduler history.
//! - `CommDone`    — a communication leg finished (FA's per-task
//!   upload; the round-tail broadcast/upload chain).
//! - `DeviceJoin`  — an executor slot (re)enters the cluster and starts
//!   pulling work.
//! - `DeviceLeave` — an executor departs mid-round; its in-flight and
//!   queued tasks are orphaned and re-placed on the survivors via the
//!   scheduler's greedy step ([`Scheduler::reassign_orphans`]).
//! - `ClientUnavailable` — a scheduled client vanishes mid-task; the
//!   partial work is wasted and the task is lost (not retried).
//!
//! Stale-event hygiene: every executor carries an `epoch` bumped on
//! departure; task/comm events remember the epoch they were scheduled
//! under and are discarded if it no longer matches (the discrete-event
//! analogue of cancelling a timer).
//!
//! With a fully static [`DynamicsSpec`] the engine reproduces the
//! legacy closed-form per-scheme loops exactly (property-tested in
//! [`super::tests`]): same noise draws, same placements, same totals.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::scheduler::{Scheduler, TaskRecord};
use crate::statestore::StatePlan;
use crate::util::rng::Rng;

use super::availability::{ChurnKind, DynamicsSpec};

/// The event taxonomy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    TaskStart { task: usize, device: usize },
    TaskDone { task: usize, device: usize },
    CommDone { device: usize, bytes: u64 },
    DeviceJoin { device: usize },
    DeviceLeave { device: usize },
    ClientUnavailable { task: usize, device: usize },
}

/// Heap entry: earliest virtual time pops first; ties break by
/// insertion order (`seq`) for determinism.
#[derive(Debug)]
struct Scheduled {
    time: f64,
    seq: u64,
    epoch: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we pop the earliest event.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    Pending,
    Running,
    Done,
    Dropped,
}

/// One client task flowing through the engine.
#[derive(Debug, Clone)]
pub struct SimTask {
    pub client: usize,
    /// Effective samples N_m · E.
    pub n_eff: usize,
    /// Pre-drawn multiplicative measurement-noise factor (clamped to
    /// ≥ 0.2 like the legacy `realize`); drawn at plan time in the
    /// legacy iteration order so static runs reproduce old timelines.
    pub noise: f64,
    /// Scheduler-predicted seconds on the planned device (None during
    /// warm-up / uniform scheduling) — feeds the est-err metric.
    pub predicted: Option<f64>,
    pub state: TaskState,
    /// Realized compute seconds (valid once `Done`).
    pub realized: f64,
}

impl SimTask {
    pub fn new(client: usize, n_eff: usize, noise: f64) -> SimTask {
        SimTask { client, n_eff, noise, predicted: None, state: TaskState::Pending, realized: 0.0 }
    }
}

/// How a freed executor gets its next task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefillPolicy {
    /// Run the pre-assigned per-executor queue only (SP, RW/SD, Parrot).
    Assigned,
    /// Pull the next task from the shared round queue (FA Dist.).
    SharedPull,
}

/// Where a departed executor's orphaned tasks go.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReassignPolicy {
    /// Back onto the front of the shared pull queue (FA Dist.).
    Requeue,
    /// Onto the alive executor with the least projected load (SP, RW/SD).
    LeastLoaded,
    /// Through the scheduler's greedy min-max step over the survivors
    /// (Parrot, Alg. 3); falls back to `LeastLoaded` without a
    /// scheduler or when executor slots don't map 1:1 to devices.
    Greedy,
}

/// Round-tail communication shape (after the compute phase drains).
/// Down and up legs carry distinct byte counts: the broadcast ships raw
/// f32 params while uploads ship the round codec's *encoded* size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TailComm {
    /// No round-tail communication (SP; FA pays per task instead).
    None,
    /// One broadcast down + one serialized upload per *completed task*
    /// into the server NIC (RW/SD: every executor ships its client's
    /// params).
    PerExecutor { down: u64, up: u64 },
    /// One broadcast + one locally-aggregated upload per alive device,
    /// plus the special-params payload (Parrot's hierarchical
    /// aggregation: upload = s_a·K + s_e·M_p, with s_a encoded).
    Hierarchical { s_a_down: u64, s_a_up: u64, s_e_total: u64 },
}

/// What a scheme policy hands the engine for one round.
#[derive(Debug)]
pub struct RoundPlan {
    pub tasks: Vec<SimTask>,
    /// Executor count (SP: 1, RW/SD: M_p, FA/Parrot: K).
    pub n_exec: usize,
    /// Initial alive mask per executor slot (length `n_exec`).
    pub alive: Vec<bool>,
    /// Initial per-executor task queues (`Assigned` refill).
    pub assigned: Vec<Vec<usize>>,
    /// Shared queue order (`SharedPull` refill).
    pub pull: Vec<usize>,
    pub refill: RefillPolicy,
    pub reassign: ReassignPolicy,
    /// Per-task comm seconds serialized on the executor around the
    /// compute (down, up) — FA's params-move-per-task law.
    pub per_task_comm: (f64, f64),
    /// Per-task comm bytes (down, up).
    pub per_task_bytes: (u64, u64),
    pub tail: TailComm,
    /// Per-task `StateLoad` legs + the round-tail `StateFlush` leg from
    /// the client-state store (empty `StatePlan` = no store attached).
    /// With `prefetch` the loads pipeline ahead of execution in task
    /// order; otherwise each load serializes before its task's compute.
    pub state: StatePlan,
    /// Feed completed-task records into the scheduler history and prune
    /// it on departures (Parrot).
    pub record_history: bool,
}

/// Per-executor runtime state.
#[derive(Debug, Clone)]
struct ExecState {
    alive: bool,
    epoch: u64,
    busy: f64,
    comm: f64,
    wasted: f64,
    queue: VecDeque<usize>,
    /// (task, claim/start time, compute duration) — duration 0 until
    /// `TaskStart` actually fires.
    current: Option<(usize, f64, f64)>,
}

/// Everything the round produced.
#[derive(Debug)]
pub struct RoundOutcome {
    pub tasks: Vec<SimTask>,
    /// Per-executor productive compute seconds.
    pub busy: Vec<f64>,
    /// Per-executor per-task comm occupancy seconds.
    pub comm_occ: Vec<f64>,
    /// Virtual time when the compute phase drained.
    pub work_end: f64,
    /// Virtual time after the round-tail comm chain.
    pub end: f64,
    pub bytes: u64,
    pub trips: u64,
    /// Aborted partial compute (departures + mid-task client drops).
    pub wasted_secs: f64,
    pub dropped_tasks: usize,
    pub completed_tasks: usize,
    pub departures: usize,
    pub joins: usize,
    /// Final alive mask (same length as the plan's executor space).
    pub alive: Vec<bool>,
    /// State-movement bytes booked from the plan's `StateLoad`/
    /// `StateFlush` legs.  Every planned leg is booked exactly once —
    /// started or not (prefetch moves bytes ahead of execution) — so
    /// this column equals the state store's own counters on any seed.
    pub state_bytes: u64,
    /// Seconds executors stalled waiting on state loads, plus the
    /// round-tail flush time.
    pub state_secs: f64,
}

struct Core<'a> {
    round: usize,
    cluster: &'a ClusterProfile,
    cost: &'a WorkloadCost,
    dynamics: &'a DynamicsSpec,
    rng: Rng,
    tasks: Vec<SimTask>,
    execs: Vec<ExecState>,
    shared: VecDeque<usize>,
    refill: RefillPolicy,
    reassign: ReassignPolicy,
    comm_down: f64,
    comm_up: f64,
    bytes_down: u64,
    bytes_up: u64,
    state: StatePlan,
    state_booked: Vec<bool>,
    state_bytes: u64,
    state_secs: f64,
    record_history: bool,
    heap: BinaryHeap<Scheduled>,
    now: f64,
    work_end: f64,
    seq: u64,
    bytes: u64,
    trips: u64,
    wasted: f64,
    dropped: usize,
    completed: usize,
    departures: usize,
    joins: usize,
}

impl<'a> Core<'a> {
    fn push(&mut self, time: f64, epoch: u64, event: Event) {
        self.heap.push(Scheduled { time, seq: self.seq, epoch, event });
        self.seq += 1;
    }

    fn alive_count(&self) -> usize {
        self.execs.iter().filter(|e| e.alive).count()
    }

    /// Compute seconds of `task` on executor `slot` (heterogeneity ×
    /// pre-drawn noise; straggler injection is applied at TaskStart).
    fn base_secs(&self, slot: usize, task: usize) -> f64 {
        let t = &self.tasks[task];
        let model = self.cluster.executor_model(slot);
        self.cluster.task_time(self.cost, model, self.round, t.n_eff, 1) * t.noise
    }

    /// Remaining committed seconds on `slot` (in-flight + queued) — the
    /// base load the greedy reassignment step starts from.
    fn projected_load(&self, slot: usize) -> f64 {
        let e = &self.execs[slot];
        let mut load = match e.current {
            Some((_, start, dur)) => {
                (start + self.comm_down + dur + self.comm_up - self.now).max(0.0)
            }
            None => 0.0,
        };
        for &t in &e.queue {
            load += self.base_secs(slot, t) + self.comm_down + self.comm_up;
        }
        load
    }

    /// Claim the next task for `slot` (if idle and alive) and emit its
    /// TaskStart event at the current time.
    fn try_start(&mut self, slot: usize) {
        if !self.execs[slot].alive || self.execs[slot].current.is_some() {
            return;
        }
        let task = match self.refill {
            RefillPolicy::Assigned => self.execs[slot].queue.pop_front(),
            RefillPolicy::SharedPull => self.shared.pop_front(),
        };
        if let Some(task) = task {
            // Claim now so no other same-time event double-assigns.
            self.execs[slot].current = Some((task, self.now, 0.0));
            let epoch = self.execs[slot].epoch;
            self.push(self.now, epoch, Event::TaskStart { task, device: slot });
        }
    }

    /// The state-load stall this task pays before its down leg: with
    /// prefetch, only the slack until the pipelined load is ready; the
    /// leg's bytes are booked here.  Both bytes and stall are paid
    /// exactly once per task — a task re-started after a mid-round
    /// reassignment already has its state in flight (plan-level
    /// accounting), so a second `TaskStart` must not double-charge the
    /// load into `state_secs` or the timeline.
    fn state_stall(&mut self, task: usize) -> f64 {
        if self.state.legs.is_empty() || self.state_booked[task] {
            return 0.0;
        }
        let leg = self.state.legs.get(task).copied().unwrap_or_default();
        self.state_booked[task] = true;
        self.state_bytes += leg.bytes;
        let stall = if self.state.prefetch { (leg.ready - self.now).max(0.0) } else { leg.secs };
        self.state_secs += stall;
        stall
    }

    fn on_task_start(&mut self, slot: usize, task: usize) {
        let mut dur = self.base_secs(slot, task);
        let st = &self.dynamics.straggler;
        if st.prob > 0.0 && self.rng.next_f64() < st.prob {
            dur *= st.law.sample(&mut self.rng);
        }
        let stall = self.state_stall(task);
        self.tasks[task].state = TaskState::Running;
        // The stall shifts the task's effective start so downstream
        // elapsed/projected arithmetic stays exact.
        self.execs[slot].current = Some((task, self.now + stall, dur));
        if self.bytes_down > 0 {
            self.bytes += self.bytes_down;
            self.trips += 1;
        }
        let st = &self.dynamics.straggler;
        let epoch = self.execs[slot].epoch;
        if st.drop_prob > 0.0 && self.rng.next_f64() < st.drop_prob {
            let frac = self.rng.next_f64();
            self.push(
                self.now + stall + self.comm_down + dur * frac,
                epoch,
                Event::ClientUnavailable { task, device: slot },
            );
        } else {
            self.push(
                self.now + stall + self.comm_down + dur,
                epoch,
                Event::TaskDone { task, device: slot },
            );
        }
    }

    fn on_task_done(&mut self, slot: usize, task: usize, sched: &mut Option<&mut Scheduler>) {
        let (_, _, dur) = self.execs[slot].current.expect("TaskDone without a current task");
        self.execs[slot].busy += dur;
        // The down leg has completed by now; the up leg is booked at
        // its own CommDone (a departure mid-upload loses that leg).
        self.execs[slot].comm += self.comm_down;
        self.tasks[task].state = TaskState::Done;
        self.tasks[task].realized = dur;
        self.completed += 1;
        self.work_end = self.now;
        if self.record_history {
            if let Some(s) = sched.as_deref_mut() {
                s.record(TaskRecord {
                    round: self.round,
                    device: slot,
                    n_samples: self.tasks[task].n_eff,
                    secs: dur,
                });
            }
        }
        if self.comm_up > 0.0 || self.bytes_up > 0 {
            let epoch = self.execs[slot].epoch;
            self.push(
                self.now + self.comm_up,
                epoch,
                Event::CommDone { device: slot, bytes: self.bytes_up },
            );
        } else {
            self.execs[slot].current = None;
            self.try_start(slot);
        }
    }

    fn on_comm_done(&mut self, slot: usize, bytes: u64) {
        if bytes > 0 {
            self.bytes += bytes;
            self.trips += 1;
        }
        self.execs[slot].comm += self.comm_up;
        self.work_end = self.now;
        self.execs[slot].current = None;
        self.try_start(slot);
    }

    fn on_client_unavailable(&mut self, slot: usize, task: usize) {
        let (cur, start, _) =
            self.execs[slot].current.take().expect("ClientUnavailable without a current task");
        debug_assert_eq!(cur, task);
        let elapsed = (self.now - start - self.comm_down).max(0.0);
        self.execs[slot].wasted += elapsed;
        self.wasted += elapsed;
        // The down leg did happen (the drop fires during compute).
        self.execs[slot].comm += self.comm_down;
        self.tasks[task].state = TaskState::Dropped;
        self.dropped += 1;
        self.work_end = self.now;
        self.try_start(slot);
    }

    fn on_device_leave(&mut self, slot: usize, sched: &mut Option<&mut Scheduler>) {
        if slot >= self.execs.len() || !self.execs[slot].alive {
            return;
        }
        if self.alive_count() <= 1 {
            // Never orphan the whole round: the last executor stays.
            return;
        }
        self.execs[slot].alive = false;
        self.execs[slot].epoch += 1;
        self.departures += 1;
        let mut orphans: Vec<usize> = Vec::new();
        if let Some((task, start, dur)) = self.execs[slot].current.take() {
            if self.tasks[task].state != TaskState::Done {
                // Abort the in-flight task: partial work is wasted.
                let elapsed =
                    (self.now - start - self.comm_down).max(0.0).min(dur.max(0.0));
                self.execs[slot].wasted += elapsed;
                self.wasted += elapsed;
                self.tasks[task].state = TaskState::Pending;
                orphans.push(task);
            }
            // A Done task whose upload leg was in flight keeps its
            // result (records were piggybacked at TaskDone); only the
            // final comm trip is lost.
        }
        orphans.extend(self.execs[slot].queue.drain(..));
        if self.record_history {
            if let Some(s) = sched.as_deref_mut() {
                s.prune_device(slot);
            }
        }
        self.place_orphans(orphans, sched);
        for s in 0..self.execs.len() {
            self.try_start(s);
        }
    }

    fn on_device_join(&mut self, slot: usize) {
        // Joins re-activate a departed slot. Slots beyond the plan's
        // executor space are ignored: the scheduler's device space is
        // fixed for the run, so a brand-new slot could not persist
        // past this round anyway.
        if slot >= self.execs.len() || self.execs[slot].alive {
            return;
        }
        self.execs[slot].alive = true;
        self.joins += 1;
        self.try_start(slot);
    }

    fn place_orphans(&mut self, orphans: Vec<usize>, sched: &mut Option<&mut Scheduler>) {
        if orphans.is_empty() {
            return;
        }
        let alive: Vec<bool> = self.execs.iter().map(|e| e.alive).collect();
        if !alive.iter().any(|&a| a) {
            for t in orphans {
                self.tasks[t].state = TaskState::Dropped;
                self.dropped += 1;
            }
            return;
        }
        match self.reassign {
            ReassignPolicy::Requeue => {
                for t in orphans.into_iter().rev() {
                    self.shared.push_front(t);
                }
            }
            ReassignPolicy::LeastLoaded => self.place_least_loaded(orphans),
            ReassignPolicy::Greedy => {
                let can_greedy = match sched.as_deref_mut() {
                    Some(s) => s.n_devices() == self.execs.len(),
                    None => false,
                };
                if can_greedy {
                    let items: Vec<(usize, usize)> =
                        orphans.iter().map(|&t| (t, self.tasks[t].n_eff)).collect();
                    let base: Vec<f64> =
                        (0..self.execs.len()).map(|i| self.projected_load(i)).collect();
                    let placed = sched.as_deref_mut().unwrap().reassign_orphans(
                        self.round,
                        &items,
                        &alive,
                        &base,
                    );
                    for (slot, ts) in placed.into_iter().enumerate() {
                        for t in ts {
                            self.execs[slot].queue.push_back(t);
                        }
                    }
                } else {
                    self.place_least_loaded(orphans);
                }
            }
        }
    }

    fn place_least_loaded(&mut self, orphans: Vec<usize>) {
        for t in orphans {
            let mut best = usize::MAX;
            let mut best_load = f64::INFINITY;
            for i in 0..self.execs.len() {
                if !self.execs[i].alive {
                    continue;
                }
                let l = self.projected_load(i);
                if l < best_load {
                    best_load = l;
                    best = i;
                }
            }
            self.execs[best].queue.push_back(t);
        }
    }

    /// The round-tail comm chain, expressed as the serialized CommDone
    /// sequence over the server NIC (bytes/trips booked per leg).
    fn run_tail(&mut self, tail: TailComm, initial_alive: usize) {
        let end = self.work_end;
        let mut t = end;
        match tail {
            TailComm::None => {}
            TailComm::PerExecutor { down, up } => {
                // Broadcast down to every scheduled task's executor.
                let scheduled = self.tasks.len() as u64;
                self.bytes += down * scheduled;
                self.trips += scheduled;
                t += self.cluster.comm_time(down as usize);
                // Uploads (encoded size) serialize into the server NIC.
                let per = self.cluster.latency + up as f64 / self.cluster.bandwidth;
                for _ in 0..self.completed {
                    t += per;
                    self.bytes += up;
                    self.trips += 1;
                }
            }
            TailComm::Hierarchical { s_a_down, s_a_up, s_e_total } => {
                let k_up = self.alive_count() as u64;
                // Broadcast s_a down per initially-alive device.
                self.bytes += s_a_down * initial_alive as u64;
                self.trips += initial_alive as u64;
                t += self.cluster.comm_time(s_a_down as usize);
                // One aggregated (encoded) upload per surviving device:
                // the first pays the full payload time, the rest
                // pipeline behind it at one trip latency each, plus the
                // special-params payload (s_e · M_p) at the end.
                if k_up > 0 {
                    t += self.cluster.comm_time(s_a_up as usize);
                    t += (k_up - 1) as f64 * self.cluster.latency;
                    self.bytes += s_a_up * k_up + s_e_total;
                    self.trips += k_up;
                    if s_e_total > 0 {
                        t += s_e_total as f64 / self.cluster.bandwidth;
                    }
                }
            }
        }
        // StateFlush leg: round-boundary dirty write-back plus remote
        // write-back returns, serialized after the comm tail.
        if self.state.tail_secs > 0.0 || self.state.tail_bytes > 0 {
            t += self.state.tail_secs;
            self.state_secs += self.state.tail_secs;
            self.state_bytes += self.state.tail_bytes;
        }
        // Late churn events may have advanced `now` past the last real
        // work; the round ends when work + tail comm end, not when the
        // last scripted event was probed.
        self.now = t;
    }

    fn run(mut self, tail: TailComm, mut sched: Option<&mut Scheduler>) -> RoundOutcome {
        let initial_alive = self.alive_count();
        for slot in 0..self.execs.len() {
            self.try_start(slot);
        }
        while let Some(s) = self.heap.pop() {
            self.now = self.now.max(s.time);
            match s.event {
                Event::TaskStart { task, device } => {
                    if s.epoch != self.execs[device].epoch || !self.execs[device].alive {
                        continue;
                    }
                    self.on_task_start(device, task);
                }
                Event::TaskDone { task, device } => {
                    if s.epoch != self.execs[device].epoch {
                        continue;
                    }
                    self.on_task_done(device, task, &mut sched);
                }
                Event::CommDone { device, bytes } => {
                    if s.epoch != self.execs[device].epoch {
                        continue;
                    }
                    self.on_comm_done(device, bytes);
                }
                Event::DeviceLeave { device } => self.on_device_leave(device, &mut sched),
                Event::DeviceJoin { device } => self.on_device_join(device),
                Event::ClientUnavailable { task, device } => {
                    if s.epoch != self.execs[device].epoch {
                        continue;
                    }
                    self.on_client_unavailable(device, task);
                }
            }
        }
        // Anything still pending had nowhere to run.
        for t in &mut self.tasks {
            if t.state == TaskState::Pending {
                t.state = TaskState::Dropped;
                self.dropped += 1;
            }
        }
        // Book the legs of tasks that never reached TaskStart: the
        // plan-driven prefetch already moved (and the write-back tail
        // will still flush) their state, so the bytes were spent even
        // though no compute happened — this is what keeps the engine's
        // state column equal to the store's counters under drops.
        if !self.state.legs.is_empty() {
            for t in 0..self.state_booked.len() {
                if !self.state_booked[t] {
                    self.state_booked[t] = true;
                    self.state_bytes += self.state.legs.get(t).map(|l| l.bytes).unwrap_or(0);
                }
            }
        }
        self.run_tail(tail, initial_alive);
        RoundOutcome {
            busy: self.execs.iter().map(|e| e.busy).collect(),
            comm_occ: self.execs.iter().map(|e| e.comm).collect(),
            alive: self.execs.iter().map(|e| e.alive).collect(),
            tasks: self.tasks,
            work_end: self.work_end,
            end: self.now,
            bytes: self.bytes,
            trips: self.trips,
            wasted_secs: self.wasted,
            dropped_tasks: self.dropped,
            completed_tasks: self.completed,
            departures: self.departures,
            joins: self.joins,
            state_bytes: self.state_bytes,
            state_secs: self.state_secs,
        }
    }
}

/// Execute one round of `plan` on the discrete-event core.
///
/// `dyn_seed` seeds the dynamics stream (stragglers, drops, random
/// churn) — a stream separate from the measurement-noise draws so that
/// enabling dynamics never perturbs the base timeline's noise sequence.
pub fn run_round(
    plan: RoundPlan,
    cluster: &ClusterProfile,
    cost: &WorkloadCost,
    round: usize,
    dynamics: &DynamicsSpec,
    dyn_seed: u64,
    scheduler: Option<&mut Scheduler>,
) -> RoundOutcome {
    debug_assert_eq!(plan.alive.len(), plan.n_exec);
    let mut rng = Rng::new(dyn_seed).derive(round as u64);
    let execs: Vec<ExecState> = (0..plan.n_exec)
        .map(|i| ExecState {
            alive: plan.alive[i],
            epoch: 0,
            busy: 0.0,
            comm: 0.0,
            wasted: 0.0,
            queue: plan.assigned.get(i).map(|q| q.iter().cloned().collect()).unwrap_or_default(),
            current: None,
        })
        .collect();

    let n_tasks = plan.tasks.len();
    let mut core = Core {
        round,
        cluster,
        cost,
        dynamics,
        rng: rng.derive(0x57A6),
        tasks: plan.tasks,
        execs,
        shared: plan.pull.into_iter().collect(),
        refill: plan.refill,
        reassign: plan.reassign,
        comm_down: plan.per_task_comm.0,
        comm_up: plan.per_task_comm.1,
        bytes_down: plan.per_task_bytes.0,
        bytes_up: plan.per_task_bytes.1,
        state: plan.state,
        state_booked: vec![false; n_tasks],
        state_bytes: 0,
        state_secs: 0.0,
        record_history: plan.record_history,
        heap: BinaryHeap::new(),
        now: 0.0,
        work_end: 0.0,
        seq: 0,
        bytes: 0,
        trips: 0,
        wasted: 0.0,
        dropped: 0,
        completed: 0,
        departures: 0,
        joins: 0,
    };

    if core.tasks.is_empty() {
        return core.run(TailComm::None, scheduler);
    }

    // Scripted churn for this round.
    for ev in dynamics.churn.scripted(round) {
        let event = match ev.kind {
            ChurnKind::Leave => Event::DeviceLeave { device: ev.device },
            ChurnKind::Join => Event::DeviceJoin { device: ev.device },
        };
        core.push(ev.secs.max(0.0), 0, event);
    }
    // Random churn: departure/rejoin times drawn within a crude
    // makespan estimate so they actually land mid-round.
    if dynamics.churn.leave_prob > 0.0 || dynamics.churn.join_prob > 0.0 {
        let total_base: f64 = core
            .tasks
            .iter()
            .map(|t| (cost.t_sample * t.n_eff as f64 + cost.b_fixed) * t.noise)
            .sum();
        let horizon = total_base / core.alive_count().max(1) as f64;
        for slot in 0..core.execs.len() {
            if core.execs[slot].alive {
                if dynamics.churn.leave_prob > 0.0 && rng.next_f64() < dynamics.churn.leave_prob
                {
                    let t = rng.next_f64() * horizon;
                    core.push(t, 0, Event::DeviceLeave { device: slot });
                }
            } else if dynamics.churn.join_prob > 0.0 && rng.next_f64() < dynamics.churn.join_prob
            {
                let t = rng.next_f64() * horizon;
                core.push(t, 0, Event::DeviceJoin { device: slot });
            }
        }
    }

    core.run(plan.tail, scheduler)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulation::availability::{ChurnEvent, ChurnSpec, SlowdownLaw, StragglerSpec};

    fn static_dynamics() -> DynamicsSpec {
        DynamicsSpec::default()
    }

    fn plan_assigned(n_exec: usize, sizes: &[usize], tail: TailComm) -> RoundPlan {
        let tasks: Vec<SimTask> =
            sizes.iter().enumerate().map(|(i, &n)| SimTask::new(i, n, 1.0)).collect();
        let mut assigned = vec![Vec::new(); n_exec];
        for i in 0..tasks.len() {
            assigned[i % n_exec].push(i);
        }
        RoundPlan {
            tasks,
            n_exec,
            alive: vec![true; n_exec],
            assigned,
            pull: Vec::new(),
            refill: RefillPolicy::Assigned,
            reassign: ReassignPolicy::LeastLoaded,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail,
            state: StatePlan::default(),
            record_history: false,
        }
    }

    fn homo(k: usize) -> ClusterProfile {
        ClusterProfile::homogeneous(k)
    }

    #[test]
    fn serial_executor_sums_durations() {
        let cost = WorkloadCost::femnist();
        let plan = plan_assigned(1, &[100, 200, 300], TailComm::None);
        let out = run_round(plan, &homo(1), &cost, 0, &static_dynamics(), 1, None);
        let want: f64 = [100, 200, 300]
            .iter()
            .map(|&n| cost.t_sample * n as f64 + cost.b_fixed)
            .sum();
        assert!((out.end - want).abs() < 1e-9, "{} vs {want}", out.end);
        assert_eq!(out.completed_tasks, 3);
        assert_eq!(out.busy.len(), 1);
        assert!((out.busy[0] - want).abs() < 1e-9);
    }

    #[test]
    fn parallel_executors_take_makespan() {
        let cost = WorkloadCost::femnist();
        let plan = plan_assigned(3, &[100, 100, 400], TailComm::None);
        let out = run_round(plan, &homo(3), &cost, 0, &static_dynamics(), 1, None);
        let slowest = cost.t_sample * 400.0 + cost.b_fixed;
        assert!((out.end - slowest).abs() < 1e-9);
        assert_eq!(out.busy.len(), 3);
        assert!(out.busy.iter().all(|&b| b > 0.0));
    }

    #[test]
    fn shared_pull_balances_like_earliest_free() {
        let cost = WorkloadCost::femnist();
        let sizes = [500usize, 400, 300, 200, 100, 50];
        let tasks: Vec<SimTask> =
            sizes.iter().enumerate().map(|(i, &n)| SimTask::new(i, n, 1.0)).collect();
        let plan = RoundPlan {
            pull: (0..tasks.len()).collect(),
            tasks,
            n_exec: 2,
            alive: vec![true; 2],
            assigned: vec![Vec::new(); 2],
            refill: RefillPolicy::SharedPull,
            reassign: ReassignPolicy::Requeue,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail: TailComm::None,
            state: StatePlan::default(),
            record_history: false,
        };
        let out = run_round(plan, &homo(2), &cost, 0, &static_dynamics(), 1, None);
        // Greedy earliest-free replay: dev0 <- 500, dev1 <- 400; dev1
        // frees first and pulls 300, etc.
        let d = |n: usize| cost.t_sample * n as f64 + cost.b_fixed;
        let mut free = [0.0f64; 2];
        for &n in &sizes {
            let i = if free[0] <= free[1] { 0 } else { 1 };
            free[i] += d(n);
        }
        let want = free[0].max(free[1]);
        assert!((out.end - want).abs() < 1e-9, "{} vs {}", out.end, want);
        assert_eq!(out.completed_tasks, sizes.len());
    }

    #[test]
    fn device_leave_reassigns_orphans_and_all_tasks_finish() {
        let cost = WorkloadCost::femnist();
        let mut plan = plan_assigned(4, &[300; 12], TailComm::None);
        plan.reassign = ReassignPolicy::LeastLoaded;
        let dynamics = DynamicsSpec {
            churn: ChurnSpec {
                events: vec![ChurnEvent {
                    round: 0,
                    device: 0,
                    secs: 0.1,
                    kind: ChurnKind::Leave,
                }],
                leave_prob: 0.0,
                join_prob: 0.0,
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(4), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.departures, 1);
        assert_eq!(out.dropped_tasks, 0, "orphans must be re-placed");
        assert_eq!(out.completed_tasks, 12);
        assert!(!out.alive[0] && out.alive[1]);
        // the dead device stops accruing busy time, the rest absorb it
        let survivors: f64 = out.busy[1..].iter().sum();
        assert!(survivors > out.busy[0], "{:?}", out.busy);
        assert!(out.wasted_secs >= 0.0);
    }

    #[test]
    fn device_join_pulls_shared_work() {
        let cost = WorkloadCost::femnist();
        let sizes = vec![400usize; 8];
        let tasks: Vec<SimTask> =
            sizes.iter().enumerate().map(|(i, &n)| SimTask::new(i, n, 1.0)).collect();
        let plan = RoundPlan {
            pull: (0..tasks.len()).collect(),
            tasks,
            n_exec: 2,
            alive: vec![true, false],
            assigned: vec![Vec::new(); 2],
            refill: RefillPolicy::SharedPull,
            reassign: ReassignPolicy::Requeue,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail: TailComm::None,
            state: StatePlan::default(),
            record_history: false,
        };
        let dynamics = DynamicsSpec {
            churn: ChurnSpec {
                events: vec![ChurnEvent {
                    round: 0,
                    device: 1,
                    secs: 0.0,
                    kind: ChurnKind::Join,
                }],
                leave_prob: 0.0,
                join_prob: 0.0,
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(2), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.joins, 1);
        assert_eq!(out.completed_tasks, 8);
        assert!(out.busy[1] > 0.0, "joined device must have worked: {:?}", out.busy);
    }

    #[test]
    fn client_drop_wastes_partial_work() {
        let cost = WorkloadCost::femnist();
        let plan = plan_assigned(2, &[500; 10], TailComm::None);
        let dynamics = DynamicsSpec {
            straggler: StragglerSpec {
                prob: 0.0,
                law: SlowdownLaw::Fixed(1.0),
                drop_prob: 1.0, // every client vanishes mid-task
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(2), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.dropped_tasks, 10);
        assert_eq!(out.completed_tasks, 0);
        assert!(out.wasted_secs > 0.0);
        assert!(out.busy.iter().all(|&b| b == 0.0), "dropped work is not busy time");
    }

    #[test]
    fn stragglers_stretch_the_round() {
        let cost = WorkloadCost::femnist();
        let base = run_round(
            plan_assigned(2, &[300; 8], TailComm::None),
            &homo(2),
            &cost,
            0,
            &static_dynamics(),
            1,
            None,
        );
        let dynamics = DynamicsSpec {
            straggler: StragglerSpec { prob: 1.0, law: SlowdownLaw::Fixed(4.0), drop_prob: 0.0 },
            ..Default::default()
        };
        let slow = run_round(
            plan_assigned(2, &[300; 8], TailComm::None),
            &homo(2),
            &cost,
            0,
            &dynamics,
            1,
            None,
        );
        assert!((slow.end - 4.0 * base.end).abs() < 1e-9, "{} vs {}", slow.end, base.end);
    }

    #[test]
    fn last_executor_never_leaves() {
        let cost = WorkloadCost::femnist();
        let plan = plan_assigned(1, &[100; 3], TailComm::None);
        let dynamics = DynamicsSpec {
            churn: ChurnSpec {
                events: vec![ChurnEvent {
                    round: 0,
                    device: 0,
                    secs: 0.0,
                    kind: ChurnKind::Leave,
                }],
                leave_prob: 0.0,
                join_prob: 0.0,
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(1), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.departures, 0);
        assert_eq!(out.completed_tasks, 3);
    }

    #[test]
    fn state_loads_serialize_without_prefetch() {
        use crate::statestore::StateLeg;
        let cost = WorkloadCost::femnist();
        let compute = cost.t_sample * 200.0 + cost.b_fixed;
        let mut plan = plan_assigned(1, &[200, 200], TailComm::None);
        plan.state = StatePlan {
            legs: vec![
                StateLeg { bytes: 1000, secs: 0.5, ready: 0.5 },
                StateLeg { bytes: 2000, secs: 0.5, ready: 1.0 },
            ],
            prefetch: false,
            tail_bytes: 0,
            tail_secs: 0.0,
        };
        let out = run_round(plan, &homo(1), &cost, 0, &static_dynamics(), 1, None);
        assert!((out.end - (2.0 * compute + 1.0)).abs() < 1e-9, "{}", out.end);
        assert_eq!(out.state_bytes, 3000);
        assert!((out.state_secs - 1.0).abs() < 1e-9);
        // Load stalls are neither busy compute nor comm occupancy.
        assert!((out.busy[0] - 2.0 * compute).abs() < 1e-9);
        assert_eq!(out.completed_tasks, 2);
    }

    #[test]
    fn prefetch_pipelines_loads_behind_compute() {
        use crate::statestore::StateLeg;
        let cost = WorkloadCost::femnist();
        let compute = cost.t_sample * 200.0 + cost.b_fixed; // 0.55s
        let mut plan = plan_assigned(1, &[200, 200], TailComm::None);
        // Channel: first load ready at 0.3, second at 0.6 — the second
        // finishes while task 1 computes, so only the initial 0.3 stalls.
        plan.state = StatePlan {
            legs: vec![
                StateLeg { bytes: 10, secs: 0.3, ready: 0.3 },
                StateLeg { bytes: 10, secs: 0.3, ready: 0.6 },
            ],
            prefetch: true,
            tail_bytes: 0,
            tail_secs: 0.0,
        };
        let out = run_round(plan, &homo(1), &cost, 0, &static_dynamics(), 1, None);
        assert!(
            (out.end - (0.3 + 2.0 * compute)).abs() < 1e-9,
            "prefetch must hide the second load: {} vs {}",
            out.end,
            0.3 + 2.0 * compute
        );
        assert!((out.state_secs - 0.3).abs() < 1e-9);
        assert_eq!(out.state_bytes, 20);
    }

    #[test]
    fn state_flush_tail_extends_round_and_books_bytes() {
        let cost = WorkloadCost::femnist();
        let mut plan = plan_assigned(2, &[100, 100], TailComm::None);
        plan.state = StatePlan {
            legs: vec![Default::default(); 2],
            prefetch: true,
            tail_bytes: 4096,
            tail_secs: 0.25,
        };
        let base = run_round(
            plan_assigned(2, &[100, 100], TailComm::None),
            &homo(2),
            &cost,
            0,
            &static_dynamics(),
            1,
            None,
        );
        let out = run_round(plan, &homo(2), &cost, 0, &static_dynamics(), 1, None);
        assert!((out.end - (base.end + 0.25)).abs() < 1e-9);
        assert_eq!(out.state_bytes, 4096);
    }

    #[test]
    fn dropped_tasks_still_book_planned_state_bytes() {
        use crate::statestore::StateLeg;
        let cost = WorkloadCost::femnist();
        let mut plan = plan_assigned(2, &[300; 6], TailComm::None);
        plan.state = StatePlan {
            legs: vec![StateLeg { bytes: 100, secs: 0.0, ready: 0.0 }; 6],
            prefetch: true,
            tail_bytes: 50,
            tail_secs: 0.0,
        };
        let dynamics = DynamicsSpec {
            straggler: StragglerSpec {
                prob: 0.0,
                law: SlowdownLaw::Fixed(1.0),
                drop_prob: 1.0, // every client vanishes mid-task
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(2), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.dropped_tasks, 6);
        assert_eq!(
            out.state_bytes,
            6 * 100 + 50,
            "prefetched bytes are spent whether or not the task survives"
        );
    }

    #[test]
    fn per_task_comm_occupies_but_is_not_busy() {
        let cost = WorkloadCost::femnist();
        let mut plan = plan_assigned(2, &[200; 4], TailComm::None);
        plan.per_task_comm = (0.5, 0.5);
        plan.per_task_bytes = (10, 10);
        let out = run_round(plan, &homo(2), &cost, 0, &static_dynamics(), 1, None);
        let compute = cost.t_sample * 200.0 + cost.b_fixed;
        // two tasks per device, each occupying compute + 1s comm
        assert!((out.end - 2.0 * (compute + 1.0)).abs() < 1e-9);
        assert!((out.busy[0] - 2.0 * compute).abs() < 1e-9);
        assert!((out.comm_occ[0] - 2.0).abs() < 1e-9);
        assert_eq!(out.bytes, 4 * 20);
        assert_eq!(out.trips, 8);
    }
}
