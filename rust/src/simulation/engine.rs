//! The discrete-event core shared by every scheme timeline.
//!
//! One binary-heap event queue ordered by `(virtual_time, seq)` drives
//! the whole round; the scheme policies in [`super`] only decide *what*
//! to enqueue (initial placement, pull vs. assigned refill, comm
//! shape).  Event taxonomy:
//!
//! - `TaskStart`   — an executor begins a client task (straggler
//!   injection and mid-task drop decisions happen here).
//! - `TaskDone`    — compute finished; busy time booked, runtime record
//!   fed back to the scheduler history.
//! - `CommDone`    — a communication leg finished (FA's per-task
//!   upload; the round-tail broadcast/upload chain).
//! - `DeviceJoin`  — an executor slot (re)enters the cluster and starts
//!   pulling work.
//! - `DeviceLeave` — an executor departs mid-round; its in-flight and
//!   queued tasks are orphaned and re-placed on the survivors via the
//!   scheduler's greedy step ([`Scheduler::reassign_orphans`]).
//! - `ClientUnavailable` — a scheduled client vanishes mid-task; the
//!   partial work is wasted and the task is lost (not retried).
//!
//! Stale-event hygiene: every executor carries an `epoch` bumped on
//! departure; task/comm events remember the epoch they were scheduled
//! under and are discarded if it no longer matches (the discrete-event
//! analogue of cancelling a timer).
//!
//! With a fully static [`DynamicsSpec`] the engine reproduces the
//! legacy closed-form per-scheme loops exactly (property-tested in
//! [`super::tests`]): same noise draws, same placements, same totals.
//!
//! ## Group-sharded execution (`--threads N`)
//!
//! Grouped plans (`Assigned` refill + a [`TailComm::Tiered`] tail with
//! more than one leaf group) run one event-heap *shard per leaf group*:
//! all intra-round interaction (task starts, per-task comm, churn
//! orphaning) is confined to a group, and the only cross-WAN
//! interaction is the tiered round tail, which starts strictly after
//! every shard's compute phase has drained.  That tail is the
//! conservative lookahead barrier: a shard may advance freely to the
//! end of its own timeline because the earliest possible cross-WAN
//! event — the tier merge — cannot precede `max(shard work_end)`, and
//! no shard observes a cross-WAN event before that barrier time.
//!
//! Determinism is by construction, not by locking: the *same* sharded
//! algorithm runs at every `--threads N` (threads only bounds the
//! worker pool), each shard owns a disjoint slice of executors/tasks
//! with its own derived RNG stream and a namespaced event-sequence
//! counter (`seq = shard + k·n_shards`), and shard results merge in
//! shard-index order — so per-shard queues recombine on
//! `(virtual_time, global_seq)` exactly as the single heap orders
//! [`Scheduled`], and same seed ≡ same trace holds for any thread
//! count (pinned by `tests/determinism.rs` and the
//! `prop_sharded_pop_sequence_is_thread_invariant` property).
//!
//! Shard-local couplings, by design: orphan reassignment stays inside
//! the departing executor's group (`Greedy` degrades to the
//! least-loaded rule over the group's survivors), and the
//! "last executor never leaves" guard is per group — a leaf group
//! never fully dies mid-round.  Flat, shared-pull, and async plans are
//! untouched and run the legacy single-heap path.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::obs::{Ev, EvKind, Track};
use crate::scheduler::{Scheduler, TaskRecord};
use crate::statestore::StatePlan;
use crate::util::rng::Rng;

use super::availability::{ChurnKind, DynamicsSpec};

/// The event taxonomy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    TaskStart { task: usize, device: usize },
    TaskDone { task: usize, device: usize },
    CommDone { device: usize, bytes: u64 },
    DeviceJoin { device: usize },
    DeviceLeave { device: usize },
    ClientUnavailable { task: usize, device: usize },
    /// A buffered-aggregation flush chain finished on the server NIC
    /// (async scheme only — the work-conserving dispatcher's analogue
    /// of the round-tail `CommDone` chain).
    FlushDone,
}

// Typed trace events ([`crate::obs::Ev`]) replace the old bare
// `(time, seq, discriminant)` pop log: handlers emit spans/instants
// keyed by the emitting pop's `(time bits, namespaced seq)`, so
// per-shard buffers still merge on exactly the order the single heap
// would pop — same merge law, but the rows now carry what happened
// (task/comm/state spans) instead of just that something popped.

/// A scheduler-history side effect raised during a shard's event phase.
/// Workers cannot share `&mut Scheduler`, so sharded cores buffer these
/// tagged with `(virtual_time, global_seq)` and the merge step applies
/// them in global event order — per-device subsequences (all ops of a
/// device come from its own shard) land in the same relative order the
/// single-heap path would produce.
#[derive(Debug)]
enum HistOp {
    Record(TaskRecord),
    Prune(usize),
}

/// Heap entry: earliest virtual time pops first; ties break by
/// insertion order (`seq`) for determinism.
#[derive(Debug)]
struct Scheduled {
    time: f64,
    seq: u64,
    epoch: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we pop the earliest event.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    Pending,
    Running,
    Done,
    Dropped,
}

/// One client task flowing through the engine.  Plan builders still
/// construct tasks one at a time through this row view; the engine
/// stores them columnar in a [`TaskTable`].
#[derive(Debug, Clone, Copy)]
pub struct SimTask {
    pub client: usize,
    /// Effective samples N_m · E.
    pub n_eff: usize,
    /// Pre-drawn multiplicative measurement-noise factor (clamped to
    /// ≥ 0.2 like the legacy `realize`); drawn at plan time in the
    /// legacy iteration order so static runs reproduce old timelines.
    pub noise: f64,
    /// Scheduler-predicted seconds on the planned device (None during
    /// warm-up / uniform scheduling) — feeds the est-err metric.
    pub predicted: Option<f64>,
    pub state: TaskState,
    /// Realized compute seconds (valid once `Done`).
    pub realized: f64,
}

impl SimTask {
    pub fn new(client: usize, n_eff: usize, noise: f64) -> SimTask {
        SimTask { client, n_eff, noise, predicted: None, state: TaskState::Pending, realized: 0.0 }
    }
}

/// Struct-of-arrays task storage: [`SimTask`]'s fields as parallel
/// columns indexed by dense task id.  The megascale layout — one
/// 100k-task round is six flat allocations instead of 100k heap
/// objects, shards borrow the immutable columns instead of cloning
/// their slice of tasks, and the mutable columns (`state`, `realized`)
/// are the only per-round scratch.
#[derive(Debug, Clone, Default)]
pub struct TaskTable {
    pub client: Vec<usize>,
    pub n_eff: Vec<usize>,
    pub noise: Vec<f64>,
    pub predicted: Vec<Option<f64>>,
    pub state: Vec<TaskState>,
    pub realized: Vec<f64>,
}

impl TaskTable {
    pub fn new() -> TaskTable {
        TaskTable::default()
    }

    pub fn with_capacity(n: usize) -> TaskTable {
        TaskTable {
            client: Vec::with_capacity(n),
            n_eff: Vec::with_capacity(n),
            noise: Vec::with_capacity(n),
            predicted: Vec::with_capacity(n),
            state: Vec::with_capacity(n),
            realized: Vec::with_capacity(n),
        }
    }

    pub fn len(&self) -> usize {
        self.client.len()
    }

    pub fn is_empty(&self) -> bool {
        self.client.is_empty()
    }

    /// Append one task; returns its dense id.
    pub fn push(&mut self, t: SimTask) -> usize {
        let id = self.client.len();
        self.client.push(t.client);
        self.n_eff.push(t.n_eff);
        self.noise.push(t.noise);
        self.predicted.push(t.predicted);
        self.state.push(t.state);
        self.realized.push(t.realized);
        id
    }

    /// Row view of task `i` (copies the scalars out of the columns).
    pub fn row(&self, i: usize) -> SimTask {
        SimTask {
            client: self.client[i],
            n_eff: self.n_eff[i],
            noise: self.noise[i],
            predicted: self.predicted[i],
            state: self.state[i],
            realized: self.realized[i],
        }
    }

    /// Iterate row views in task-id order.
    pub fn rows(&self) -> impl Iterator<Item = SimTask> + '_ {
        (0..self.len()).map(|i| self.row(i))
    }

    /// Re-attach the engine's mutable columns (`state`, `realized`)
    /// to this table's immutable ones.  The engine borrows the
    /// immutable columns for the round and hands back only what it
    /// mutated; this stitches the full table together for the outcome.
    pub fn restore(mut self, run: TaskTable) -> TaskTable {
        debug_assert_eq!(run.state.len(), self.len());
        debug_assert_eq!(run.realized.len(), self.len());
        self.state = run.state;
        self.realized = run.realized;
        self
    }
}

impl FromIterator<SimTask> for TaskTable {
    fn from_iter<I: IntoIterator<Item = SimTask>>(iter: I) -> TaskTable {
        let it = iter.into_iter();
        let mut t = TaskTable::with_capacity(it.size_hint().0);
        for task in it {
            t.push(task);
        }
        t
    }
}

/// How a freed executor gets its next task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefillPolicy {
    /// Run the pre-assigned per-executor queue only (SP, RW/SD, Parrot).
    Assigned,
    /// Pull the next task from the shared round queue (FA Dist.).
    SharedPull,
}

/// Where a departed executor's orphaned tasks go.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReassignPolicy {
    /// Back onto the front of the shared pull queue (FA Dist.).
    Requeue,
    /// Onto the alive executor with the least projected load (SP, RW/SD).
    LeastLoaded,
    /// Through the scheduler's greedy min-max step over the survivors
    /// (Parrot, Alg. 3); falls back to `LeastLoaded` without a
    /// scheduler or when executor slots don't map 1:1 to devices.
    Greedy,
}

/// Round-tail communication shape (after the compute phase drains).
/// Down and up legs carry distinct byte counts: the broadcast ships raw
/// f32 params while uploads ship the round codec's *encoded* size.
#[derive(Debug, Clone, PartialEq)]
pub enum TailComm {
    /// No round-tail communication (SP; FA pays per task instead).
    None,
    /// One broadcast down + one serialized upload per *completed task*
    /// into the server NIC (RW/SD: every executor ships its client's
    /// params).
    PerExecutor { down: u64, up: u64 },
    /// One broadcast + one locally-aggregated upload per alive device,
    /// plus the special-params payload (Parrot's hierarchical
    /// aggregation: upload = s_a·K + s_e·M_p, with s_a encoded).  Every
    /// leg is root-adjacent, so the whole tail books as cross-group
    /// (WAN) bytes — the flat baseline the `--topology` sweeps compare
    /// against.
    Hierarchical { s_a_down: u64, s_a_up: u64, s_e_total: u64 },
    /// Multi-level hierarchical aggregation over a grouped topology
    /// (`--topology groups:G | tree:SPEC`): member devices merge into
    /// their leaf-group aggregator over the LAN (group tail bursts
    /// overlap across groups), intermediate tiers merge upward, and
    /// only the root-adjacent aggregates serialize into the server NIC
    /// over the WAN.
    Tiered(TieredTail),
}

/// The grouped tail's shape and links (see [`TailComm::Tiered`]).
///
/// Pricing model: the down broadcast is one multicast wave per level
/// (WAN hop, then LAN relays); the up path serializes children into
/// each parent's NIC (first pays the full payload, the rest pipeline at
/// one trip latency each — the same law as the flat hierarchical tail)
/// with sibling parents overlapping; the root-adjacent chain plus the
/// uncompressible special-params payload ride the WAN.  Leaf-group
/// liveness is exact (churn-aware); the special-params transfer time is
/// charged on the WAN leg only (the bottleneck), though its bytes are
/// metered on every hop.
#[derive(Debug, Clone, PartialEq)]
pub struct TieredTail {
    pub s_a_down: u64,
    pub s_a_up: u64,
    pub s_e_total: u64,
    /// Leaf-group id per executor slot.
    pub group_of: Vec<usize>,
    /// Leaf-group count (== `levels.iter().product()`).
    pub n_groups: usize,
    /// Per-level fanouts from the server down (len = tree depth ≥ 1).
    pub levels: Vec<usize>,
    /// Root-adjacent (WAN) link.
    pub wan_bandwidth: f64,
    pub wan_latency: f64,
    /// Intra-group (LAN) link — the cluster's base link.
    pub lan_bandwidth: f64,
    pub lan_latency: f64,
}

/// What a scheme policy hands the engine for one round.
#[derive(Debug)]
pub struct RoundPlan {
    pub tasks: TaskTable,
    /// Executor count (SP: 1, RW/SD: M_p, FA/Parrot: K).
    pub n_exec: usize,
    /// Initial alive mask per executor slot (length `n_exec`).
    pub alive: Vec<bool>,
    /// Initial per-executor task queues (`Assigned` refill).
    pub assigned: Vec<Vec<usize>>,
    /// Shared queue order (`SharedPull` refill).
    pub pull: Vec<usize>,
    pub refill: RefillPolicy,
    pub reassign: ReassignPolicy,
    /// Per-task comm seconds serialized on the executor around the
    /// compute (down, up) — FA's params-move-per-task law.
    pub per_task_comm: (f64, f64),
    /// Per-task comm bytes (down, up).
    pub per_task_bytes: (u64, u64),
    pub tail: TailComm,
    /// Per-task `StateLoad` legs + the round-tail `StateFlush` leg from
    /// the client-state store (empty `StatePlan` = no store attached).
    /// With `prefetch` the loads pipeline ahead of execution in task
    /// order; otherwise each load serializes before its task's compute.
    pub state: StatePlan,
    /// Feed completed-task records into the scheduler history and prune
    /// it on departures (Parrot).
    pub record_history: bool,
}

/// Per-executor runtime state.
#[derive(Debug, Clone)]
struct ExecState {
    alive: bool,
    epoch: u64,
    busy: f64,
    comm: f64,
    wasted: f64,
    queue: VecDeque<usize>,
    /// (task, claim/start time, compute duration) — duration 0 until
    /// `TaskStart` actually fires.
    current: Option<(usize, f64, f64)>,
}

/// Everything the round produced.
#[derive(Debug)]
pub struct RoundOutcome {
    pub tasks: TaskTable,
    /// Heap pops handled this round (deterministic event throughput
    /// numerator for the megascale events/sec column).
    pub events: u64,
    /// Per-executor productive compute seconds.
    pub busy: Vec<f64>,
    /// Per-executor per-task comm occupancy seconds.
    pub comm_occ: Vec<f64>,
    /// Virtual time when the compute phase drained.
    pub work_end: f64,
    /// Virtual time after the round-tail comm chain.
    pub end: f64,
    pub bytes: u64,
    pub trips: u64,
    /// Aborted partial compute (departures + mid-task client drops).
    pub wasted_secs: f64,
    pub dropped_tasks: usize,
    pub completed_tasks: usize,
    pub departures: usize,
    pub joins: usize,
    /// Final alive mask (same length as the plan's executor space).
    pub alive: Vec<bool>,
    /// State-movement bytes booked from the plan's `StateLoad`/
    /// `StateFlush` legs.  Every planned leg is booked exactly once —
    /// started or not (prefetch moves bytes ahead of execution) — so
    /// this column equals the state store's own counters on any seed.
    pub state_bytes: u64,
    /// Seconds executors stalled waiting on state loads, plus the
    /// round-tail flush time.
    pub state_secs: f64,
    /// Bytes that crossed the root-adjacent (WAN) links in the round
    /// tail.  Flat hierarchical tails book every leg here (device↔server
    /// is root-adjacent); grouped tails book only the top-tier legs —
    /// the cross-WAN-shrinkage metric of the `--topology` sweeps.
    pub cross_group_bytes: u64,
    /// Aggregates the server merged in the tail (alive devices for the
    /// flat tail, root-adjacent groups for a tiered one).
    pub group_aggs: usize,
}

struct Core<'a> {
    round: usize,
    cluster: &'a ClusterProfile,
    cost: &'a WorkloadCost,
    dynamics: &'a DynamicsSpec,
    rng: Rng,
    /// Immutable task columns, borrowed from the round's [`TaskTable`]
    /// (global task-id space; shard cores index them through `ids`).
    clients: &'a [usize],
    n_effs: &'a [usize],
    noises: &'a [f64],
    /// Local→global task-id map for shard cores (`None` = identity:
    /// the single-heap path and the merge parent run in global ids).
    ids: Option<&'a [usize]>,
    /// Mutable task columns, owned for the round (local id space).
    task_state: Vec<TaskState>,
    task_realized: Vec<f64>,
    execs: Vec<ExecState>,
    /// Incrementally-maintained alive-executor count (kept in lockstep
    /// with `execs[..].alive` by DeviceJoin/DeviceLeave).
    alive: usize,
    shared: VecDeque<usize>,
    refill: RefillPolicy,
    reassign: ReassignPolicy,
    comm_down: f64,
    comm_up: f64,
    bytes_down: u64,
    bytes_up: u64,
    /// Per-task `StateLoad` legs, global task-id indexed (borrowed from
    /// the plan; shard cores read through `ids`).
    state_legs: &'a [StateLeg],
    state_prefetch: bool,
    /// Round-tail `StateFlush` leg (priced once, by whoever runs the
    /// tail — zeroed on shard cores).
    state_tail_bytes: u64,
    state_tail_secs: f64,
    state_booked: Vec<bool>,
    state_bytes: u64,
    state_secs: f64,
    record_history: bool,
    heap: BinaryHeap<Scheduled>,
    now: f64,
    work_end: f64,
    seq: u64,
    /// Sequence-number stride: 1 for the single-heap path; `n_shards`
    /// for a shard core (seq starts at the shard id), so merged shard
    /// sequences interleave without collisions.
    seq_stride: u64,
    /// `Some` on shard cores: scheduler-history ops buffered for the
    /// post-join merge instead of applied live.
    sched_ops: Option<Vec<(f64, u64, HistOp)>>,
    /// Typed event sink (None = tracing off, pure branch cost).
    trace: Option<Vec<Ev>>,
    /// The current pop's `(time bits, seq)` — the deterministic order
    /// key stamped onto every event emitted while handling it.
    key: (u64, u64),
    bytes: u64,
    trips: u64,
    cross_bytes: u64,
    group_aggs: usize,
    wasted: f64,
    dropped: usize,
    completed: usize,
    departures: usize,
    joins: usize,
    /// Heap pops handled (the deterministic events/sec numerator).
    events: u64,
}

impl<'a> Core<'a> {
    /// Global task id for local id `t`.
    #[inline]
    fn gid(&self, t: usize) -> usize {
        match self.ids {
            Some(m) => m[t],
            None => t,
        }
    }

    fn push(&mut self, time: f64, epoch: u64, event: Event) {
        self.heap.push(Scheduled { time, seq: self.seq, epoch, event });
        self.seq += self.seq_stride;
    }

    /// Record a span (`t1 > t0`) or instant under the current pop key.
    fn emit(&mut self, t0: f64, t1: f64, track: Track, kind: EvKind) {
        if let Some(tr) = self.trace.as_mut() {
            tr.push(Ev { at: self.key.0, seq: self.key.1, t0, t1, track, kind });
        }
    }

    /// O(devices) reference scan for the incremental `alive` counter —
    /// kept only as the regression-test oracle (the counter replaced it
    /// on the per-event paths).
    #[cfg(test)]
    fn alive_scan(&self) -> usize {
        self.execs.iter().filter(|e| e.alive).count()
    }

    /// Compute seconds of `task` on executor `slot` (heterogeneity ×
    /// pre-drawn noise; straggler injection is applied at TaskStart).
    fn base_secs(&self, slot: usize, task: usize) -> f64 {
        let g = self.gid(task);
        let model = self.cluster.executor_model(slot);
        self.cluster.task_time(self.cost, model, self.round, self.n_effs[g], 1) * self.noises[g]
    }

    /// Remaining committed seconds on `slot` (in-flight + queued) — the
    /// base load the greedy reassignment step starts from.
    fn projected_load(&self, slot: usize) -> f64 {
        let e = &self.execs[slot];
        let mut load = match e.current {
            Some((_, start, dur)) => {
                (start + self.comm_down + dur + self.comm_up - self.now).max(0.0)
            }
            None => 0.0,
        };
        for &t in &e.queue {
            load += self.base_secs(slot, t) + self.comm_down + self.comm_up;
        }
        load
    }

    /// Claim the next task for `slot` (if idle and alive) and emit its
    /// TaskStart event at the current time.
    fn try_start(&mut self, slot: usize) {
        if !self.execs[slot].alive || self.execs[slot].current.is_some() {
            return;
        }
        let task = match self.refill {
            RefillPolicy::Assigned => self.execs[slot].queue.pop_front(),
            RefillPolicy::SharedPull => self.shared.pop_front(),
        };
        if let Some(task) = task {
            // Claim now so no other same-time event double-assigns.
            self.execs[slot].current = Some((task, self.now, 0.0));
            let epoch = self.execs[slot].epoch;
            self.push(self.now, epoch, Event::TaskStart { task, device: slot });
        }
    }

    /// The state-load stall this task pays before its down leg: with
    /// prefetch, only the slack until the pipelined load is ready; the
    /// leg's bytes are booked here.  Both bytes and stall are paid
    /// exactly once per task — a task re-started after a mid-round
    /// reassignment already has its state in flight (plan-level
    /// accounting), so a second `TaskStart` must not double-charge the
    /// load into `state_secs` or the timeline.
    fn state_stall(&mut self, task: usize) -> f64 {
        if self.state_legs.is_empty() || self.state_booked[task] {
            return 0.0;
        }
        let leg = self.state_legs.get(self.gid(task)).copied().unwrap_or_default();
        self.state_booked[task] = true;
        self.state_bytes += leg.bytes;
        let stall = if self.state_prefetch { (leg.ready - self.now).max(0.0) } else { leg.secs };
        self.state_secs += stall;
        stall
    }

    fn on_task_start(&mut self, slot: usize, task: usize) {
        let mut dur = self.base_secs(slot, task);
        let st = &self.dynamics.straggler;
        if st.prob > 0.0 && self.rng.next_f64() < st.prob {
            dur *= st.law.sample(&mut self.rng);
        }
        let stall = self.state_stall(task);
        self.task_state[task] = TaskState::Running;
        // The stall shifts the task's effective start so downstream
        // elapsed/projected arithmetic stays exact.
        self.execs[slot].current = Some((task, self.now + stall, dur));
        if stall > 0.0 {
            self.emit(
                self.now,
                self.now + stall,
                Track::Device(slot),
                EvKind::StateLoad { clients: 1 },
            );
        }
        if self.comm_down > 0.0 {
            let (t0, bytes) = (self.now + stall, self.bytes_down);
            self.emit(
                t0,
                t0 + self.comm_down,
                Track::Net(slot),
                EvKind::CommDown { task, bytes },
            );
        }
        if self.bytes_down > 0 {
            self.bytes += self.bytes_down;
            self.trips += 1;
        }
        let st = &self.dynamics.straggler;
        let epoch = self.execs[slot].epoch;
        if st.drop_prob > 0.0 && self.rng.next_f64() < st.drop_prob {
            let frac = self.rng.next_f64();
            self.push(
                self.now + stall + self.comm_down + dur * frac,
                epoch,
                Event::ClientUnavailable { task, device: slot },
            );
        } else {
            self.push(
                self.now + stall + self.comm_down + dur,
                epoch,
                Event::TaskDone { task, device: slot },
            );
        }
    }

    fn on_task_done(&mut self, slot: usize, task: usize, sched: &mut Option<&mut Scheduler>) {
        let (_, _, dur) = self.execs[slot].current.expect("TaskDone without a current task");
        self.execs[slot].busy += dur;
        // The down leg has completed by now; the up leg is booked at
        // its own CommDone (a departure mid-upload loses that leg).
        self.execs[slot].comm += self.comm_down;
        self.task_state[task] = TaskState::Done;
        self.task_realized[task] = dur;
        self.completed += 1;
        self.work_end = self.now;
        let client = self.clients[self.gid(task)];
        self.emit(self.now - dur, self.now, Track::Device(slot), EvKind::Task { task, client });
        if self.record_history {
            let rec = TaskRecord {
                round: self.round,
                device: slot,
                n_samples: self.n_effs[self.gid(task)],
                secs: dur,
            };
            if let Some(buf) = self.sched_ops.as_mut() {
                buf.push((self.now, self.seq, HistOp::Record(rec)));
            } else if let Some(s) = sched.as_deref_mut() {
                s.record(rec);
            }
        }
        if self.comm_up > 0.0 || self.bytes_up > 0 {
            self.emit(
                self.now,
                self.now + self.comm_up,
                Track::Net(slot),
                EvKind::CommUp { task, bytes: self.bytes_up },
            );
            let epoch = self.execs[slot].epoch;
            self.push(
                self.now + self.comm_up,
                epoch,
                Event::CommDone { device: slot, bytes: self.bytes_up },
            );
        } else {
            self.execs[slot].current = None;
            self.try_start(slot);
        }
    }

    fn on_comm_done(&mut self, slot: usize, bytes: u64) {
        if bytes > 0 {
            self.bytes += bytes;
            self.trips += 1;
        }
        self.execs[slot].comm += self.comm_up;
        self.work_end = self.now;
        self.execs[slot].current = None;
        self.try_start(slot);
    }

    fn on_client_unavailable(&mut self, slot: usize, task: usize) {
        let (cur, start, _) =
            self.execs[slot].current.take().expect("ClientUnavailable without a current task");
        debug_assert_eq!(cur, task);
        let elapsed = (self.now - start - self.comm_down).max(0.0);
        self.emit(self.now - elapsed, self.now, Track::Device(slot), EvKind::TaskAborted { task });
        self.execs[slot].wasted += elapsed;
        self.wasted += elapsed;
        // The down leg did happen (the drop fires during compute).
        self.execs[slot].comm += self.comm_down;
        self.task_state[task] = TaskState::Dropped;
        self.dropped += 1;
        self.work_end = self.now;
        self.try_start(slot);
    }

    fn on_device_leave(&mut self, slot: usize, sched: &mut Option<&mut Scheduler>) {
        if slot >= self.execs.len() || !self.execs[slot].alive {
            return;
        }
        if self.alive <= 1 {
            // Never orphan the whole round: the last executor stays.
            return;
        }
        self.execs[slot].alive = false;
        self.alive -= 1;
        self.execs[slot].epoch += 1;
        self.departures += 1;
        self.emit(self.now, self.now, Track::Device(slot), EvKind::DeviceLeave { device: slot });
        let mut orphans: Vec<usize> = Vec::new();
        if let Some((task, start, dur)) = self.execs[slot].current.take() {
            if self.task_state[task] != TaskState::Done {
                // Abort the in-flight task: partial work is wasted.
                let elapsed =
                    (self.now - start - self.comm_down).max(0.0).min(dur.max(0.0));
                self.execs[slot].wasted += elapsed;
                self.wasted += elapsed;
                self.task_state[task] = TaskState::Pending;
                orphans.push(task);
            }
            // A Done task whose upload leg was in flight keeps its
            // result (records were piggybacked at TaskDone); only the
            // final comm trip is lost.
        }
        orphans.extend(self.execs[slot].queue.drain(..));
        if self.record_history {
            if let Some(buf) = self.sched_ops.as_mut() {
                buf.push((self.now, self.seq, HistOp::Prune(slot)));
            } else if let Some(s) = sched.as_deref_mut() {
                s.prune_device(slot);
            }
        }
        self.place_orphans(orphans, sched);
        for s in 0..self.execs.len() {
            self.try_start(s);
        }
    }

    fn on_device_join(&mut self, slot: usize) {
        // Joins re-activate a departed slot. Slots beyond the plan's
        // executor space are ignored: the scheduler's device space is
        // fixed for the run, so a brand-new slot could not persist
        // past this round anyway.
        if slot >= self.execs.len() || self.execs[slot].alive {
            return;
        }
        self.execs[slot].alive = true;
        self.alive += 1;
        self.joins += 1;
        self.emit(self.now, self.now, Track::Device(slot), EvKind::DeviceJoin { device: slot });
        self.try_start(slot);
    }

    fn place_orphans(&mut self, orphans: Vec<usize>, sched: &mut Option<&mut Scheduler>) {
        if orphans.is_empty() {
            return;
        }
        let alive: Vec<bool> = self.execs.iter().map(|e| e.alive).collect();
        if !alive.iter().any(|&a| a) {
            for t in orphans {
                self.task_state[t] = TaskState::Dropped;
                self.dropped += 1;
            }
            return;
        }
        match self.reassign {
            ReassignPolicy::Requeue => {
                for t in orphans.into_iter().rev() {
                    self.shared.push_front(t);
                }
            }
            ReassignPolicy::LeastLoaded => self.place_least_loaded(orphans),
            ReassignPolicy::Greedy => {
                let can_greedy = match sched.as_deref_mut() {
                    Some(s) => s.n_devices() == self.execs.len(),
                    None => false,
                };
                if can_greedy {
                    let items: Vec<(usize, usize)> =
                        orphans.iter().map(|&t| (t, self.n_effs[self.gid(t)])).collect();
                    let base: Vec<f64> =
                        (0..self.execs.len()).map(|i| self.projected_load(i)).collect();
                    let placed = sched.as_deref_mut().unwrap().reassign_orphans(
                        self.round,
                        &items,
                        &alive,
                        &base,
                    );
                    for (slot, ts) in placed.into_iter().enumerate() {
                        for t in ts {
                            self.execs[slot].queue.push_back(t);
                        }
                    }
                } else {
                    self.place_least_loaded(orphans);
                }
            }
        }
    }

    fn place_least_loaded(&mut self, orphans: Vec<usize>) {
        for t in orphans {
            let mut best = usize::MAX;
            let mut best_load = f64::INFINITY;
            for i in 0..self.execs.len() {
                if !self.execs[i].alive {
                    continue;
                }
                let l = self.projected_load(i);
                if l < best_load {
                    best_load = l;
                    best = i;
                }
            }
            if best == usize::MAX {
                // No executor could take the task — every slot is dead
                // (or every projected load compared as NaN).  Mirror the
                // all-dead early return in `place_orphans`: the orphan
                // is dropped, not a crash.
                self.task_state[t] = TaskState::Dropped;
                self.dropped += 1;
                continue;
            }
            self.execs[best].queue.push_back(t);
        }
    }

    /// Price the multi-level tail of a grouped topology (see
    /// [`TieredTail`] for the model).  Returns the advanced clock.
    fn run_tiered_tail(&mut self, tt: &TieredTail, initial_mask: &[bool], start: f64) -> f64 {
        let mut t = start;
        // An empty fanout list degrades to one level of n_groups.
        let levels: Vec<usize> =
            if tt.levels.is_empty() { vec![tt.n_groups] } else { tt.levels.clone() };
        let depth = levels.len();
        // Nodes per level, top-down: node_counts[0] = levels[0], ...,
        // node_counts[depth-1] = n_groups.
        let mut node_counts = Vec::with_capacity(depth);
        let mut prod = 1usize;
        for &f in &levels {
            prod *= f;
            node_counts.push(prod);
        }
        // Leaf-group liveness, at round start (broadcast) and now (up).
        let mut init_members = vec![0usize; tt.n_groups];
        let mut alive_members = vec![0usize; tt.n_groups];
        for (slot, &grp) in tt.group_of.iter().enumerate() {
            if slot < self.execs.len() {
                if initial_mask.get(slot).copied().unwrap_or(false) {
                    init_members[grp] += 1;
                }
                if self.execs[slot].alive {
                    alive_members[grp] += 1;
                }
            }
        }
        // Active node masks per level, for a leaf-activity predicate.
        let active_at = |leaf_active: &[bool], level: usize| -> Vec<bool> {
            let stride = tt.n_groups / node_counts[level];
            let mut v = vec![false; node_counts[level]];
            for (leaf, &a) in leaf_active.iter().enumerate() {
                if a {
                    v[leaf / stride] = true;
                }
            }
            v
        };

        // ---- down: one multicast wave per level ----------------------
        let init_leaf: Vec<bool> = init_members.iter().map(|&m| m > 0).collect();
        let init_devices: u64 = init_members.iter().map(|&m| m as u64).sum();
        if init_devices > 0 {
            // WAN hop to the root-adjacent nodes.
            let top_down = active_at(&init_leaf, 0).iter().filter(|&&a| a).count() as u64;
            t += tt.wan_latency + tt.s_a_down as f64 / tt.wan_bandwidth;
            self.cross_bytes += tt.s_a_down * top_down;
            self.bytes += tt.s_a_down * top_down;
            self.trips += top_down;
            // LAN relay hops through the intermediate levels.
            for level in 1..depth {
                let n = active_at(&init_leaf, level).iter().filter(|&&a| a).count() as u64;
                t += tt.lan_latency + tt.s_a_down as f64 / tt.lan_bandwidth;
                self.bytes += tt.s_a_down * n;
                self.trips += n;
            }
            // Final LAN hop: leaf aggregator -> member devices.
            t += tt.lan_latency + tt.s_a_down as f64 / tt.lan_bandwidth;
            self.bytes += tt.s_a_down * init_devices;
            self.trips += init_devices;
        }

        // ---- up: member bursts overlap across groups, then merge -----
        let alive_leaf: Vec<bool> = alive_members.iter().map(|&m| m > 0).collect();
        let k_up: u64 = alive_members.iter().map(|&m| m as u64).sum();
        if k_up == 0 {
            self.group_aggs = 0;
            return t;
        }
        // Leaf groups: each group's members serialize into its
        // aggregator NIC; groups run concurrently (max, not sum).
        let mut leaf_burst = 0.0f64;
        for &m in &alive_members {
            if m > 0 {
                let tg = tt.lan_latency
                    + tt.s_a_up as f64 / tt.lan_bandwidth
                    + (m - 1) as f64 * tt.lan_latency;
                leaf_burst = leaf_burst.max(tg);
            }
        }
        t += leaf_burst;
        self.bytes += tt.s_a_up * k_up + tt.s_e_total;
        self.trips += k_up;
        // Intermediate merge levels, bottom-up: at level `level` the
        // active nodes upload their merged aggregate to their parents;
        // children of one parent serialize, parents overlap.
        for level in (1..depth).rev() {
            let children = active_at(&alive_leaf, level);
            let fan = levels[level];
            let mut burst = 0.0f64;
            let mut n_children = 0u64;
            for parent in 0..node_counts[level - 1] {
                let c = (0..fan)
                    .filter(|j| children[parent * fan + j])
                    .count() as u64;
                if c > 0 {
                    let tp = tt.lan_latency
                        + tt.s_a_up as f64 / tt.lan_bandwidth
                        + (c - 1) as f64 * tt.lan_latency;
                    burst = burst.max(tp);
                    n_children += c;
                }
            }
            t += burst;
            self.bytes += tt.s_a_up * n_children + tt.s_e_total;
            self.trips += n_children;
        }
        // Root-adjacent chain: the top-tier aggregates serialize into
        // the server NIC over the WAN, special params at the end.
        let n_top = active_at(&alive_leaf, 0).iter().filter(|&&a| a).count() as u64;
        t += tt.wan_latency + tt.s_a_up as f64 / tt.wan_bandwidth;
        t += (n_top - 1) as f64 * tt.wan_latency;
        self.bytes += tt.s_a_up * n_top + tt.s_e_total;
        self.trips += n_top;
        if tt.s_e_total > 0 {
            t += tt.s_e_total as f64 / tt.wan_bandwidth;
        }
        self.cross_bytes += tt.s_a_up * n_top + tt.s_e_total;
        self.group_aggs = n_top as usize;
        t
    }

    /// The round-tail comm chain, expressed as the serialized CommDone
    /// sequence over the server NIC (bytes/trips booked per leg).
    /// `initial_mask` is the per-slot alive mask at round start (the
    /// broadcast went to those executors).
    fn run_tail(&mut self, tail: TailComm, initial_mask: &[bool]) {
        let initial_alive = initial_mask.iter().filter(|&&a| a).count();
        let end = self.work_end;
        let mut t = end;
        let (bytes0, cross0) = (self.bytes, self.cross_bytes);
        match tail {
            TailComm::None => {}
            TailComm::PerExecutor { down, up } => {
                // Broadcast down to every scheduled task's executor.
                let scheduled = self.task_state.len() as u64;
                self.bytes += down * scheduled;
                self.trips += scheduled;
                t += self.cluster.comm_time(down as usize);
                // Uploads (encoded size) serialize into the server NIC.
                let per = self.cluster.latency + up as f64 / self.cluster.bandwidth;
                for _ in 0..self.completed {
                    t += per;
                    self.bytes += up;
                    self.trips += 1;
                }
            }
            TailComm::Hierarchical { s_a_down, s_a_up, s_e_total } => {
                let k_up = self.alive as u64;
                // Broadcast s_a down per initially-alive device.
                self.bytes += s_a_down * initial_alive as u64;
                self.trips += initial_alive as u64;
                t += self.cluster.comm_time(s_a_down as usize);
                // One aggregated (encoded) upload per surviving device:
                // the first pays the full payload time, the rest
                // pipeline behind it at one trip latency each, plus the
                // special-params payload (s_e · M_p) at the end.
                if k_up > 0 {
                    t += self.cluster.comm_time(s_a_up as usize);
                    t += (k_up - 1) as f64 * self.cluster.latency;
                    self.bytes += s_a_up * k_up + s_e_total;
                    self.trips += k_up;
                    if s_e_total > 0 {
                        t += s_e_total as f64 / self.cluster.bandwidth;
                    }
                    self.cross_bytes += s_a_up * k_up + s_e_total;
                }
                // Flat tail: every leg is root-adjacent.
                self.cross_bytes += s_a_down * initial_alive as u64;
                self.group_aggs = k_up as usize;
            }
            TailComm::Tiered(tt) => t = self.run_tiered_tail(&tt, initial_mask, t),
        }
        if t > end {
            let (db, dc) = (self.bytes - bytes0, self.cross_bytes - cross0);
            let ga = self.group_aggs;
            self.emit(
                end,
                t,
                Track::Server,
                EvKind::Tail { bytes: db, cross_bytes: dc, group_aggs: ga },
            );
        }
        // StateFlush leg: round-boundary dirty write-back plus remote
        // write-back returns, serialized after the comm tail.
        if self.state_tail_secs > 0.0 || self.state_tail_bytes > 0 {
            let bytes = self.state_tail_bytes;
            self.emit(t, t + self.state_tail_secs, Track::Server, EvKind::StateFlush { bytes });
            t += self.state_tail_secs;
            self.state_secs += self.state_tail_secs;
            self.state_bytes += self.state_tail_bytes;
        }
        // Late churn events may have advanced `now` past the last real
        // work; the round ends when work + tail comm end, not when the
        // last scripted event was probed.
        self.now = t;
    }

    /// The compute phase: drain the event heap, then sweep unplaceable
    /// tasks to `Dropped` and book the state legs of tasks that never
    /// started.  Everything before the round tail — on the sharded
    /// path each shard core runs exactly this over its own group.
    fn run_events(&mut self, sched: &mut Option<&mut Scheduler>) {
        for slot in 0..self.execs.len() {
            self.try_start(slot);
        }
        while let Some(s) = self.heap.pop() {
            self.events += 1;
            self.now = self.now.max(s.time);
            self.key = (s.time.to_bits(), s.seq);
            match s.event {
                Event::TaskStart { task, device } => {
                    if s.epoch != self.execs[device].epoch || !self.execs[device].alive {
                        continue;
                    }
                    self.on_task_start(device, task);
                }
                Event::TaskDone { task, device } => {
                    if s.epoch != self.execs[device].epoch {
                        continue;
                    }
                    self.on_task_done(device, task, sched);
                }
                Event::CommDone { device, bytes } => {
                    if s.epoch != self.execs[device].epoch {
                        continue;
                    }
                    self.on_comm_done(device, bytes);
                }
                Event::DeviceLeave { device } => self.on_device_leave(device, sched),
                Event::DeviceJoin { device } => self.on_device_join(device),
                Event::ClientUnavailable { task, device } => {
                    if s.epoch != self.execs[device].epoch {
                        continue;
                    }
                    self.on_client_unavailable(device, task);
                }
                Event::FlushDone => unreachable!("sync rounds never schedule flushes"),
            }
        }
        // Anything still pending had nowhere to run.
        for st in &mut self.task_state {
            if *st == TaskState::Pending {
                *st = TaskState::Dropped;
                self.dropped += 1;
            }
        }
        // Book the legs of tasks that never reached TaskStart: the
        // plan-driven prefetch already moved (and the write-back tail
        // will still flush) their state, so the bytes were spent even
        // though no compute happened — this is what keeps the engine's
        // state column equal to the store's counters under drops.
        if !self.state_legs.is_empty() {
            for t in 0..self.state_booked.len() {
                if !self.state_booked[t] {
                    self.state_booked[t] = true;
                    let g = self.gid(t);
                    self.state_bytes += self.state_legs.get(g).map(|l| l.bytes).unwrap_or(0);
                }
            }
        }
    }

    /// Price the round tail and assemble the outcome (runs once, on
    /// merged state in the sharded path).  The trace comes back with
    /// the outcome so tail spans — emitted inside `run_tail` — are
    /// part of it.
    fn finish(
        mut self,
        tail: TailComm,
        initial_mask: &[bool],
    ) -> (RoundOutcome, Option<Vec<Ev>>) {
        self.run_tail(tail, initial_mask);
        let trace = self.trace.take();
        let outcome = RoundOutcome {
            busy: self.execs.iter().map(|e| e.busy).collect(),
            comm_occ: self.execs.iter().map(|e| e.comm).collect(),
            alive: self.execs.iter().map(|e| e.alive).collect(),
            // Only the mutable columns are owned here; the caller
            // re-attaches the immutable ones via `TaskTable::restore`.
            tasks: TaskTable {
                client: Vec::new(),
                n_eff: Vec::new(),
                noise: Vec::new(),
                predicted: Vec::new(),
                state: self.task_state,
                realized: self.task_realized,
            },
            events: self.events,
            work_end: self.work_end,
            end: self.now,
            bytes: self.bytes,
            trips: self.trips,
            wasted_secs: self.wasted,
            dropped_tasks: self.dropped,
            completed_tasks: self.completed,
            departures: self.departures,
            joins: self.joins,
            state_bytes: self.state_bytes,
            state_secs: self.state_secs,
            cross_group_bytes: self.cross_bytes,
            group_aggs: self.group_aggs,
        };
        (outcome, trace)
    }

    /// Single-heap execution: events, then the tail (the legacy path —
    /// flat, shared-pull, and async-degenerate plans).  Returns the
    /// typed event trace alongside the outcome when tracing was on.
    fn run(
        mut self,
        tail: TailComm,
        mut sched: Option<&mut Scheduler>,
    ) -> (RoundOutcome, Option<Vec<Ev>>) {
        let initial_mask: Vec<bool> = self.execs.iter().map(|e| e.alive).collect();
        self.run_events(&mut sched);
        self.finish(tail, &initial_mask)
    }
}

/// Execute one round of `plan` on the discrete-event core
/// (compatibility wrapper over [`run_round_opts`] with one worker and
/// no event trace — same result for every thread count).
pub fn run_round(
    plan: RoundPlan,
    cluster: &ClusterProfile,
    cost: &WorkloadCost,
    round: usize,
    dynamics: &DynamicsSpec,
    dyn_seed: u64,
    scheduler: Option<&mut Scheduler>,
) -> RoundOutcome {
    run_round_opts(plan, cluster, cost, round, dynamics, dyn_seed, scheduler, 1, None)
}

/// Fresh per-executor runtime state from the plan's alive mask and
/// assigned queues.
fn exec_states(plan: &RoundPlan) -> Vec<ExecState> {
    (0..plan.n_exec)
        .map(|i| ExecState {
            alive: plan.alive[i],
            epoch: 0,
            busy: 0.0,
            comm: 0.0,
            wasted: 0.0,
            queue: plan.assigned.get(i).map(|q| q.iter().cloned().collect()).unwrap_or_default(),
            current: None,
        })
        .collect()
}

/// Execute one round of `plan` on the discrete-event core.
///
/// `dyn_seed` seeds the dynamics stream (stragglers, drops, random
/// churn) — a stream separate from the measurement-noise draws so that
/// enabling dynamics never perturbs the base timeline's noise sequence.
///
/// `threads` bounds the worker pool for the group-sharded path (see
/// the module docs); the outcome is byte-identical for every value —
/// grouped plans always run the sharded algorithm, everything else
/// always runs the single heap.  `trace` collects the typed span/event
/// stream ([`Ev`]) in merged `(time_bits, seq)` order when provided.
#[allow(clippy::too_many_arguments)]
pub fn run_round_opts(
    plan: RoundPlan,
    cluster: &ClusterProfile,
    cost: &WorkloadCost,
    round: usize,
    dynamics: &DynamicsSpec,
    dyn_seed: u64,
    scheduler: Option<&mut Scheduler>,
    threads: usize,
    trace: Option<&mut Vec<Ev>>,
) -> RoundOutcome {
    debug_assert_eq!(plan.alive.len(), plan.n_exec);
    let tiered = match &plan.tail {
        TailComm::Tiered(tt)
            if plan.refill == RefillPolicy::Assigned
                && tt.n_groups > 1
                && !plan.tasks.is_empty() =>
        {
            Some(tt.clone())
        }
        _ => None,
    };
    if let Some(tt) = tiered {
        return run_round_sharded(
            plan,
            tt,
            cluster,
            cost,
            round,
            dynamics,
            dyn_seed,
            scheduler,
            threads.max(1),
            trace,
        );
    }

    // ---- legacy single-heap path (flat / shared-pull plans) ----------
    let mut rng = Rng::new(dyn_seed).derive(round as u64);
    let execs = exec_states(&plan);
    let mut table = plan.tasks;
    let state = plan.state;
    let n_tasks = table.len();
    let alive_now = execs.iter().filter(|e| e.alive).count();
    let mut core = Core {
        round,
        cluster,
        cost,
        dynamics,
        rng: rng.derive(0x57A6),
        clients: &table.client,
        n_effs: &table.n_eff,
        noises: &table.noise,
        ids: None,
        task_state: std::mem::take(&mut table.state),
        task_realized: std::mem::take(&mut table.realized),
        execs,
        alive: alive_now,
        shared: plan.pull.into_iter().collect(),
        refill: plan.refill,
        reassign: plan.reassign,
        comm_down: plan.per_task_comm.0,
        comm_up: plan.per_task_comm.1,
        bytes_down: plan.per_task_bytes.0,
        bytes_up: plan.per_task_bytes.1,
        state_legs: &state.legs,
        state_prefetch: state.prefetch,
        state_tail_bytes: state.tail_bytes,
        state_tail_secs: state.tail_secs,
        state_booked: vec![false; n_tasks],
        state_bytes: 0,
        state_secs: 0.0,
        record_history: plan.record_history,
        heap: BinaryHeap::new(),
        now: 0.0,
        work_end: 0.0,
        seq: 0,
        seq_stride: 1,
        sched_ops: None,
        trace: trace.is_some().then(Vec::new),
        key: (0, 0),
        bytes: 0,
        trips: 0,
        cross_bytes: 0,
        group_aggs: 0,
        wasted: 0.0,
        dropped: 0,
        completed: 0,
        departures: 0,
        joins: 0,
        events: 0,
    };

    if n_tasks == 0 {
        let (mut out, tr) = core.run(TailComm::None, scheduler);
        if let (Some(dst), Some(tr)) = (trace, tr) {
            *dst = tr;
        }
        let run_cols = std::mem::take(&mut out.tasks);
        out.tasks = table.restore(run_cols);
        return out;
    }

    // Scripted churn for this round.
    for ev in dynamics.churn.scripted(round) {
        let event = match ev.kind {
            ChurnKind::Leave => Event::DeviceLeave { device: ev.device },
            ChurnKind::Join => Event::DeviceJoin { device: ev.device },
        };
        core.push(ev.secs.max(0.0), 0, event);
    }
    // Random churn: departure/rejoin times drawn within a crude
    // makespan estimate so they actually land mid-round.
    if dynamics.churn.leave_prob > 0.0 || dynamics.churn.join_prob > 0.0 {
        let total_base: f64 = table
            .n_eff
            .iter()
            .zip(&table.noise)
            .map(|(&n, &noise)| (cost.t_sample * n as f64 + cost.b_fixed) * noise)
            .sum();
        let horizon = total_base / core.alive.max(1) as f64;
        for slot in 0..core.execs.len() {
            if core.execs[slot].alive {
                if dynamics.churn.leave_prob > 0.0 && rng.next_f64() < dynamics.churn.leave_prob
                {
                    let t = rng.next_f64() * horizon;
                    core.push(t, 0, Event::DeviceLeave { device: slot });
                }
            } else if dynamics.churn.join_prob > 0.0 && rng.next_f64() < dynamics.churn.join_prob
            {
                let t = rng.next_f64() * horizon;
                core.push(t, 0, Event::DeviceJoin { device: slot });
            }
        }
    }

    let (mut out, tr) = core.run(plan.tail, scheduler);
    if let (Some(dst), Some(tr)) = (trace, tr) {
        *dst = tr;
    }
    let run_cols = std::mem::take(&mut out.tasks);
    out.tasks = table.restore(run_cols);
    out
}

/// One leaf group's slice of the round, built serially before the
/// workers launch (all index mapping is thread-count independent).
/// Shards *borrow* the global task table and state legs — index-range
/// views instead of per-shard deep clones; only the per-shard runtime
/// scratch (alive mask, queues, churn) is owned.
struct ShardInput<'a> {
    shard: usize,
    /// Global slot per local executor index (increasing order).
    slots: &'a [usize],
    /// Global task index per local task index (increasing order).
    task_globals: &'a [usize],
    /// The round's global task columns (read through `task_globals`).
    table: &'a TaskTable,
    /// Global state legs (no flush tail — the parent prices it once).
    legs: &'a [StateLeg],
    prefetch: bool,
    alive: Vec<bool>,
    /// Per local executor: queue of *local* task indices.
    queues: Vec<VecDeque<usize>>,
    /// Churn events for this group, in global draw order, with
    /// device ids already translated to local slots.
    churn: Vec<(f64, Event)>,
}

/// What a shard worker hands back for the merge: the mutable task
/// columns (local id space) plus counters — the index maps stay with
/// the parent.
struct ShardOut {
    shard: usize,
    task_state: Vec<TaskState>,
    task_realized: Vec<f64>,
    execs: Vec<ExecState>,
    work_end: f64,
    bytes: u64,
    trips: u64,
    state_bytes: u64,
    state_secs: f64,
    wasted: f64,
    dropped: usize,
    completed: usize,
    departures: usize,
    joins: usize,
    events: u64,
    ops: Vec<(f64, u64, HistOp)>,
    trace: Vec<Ev>,
}

/// Run one shard's compute phase to completion on its own heap.
#[allow(clippy::too_many_arguments)]
fn run_shard(
    input: ShardInput,
    plan: &RoundPlan,
    cluster: &ClusterProfile,
    cost: &WorkloadCost,
    dynamics: &DynamicsSpec,
    round: usize,
    dyn_seed: u64,
    n_shards: usize,
    want_trace: bool,
) -> ShardOut {
    let ShardInput { shard, slots: _, task_globals, table, legs, prefetch, alive, queues, churn } =
        input;
    let n_tasks = task_globals.len();
    let alive_now = alive.iter().filter(|&&a| a).count();
    let execs: Vec<ExecState> = alive
        .iter()
        .zip(queues)
        .map(|(&alive, queue)| ExecState {
            alive,
            epoch: 0,
            busy: 0.0,
            comm: 0.0,
            wasted: 0.0,
            queue,
            current: None,
        })
        .collect();
    let mut core = Core {
        round,
        cluster,
        cost,
        dynamics,
        // One derived dynamics stream per shard: straggler/drop draws
        // are consumed group-locally, so the stream cannot depend on
        // cross-group event interleaving (or the worker count).
        rng: Rng::new(dyn_seed).derive(round as u64).derive(0x57A6).derive(shard as u64),
        // Index-range views over the global columns — local task ids
        // reach them through the `ids` map; nothing is cloned.
        clients: &table.client,
        n_effs: &table.n_eff,
        noises: &table.noise,
        ids: Some(task_globals),
        task_state: task_globals.iter().map(|&g| table.state[g]).collect(),
        task_realized: task_globals.iter().map(|&g| table.realized[g]).collect(),
        execs,
        alive: alive_now,
        shared: VecDeque::new(),
        refill: plan.refill,
        reassign: plan.reassign,
        comm_down: plan.per_task_comm.0,
        comm_up: plan.per_task_comm.1,
        bytes_down: plan.per_task_bytes.0,
        bytes_up: plan.per_task_bytes.1,
        state_legs: legs,
        state_prefetch: prefetch,
        state_tail_bytes: 0,
        state_tail_secs: 0.0,
        state_booked: vec![false; n_tasks],
        state_bytes: 0,
        state_secs: 0.0,
        record_history: plan.record_history,
        heap: BinaryHeap::new(),
        now: 0.0,
        work_end: 0.0,
        // Namespaced sequence counter: shard + k·n_shards, so merged
        // shard queues interleave on (time, seq) without collisions.
        seq: shard as u64,
        seq_stride: n_shards as u64,
        sched_ops: Some(Vec::new()),
        trace: want_trace.then(Vec::new),
        // Until the first pop, emissions (the initial try_start sweep)
        // carry the construction key: rounds start at now = 0.0, whose
        // bit pattern is 0, so the merge still orders them by shard id.
        key: (0, shard as u64),
        bytes: 0,
        trips: 0,
        cross_bytes: 0,
        group_aggs: 0,
        wasted: 0.0,
        dropped: 0,
        completed: 0,
        departures: 0,
        joins: 0,
        events: 0,
    };
    for (t, event) in churn {
        core.push(t, 0, event);
    }
    let mut no_sched: Option<&mut Scheduler> = None;
    core.run_events(&mut no_sched);
    ShardOut {
        shard,
        task_state: core.task_state,
        task_realized: core.task_realized,
        execs: core.execs,
        work_end: core.work_end,
        bytes: core.bytes,
        trips: core.trips,
        state_bytes: core.state_bytes,
        state_secs: core.state_secs,
        wasted: core.wasted,
        dropped: core.dropped,
        completed: core.completed,
        departures: core.departures,
        joins: core.joins,
        events: core.events,
        ops: core.sched_ops.take().unwrap_or_default(),
        trace: core.trace.take().unwrap_or_default(),
    }
}

/// The group-sharded round: one event-heap shard per leaf group on up
/// to `threads` scoped workers, merged at the WAN barrier (the tiered
/// tail).  See the module docs for the determinism argument.
#[allow(clippy::too_many_arguments)]
fn run_round_sharded(
    plan: RoundPlan,
    tt: TieredTail,
    cluster: &ClusterProfile,
    cost: &WorkloadCost,
    round: usize,
    dynamics: &DynamicsSpec,
    dyn_seed: u64,
    scheduler: Option<&mut Scheduler>,
    threads: usize,
    trace: Option<&mut Vec<Ev>>,
) -> RoundOutcome {
    let n_shards = tt.n_groups;
    let n_exec = plan.n_exec;
    let shard_of: Vec<usize> = (0..n_exec)
        .map(|s| tt.group_of.get(s).copied().unwrap_or(0).min(n_shards - 1))
        .collect();

    // Local index spaces: executors and tasks, per shard.
    let mut slots: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
    let mut slot_local = vec![0usize; n_exec];
    for s in 0..n_exec {
        slot_local[s] = slots[shard_of[s]].len();
        slots[shard_of[s]].push(s);
    }
    // Task ownership follows the assigned executor; tasks no queue
    // mentions stay with the parent and are dropped in the merge sweep
    // (the single heap would never start them either).
    let mut task_shard = vec![usize::MAX; plan.tasks.len()];
    for (exec, q) in plan.assigned.iter().enumerate() {
        for &t in q {
            if exec < n_exec && t < task_shard.len() {
                task_shard[t] = shard_of[exec];
            }
        }
    }
    let mut task_globals: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
    let mut task_local = vec![0usize; plan.tasks.len()];
    for (t, &sh) in task_shard.iter().enumerate() {
        if sh != usize::MAX {
            task_local[t] = task_globals[sh].len();
            task_globals[sh].push(t);
        }
    }

    // Churn events, drawn in the legacy global order (scripted events
    // first, then one pass over the slots for the random draws — the
    // round stream is consumed identically to the single-heap path),
    // then routed to the owning shard.  Events for slots outside the
    // executor space are no-ops on the single heap and are skipped.
    let mut rng = Rng::new(dyn_seed).derive(round as u64);
    let mut churn: Vec<Vec<(f64, Event)>> = vec![Vec::new(); n_shards];
    for ev in dynamics.churn.scripted(round) {
        if ev.device >= n_exec {
            continue;
        }
        let device = slot_local[ev.device];
        let event = match ev.kind {
            ChurnKind::Leave => Event::DeviceLeave { device },
            ChurnKind::Join => Event::DeviceJoin { device },
        };
        churn[shard_of[ev.device]].push((ev.secs.max(0.0), event));
    }
    if dynamics.churn.leave_prob > 0.0 || dynamics.churn.join_prob > 0.0 {
        let total_base: f64 = plan
            .tasks
            .n_eff
            .iter()
            .zip(&plan.tasks.noise)
            .map(|(&n, &noise)| (cost.t_sample * n as f64 + cost.b_fixed) * noise)
            .sum();
        let alive_count = plan.alive.iter().filter(|&&a| a).count();
        let horizon = total_base / alive_count.max(1) as f64;
        for slot in 0..n_exec {
            if plan.alive[slot] {
                if dynamics.churn.leave_prob > 0.0 && rng.next_f64() < dynamics.churn.leave_prob
                {
                    let t = rng.next_f64() * horizon;
                    churn[shard_of[slot]]
                        .push((t, Event::DeviceLeave { device: slot_local[slot] }));
                }
            } else if dynamics.churn.join_prob > 0.0 && rng.next_f64() < dynamics.churn.join_prob
            {
                let t = rng.next_f64() * horizon;
                churn[shard_of[slot]].push((t, Event::DeviceJoin { device: slot_local[slot] }));
            }
        }
    }

    let want_trace = trace.is_some();
    let mut inputs: Vec<ShardInput> = Vec::with_capacity(n_shards);
    for (sh, churn) in churn.into_iter().enumerate() {
        let alive: Vec<bool> = slots[sh].iter().map(|&g| plan.alive[g]).collect();
        let queues: Vec<VecDeque<usize>> = slots[sh]
            .iter()
            .map(|&g| {
                plan.assigned
                    .get(g)
                    .map(|q| q.iter().map(|&t| task_local[t]).collect())
                    .unwrap_or_default()
            })
            .collect();
        inputs.push(ShardInput {
            shard: sh,
            slots: &slots[sh],
            task_globals: &task_globals[sh],
            table: &plan.tasks,
            legs: &plan.state.legs,
            prefetch: plan.state.prefetch,
            alive,
            queues,
            churn,
        });
    }

    // Static shard→worker round-robin on scoped threads: the partition
    // changes with `threads`, the per-shard computations do not — so
    // the merged result is identical for every worker count.  One
    // worker spawns no threads at all.
    let workers = threads.min(n_shards).max(1);
    let mut per_worker: Vec<Vec<ShardInput>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, input) in inputs.into_iter().enumerate() {
        per_worker[i % workers].push(input);
    }
    let plan_ref = &plan;
    let run_batch = |batch: Vec<ShardInput>| -> Vec<ShardOut> {
        batch
            .into_iter()
            .map(|input| {
                run_shard(
                    input, plan_ref, cluster, cost, dynamics, round, dyn_seed, n_shards,
                    want_trace,
                )
            })
            .collect()
    };
    let mut outs: Vec<ShardOut> = std::thread::scope(|scope| {
        let mut batches = per_worker.into_iter();
        let mine = batches.next().unwrap_or_default();
        let handles: Vec<_> = batches.map(|batch| scope.spawn(|| run_batch(batch))).collect();
        let mut all = run_batch(mine);
        for h in handles {
            all.extend(h.join().expect("shard worker panicked"));
        }
        all
    });
    outs.sort_by_key(|o| o.shard);

    // ---- merge at the WAN barrier, in shard-index order --------------
    let record_history = plan.record_history;
    let initial_mask = plan.alive.clone();
    let execs = exec_states(&plan);
    let alive_init = execs.iter().filter(|e| e.alive).count();
    let mut table = plan.tasks;
    let state = plan.state;
    let n_tasks = table.len();
    let mut parent = Core {
        round,
        cluster,
        cost,
        dynamics,
        rng: Rng::new(dyn_seed).derive(round as u64).derive(0x57A6),
        clients: &table.client,
        n_effs: &table.n_eff,
        noises: &table.noise,
        ids: None,
        task_state: std::mem::take(&mut table.state),
        task_realized: std::mem::take(&mut table.realized),
        execs,
        alive: alive_init,
        shared: VecDeque::new(),
        refill: plan.refill,
        reassign: plan.reassign,
        comm_down: plan.per_task_comm.0,
        comm_up: plan.per_task_comm.1,
        bytes_down: plan.per_task_bytes.0,
        bytes_up: plan.per_task_bytes.1,
        state_legs: &state.legs,
        state_prefetch: state.prefetch,
        state_tail_bytes: state.tail_bytes,
        state_tail_secs: state.tail_secs,
        state_booked: vec![false; n_tasks],
        state_bytes: 0,
        state_secs: 0.0,
        record_history,
        heap: BinaryHeap::new(),
        now: 0.0,
        work_end: 0.0,
        seq: 0,
        seq_stride: 1,
        sched_ops: None,
        trace: None,
        key: (0, 0),
        bytes: 0,
        trips: 0,
        cross_bytes: 0,
        group_aggs: 0,
        wasted: 0.0,
        dropped: 0,
        completed: 0,
        departures: 0,
        joins: 0,
        events: 0,
    };
    let mut all_ops: Vec<(f64, u64, HistOp)> = Vec::new();
    let mut merged_trace: Vec<Ev> = Vec::new();
    for out in outs {
        let ShardOut {
            shard,
            task_state,
            task_realized,
            execs,
            work_end,
            bytes,
            trips,
            state_bytes,
            state_secs,
            wasted,
            dropped,
            completed,
            departures,
            joins,
            events,
            ops,
            trace,
        } = out;
        let (slots, task_globals) = (&slots[shard], &task_globals[shard]);
        for (local, e) in execs.into_iter().enumerate() {
            parent.execs[slots[local]] = e;
        }
        for (local, st) in task_state.into_iter().enumerate() {
            parent.task_state[task_globals[local]] = st;
        }
        for (local, r) in task_realized.into_iter().enumerate() {
            parent.task_realized[task_globals[local]] = r;
        }
        parent.events += events;
        parent.work_end = parent.work_end.max(work_end);
        parent.bytes += bytes;
        parent.trips += trips;
        parent.state_bytes += state_bytes;
        parent.state_secs += state_secs;
        parent.wasted += wasted;
        parent.dropped += dropped;
        parent.completed += completed;
        parent.departures += departures;
        parent.joins += joins;
        for (time, seq, op) in ops {
            let op = match op {
                HistOp::Record(mut r) => {
                    r.device = slots[r.device];
                    HistOp::Record(r)
                }
                HistOp::Prune(d) => HistOp::Prune(slots[d]),
            };
            all_ops.push((time, seq, op));
        }
        // Shard traces carry local slot/task ids; translate back to the
        // global index space so the merged trace matches the single
        // heap's labelling.
        for mut e in trace {
            e.track = match e.track {
                Track::Device(i) => Track::Device(slots[i]),
                Track::Net(i) => Track::Net(slots[i]),
                other => other,
            };
            e.kind = match e.kind {
                EvKind::Task { task, client } => {
                    EvKind::Task { task: task_globals[task], client }
                }
                EvKind::TaskAborted { task } => {
                    EvKind::TaskAborted { task: task_globals[task] }
                }
                EvKind::CommDown { task, bytes } => {
                    EvKind::CommDown { task: task_globals[task], bytes }
                }
                EvKind::CommUp { task, bytes } => {
                    EvKind::CommUp { task: task_globals[task], bytes }
                }
                EvKind::DeviceLeave { device } => {
                    EvKind::DeviceLeave { device: slots[device] }
                }
                EvKind::DeviceJoin { device } => EvKind::DeviceJoin { device: slots[device] },
                other => other,
            };
            merged_trace.push(e);
        }
    }
    // Tasks no shard owned (never queued anywhere): the single heap
    // would sweep them to Dropped and book their state legs.
    for t in 0..n_tasks {
        if task_shard[t] == usize::MAX {
            if parent.task_state[t] == TaskState::Pending {
                parent.task_state[t] = TaskState::Dropped;
                parent.dropped += 1;
            }
            if !parent.state_legs.is_empty() {
                parent.state_bytes += parent.state_legs.get(t).map(|l| l.bytes).unwrap_or(0);
            }
        }
    }
    // The scattered exec states carry post-churn liveness; resync the
    // incremental counter before the tail prices against it.
    parent.alive = parent.execs.iter().filter(|e| e.alive).count();
    // Scheduler history: shard-buffered ops applied in global
    // (time, seq) order — seq values are shard-namespaced, so the sort
    // is a total order and per-device subsequences keep their shard's
    // relative order.
    if record_history {
        all_ops.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        if let Some(s) = scheduler {
            for (_, _, op) in all_ops {
                match op {
                    HistOp::Record(r) => s.record(r),
                    HistOp::Prune(d) => s.prune_device(d),
                }
            }
        }
    }
    if want_trace {
        // Merge on the pop key `(time, seq)` — the namespaced seq makes
        // this a total order across shards, and the stable sort keeps
        // each pop's multi-event emission order intact.  The parent
        // appends the tail spans afterwards (never re-sorted).
        merged_trace.sort_by(|a, b| {
            f64::from_bits(a.at).total_cmp(&f64::from_bits(b.at)).then(a.seq.cmp(&b.seq))
        });
        parent.trace = Some(merged_trace);
    }
    // The conservative barrier: every shard has drained, so the tiered
    // tail (the earliest possible cross-WAN interaction) starts at the
    // global work end.
    parent.now = parent.work_end;
    let (mut out, tr) = parent.finish(TailComm::Tiered(tt), &initial_mask);
    if let (Some(dst), Some(tr)) = (trace, tr) {
        *dst = tr;
    }
    let run_cols = std::mem::take(&mut out.tasks);
    out.tasks = table.restore(run_cols);
    out
}

// ===================================================================
// Asynchronous buffered execution (FedBuff/FLUTE-style, `--scheme
// async`): the work-conserving dispatcher.
//
// No TaskStart barrier exists between "rounds".  Client cohorts are
// *admitted* on demand — whenever an executor runs out of work and the
// staleness window has room — and placed through the scheduler's greedy
// cost rule incrementally (`Scheduler::schedule_from` with the
// executors' current projected loads as the base).  Each completed task
// joins its executor's open local aggregate; the server applies a flush
// whenever `buffer` client updates have accumulated, discounting each
// update by `weight(staleness)` where staleness counts the flushes
// applied since the update's model version.  Buffered aggregates ship
// in one serialized NIC burst when the flush triggers (broadcast down +
// one upload per contributing executor), which is exactly the sync
// hierarchical round tail — so `buffer == M_p` with `max_staleness ==
// 0` reproduces the synchronous Parrot timeline event-for-event
// (property-tested in `super::tests`).
//
// Admission gate: a cohort is admitted only while
// `pending < buffer · (max_staleness + 1)` — at most S+1 flushes of
// work may be in the pipeline, so an update's *projected* staleness at
// dispatch never exceeds S.  (Realized staleness is still measured at
// apply time; an update overtaken by faster peers can exceed S and is
// then dropped with weight 0 — FedBuff's discard rule.)

use crate::aggregation::StalenessWeight;
use crate::statestore::StateLeg;

/// Async buffered-aggregation parameters (`--buffer`,
/// `--max-staleness`, `--staleness-weight`).
#[derive(Debug, Clone, Copy)]
pub struct AsyncSpec {
    /// Client updates per flush (K of FedBuff).  Must be ≥ 1 — the
    /// driver resolves the CLI's `0 = M_p` convention before this.
    pub buffer: usize,
    /// Updates staler than this many flushes are dropped (weight 0).
    pub max_staleness: usize,
    pub weight: StalenessWeight,
}

/// Comm sizes of the async path (the hierarchical shape of Parrot).
#[derive(Debug, Clone)]
pub struct AsyncComm {
    pub s_a_down: u64,
    pub s_a_up: u64,
    /// Special-params bytes per client update.
    pub s_e: u64,
    /// Grouped-topology pricing (`--topology groups:G` with `--scheme
    /// async`): member bursts merge at the edge aggregator over the
    /// LAN, only merged group aggregates cross the WAN.  None = flat.
    pub tier: Option<AsyncTier>,
}

/// Depth-1 grouping for the async flush chain (deeper trees are
/// rejected by config validation — the work-conserving dispatcher
/// prices exactly one aggregator tier).
#[derive(Debug, Clone)]
pub struct AsyncTier {
    pub n_groups: usize,
    /// Leaf-group id per executor slot.
    pub group_of: Vec<usize>,
    pub wan_bandwidth: f64,
    pub wan_latency: f64,
    pub lan_bandwidth: f64,
    pub lan_latency: f64,
}

/// One admitted cohort from the dispatcher's source callback: tasks,
/// their per-executor queues, and the cohort's state-store plan (leg
/// `ready` times relative to the admission instant).  The task columns
/// are spliced wholesale into the dispatcher's arena at admission — a
/// cohort is an `(arena start, len)` range, not a Vec of task objects.
pub struct AsyncCohort {
    pub tasks: TaskTable,
    pub assigned: Vec<Vec<usize>>,
    pub state: StatePlan,
    pub sched_secs: f64,
    /// Selected-but-unavailable clients (availability filter).
    pub unavailable: usize,
}

/// Per-flush accounting (the async analogue of a `VRound`).
#[derive(Debug, Clone)]
pub struct FlushRecord {
    pub flush: usize,
    /// Absolute virtual time of the flush chain's end.
    pub end: f64,
    /// Seconds since the previous flush ended (Σ = total makespan).
    pub interval: f64,
    /// Serialized NIC chain seconds (broadcast + uploads + state tail).
    pub chain_secs: f64,
    pub bytes: u64,
    pub trips: u64,
    /// Updates applied (staleness within bound).
    pub updates: usize,
    /// Device aggregates merged in this flush.
    pub aggs: usize,
    /// Updates discarded for exceeding `max_staleness`.
    pub stale_dropped: usize,
    /// `staleness_hist[s]` = applied updates that were `s` flushes old.
    pub staleness_hist: Vec<usize>,
    /// Group aggregates this flush merged at the server (contributing
    /// devices for a flat run, contributing groups when grouped).
    pub group_aggs: usize,
    /// Bytes that crossed the root-adjacent (WAN) links in this flush
    /// chain (all of them for a flat run).
    pub cross_group_bytes: u64,
    /// Per-executor productive compute seconds in this interval.
    pub busy: Vec<f64>,
    pub completed: usize,
    pub dropped: usize,
    pub wasted_secs: f64,
    pub sched_secs: f64,
    pub state_bytes: u64,
    pub state_secs: f64,
    pub unavailable: usize,
    pub est_err: Option<f64>,
}

/// Everything an async run produced.
#[derive(Debug)]
pub struct AsyncOutcome {
    pub flushes: Vec<FlushRecord>,
    pub end: f64,
    pub busy: Vec<f64>,
    pub completed: usize,
    pub dropped: usize,
    pub wasted_secs: f64,
    /// Born model-version of every buffered update in arrival order —
    /// the deploy-side `FlushLedger` differential replays exactly this
    /// sequence (`parrot exp asyncscale --smoke`).
    pub arrivals: Vec<u64>,
    pub cohorts: usize,
    /// Heap pops handled (deterministic events/sec numerator).
    pub events: u64,
}

struct ADev {
    queue: VecDeque<usize>,
    /// (task, effective start incl. state stall, compute duration).
    current: Option<(usize, f64, f64)>,
    busy: f64,
}

/// A triggered flush riding the server NIC (chains are FIFO).
struct ChainBatch {
    /// (device, born version) per buffered update.
    updates: Vec<(usize, u64)>,
    aggs: usize,
    group_aggs: usize,
    chain_secs: f64,
    bytes: u64,
    trips: u64,
    cross_bytes: u64,
    state_tail_bytes: u64,
    state_tail_secs: f64,
}

/// Interval accumulators snapshotted into each [`FlushRecord`].
#[derive(Default)]
struct IntervalAcc {
    completed: usize,
    dropped: usize,
    wasted: f64,
    sched_secs: f64,
    state_bytes: u64,
    state_secs: f64,
    unavailable: usize,
    act: Vec<f64>,
    pred: Vec<f64>,
}

/// The dispatcher's cohort feed: `(scheduler, cohort index, alive mask,
/// per-executor projected base loads) -> cohort`, `None` = exhausted.
pub type AsyncSource<'s> =
    dyn FnMut(&mut Scheduler, usize, &[bool], &[f64]) -> Option<AsyncCohort> + 's;

struct AsyncCore<'a> {
    cluster: &'a ClusterProfile,
    cost: &'a WorkloadCost,
    dynamics: &'a DynamicsSpec,
    dyn_seed: u64,
    spec: AsyncSpec,
    comm: AsyncComm,
    // Arena-allocated task columns (append-only, admission order): one
    // in-flight task = one index across these parallel vectors.
    a_n_eff: Vec<usize>,
    a_noise: Vec<f64>,
    a_predicted: Vec<Option<f64>>,
    /// Global client id (trace labelling only).
    a_client: Vec<usize>,
    a_cohort: Vec<usize>,
    /// Model version the executor held when the task started.
    a_born: Vec<u64>,
    a_leg_booked: Vec<bool>,
    /// Per cohort: `(arena start, len)` of its task range.
    cohort_range: Vec<(usize, usize)>,
    /// Per cohort: its state plan (legs local-indexed; `ready` times
    /// relative to the admission instant in `cohort_admit`).
    cohort_state: Vec<StatePlan>,
    /// Per cohort: absolute admission time.
    cohort_admit: Vec<f64>,
    devs: Vec<ADev>,
    heap: BinaryHeap<Scheduled>,
    seq: u64,
    now: f64,
    /// Applied flush count == current global model version.
    version: u64,
    /// Dispatched-but-unapplied client updates (inflight + buffered):
    /// the admission gate's pipeline depth.
    pending: usize,
    /// (device, born) of updates awaiting the next flush trigger.
    buffered: Vec<(usize, u64)>,
    chains: VecDeque<ChainBatch>,
    nic_free: f64,
    cohort_rng: Vec<Rng>,
    cohort_left: Vec<usize>,
    cohort_tail: Vec<(u64, f64)>,
    ready_tail_bytes: u64,
    ready_tail_secs: f64,
    next_cohort: usize,
    exhausted: bool,
    acc: IntervalAcc,
    busy_prev: Vec<f64>,
    last_flush_end: f64,
    flushes: Vec<FlushRecord>,
    arrivals: Vec<u64>,
    completed: usize,
    dropped: usize,
    wasted: f64,
    /// Heap pops handled (deterministic events/sec numerator).
    events: u64,
    /// Typed event trace (None = tracing off).  The dispatcher is
    /// single-heap and single-threaded, so emission order is already
    /// the total order — `seq` is just the buffer index.
    trace: Option<Vec<Ev>>,
}

impl<'a> AsyncCore<'a> {
    fn push(&mut self, time: f64, event: Event) {
        self.heap.push(Scheduled { time, seq: self.seq, epoch: 0, event });
        self.seq += 1;
    }

    fn emit(&mut self, t0: f64, t1: f64, track: Track, kind: EvKind) {
        if let Some(tr) = self.trace.as_mut() {
            let seq = tr.len() as u64;
            tr.push(Ev { at: t0.to_bits(), seq, t0, t1, track, kind });
        }
    }

    fn base_secs(&self, slot: usize, task: usize) -> f64 {
        let model = self.cluster.executor_model(slot);
        let (cohort, n_eff) = (self.a_cohort[task], self.a_n_eff[task]);
        self.cluster.task_time(self.cost, model, cohort, n_eff, 1) * self.a_noise[task]
    }

    /// Remaining committed seconds on `slot` (in-flight + queued), in
    /// the engine's actual task-time model — the base the incremental
    /// greedy admission starts from (mirrors the sync engine's
    /// `projected_load` used for orphan re-placement).
    fn projected_load(&self, slot: usize) -> f64 {
        let d = &self.devs[slot];
        let mut load = match d.current {
            Some((_, start, dur)) => (start + dur - self.now).max(0.0),
            None => 0.0,
        };
        for &t in &d.queue {
            load += self.base_secs(slot, t);
        }
        load
    }

    fn try_start(&mut self, slot: usize) {
        if self.devs[slot].current.is_some() {
            return;
        }
        if let Some(task) = self.devs[slot].queue.pop_front() {
            self.devs[slot].current = Some((task, self.now, 0.0));
            self.push(self.now, Event::TaskStart { task, device: slot });
        }
    }

    /// Book the task's state leg exactly once and return its stall
    /// (same discipline as the sync engine's `state_stall`).  Legs live
    /// in the owning cohort's plan, reached through the cohort's arena
    /// range; plan-relative `ready` times shift by the admission
    /// instant.
    fn state_stall(&mut self, task: usize) -> f64 {
        let c = self.a_cohort[task];
        if self.cohort_state[c].legs.is_empty() || self.a_leg_booked[task] {
            return 0.0;
        }
        let (start, _) = self.cohort_range[c];
        let mut leg = self.cohort_state[c].legs.get(task - start).copied().unwrap_or_default();
        leg.ready += self.cohort_admit[c];
        let prefetch = self.cohort_state[c].prefetch;
        self.a_leg_booked[task] = true;
        self.acc.state_bytes += leg.bytes;
        let stall = if prefetch { (leg.ready - self.now).max(0.0) } else { leg.secs };
        self.acc.state_secs += stall;
        stall
    }

    fn on_task_start(&mut self, slot: usize, task: usize) {
        let mut dur = self.base_secs(slot, task);
        let c = self.a_cohort[task];
        let st = &self.dynamics.straggler;
        if st.prob > 0.0 && self.cohort_rng[c].next_f64() < st.prob {
            dur *= st.law.sample(&mut self.cohort_rng[c]);
        }
        let stall = self.state_stall(task);
        self.a_born[task] = self.version;
        self.devs[slot].current = Some((task, self.now + stall, dur));
        if stall > 0.0 {
            let (t0, t1) = (self.now, self.now + stall);
            self.emit(t0, t1, Track::Device(slot), EvKind::StateLoad { clients: 1 });
        }
        let st = &self.dynamics.straggler;
        if st.drop_prob > 0.0 && self.cohort_rng[c].next_f64() < st.drop_prob {
            let frac = self.cohort_rng[c].next_f64();
            self.push(self.now + stall + dur * frac, Event::ClientUnavailable {
                task,
                device: slot,
            });
        } else {
            self.push(self.now + stall + dur, Event::TaskDone { task, device: slot });
        }
    }

    /// A cohort's update left the pipeline (buffered or dropped); once
    /// its last one does, the cohort's state-flush tail becomes part of
    /// the next flush chain.
    fn cohort_settled(&mut self, cohort: usize) {
        self.cohort_left[cohort] -= 1;
        if self.cohort_left[cohort] == 0 {
            let (b, s) = self.cohort_tail[cohort];
            self.ready_tail_bytes += b;
            self.ready_tail_secs += s;
            self.cohort_tail[cohort] = (0, 0.0);
        }
    }

    fn on_task_done(
        &mut self,
        slot: usize,
        task: usize,
        scheduler: &mut Scheduler,
        source: &mut AsyncSource<'_>,
    ) {
        let (cur, _, dur) = self.devs[slot].current.expect("TaskDone without a current task");
        debug_assert_eq!(cur, task);
        self.devs[slot].busy += dur;
        self.completed += 1;
        self.acc.completed += 1;
        let client = self.a_client[task];
        self.emit(self.now - dur, self.now, Track::Device(slot), EvKind::Task { task, client });
        if let Some(p) = self.a_predicted[task] {
            self.acc.act.push(dur);
            self.acc.pred.push(p);
        }
        scheduler.record(TaskRecord {
            round: self.a_cohort[task],
            device: slot,
            n_samples: self.a_n_eff[task],
            secs: dur,
        });
        let born = self.a_born[task];
        self.buffered.push((slot, born));
        self.arrivals.push(born);
        self.cohort_settled(self.a_cohort[task]);
        self.devs[slot].current = None;
        self.try_start(slot);
        if self.buffered.len() >= self.spec.buffer {
            self.trigger_flush();
        }
        self.try_admit(scheduler, source);
    }

    fn on_client_unavailable(
        &mut self,
        slot: usize,
        task: usize,
        scheduler: &mut Scheduler,
        source: &mut AsyncSource<'_>,
    ) {
        let (cur, start, dur) =
            self.devs[slot].current.take().expect("ClientUnavailable without a current task");
        debug_assert_eq!(cur, task);
        let elapsed = (self.now - start).max(0.0).min(dur.max(0.0));
        self.emit(self.now - elapsed, self.now, Track::Device(slot), EvKind::TaskAborted { task });
        self.wasted += elapsed;
        self.acc.wasted += elapsed;
        self.dropped += 1;
        self.acc.dropped += 1;
        self.pending -= 1;
        self.cohort_settled(self.a_cohort[task]);
        self.try_start(slot);
        self.try_admit(scheduler, source);
    }

    /// The buffer filled: ship every open aggregate in one serialized
    /// NIC burst — broadcast down to all executors, one upload per
    /// contributing executor — plus any settled cohorts' state tails.
    /// This is byte- and second-identical to the sync hierarchical
    /// round tail, which is what makes `buffer == M_p` degenerate to
    /// the synchronous timeline.
    fn trigger_flush(&mut self) {
        let updates = std::mem::take(&mut self.buffered);
        let n_updates = updates.len();
        let mut seen = vec![false; self.devs.len()];
        for &(dev, _) in &updates {
            seen[dev] = true;
        }
        let aggs = seen.iter().filter(|&&s| s).count();
        let s_e_total = self.comm.s_e * n_updates as u64;
        let mut secs: f64;
        let mut bytes: u64;
        let mut trips: u64;
        let cross_bytes: u64;
        let group_aggs: usize;
        match &self.comm.tier {
            None => {
                // Flat: the sync hierarchical burst — every leg WAN.
                secs = self.cluster.comm_time(self.comm.s_a_down as usize);
                bytes = self.comm.s_a_down * self.devs.len() as u64;
                trips = self.devs.len() as u64;
                let mut cross = self.comm.s_a_down * self.devs.len() as u64;
                if aggs > 0 {
                    secs += self.cluster.comm_time(self.comm.s_a_up as usize)
                        + (aggs - 1) as f64 * self.cluster.latency;
                    bytes += self.comm.s_a_up * aggs as u64 + s_e_total;
                    trips += aggs as u64;
                    cross += self.comm.s_a_up * aggs as u64 + s_e_total;
                    if s_e_total > 0 {
                        secs += s_e_total as f64 / self.cluster.bandwidth;
                    }
                }
                cross_bytes = cross;
                group_aggs = aggs;
            }
            Some(tier) => {
                // Grouped: contributing members merge at their edge
                // aggregator (bursts overlap across groups), merged
                // group aggregates serialize into the server over the
                // WAN; the refreshed model fans back out WAN→LAN.
                let mut members = vec![0usize; tier.n_groups];
                for (dev, &s) in seen.iter().enumerate() {
                    if s {
                        members[tier.group_of[dev]] += 1;
                    }
                }
                let g_aggs = members.iter().filter(|&&m| m > 0).count();
                // Down: one WAN wave to the groups + one LAN wave to
                // every device.
                secs = tier.wan_latency + self.comm.s_a_down as f64 / tier.wan_bandwidth
                    + tier.lan_latency
                    + self.comm.s_a_down as f64 / tier.lan_bandwidth;
                bytes = self.comm.s_a_down * (tier.n_groups + self.devs.len()) as u64;
                trips = (tier.n_groups + self.devs.len()) as u64;
                let mut cross = self.comm.s_a_down * tier.n_groups as u64;
                if g_aggs > 0 {
                    let mut burst = 0.0f64;
                    for &m in &members {
                        if m > 0 {
                            let tg = tier.lan_latency
                                + self.comm.s_a_up as f64 / tier.lan_bandwidth
                                + (m - 1) as f64 * tier.lan_latency;
                            burst = burst.max(tg);
                        }
                    }
                    secs += burst
                        + tier.wan_latency
                        + self.comm.s_a_up as f64 / tier.wan_bandwidth
                        + (g_aggs - 1) as f64 * tier.wan_latency;
                    bytes += self.comm.s_a_up * (aggs + g_aggs) as u64 + 2 * s_e_total;
                    trips += (aggs + g_aggs) as u64;
                    cross += self.comm.s_a_up * g_aggs as u64 + s_e_total;
                    if s_e_total > 0 {
                        secs += s_e_total as f64 / tier.wan_bandwidth;
                    }
                }
                cross_bytes = cross;
                group_aggs = g_aggs;
            }
        }
        let state_tail_bytes = std::mem::take(&mut self.ready_tail_bytes);
        let state_tail_secs = std::mem::take(&mut self.ready_tail_secs);
        secs += state_tail_secs;
        let start = self.now.max(self.nic_free);
        let end = start + secs;
        self.nic_free = end;
        self.chains.push_back(ChainBatch {
            updates,
            aggs,
            group_aggs,
            chain_secs: secs,
            bytes,
            trips,
            cross_bytes,
            state_tail_bytes,
            state_tail_secs,
        });
        self.push(end, Event::FlushDone);
    }

    fn on_flush_done(&mut self, scheduler: &mut Scheduler, source: &mut AsyncSource<'_>) {
        let batch = self.chains.pop_front().expect("FlushDone without a queued chain");
        let mut hist: Vec<usize> = vec![0; self.spec.max_staleness + 1];
        let mut stale_dropped = 0usize;
        let mut applied = 0usize;
        for &(_, born) in &batch.updates {
            let s = (self.version - born) as usize;
            if s > self.spec.max_staleness {
                stale_dropped += 1;
            } else {
                hist[s] += 1;
                applied += 1;
            }
        }
        self.version += 1;
        self.pending -= batch.updates.len();
        // The chain's bytes (and state tail) land in this interval.
        self.acc.state_bytes += batch.state_tail_bytes;
        self.acc.state_secs += batch.state_tail_secs;
        let busy: Vec<f64> = self
            .devs
            .iter()
            .zip(&self.busy_prev)
            .map(|(d, prev)| d.busy - prev)
            .collect();
        self.busy_prev = self.devs.iter().map(|d| d.busy).collect();
        let est_err = if self.acc.act.is_empty() {
            None
        } else {
            Some(crate::util::stats::mape(&self.acc.act, &self.acc.pred))
        };
        let acc = std::mem::take(&mut self.acc);
        // The chain occupied the NIC for chain_secs ending now.
        self.emit(self.now - batch.chain_secs, self.now, Track::Server, EvKind::Flush {
            flush: self.flushes.len(),
            applied,
            stale: stale_dropped,
        });
        self.flushes.push(FlushRecord {
            flush: self.flushes.len(),
            end: self.now,
            interval: self.now - self.last_flush_end,
            chain_secs: batch.chain_secs,
            bytes: batch.bytes,
            trips: batch.trips,
            updates: applied,
            aggs: batch.aggs,
            stale_dropped,
            staleness_hist: hist,
            group_aggs: batch.group_aggs,
            cross_group_bytes: batch.cross_bytes,
            busy,
            completed: acc.completed,
            dropped: acc.dropped,
            wasted_secs: acc.wasted,
            sched_secs: acc.sched_secs,
            state_bytes: acc.state_bytes,
            state_secs: acc.state_secs,
            unavailable: acc.unavailable,
            est_err,
        });
        self.last_flush_end = self.now;
        self.try_admit(scheduler, source);
    }

    /// Work-conserving admission: while some executor is out of work
    /// and the staleness window has room, pull the next cohort and
    /// place it via the scheduler's greedy step from the executors'
    /// current projected loads.
    fn try_admit(&mut self, scheduler: &mut Scheduler, source: &mut AsyncSource<'_>) {
        loop {
            if self.exhausted {
                return;
            }
            if self.pending >= self.spec.buffer.saturating_mul(self.spec.max_staleness + 1) {
                return;
            }
            if !self.devs.iter().any(|d| d.current.is_none() && d.queue.is_empty()) {
                return;
            }
            let alive = vec![true; self.devs.len()];
            let base: Vec<f64> = (0..self.devs.len()).map(|s| self.projected_load(s)).collect();
            let cohort = match source(scheduler, self.next_cohort, &alive, &base) {
                None => {
                    self.exhausted = true;
                    return;
                }
                Some(c) => c,
            };
            let id = self.next_cohort;
            self.next_cohort += 1;
            self.cohort_rng
                .push(Rng::new(self.dyn_seed).derive(id as u64).derive(0x57A6));
            self.cohort_left.push(cohort.tasks.len());
            self.cohort_tail.push((cohort.state.tail_bytes, cohort.state.tail_secs));
            self.acc.sched_secs += cohort.sched_secs;
            self.acc.unavailable += cohort.unavailable;
            // Virtual-time admission marker; the wallclock sched cost
            // stays in `sched_secs` only (never in the trace, which
            // must be run-to-run identical).
            let placed = cohort.tasks.len();
            self.emit(self.now, self.now, Track::Run, EvKind::Sched { round: id, placed });
            // Batch admission: the cohort becomes an `(arena start,
            // len)` range — its columns are spliced into the arena
            // wholesale (six memcpy-style extends, not one heap object
            // per task) and its state plan is kept cohort-level, with
            // prefetch `ready` times resolved lazily against the
            // admission instant.  Ranges are recorded for empty cohorts
            // too, so cohort id stays a valid index everywhere.
            let n = cohort.tasks.len();
            let base_id = self.a_client.len();
            self.cohort_range.push((base_id, n));
            self.cohort_admit.push(self.now);
            let AsyncCohort { tasks, assigned, state, .. } = cohort;
            self.cohort_state.push(state);
            if n == 0 {
                continue; // fully-unavailable cohort: nothing to run
            }
            self.a_n_eff.extend_from_slice(&tasks.n_eff);
            self.a_noise.extend_from_slice(&tasks.noise);
            self.a_predicted.extend_from_slice(&tasks.predicted);
            self.a_client.extend_from_slice(&tasks.client);
            self.a_cohort.resize(base_id + n, id);
            self.a_born.resize(base_id + n, 0);
            self.a_leg_booked.resize(base_id + n, false);
            self.pending += n;
            // Per-executor batched scheduling: one queue extend per
            // executor instead of one push per task.  Event-identical
            // to the per-task loop — an idle executor by invariant has
            // an empty queue, so its first claim is the same task, and
            // `try_start` on a busy slot consumes no sequence numbers.
            for (slot, q) in assigned.iter().enumerate() {
                self.devs[slot].queue.extend(q.iter().map(|&local| base_id + local));
            }
            // Mirror the sync engine's initial sweep: freed executors
            // claim their first task in slot order.
            for slot in 0..self.devs.len() {
                self.try_start(slot);
            }
        }
    }

    fn run(
        mut self,
        scheduler: &mut Scheduler,
        source: &mut AsyncSource<'_>,
    ) -> (AsyncOutcome, Option<Vec<Ev>>) {
        self.try_admit(scheduler, source);
        loop {
            match self.heap.pop() {
                Some(s) => {
                    self.events += 1;
                    self.now = self.now.max(s.time);
                    match s.event {
                        Event::TaskStart { task, device } => self.on_task_start(device, task),
                        Event::TaskDone { task, device } => {
                            self.on_task_done(device, task, scheduler, source)
                        }
                        Event::ClientUnavailable { task, device } => {
                            self.on_client_unavailable(device, task, scheduler, source)
                        }
                        Event::FlushDone => self.on_flush_done(scheduler, source),
                        other => unreachable!("async dispatcher never schedules {other:?}"),
                    }
                }
                None => {
                    // Quiescent: ship a final partial flush, or admit
                    // more work, or finish.
                    if !self.buffered.is_empty() {
                        self.trigger_flush();
                        continue;
                    }
                    self.try_admit(scheduler, source);
                    if self.heap.is_empty() {
                        break;
                    }
                }
            }
        }
        // Book legs of tasks that never started (cannot happen without
        // churn, but the exactly-once invariant is cheap to keep), and
        // any settled-cohort flush tail a trailing drop left behind —
        // the store already spent those bytes.
        for c in 0..self.cohort_state.len() {
            if self.cohort_state[c].legs.is_empty() {
                continue;
            }
            let (start, n) = self.cohort_range[c];
            for local in 0..n {
                let t = start + local;
                if !self.a_leg_booked[t] {
                    self.a_leg_booked[t] = true;
                    self.acc.state_bytes +=
                        self.cohort_state[c].legs.get(local).map(|l| l.bytes).unwrap_or(0);
                }
            }
        }
        self.acc.state_bytes += std::mem::take(&mut self.ready_tail_bytes);
        self.acc.state_secs += std::mem::take(&mut self.ready_tail_secs);
        // Trailing interval (post-last-flush drops / stats) surfaces as
        // a zero-update record so the columns still sum run-wide.
        let acc = std::mem::take(&mut self.acc);
        if acc.completed > 0
            || acc.dropped > 0
            || acc.state_bytes > 0
            || acc.state_secs > 0.0
            || acc.wasted > 0.0
            || acc.sched_secs > 0.0
            || acc.unavailable > 0
        {
            let busy: Vec<f64> = self
                .devs
                .iter()
                .zip(&self.busy_prev)
                .map(|(d, prev)| d.busy - prev)
                .collect();
            self.flushes.push(FlushRecord {
                flush: self.flushes.len(),
                end: self.now.max(self.last_flush_end),
                interval: (self.now - self.last_flush_end).max(0.0),
                chain_secs: 0.0,
                bytes: 0,
                trips: 0,
                updates: 0,
                aggs: 0,
                stale_dropped: 0,
                staleness_hist: vec![0; self.spec.max_staleness + 1],
                group_aggs: 0,
                cross_group_bytes: 0,
                busy,
                completed: acc.completed,
                dropped: acc.dropped,
                wasted_secs: acc.wasted,
                sched_secs: acc.sched_secs,
                state_bytes: acc.state_bytes,
                state_secs: acc.state_secs,
                unavailable: acc.unavailable,
                est_err: None,
            });
        }
        let trace = self.trace.take();
        let outcome = AsyncOutcome {
            end: self.now,
            busy: self.devs.iter().map(|d| d.busy).collect(),
            completed: self.completed,
            dropped: self.dropped,
            wasted_secs: self.wasted,
            arrivals: self.arrivals,
            cohorts: self.next_cohort,
            events: self.events,
            flushes: self.flushes,
        };
        (outcome, trace)
    }
}

/// Execute an asynchronous buffered run on the work-conserving
/// dispatcher.  `source` feeds cohorts on demand (selection +
/// availability + placement live with the caller); `dyn_seed` seeds the
/// same per-cohort straggler/drop streams the sync engine derives per
/// round, so the degenerate configuration replays identical draws.
#[allow(clippy::too_many_arguments)]
pub fn run_async(
    n_exec: usize,
    cluster: &ClusterProfile,
    cost: &WorkloadCost,
    dynamics: &DynamicsSpec,
    dyn_seed: u64,
    spec: AsyncSpec,
    comm: AsyncComm,
    scheduler: &mut Scheduler,
    source: &mut AsyncSource<'_>,
    trace: Option<&mut Vec<Ev>>,
) -> AsyncOutcome {
    assert!(spec.buffer >= 1, "async buffer must be >= 1");
    assert!(n_exec >= 1, "async dispatch needs at least one executor");
    let core = AsyncCore {
        cluster,
        cost,
        dynamics,
        dyn_seed,
        spec,
        comm,
        a_n_eff: Vec::new(),
        a_noise: Vec::new(),
        a_predicted: Vec::new(),
        a_client: Vec::new(),
        a_cohort: Vec::new(),
        a_born: Vec::new(),
        a_leg_booked: Vec::new(),
        cohort_range: Vec::new(),
        cohort_state: Vec::new(),
        cohort_admit: Vec::new(),
        devs: (0..n_exec)
            .map(|_| ADev { queue: VecDeque::new(), current: None, busy: 0.0 })
            .collect(),
        heap: BinaryHeap::new(),
        seq: 0,
        now: 0.0,
        version: 0,
        pending: 0,
        buffered: Vec::new(),
        chains: VecDeque::new(),
        nic_free: 0.0,
        cohort_rng: Vec::new(),
        cohort_left: Vec::new(),
        cohort_tail: Vec::new(),
        ready_tail_bytes: 0,
        ready_tail_secs: 0.0,
        next_cohort: 0,
        exhausted: false,
        acc: IntervalAcc::default(),
        busy_prev: vec![0.0; n_exec],
        last_flush_end: 0.0,
        flushes: Vec::new(),
        arrivals: Vec::new(),
        completed: 0,
        dropped: 0,
        wasted: 0.0,
        events: 0,
        trace: trace.is_some().then(Vec::new),
    };
    let (out, tr) = core.run(scheduler, source);
    if let (Some(dst), Some(tr)) = (trace, tr) {
        *dst = tr;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulation::availability::{ChurnEvent, ChurnSpec, SlowdownLaw, StragglerSpec};

    fn static_dynamics() -> DynamicsSpec {
        DynamicsSpec::default()
    }

    fn plan_assigned(n_exec: usize, sizes: &[usize], tail: TailComm) -> RoundPlan {
        let tasks: TaskTable =
            sizes.iter().enumerate().map(|(i, &n)| SimTask::new(i, n, 1.0)).collect();
        let mut assigned = vec![Vec::new(); n_exec];
        for i in 0..tasks.len() {
            assigned[i % n_exec].push(i);
        }
        RoundPlan {
            tasks,
            n_exec,
            alive: vec![true; n_exec],
            assigned,
            pull: Vec::new(),
            refill: RefillPolicy::Assigned,
            reassign: ReassignPolicy::LeastLoaded,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail,
            state: StatePlan::default(),
            record_history: false,
        }
    }

    fn homo(k: usize) -> ClusterProfile {
        ClusterProfile::homogeneous(k)
    }

    #[test]
    fn serial_executor_sums_durations() {
        let cost = WorkloadCost::femnist();
        let plan = plan_assigned(1, &[100, 200, 300], TailComm::None);
        let out = run_round(plan, &homo(1), &cost, 0, &static_dynamics(), 1, None);
        let want: f64 = [100, 200, 300]
            .iter()
            .map(|&n| cost.t_sample * n as f64 + cost.b_fixed)
            .sum();
        assert!((out.end - want).abs() < 1e-9, "{} vs {want}", out.end);
        assert_eq!(out.completed_tasks, 3);
        assert_eq!(out.busy.len(), 1);
        assert!((out.busy[0] - want).abs() < 1e-9);
    }

    #[test]
    fn parallel_executors_take_makespan() {
        let cost = WorkloadCost::femnist();
        let plan = plan_assigned(3, &[100, 100, 400], TailComm::None);
        let out = run_round(plan, &homo(3), &cost, 0, &static_dynamics(), 1, None);
        let slowest = cost.t_sample * 400.0 + cost.b_fixed;
        assert!((out.end - slowest).abs() < 1e-9);
        assert_eq!(out.busy.len(), 3);
        assert!(out.busy.iter().all(|&b| b > 0.0));
    }

    #[test]
    fn shared_pull_balances_like_earliest_free() {
        let cost = WorkloadCost::femnist();
        let sizes = [500usize, 400, 300, 200, 100, 50];
        let tasks: TaskTable =
            sizes.iter().enumerate().map(|(i, &n)| SimTask::new(i, n, 1.0)).collect();
        let plan = RoundPlan {
            pull: (0..tasks.len()).collect(),
            tasks,
            n_exec: 2,
            alive: vec![true; 2],
            assigned: vec![Vec::new(); 2],
            refill: RefillPolicy::SharedPull,
            reassign: ReassignPolicy::Requeue,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail: TailComm::None,
            state: StatePlan::default(),
            record_history: false,
        };
        let out = run_round(plan, &homo(2), &cost, 0, &static_dynamics(), 1, None);
        // Greedy earliest-free replay: dev0 <- 500, dev1 <- 400; dev1
        // frees first and pulls 300, etc.
        let d = |n: usize| cost.t_sample * n as f64 + cost.b_fixed;
        let mut free = [0.0f64; 2];
        for &n in &sizes {
            let i = if free[0] <= free[1] { 0 } else { 1 };
            free[i] += d(n);
        }
        let want = free[0].max(free[1]);
        assert!((out.end - want).abs() < 1e-9, "{} vs {}", out.end, want);
        assert_eq!(out.completed_tasks, sizes.len());
    }

    #[test]
    fn device_leave_reassigns_orphans_and_all_tasks_finish() {
        let cost = WorkloadCost::femnist();
        let mut plan = plan_assigned(4, &[300; 12], TailComm::None);
        plan.reassign = ReassignPolicy::LeastLoaded;
        let dynamics = DynamicsSpec {
            churn: ChurnSpec {
                events: vec![ChurnEvent {
                    round: 0,
                    device: 0,
                    secs: 0.1,
                    kind: ChurnKind::Leave,
                }],
                leave_prob: 0.0,
                join_prob: 0.0,
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(4), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.departures, 1);
        assert_eq!(out.dropped_tasks, 0, "orphans must be re-placed");
        assert_eq!(out.completed_tasks, 12);
        assert!(!out.alive[0] && out.alive[1]);
        // the dead device stops accruing busy time, the rest absorb it
        let survivors: f64 = out.busy[1..].iter().sum();
        assert!(survivors > out.busy[0], "{:?}", out.busy);
        assert!(out.wasted_secs >= 0.0);
    }

    #[test]
    fn device_join_pulls_shared_work() {
        let cost = WorkloadCost::femnist();
        let sizes = vec![400usize; 8];
        let tasks: TaskTable =
            sizes.iter().enumerate().map(|(i, &n)| SimTask::new(i, n, 1.0)).collect();
        let plan = RoundPlan {
            pull: (0..tasks.len()).collect(),
            tasks,
            n_exec: 2,
            alive: vec![true, false],
            assigned: vec![Vec::new(); 2],
            refill: RefillPolicy::SharedPull,
            reassign: ReassignPolicy::Requeue,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail: TailComm::None,
            state: StatePlan::default(),
            record_history: false,
        };
        let dynamics = DynamicsSpec {
            churn: ChurnSpec {
                events: vec![ChurnEvent {
                    round: 0,
                    device: 1,
                    secs: 0.0,
                    kind: ChurnKind::Join,
                }],
                leave_prob: 0.0,
                join_prob: 0.0,
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(2), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.joins, 1);
        assert_eq!(out.completed_tasks, 8);
        assert!(out.busy[1] > 0.0, "joined device must have worked: {:?}", out.busy);
    }

    #[test]
    fn client_drop_wastes_partial_work() {
        let cost = WorkloadCost::femnist();
        let plan = plan_assigned(2, &[500; 10], TailComm::None);
        let dynamics = DynamicsSpec {
            straggler: StragglerSpec {
                prob: 0.0,
                law: SlowdownLaw::Fixed(1.0),
                drop_prob: 1.0, // every client vanishes mid-task
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(2), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.dropped_tasks, 10);
        assert_eq!(out.completed_tasks, 0);
        assert!(out.wasted_secs > 0.0);
        assert!(out.busy.iter().all(|&b| b == 0.0), "dropped work is not busy time");
    }

    #[test]
    fn stragglers_stretch_the_round() {
        let cost = WorkloadCost::femnist();
        let base = run_round(
            plan_assigned(2, &[300; 8], TailComm::None),
            &homo(2),
            &cost,
            0,
            &static_dynamics(),
            1,
            None,
        );
        let dynamics = DynamicsSpec {
            straggler: StragglerSpec { prob: 1.0, law: SlowdownLaw::Fixed(4.0), drop_prob: 0.0 },
            ..Default::default()
        };
        let slow = run_round(
            plan_assigned(2, &[300; 8], TailComm::None),
            &homo(2),
            &cost,
            0,
            &dynamics,
            1,
            None,
        );
        assert!((slow.end - 4.0 * base.end).abs() < 1e-9, "{} vs {}", slow.end, base.end);
    }

    #[test]
    fn last_executor_never_leaves() {
        let cost = WorkloadCost::femnist();
        let plan = plan_assigned(1, &[100; 3], TailComm::None);
        let dynamics = DynamicsSpec {
            churn: ChurnSpec {
                events: vec![ChurnEvent {
                    round: 0,
                    device: 0,
                    secs: 0.0,
                    kind: ChurnKind::Leave,
                }],
                leave_prob: 0.0,
                join_prob: 0.0,
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(1), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.departures, 0);
        assert_eq!(out.completed_tasks, 3);
    }

    #[test]
    fn state_loads_serialize_without_prefetch() {
        use crate::statestore::StateLeg;
        let cost = WorkloadCost::femnist();
        let compute = cost.t_sample * 200.0 + cost.b_fixed;
        let mut plan = plan_assigned(1, &[200, 200], TailComm::None);
        plan.state = StatePlan {
            legs: vec![
                StateLeg { bytes: 1000, secs: 0.5, ready: 0.5 },
                StateLeg { bytes: 2000, secs: 0.5, ready: 1.0 },
            ],
            prefetch: false,
            tail_bytes: 0,
            tail_secs: 0.0,
        };
        let out = run_round(plan, &homo(1), &cost, 0, &static_dynamics(), 1, None);
        assert!((out.end - (2.0 * compute + 1.0)).abs() < 1e-9, "{}", out.end);
        assert_eq!(out.state_bytes, 3000);
        assert!((out.state_secs - 1.0).abs() < 1e-9);
        // Load stalls are neither busy compute nor comm occupancy.
        assert!((out.busy[0] - 2.0 * compute).abs() < 1e-9);
        assert_eq!(out.completed_tasks, 2);
    }

    #[test]
    fn prefetch_pipelines_loads_behind_compute() {
        use crate::statestore::StateLeg;
        let cost = WorkloadCost::femnist();
        let compute = cost.t_sample * 200.0 + cost.b_fixed; // 0.55s
        let mut plan = plan_assigned(1, &[200, 200], TailComm::None);
        // Channel: first load ready at 0.3, second at 0.6 — the second
        // finishes while task 1 computes, so only the initial 0.3 stalls.
        plan.state = StatePlan {
            legs: vec![
                StateLeg { bytes: 10, secs: 0.3, ready: 0.3 },
                StateLeg { bytes: 10, secs: 0.3, ready: 0.6 },
            ],
            prefetch: true,
            tail_bytes: 0,
            tail_secs: 0.0,
        };
        let out = run_round(plan, &homo(1), &cost, 0, &static_dynamics(), 1, None);
        assert!(
            (out.end - (0.3 + 2.0 * compute)).abs() < 1e-9,
            "prefetch must hide the second load: {} vs {}",
            out.end,
            0.3 + 2.0 * compute
        );
        assert!((out.state_secs - 0.3).abs() < 1e-9);
        assert_eq!(out.state_bytes, 20);
    }

    #[test]
    fn state_flush_tail_extends_round_and_books_bytes() {
        let cost = WorkloadCost::femnist();
        let mut plan = plan_assigned(2, &[100, 100], TailComm::None);
        plan.state = StatePlan {
            legs: vec![Default::default(); 2],
            prefetch: true,
            tail_bytes: 4096,
            tail_secs: 0.25,
        };
        let base = run_round(
            plan_assigned(2, &[100, 100], TailComm::None),
            &homo(2),
            &cost,
            0,
            &static_dynamics(),
            1,
            None,
        );
        let out = run_round(plan, &homo(2), &cost, 0, &static_dynamics(), 1, None);
        assert!((out.end - (base.end + 0.25)).abs() < 1e-9);
        assert_eq!(out.state_bytes, 4096);
    }

    #[test]
    fn dropped_tasks_still_book_planned_state_bytes() {
        use crate::statestore::StateLeg;
        let cost = WorkloadCost::femnist();
        let mut plan = plan_assigned(2, &[300; 6], TailComm::None);
        plan.state = StatePlan {
            legs: vec![StateLeg { bytes: 100, secs: 0.0, ready: 0.0 }; 6],
            prefetch: true,
            tail_bytes: 50,
            tail_secs: 0.0,
        };
        let dynamics = DynamicsSpec {
            straggler: StragglerSpec {
                prob: 0.0,
                law: SlowdownLaw::Fixed(1.0),
                drop_prob: 1.0, // every client vanishes mid-task
            },
            ..Default::default()
        };
        let out = run_round(plan, &homo(2), &cost, 0, &dynamics, 1, None);
        assert_eq!(out.dropped_tasks, 6);
        assert_eq!(
            out.state_bytes,
            6 * 100 + 50,
            "prefetched bytes are spent whether or not the task survives"
        );
    }

    #[test]
    fn per_task_comm_occupies_but_is_not_busy() {
        let cost = WorkloadCost::femnist();
        let mut plan = plan_assigned(2, &[200; 4], TailComm::None);
        plan.per_task_comm = (0.5, 0.5);
        plan.per_task_bytes = (10, 10);
        let out = run_round(plan, &homo(2), &cost, 0, &static_dynamics(), 1, None);
        let compute = cost.t_sample * 200.0 + cost.b_fixed;
        // two tasks per device, each occupying compute + 1s comm
        assert!((out.end - 2.0 * (compute + 1.0)).abs() < 1e-9);
        assert!((out.busy[0] - 2.0 * compute).abs() < 1e-9);
        assert!((out.comm_occ[0] - 2.0).abs() < 1e-9);
        assert_eq!(out.bytes, 4 * 20);
        assert_eq!(out.trips, 8);
    }

    // ------------------------------------------------ tiered tails

    fn tiered(k: usize, n_groups: usize, c: &ClusterProfile) -> TieredTail {
        TieredTail {
            s_a_down: 1_000_000,
            s_a_up: 1_000_000,
            s_e_total: 0,
            group_of: (0..k).map(|d| d % n_groups).collect(),
            n_groups,
            levels: vec![n_groups],
            wan_bandwidth: c.bandwidth,
            wan_latency: c.latency,
            lan_bandwidth: c.bandwidth,
            lan_latency: c.latency,
        }
    }

    #[test]
    fn flat_hierarchical_tail_books_everything_as_cross_group() {
        let cost = WorkloadCost::femnist();
        let plan = plan_assigned(
            4,
            &[100; 8],
            TailComm::Hierarchical { s_a_down: 500, s_a_up: 300, s_e_total: 40 },
        );
        let out = run_round(plan, &homo(4), &cost, 0, &static_dynamics(), 1, None);
        assert_eq!(out.bytes, 4 * 500 + 4 * 300 + 40);
        assert_eq!(out.cross_group_bytes, out.bytes, "flat tail: every leg is WAN");
        assert_eq!(out.group_aggs, 4);
    }

    #[test]
    fn tiered_tail_prices_groups_and_shrinks_cross_bytes() {
        let cost = WorkloadCost::femnist();
        let cluster = homo(4);
        let tt = tiered(4, 2, &cluster);
        let (s_a, lat, bw) = (1_000_000u64, cluster.latency, cluster.bandwidth);
        let plan = plan_assigned(4, &[100; 8], TailComm::Tiered(tt));
        let out = run_round(plan, &cluster, &cost, 0, &static_dynamics(), 1, None);
        // bytes: down = s_a·(2 groups + 4 devices); up = s_a·(4 members
        // + 2 group aggregates).
        assert_eq!(out.bytes, s_a * (2 + 4) + s_a * (4 + 2));
        // cross-WAN: only the root-adjacent legs.
        assert_eq!(out.cross_group_bytes, s_a * 2 + s_a * 2);
        assert_eq!(out.group_aggs, 2);
        assert_eq!(out.trips, (2 + 4) + (4 + 2));
        // time: down wave (WAN hop + member hop) + member burst
        // (2 members serialize per group, groups overlap) + WAN chain
        // (2 group aggregates).
        let payload = s_a as f64 / bw;
        let want_tail = (lat + payload) + (lat + payload)       // down
            + (lat + payload + lat)                             // leaf burst
            + (lat + payload + lat);                            // WAN chain
        assert!(
            (out.end - out.work_end - want_tail).abs() < 1e-9,
            "tail {} vs {want_tail}",
            out.end - out.work_end
        );
        // The flat tail at the same sizes crosses strictly more WAN
        // bytes (4 uploads + 4 broadcasts vs 2 + 2).
        let flat = run_round(
            plan_assigned(
                4,
                &[100; 8],
                TailComm::Hierarchical { s_a_down: s_a, s_a_up: s_a, s_e_total: 0 },
            ),
            &cluster,
            &cost,
            0,
            &static_dynamics(),
            1,
            None,
        );
        assert!(out.cross_group_bytes < flat.cross_group_bytes);
    }

    #[test]
    fn tiered_tail_depth_two_adds_one_merge_hop() {
        let cost = WorkloadCost::femnist();
        let cluster = homo(4);
        let mut tt = tiered(4, 4, &cluster); // 4 leaf groups, 1 device each
        tt.levels = vec![2, 2]; // ... under 2 top-level sites
        let (s_a, lat, bw) = (1_000_000u64, cluster.latency, cluster.bandwidth);
        let plan = plan_assigned(4, &[100; 4], TailComm::Tiered(tt));
        let out = run_round(plan, &cluster, &cost, 0, &static_dynamics(), 1, None);
        let payload = s_a as f64 / bw;
        // down: WAN hop + intermediate relay + member hop; up: leaf
        // burst (1 member) + intermediate merge (2 children serialize,
        // parents overlap) + WAN chain (2 top aggregates).
        let want_tail = (lat + payload) + (lat + payload) + (lat + payload)
            + (lat + payload)
            + (lat + payload + lat)
            + (lat + payload + lat);
        assert!(
            (out.end - out.work_end - want_tail).abs() < 1e-9,
            "tail {} vs {want_tail}",
            out.end - out.work_end
        );
        // bytes: down 2 top + 4 leaf relays + 4 devices; up 4 members +
        // 4 leaf aggs + 2 top aggs.  Cross-WAN: 2 down + 2 up.
        assert_eq!(out.bytes, s_a * (2 + 4 + 4) + s_a * (4 + 4 + 2));
        assert_eq!(out.cross_group_bytes, s_a * 4);
        assert_eq!(out.group_aggs, 2, "the server merges the top tier");
    }

    #[test]
    fn tiered_tail_skips_dead_groups() {
        // Both devices of group 1 never existed (alive=false from the
        // start): its legs must not be priced or booked.
        let cost = WorkloadCost::femnist();
        let cluster = homo(4);
        let tt = tiered(4, 2, &cluster);
        let s_a = 1_000_000u64;
        let tasks: TaskTable = (0..4).map(|i| SimTask::new(i, 100, 1.0)).collect();
        let plan = RoundPlan {
            tasks,
            n_exec: 4,
            alive: vec![true, false, true, false], // group 1 = slots 1,3 dead
            assigned: vec![vec![0, 1], Vec::new(), vec![2, 3], Vec::new()],
            pull: Vec::new(),
            refill: RefillPolicy::Assigned,
            reassign: ReassignPolicy::LeastLoaded,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail: TailComm::Tiered(tt),
            state: StatePlan::default(),
            record_history: false,
        };
        let out = run_round(plan, &cluster, &cost, 0, &static_dynamics(), 1, None);
        assert_eq!(out.group_aggs, 1, "only group 0 reports");
        // down: 1 group + 2 devices; up: 2 members + 1 group aggregate.
        assert_eq!(out.bytes, s_a * (1 + 2) + s_a * (2 + 1));
        assert_eq!(out.cross_group_bytes, s_a * 2);
    }

    // ------------------------------------------------ async dispatcher

    use crate::config::SchedulerKind;

    /// Cohort source over fixed per-cohort client-size lists, placed
    /// through the scheduler's incremental greedy step (noise 1.0).
    fn fixed_source(
        cohorts: Vec<Vec<usize>>,
    ) -> impl FnMut(&mut Scheduler, usize, &[bool], &[f64]) -> Option<AsyncCohort> {
        move |sched, c, alive, base| {
            let sizes = cohorts.get(c)?;
            let clients: Vec<(usize, usize)> =
                sizes.iter().enumerate().map(|(i, &n)| (i, n)).collect();
            let schedule = sched.schedule_from(c, &clients, alive, base);
            let mut tasks = TaskTable::new();
            let mut assigned = vec![Vec::new(); alive.len()];
            for (dev, cls) in schedule.assignment.iter().enumerate() {
                for &cl in cls {
                    assigned[dev].push(tasks.len());
                    tasks.push(SimTask::new(cl, sizes[cl], 1.0));
                }
            }
            Some(AsyncCohort {
                tasks,
                assigned,
                state: StatePlan::default(),
                sched_secs: 0.0,
                unavailable: 0,
            })
        }
    }

    fn no_comm() -> AsyncComm {
        AsyncComm { s_a_down: 0, s_a_up: 0, s_e: 0, tier: None }
    }

    fn flat_weight() -> AsyncSpec {
        AsyncSpec {
            buffer: 1,
            max_staleness: 0,
            weight: crate::aggregation::StalenessWeight::Const,
        }
    }

    #[test]
    fn async_flushes_every_buffer_updates_and_accounts_exactly() {
        let cost = WorkloadCost::femnist();
        let mut sched = Scheduler::new(SchedulerKind::Uniform, 0, 2);
        let mut source = fixed_source(vec![vec![200; 4], vec![200; 4], vec![200; 4]]);
        let spec = AsyncSpec { buffer: 4, ..flat_weight() };
        let out = run_async(
            2,
            &homo(2),
            &cost,
            &static_dynamics(),
            7,
            spec,
            no_comm(),
            &mut sched,
            &mut source,
            None,
        );
        assert_eq!(out.completed, 12);
        assert_eq!(out.cohorts, 3);
        assert_eq!(out.flushes.len(), 3, "12 updates / buffer 4");
        let applied: usize = out.flushes.iter().map(|f| f.updates).sum();
        let stale: usize = out.flushes.iter().map(|f| f.stale_dropped).sum();
        assert_eq!(applied + stale, out.completed, "every arrival is flushed exactly once");
        assert_eq!(out.arrivals.len(), out.completed);
        // Intervals tile the run.
        let sum: f64 = out.flushes.iter().map(|f| f.interval).sum();
        assert!((sum - out.end).abs() < 1e-9, "{sum} vs {}", out.end);
        // With buffer == cohort size and S = 0, nothing is ever stale.
        assert_eq!(stale, 0);
        for f in &out.flushes {
            assert_eq!(f.staleness_hist[0], f.updates, "{f:?}");
            assert_eq!(f.aggs, 2);
        }
        // Busy columns: per-interval deltas sum to the run totals.
        let total: f64 = out.busy.iter().sum();
        let by_flush: f64 = out.flushes.iter().flat_map(|f| f.busy.iter()).sum();
        assert!((total - by_flush).abs() < 1e-9);
    }

    #[test]
    fn async_work_conservation_beats_the_barrier_under_skew() {
        // One executor is 4x slower (hetero profile).  With staleness
        // room, the fast executor keeps pulling new cohorts while the
        // slow one grinds — the run must finish strictly sooner than
        // the barrier-equivalent configuration on the identical stream.
        let cost = WorkloadCost::femnist();
        let mut hetero = ClusterProfile::homogeneous(2);
        hetero.devices[1].static_slowdown = 4.0;
        let cohorts: Vec<Vec<usize>> = (0..6).map(|_| vec![300; 4]).collect();
        let run = |buffer: usize, max_staleness: usize| {
            let mut sched = Scheduler::new(SchedulerKind::Uniform, 0, 2);
            let mut source = fixed_source(cohorts.clone());
            run_async(
                2,
                &hetero,
                &cost,
                &static_dynamics(),
                7,
                AsyncSpec { buffer, max_staleness, ..flat_weight() },
                no_comm(),
                &mut sched,
                &mut source,
                None,
            )
        };
        let barrier = run(4, 0); // flush per cohort, no pipeline depth
        let buffered = run(2, 3);
        assert_eq!(barrier.completed, buffered.completed);
        assert!(
            buffered.end < barrier.end,
            "work-conserving {:.2}s !< barrier {:.2}s",
            buffered.end,
            barrier.end
        );
        // The fast device absorbs more of the stream when unblocked.
        assert!(buffered.busy[0] > barrier.busy[0] - 1e-9);
    }

    #[test]
    fn async_overtaken_updates_get_dropped_as_stale() {
        // buffer=1 + a 10x-slow executor: the slow task is overtaken by
        // a stream of fast flushes and must land with staleness > 0 —
        // beyond max_staleness 0 it is discarded, not applied.
        let cost = WorkloadCost::femnist();
        let mut skew = ClusterProfile::homogeneous(2);
        skew.devices[1].static_slowdown = 10.0;
        let mut sched = Scheduler::new(SchedulerKind::Uniform, 0, 2);
        // Uniform round-robin puts half the tasks on the slow device.
        let mut source = fixed_source(vec![vec![400; 6], vec![400; 6]]);
        let out = run_async(
            2,
            &skew,
            &cost,
            &static_dynamics(),
            7,
            AsyncSpec { buffer: 1, max_staleness: 0, weight: crate::aggregation::StalenessWeight::Const },
            no_comm(),
            &mut sched,
            &mut source,
            None,
        );
        let stale: usize = out.flushes.iter().map(|f| f.stale_dropped).sum();
        let applied: usize = out.flushes.iter().map(|f| f.updates).sum();
        assert!(stale > 0, "slow-device updates must exceed staleness 0");
        assert_eq!(applied + stale, out.completed);
        // Raising the bound re-admits them (same stream, same seeds).
        let mut sched2 = Scheduler::new(SchedulerKind::Uniform, 0, 2);
        let mut source2 = fixed_source(vec![vec![400; 6], vec![400; 6]]);
        let out2 = run_async(
            2,
            &skew,
            &cost,
            &static_dynamics(),
            7,
            AsyncSpec {
                buffer: 1,
                max_staleness: 50,
                weight: crate::aggregation::StalenessWeight::Poly(0.5),
            },
            no_comm(),
            &mut sched2,
            &mut source2,
            None,
        );
        let stale2: usize = out2.flushes.iter().map(|f| f.stale_dropped).sum();
        assert_eq!(stale2, 0);
        // ...and the histogram actually records the nonzero staleness.
        let old: usize = out2
            .flushes
            .iter()
            .flat_map(|f| f.staleness_hist.iter().enumerate())
            .filter(|&(s, &n)| s > 0 && n > 0)
            .count();
        assert!(old > 0, "overtaken updates must show staleness > 0");
    }

    #[test]
    fn async_books_state_legs_exactly_once_with_flush_tails() {
        use crate::statestore::StateLeg;
        let cost = WorkloadCost::femnist();
        let mut sched = Scheduler::new(SchedulerKind::Uniform, 0, 1);
        let legs_per = 3usize;
        let mut source = move |s: &mut Scheduler,
                               c: usize,
                               alive: &[bool],
                               base: &[f64]|
              -> Option<AsyncCohort> {
            if c >= 2 {
                return None;
            }
            let clients: Vec<(usize, usize)> = (0..legs_per).map(|i| (i, 200)).collect();
            let schedule = s.schedule_from(c, &clients, alive, base);
            let mut tasks = TaskTable::new();
            let mut assigned = vec![Vec::new(); alive.len()];
            for (dev, cls) in schedule.assignment.iter().enumerate() {
                for &cl in cls {
                    assigned[dev].push(tasks.len());
                    tasks.push(SimTask::new(cl, 200, 1.0));
                }
            }
            Some(AsyncCohort {
                tasks,
                assigned,
                state: StatePlan {
                    legs: vec![StateLeg { bytes: 100, secs: 0.05, ready: 0.05 }; legs_per],
                    prefetch: false,
                    tail_bytes: 40,
                    tail_secs: 0.1,
                },
                sched_secs: 0.0,
                unavailable: 0,
            })
        };
        let out = run_async(
            1,
            &homo(1),
            &cost,
            &static_dynamics(),
            3,
            AsyncSpec { buffer: 3, ..flat_weight() },
            no_comm(),
            &mut sched,
            &mut source,
            None,
        );
        let state_bytes: u64 = out.flushes.iter().map(|f| f.state_bytes).sum();
        assert_eq!(
            state_bytes,
            2 * (legs_per as u64 * 100 + 40),
            "every leg and every cohort tail booked exactly once"
        );
        let state_secs: f64 = out.flushes.iter().map(|f| f.state_secs).sum();
        assert!((state_secs - 2.0 * (legs_per as f64 * 0.05 + 0.1)).abs() < 1e-9);
    }

    // ------------------------------------------------ orphan placement

    /// Build a Core directly over `plan` (the single-heap shape) so the
    /// placement paths can be driven with hand-picked liveness.
    fn core_for<'a>(
        plan: &'a RoundPlan,
        cluster: &'a ClusterProfile,
        cost: &'a WorkloadCost,
        dynamics: &'a DynamicsSpec,
    ) -> Core<'a> {
        let execs = exec_states(plan);
        let alive = execs.iter().filter(|e| e.alive).count();
        let n_tasks = plan.tasks.len();
        Core {
            round: 0,
            cluster,
            cost,
            dynamics,
            rng: Rng::new(7),
            clients: &plan.tasks.client,
            n_effs: &plan.tasks.n_eff,
            noises: &plan.tasks.noise,
            ids: None,
            task_state: plan.tasks.state.clone(),
            task_realized: plan.tasks.realized.clone(),
            execs,
            alive,
            shared: plan.pull.iter().copied().collect(),
            refill: plan.refill,
            reassign: plan.reassign,
            comm_down: plan.per_task_comm.0,
            comm_up: plan.per_task_comm.1,
            bytes_down: plan.per_task_bytes.0,
            bytes_up: plan.per_task_bytes.1,
            state_legs: &plan.state.legs,
            state_prefetch: plan.state.prefetch,
            state_tail_bytes: plan.state.tail_bytes,
            state_tail_secs: plan.state.tail_secs,
            state_booked: vec![false; n_tasks],
            state_bytes: 0,
            state_secs: 0.0,
            record_history: plan.record_history,
            heap: BinaryHeap::new(),
            now: 0.0,
            work_end: 0.0,
            seq: 0,
            seq_stride: 1,
            sched_ops: None,
            trace: None,
            key: (0, 0),
            bytes: 0,
            trips: 0,
            cross_bytes: 0,
            group_aggs: 0,
            wasted: 0.0,
            dropped: 0,
            completed: 0,
            departures: 0,
            joins: 0,
            events: 0,
        }
    }

    /// Regression: `place_least_loaded` used to index `execs[usize::MAX]`
    /// when every executor was dead (no candidate beat `f64::INFINITY`).
    /// The orphans must be dropped and counted, not a panic.
    #[test]
    fn place_least_loaded_with_all_executors_dead_drops_orphans() {
        let cost = WorkloadCost::femnist();
        let cluster = homo(2);
        let dynamics = static_dynamics();
        let mut plan = plan_assigned(2, &[100, 100], TailComm::None);
        plan.reassign = ReassignPolicy::LeastLoaded;
        let mut core = core_for(&plan, &cluster, &cost, &dynamics);
        for e in &mut core.execs {
            e.alive = false;
            e.queue.clear();
        }
        core.alive = 0;
        core.place_least_loaded(vec![0, 1]);
        assert_eq!(core.dropped, 2);
        assert!(core.task_state.iter().all(|&s| s == TaskState::Dropped));
        assert!(core.execs.iter().all(|e| e.queue.is_empty()));
    }

    /// The same guard on the `Greedy` fallback route: without a
    /// scheduler the greedy policy degrades to least-loaded placement,
    /// and with every slot dead `place_orphans` must drop (not panic).
    #[test]
    fn greedy_fallback_with_all_executors_dead_drops_orphans() {
        let cost = WorkloadCost::femnist();
        let cluster = homo(3);
        let dynamics = static_dynamics();
        let mut plan = plan_assigned(3, &[100, 100, 100], TailComm::None);
        plan.reassign = ReassignPolicy::Greedy;
        let mut core = core_for(&plan, &cluster, &cost, &dynamics);
        for e in &mut core.execs {
            e.alive = false;
            e.queue.clear();
        }
        core.alive = 0;
        let mut no_sched: Option<&mut Scheduler> = None;
        core.place_orphans(vec![0, 1, 2], &mut no_sched);
        assert_eq!(core.dropped, 3);
        assert!(core.task_state.iter().all(|&s| s == TaskState::Dropped));
        // ...and with one survivor the fallback still places there.
        let mut plan2 = plan_assigned(3, &[100, 100, 100], TailComm::None);
        plan2.reassign = ReassignPolicy::Greedy;
        let mut core2 = core_for(&plan2, &cluster, &cost, &dynamics);
        for e in &mut core2.execs {
            e.alive = false;
            e.queue.clear();
        }
        core2.execs[1].alive = true;
        core2.alive = 1;
        core2.place_orphans(vec![0, 2], &mut no_sched);
        assert_eq!(core2.dropped, 0);
        assert_eq!(core2.execs[1].queue.len(), 2);
    }

    /// End-to-end: scripted total churn mid-round under LeastLoaded —
    /// every device receives a Leave.  The last-executor guard keeps one
    /// alive, the round completes, and nothing panics.
    #[test]
    fn total_churn_mid_round_completes_without_panic() {
        let cost = WorkloadCost::femnist();
        for reassign in [ReassignPolicy::LeastLoaded, ReassignPolicy::Greedy] {
            let mut plan = plan_assigned(3, &[300; 9], TailComm::None);
            plan.reassign = reassign;
            let dynamics = DynamicsSpec {
                churn: ChurnSpec {
                    events: (0..3)
                        .map(|d| ChurnEvent {
                            round: 0,
                            device: d,
                            secs: 0.2,
                            kind: ChurnKind::Leave,
                        })
                        .collect(),
                    leave_prob: 0.0,
                    join_prob: 0.0,
                },
                ..Default::default()
            };
            let out = run_round(plan, &homo(3), &cost, 0, &dynamics, 1, None);
            assert_eq!(out.departures, 2, "the last executor never leaves");
            assert_eq!(
                out.completed_tasks + out.dropped_tasks,
                9,
                "every task resolves: {:?}",
                out
            );
            assert_eq!(out.completed_tasks, 9, "orphans land on the survivor");
        }
    }

    /// Satellite pin: the incremental `alive` counter (which replaced
    /// the O(devices) scan on `churn_roll`'s per-event path) must track
    /// the reference scan exactly under scripted churn — including the
    /// no-op edges (double-leave, double-join, out-of-range slots) and
    /// the last-executor guard that refuses the final leave.
    #[test]
    fn alive_counter_matches_scan_under_scripted_churn() {
        let cost = WorkloadCost::femnist();
        let cluster = homo(4);
        let dynamics = static_dynamics();
        let plan = plan_assigned(4, &[100; 8], TailComm::None);
        let mut core = core_for(&plan, &cluster, &cost, &dynamics);
        let mut no_sched: Option<&mut Scheduler> = None;
        assert_eq!(core.alive, core.alive_scan());
        // (slot, leave?) script exercising every transition edge.
        let script: &[(usize, bool)] = &[
            (1, true),  // plain leave
            (1, true),  // double-leave: no-op
            (3, true),  // plain leave
            (9, true),  // out-of-range: no-op
            (1, false), // rejoin
            (9, false), // out-of-range join: no-op
            (0, false), // join on an alive slot: no-op
            (0, true),
            (2, true),
            (3, true),
            (1, true),  // last executor: guard refuses, stays alive
            (2, false),
            (0, false),
        ];
        for &(slot, leave) in script {
            if leave {
                core.on_device_leave(slot, &mut no_sched);
            } else {
                core.on_device_join(slot);
            }
            assert_eq!(
                core.alive,
                core.alive_scan(),
                "counter drifted from the scan after {:?} on slot {slot}",
                if leave { "leave" } else { "join" }
            );
        }
        assert!(core.alive >= 1, "the guard never orphans the round");
    }

    // ------------------------------------------------ sharded engine

    /// Tentpole pin (satellite 4): on random grouped topologies with
    /// churn and straggler/drop injection, the sharded engine's merged
    /// typed event trace (every [`Ev`] field, including the `(time,
    /// seq)` merge key) and every outcome column must match the
    /// `--threads 1` run event-for-event at 2 and 8 workers.  Failures
    /// print the generator seed via the prop harness
    /// (`PARROT_PROP_SEED` contract).
    #[test]
    fn prop_sharded_pop_sequence_is_thread_invariant() {
        crate::util::prop::check("sharded pop sequence thread-invariant", 12, |g| {
            let k = g.int(2, 10);
            let n_groups = g.int(2, k.min(5));
            let n_tasks = g.int(1, 24);
            let sizes: Vec<usize> = (0..n_tasks).map(|_| g.int(20, 400)).collect();
            let straggler_prob = g.f64(0.0, 0.5);
            let drop_prob = g.f64(0.0, 0.25);
            let slowdown = g.f64(1.5, 6.0);
            let leave_prob = g.f64(0.0, 0.15);
            let join_prob = g.f64(0.0, 0.15);
            let events: Vec<ChurnEvent> = (0..g.int(0, 3))
                .map(|_| ChurnEvent {
                    round: 0,
                    device: g.int(0, k - 1),
                    secs: g.f64(0.0, 2.0),
                    kind: if g.bool() { ChurnKind::Leave } else { ChurnKind::Join },
                })
                .collect();
            let reassign = *g.pick(&[
                ReassignPolicy::LeastLoaded,
                ReassignPolicy::Requeue,
                ReassignPolicy::Greedy,
            ]);
            let dyn_seed = g.rng.next_u64();
            let cluster = ClusterProfile::heterogeneous(k);
            let cost = WorkloadCost::femnist();
            let dynamics = DynamicsSpec {
                churn: ChurnSpec { events: events.clone(), leave_prob, join_prob },
                straggler: StragglerSpec {
                    prob: straggler_prob,
                    law: SlowdownLaw::Fixed(slowdown),
                    drop_prob,
                },
                ..Default::default()
            };
            // RoundPlan is not Clone: regenerate it per run from the
            // drawn parameters.
            let mk_plan = || {
                let mut plan = plan_assigned(
                    k,
                    &sizes,
                    TailComm::Tiered(tiered(k, n_groups, &cluster)),
                );
                plan.reassign = reassign;
                plan
            };
            let run_at = |threads: usize| {
                let mut tr: Vec<Ev> = Vec::new();
                let out = run_round_opts(
                    mk_plan(),
                    &cluster,
                    &cost,
                    0,
                    &dynamics,
                    dyn_seed,
                    None,
                    threads,
                    Some(&mut tr),
                );
                (out, tr)
            };
            let (base, base_tr) = run_at(1);
            if base_tr.is_empty() {
                return Err("sharded run recorded no pop events".into());
            }
            for threads in [2usize, 8] {
                let (out, tr) = run_at(threads);
                if tr != base_tr {
                    let i = tr
                        .iter()
                        .zip(&base_tr)
                        .position(|(a, b)| a != b)
                        .unwrap_or(base_tr.len().min(tr.len()));
                    return Err(format!(
                        "pop sequence diverged at --threads {threads}, event {i}: \
                         {:?} vs {:?} (lens {} vs {})",
                        tr.get(i),
                        base_tr.get(i),
                        tr.len(),
                        base_tr.len()
                    ));
                }
                let summary = |o: &RoundOutcome| {
                    (
                        o.end.to_bits(),
                        o.work_end.to_bits(),
                        o.bytes,
                        o.trips,
                        o.completed_tasks,
                        o.dropped_tasks,
                        o.departures,
                        o.joins,
                        o.cross_group_bytes,
                        o.group_aggs,
                    )
                };
                if summary(&out) != summary(&base) {
                    return Err(format!(
                        "outcome diverged at --threads {threads}: {:?} vs {:?}",
                        summary(&out),
                        summary(&base)
                    ));
                }
                let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                if bits(&out.busy) != bits(&base.busy) {
                    return Err(format!(
                        "per-executor busy columns diverged at --threads {threads}: \
                         {:?} vs {:?}",
                        out.busy, base.busy
                    ));
                }
            }
            Ok(())
        });
    }
}
