//! Client availability, device churn, and straggler injection — the
//! dynamic-hardware scenarios of §4.4 ("Tackling Dynamic Hardware
//! Environments") that the per-scheme virtual-clock loops could never
//! express, now first-class inputs to the discrete-event engine.
//!
//! Three orthogonal models, all seeded and deterministic:
//!
//! - [`AvailabilityModel`] — which *clients* can participate in a round
//!   (Bernoulli draws, a periodic duty-cycle law, or an explicit
//!   trace).  A client unavailable at round r is never scheduled; a
//!   positive `drop_prob` in [`StragglerSpec`] additionally lets a
//!   scheduled client vanish *mid-task* (the engine's
//!   `ClientUnavailable` event).
//! - [`ChurnSpec`] — *devices* joining/leaving, either scripted
//!   (`leave@round:slot[:secs]`) or as per-round random rates.  A
//!   departure mid-round orphans the device's tasks; the engine
//!   re-places them through the scheduler's greedy step.
//! - [`StragglerSpec`] — injectable stragglers: with probability `prob`
//!   a task's duration is multiplied by a draw from a configurable
//!   [`SlowdownLaw`] (fixed, uniform, or Pareto-tailed).
//!
//! [`DynamicsSpec`] bundles the three and rides on
//! [`RunConfig`](crate::config::RunConfig) (CLI: `--availability`,
//! `--churn`, `--stragglers`, `--drop-prob`).  The default spec is
//! fully static, under which the engine reproduces the legacy
//! closed-form timelines exactly.

use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Round-level client availability.
#[derive(Debug, Clone, Default)]
pub enum AvailabilityModel {
    /// Every client is always available (the static baseline).
    #[default]
    Always,
    /// Each (round, client) pair is available independently with
    /// probability `p` — the classic cross-device participation model.
    Bernoulli(f64),
    /// Deterministic duty cycle: client `c` is offline at round `r`
    /// when `(r + c) % period < offline` — a cheap stand-in for
    /// diurnal / charging-pattern traces.
    Periodic { period: usize, offline: usize },
    /// Explicit trace: `round -> set of unavailable clients`.
    Trace(BTreeMap<usize, BTreeSet<usize>>),
}

impl AvailabilityModel {
    /// Is `client` available at `round`?  Deterministic in
    /// `(seed, round, client)` so repeated queries agree.
    pub fn is_available(&self, round: usize, client: usize, seed: u64) -> bool {
        match self {
            AvailabilityModel::Always => true,
            AvailabilityModel::Bernoulli(p) => {
                let mut r = Rng::new(seed ^ 0xA11A_B1E5)
                    .derive(round as u64)
                    .derive(client as u64);
                r.next_f64() < *p
            }
            AvailabilityModel::Periodic { period, offline } => {
                if *period == 0 {
                    true
                } else {
                    (round + client) % period >= *offline
                }
            }
            AvailabilityModel::Trace(t) => {
                !t.get(&round).map(|s| s.contains(&client)).unwrap_or(false)
            }
        }
    }

    /// Parse `always | 0.8 | bernoulli:0.8 | periodic:PERIOD:OFFLINE`.
    pub fn parse(s: &str) -> Result<AvailabilityModel> {
        if s == "always" || s == "1" || s == "1.0" {
            return Ok(AvailabilityModel::Always);
        }
        if let Some(p) = s.strip_prefix("bernoulli:") {
            return Self::bernoulli_checked(p.parse()?);
        }
        if let Some(rest) = s.strip_prefix("periodic:") {
            let (period, offline) = match rest.split_once(':') {
                Some((a, b)) => (a.parse()?, b.parse()?),
                None => bail!("periodic availability needs periodic:PERIOD:OFFLINE"),
            };
            return Ok(AvailabilityModel::Periodic { period, offline });
        }
        if let Ok(p) = s.parse::<f64>() {
            return Self::bernoulli_checked(p);
        }
        bail!("unknown availability model {s:?} (always|P|bernoulli:P|periodic:T:O)")
    }

    fn bernoulli_checked(p: f64) -> Result<AvailabilityModel> {
        if !(0.0..=1.0).contains(&p) {
            bail!("availability probability {p} outside [0, 1]");
        }
        Ok(AvailabilityModel::Bernoulli(p))
    }
}

/// Scripted or random device arrival/departure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnKind {
    Join,
    Leave,
}

/// One scripted churn event: at virtual second `secs` of round `round`,
/// executor slot `device` joins or leaves.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    pub round: usize,
    pub device: usize,
    pub secs: f64,
    pub kind: ChurnKind,
}

#[derive(Debug, Clone, Default)]
pub struct ChurnSpec {
    pub events: Vec<ChurnEvent>,
    /// Per-round probability that an alive device departs mid-round.
    pub leave_prob: f64,
    /// Per-round probability that a departed slot rejoins mid-round.
    pub join_prob: f64,
}

impl ChurnSpec {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.leave_prob == 0.0 && self.join_prob == 0.0
    }

    /// Scripted events for one round.
    pub fn scripted(&self, round: usize) -> impl Iterator<Item = &ChurnEvent> {
        self.events.iter().filter(move |e| e.round == round)
    }

    /// Parse a comma-separated list of
    /// `leave@ROUND:SLOT[:SECS]`, `join@ROUND:SLOT[:SECS]`, and
    /// `rand:LEAVE_P:JOIN_P` tokens, e.g.
    /// `leave@2:1:5.0,join@5:1,rand:0.02:0.05`.
    pub fn parse(s: &str) -> Result<ChurnSpec> {
        let mut out = ChurnSpec::default();
        if s == "off" || s.is_empty() {
            return Ok(out);
        }
        for tok in s.split(',') {
            let tok = tok.trim();
            if let Some(rest) = tok.strip_prefix("rand:") {
                let (pl, pj) = rest
                    .split_once(':')
                    .ok_or_else(|| anyhow::anyhow!("rand churn needs rand:LEAVE_P:JOIN_P"))?;
                out.leave_prob = pl.parse()?;
                out.join_prob = pj.parse()?;
                if !(0.0..=1.0).contains(&out.leave_prob)
                    || !(0.0..=1.0).contains(&out.join_prob)
                {
                    bail!("churn probabilities must lie in [0, 1]: {tok:?}");
                }
                continue;
            }
            let kind = if tok.starts_with("leave@") {
                ChurnKind::Leave
            } else if tok.starts_with("join@") {
                ChurnKind::Join
            } else {
                bail!("unknown churn token {tok:?} (leave@R:D[:T]|join@R:D[:T]|rand:PL:PJ)");
            };
            let body = tok.split_once('@').map(|(_, b)| b).unwrap_or_default();
            let parts: Vec<&str> = body.split(':').collect();
            if parts.len() < 2 || parts.len() > 3 {
                bail!("churn token {tok:?} needs ROUND:SLOT or ROUND:SLOT:SECS");
            }
            out.events.push(ChurnEvent {
                round: parts[0].parse()?,
                device: parts[1].parse()?,
                secs: if parts.len() == 3 { parts[2].parse()? } else { 0.0 },
                kind,
            });
        }
        Ok(out)
    }
}

/// The slowdown multiplier law a straggling task draws from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SlowdownLaw {
    /// Constant multiplier.
    Fixed(f64),
    /// Uniform in [lo, hi].
    Uniform(f64, f64),
    /// Pareto tail with the given alpha (scale 1): heavy-tailed
    /// stragglers, the empirically observed shape.
    Pareto(f64),
}

impl SlowdownLaw {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let s = match *self {
            SlowdownLaw::Fixed(s) => s,
            SlowdownLaw::Uniform(lo, hi) => rng.range_f64(lo, hi),
            SlowdownLaw::Pareto(alpha) => {
                let u = (1.0 - rng.next_f64()).max(1e-12);
                u.powf(-1.0 / alpha.max(1e-6))
            }
        };
        // A "slowdown" below 1x would be a speedup; clamp it out.
        s.max(1.0)
    }
}

/// Injectable stragglers + mid-task client drops.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerSpec {
    /// Per-task probability of straggling.
    pub prob: f64,
    pub law: SlowdownLaw,
    /// Per-task probability that the client becomes unavailable
    /// mid-task (the engine's `ClientUnavailable` event): the work is
    /// lost and the device freed at a uniform fraction of the task.
    pub drop_prob: f64,
}

impl Default for StragglerSpec {
    fn default() -> Self {
        StragglerSpec { prob: 0.0, law: SlowdownLaw::Fixed(1.0), drop_prob: 0.0 }
    }
}

impl StragglerSpec {
    pub fn is_off(&self) -> bool {
        self.prob == 0.0 && self.drop_prob == 0.0
    }

    /// Parse `off | P:xS | P:u:LO:HI | P:p:ALPHA`, e.g. `0.1:x4`
    /// (10% of tasks run 4x slower) or `0.05:p:1.5`.
    pub fn parse(s: &str) -> Result<StragglerSpec> {
        if s == "off" {
            return Ok(StragglerSpec::default());
        }
        let (p, law) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("stragglers need P:LAW, e.g. 0.1:x4"))?;
        let prob: f64 = p.parse()?;
        if !(0.0..=1.0).contains(&prob) {
            bail!("straggler probability {prob} outside [0, 1]");
        }
        let law = if let Some(x) = law.strip_prefix('x') {
            SlowdownLaw::Fixed(x.parse()?)
        } else if let Some(rest) = law.strip_prefix("u:") {
            let (lo, hi) = rest
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("uniform law needs u:LO:HI"))?;
            SlowdownLaw::Uniform(lo.parse()?, hi.parse()?)
        } else if let Some(a) = law.strip_prefix("p:") {
            SlowdownLaw::Pareto(a.parse()?)
        } else {
            bail!("unknown slowdown law {law:?} (xS|u:LO:HI|p:ALPHA)");
        };
        Ok(StragglerSpec { prob, law, drop_prob: 0.0 })
    }
}

/// Everything dynamic about one run, bundled for `config` / the CLI.
#[derive(Debug, Clone, Default)]
pub struct DynamicsSpec {
    pub availability: AvailabilityModel,
    pub churn: ChurnSpec,
    pub straggler: StragglerSpec,
}

impl DynamicsSpec {
    /// True when nothing dynamic is configured — the engine then
    /// reproduces the legacy static timelines bit-for-bit.
    pub fn is_static(&self) -> bool {
        matches!(self.availability, AvailabilityModel::Always)
            && self.churn.is_empty()
            && self.straggler.is_off()
    }

    pub fn validate(&self) -> Result<()> {
        if let AvailabilityModel::Bernoulli(p) = self.availability {
            if !(0.0..=1.0).contains(&p) {
                bail!("availability probability {p} outside [0, 1]");
            }
        }
        if let AvailabilityModel::Periodic { period, offline } = self.availability {
            if period == 0 || offline >= period {
                bail!(
                    "periodic availability needs 0 <= offline < period, got {offline}/{period} \
                     (offline >= period means every client is permanently offline)"
                );
            }
        }
        for p in [
            self.churn.leave_prob,
            self.churn.join_prob,
            self.straggler.prob,
            self.straggler.drop_prob,
        ] {
            if !(0.0..=1.0).contains(&p) {
                bail!("dynamics probability {p} outside [0, 1]");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_and_trace() {
        let a = AvailabilityModel::Always;
        assert!(a.is_available(3, 9, 1));
        let mut t = BTreeMap::new();
        t.insert(2usize, [5usize, 7].into_iter().collect::<BTreeSet<_>>());
        let tr = AvailabilityModel::Trace(t);
        assert!(!tr.is_available(2, 5, 1));
        assert!(!tr.is_available(2, 7, 1));
        assert!(tr.is_available(2, 6, 1));
        assert!(tr.is_available(3, 5, 1));
    }

    #[test]
    fn bernoulli_is_deterministic_and_roughly_calibrated() {
        let b = AvailabilityModel::Bernoulli(0.7);
        let first: Vec<bool> = (0..500).map(|c| b.is_available(4, c, 11)).collect();
        let second: Vec<bool> = (0..500).map(|c| b.is_available(4, c, 11)).collect();
        assert_eq!(first, second, "same (seed, round, client) must agree");
        let frac = first.iter().filter(|&&x| x).count() as f64 / 500.0;
        assert!((frac - 0.7).abs() < 0.08, "frac={frac}");
        // a different round reshuffles who is available
        let other: Vec<bool> = (0..500).map(|c| b.is_available(5, c, 11)).collect();
        assert_ne!(first, other);
    }

    #[test]
    fn periodic_duty_cycle() {
        let p = AvailabilityModel::Periodic { period: 4, offline: 1 };
        // client 0: offline at rounds 0, 4, 8, ...
        assert!(!p.is_available(0, 0, 1));
        assert!(p.is_available(1, 0, 1));
        assert!(!p.is_available(4, 0, 1));
        // phase-shifted per client
        assert!(!p.is_available(3, 1, 1));
    }

    #[test]
    fn availability_parse() {
        assert!(matches!(AvailabilityModel::parse("always").unwrap(), AvailabilityModel::Always));
        assert!(matches!(
            AvailabilityModel::parse("0.8").unwrap(),
            AvailabilityModel::Bernoulli(p) if (p - 0.8).abs() < 1e-12
        ));
        assert!(matches!(
            AvailabilityModel::parse("bernoulli:0.5").unwrap(),
            AvailabilityModel::Bernoulli(_)
        ));
        assert!(matches!(
            AvailabilityModel::parse("periodic:10:3").unwrap(),
            AvailabilityModel::Periodic { period: 10, offline: 3 }
        ));
        assert!(AvailabilityModel::parse("1.7").is_err());
        assert!(AvailabilityModel::parse("wat").is_err());
    }

    #[test]
    fn churn_parse_and_lookup() {
        let c = ChurnSpec::parse("leave@2:1:5.0,join@5:1,rand:0.02:0.05").unwrap();
        assert_eq!(c.events.len(), 2);
        assert_eq!(c.events[0], ChurnEvent {
            round: 2,
            device: 1,
            secs: 5.0,
            kind: ChurnKind::Leave
        });
        assert_eq!(c.events[1].kind, ChurnKind::Join);
        assert_eq!(c.events[1].secs, 0.0);
        assert!((c.leave_prob - 0.02).abs() < 1e-12);
        assert_eq!(c.scripted(2).count(), 1);
        assert_eq!(c.scripted(3).count(), 0);
        assert!(ChurnSpec::parse("explode@1:2").is_err());
        assert!(ChurnSpec::parse("rand:2.0:0.0").is_err());
        assert!(ChurnSpec::parse("off").unwrap().is_empty());
    }

    #[test]
    fn straggler_parse_and_sampling() {
        let s = StragglerSpec::parse("0.1:x4").unwrap();
        assert_eq!(s.law, SlowdownLaw::Fixed(4.0));
        let u = StragglerSpec::parse("0.2:u:2:6").unwrap();
        let p = StragglerSpec::parse("0.05:p:1.5").unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            assert_eq!(s.law.sample(&mut rng), 4.0);
            let x = u.law.sample(&mut rng);
            assert!((2.0..=6.0).contains(&x));
            assert!(p.law.sample(&mut rng) >= 1.0);
        }
        assert!(StragglerSpec::parse("1.5:x2").is_err());
        assert!(StragglerSpec::parse("0.1:q9").is_err());
        assert!(StragglerSpec::parse("off").unwrap().is_off());
    }

    #[test]
    fn dynamics_spec_static_detection_and_validation() {
        let d = DynamicsSpec::default();
        assert!(d.is_static());
        d.validate().unwrap();
        let d2 = DynamicsSpec {
            availability: AvailabilityModel::Bernoulli(0.9),
            ..Default::default()
        };
        assert!(!d2.is_static());
        d2.validate().unwrap();
        let d3 = DynamicsSpec {
            straggler: StragglerSpec { drop_prob: 1.5, ..Default::default() },
            ..Default::default()
        };
        assert!(d3.validate().is_err());
        // a duty cycle that leaves every client permanently offline is
        // a misconfiguration, not a scenario
        let d4 = DynamicsSpec {
            availability: AvailabilityModel::Periodic { period: 3, offline: 9 },
            ..Default::default()
        };
        assert!(d4.validate().is_err());
        let d5 = DynamicsSpec {
            availability: AvailabilityModel::Periodic { period: 4, offline: 1 },
            ..Default::default()
        };
        d5.validate().unwrap();
    }
}
