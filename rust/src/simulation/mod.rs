//! Virtual-time engine for the timing experiments — one discrete-event
//! core, four thin scheme policies.
//!
//! The paper's scale/timing figures (Fig. 5, 7, 8, 9, 10, 11) sweep
//! configurations — 1000 concurrent clients, 32 devices, three cluster
//! profiles, five schemes — that would take days of wallclock if every
//! point ran real training.  The engine executes the *same scheduler,
//! aggregation-size and heterogeneity code* as the real-compute path,
//! but advances a virtual clock with modeled task durations
//! (Eq. 2 × the Appendix-A slowdown laws) plus multiplicative
//! measurement noise.
//!
//! ## Architecture
//!
//! Every scheme timeline now runs through the shared discrete-event
//! core in [`engine`]: a binary-heap event queue over
//! `(virtual_time, Event)` with the taxonomy `TaskStart`, `TaskDone`,
//! `CommDone`, `DeviceJoin`, `DeviceLeave`, `ClientUnavailable`.  The
//! schemes are policy objects that only decide placement and comm
//! shape on top of it:
//!
//! - **SP** — one executor runs all M_p tasks back-to-back, no comm.
//! - **RW/SD Dist.** — one executor per selected client in parallel
//!   (executors cycle the cluster's device models); round tail = one
//!   broadcast + M_p uploads serialized into the server NIC.
//! - **FA Dist.** — K devices pull tasks greedily from a shared queue
//!   (FedScale/Flower timeline); params move per task, so each task
//!   carries its own down/up `CommDone` legs on the executor.
//! - **Parrot** — Alg. 3 schedules task *sets* (via
//!   [`Scheduler::schedule_masked`]); hierarchical aggregation gives
//!   one down + one up message per device (upload = s_a·K + s_e·M_p).
//!
//! ## Availability / churn / stragglers
//!
//! The [`availability`] module injects the dynamic-hardware scenarios
//! of §4.4: round-level client availability (a client unavailable at
//! round r is never scheduled), mid-task client drops
//! (`ClientUnavailable`), scripted or random device churn
//! (`DeviceJoin`/`DeviceLeave` — orphaned tasks are re-placed on the
//! survivors through the scheduler's greedy step, and the departed
//! device's history records are pruned), and straggler injection with
//! configurable slowdown laws.  With the default (static)
//! [`DynamicsSpec`] the engine reproduces the legacy closed-form
//! per-scheme loops exactly — property-tested below.
//!
//! ## Accounting
//!
//! Compute and communication are kept separate everywhere:
//! `device_busy` holds *productive compute seconds only* (so RW/SD
//! report one entry per executor, not a degenerate mean, and FA no
//! longer folds per-task comm into busy time while also reporting it
//! as `comm_secs`), `device_comm` holds per-executor comm occupancy,
//! and `total_secs` is the event-clock round end.

// Determinism-critical module: re-enable the workspace-wide clippy
// bans on unordered collections and ambient clocks (see clippy.toml
// and the crate-root allow in lib.rs).
#![deny(clippy::disallowed_types, clippy::disallowed_methods)]

pub mod availability;
pub mod engine;

pub use availability::{
    AvailabilityModel, ChurnEvent, ChurnKind, ChurnSpec, DynamicsSpec, SlowdownLaw, StragglerSpec,
};
pub use engine::{
    AsyncCohort, AsyncComm, AsyncOutcome, AsyncSpec, AsyncTier, Event, FlushRecord, RoundOutcome,
    RoundPlan, SimTask, TaskState, TaskTable, TieredTail,
};

use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::compress::Codec;
use crate::config::{Scheme, SchedulerKind};
use crate::data::Partition;
use crate::obs::{Ev, EvKind, Registry, Track, Tracer};
use crate::scheduler::{AffinityCtx, Scheduler};
use crate::statestore::{SimStore, StatePlan};
use crate::util::rng::Rng;

use engine::{RefillPolicy, ReassignPolicy, TailComm};

/// Byte sizes of the communicated quantities (paper model sizes, so the
/// comm:compute ratio matches the evaluated systems).
#[derive(Debug, Clone, Copy)]
pub struct CommModel {
    /// Averaged-params bytes (s_a), raw f32: full model, e.g. 44 MB for
    /// ResNet-18.  Broadcasts always ship this raw size.
    pub s_a: u64,
    /// Special-params bytes per client (s_e), 0 for most algorithms.
    /// Never compressed (§4.2's Collect entries ship verbatim).
    pub s_e: u64,
    /// Update-compression codec applied to uplink parameter payloads;
    /// upload legs book `Codec::wire_bytes` instead of raw f32.
    pub codec: Codec,
}

impl CommModel {
    pub fn femnist() -> CommModel {
        CommModel { s_a: 11_000_000 * 4, s_e: 0, codec: Codec::None } // ResNet-18, 11M params
    }

    pub fn imagenet() -> CommModel {
        CommModel { s_a: 23_000_000 * 4, s_e: 0, codec: Codec::None } // ResNet-50
    }

    pub fn reddit() -> CommModel {
        CommModel { s_a: 11_000_000 * 4, s_e: 0, codec: Codec::None } // Albert-base
    }

    pub fn by_name(name: &str) -> CommModel {
        match name {
            "imagenet" | "cnn" => CommModel::imagenet(),
            "reddit" | "tinylm" => CommModel::reddit(),
            _ => CommModel::femnist(),
        }
    }

    pub fn with_codec(mut self, codec: Codec) -> CommModel {
        self.codec = codec;
        self
    }

    /// Parameter count behind s_a (4 raw bytes per param).
    pub fn n_params(&self) -> usize {
        (self.s_a / 4) as usize
    }

    /// Encoded uplink bytes for the averaged params — the s_a·K term of
    /// Table 1 after compression.  Equals `s_a` exactly for
    /// `Codec::None`.
    pub fn s_a_up(&self) -> u64 {
        self.codec.wire_bytes(self.n_params()) as u64
    }
}

/// One simulated round's outcome.
#[derive(Debug, Clone)]
pub struct VRound {
    pub round: usize,
    /// Virtual seconds for the whole round (compute ∥ + comm).
    pub total_secs: f64,
    /// Compute-phase makespan (max per-executor busy seconds).
    pub compute_secs: f64,
    /// Round-tail comm seconds (SD/Parrot) or total per-task comm
    /// occupancy (FA — overlaps compute across devices, see
    /// [`VRound::device_comm`]).
    pub comm_secs: f64,
    pub bytes: u64,
    pub trips: u64,
    /// Scheduler wallclock overhead (real, not virtual — Fig. 8).
    pub sched_secs: f64,
    /// Per-executor *productive compute* virtual seconds.
    pub device_busy: Vec<f64>,
    /// Per-executor comm occupancy (FA's per-task legs; 0 elsewhere).
    pub device_comm: Vec<f64>,
    /// Mean absolute relative error of the workload prediction vs the
    /// realized task times (Fig. 6 / Fig. 11a).
    pub est_err: Option<f64>,
    /// Clients actually scheduled after the availability filter.
    pub scheduled_clients: usize,
    /// Selected clients that were unavailable this round.
    pub unavailable_clients: usize,
    /// Scheduled clients lost mid-task (`ClientUnavailable`) or left
    /// stranded by total device loss.
    pub dropped_clients: usize,
    /// Aborted partial compute seconds (drops + departures).
    pub wasted_secs: f64,
    pub departures: usize,
    pub joins: usize,
    /// State-store bytes the engine booked this round (StateLoad legs +
    /// the StateFlush tail); 0 without an attached store.
    pub state_bytes: u64,
    /// Executor stall on state loads + flush tail seconds.
    pub state_secs: f64,
    /// Shard-handoff bytes from device churn (ShardTransfer path).
    pub shard_transfer_bytes: u64,
    /// Buffer-flush accounting (async scheme: one `VRound` per flush;
    /// identically zero/empty for the synchronous schemes).
    /// Client updates applied by this flush (staleness within bound).
    pub flush_updates: usize,
    /// Device aggregates merged by this flush.
    pub flush_aggs: usize,
    /// Updates discarded for exceeding `--max-staleness`.
    pub stale_dropped: usize,
    /// `staleness_hist[s]` = applied updates that were s flushes old.
    pub staleness_hist: Vec<usize>,
    /// Aggregates the server merged in the round/flush tail: alive
    /// devices on a flat topology, root-adjacent group aggregates on a
    /// grouped one (`--topology groups:G | tree:SPEC`).
    pub group_aggs: usize,
    /// Bytes that crossed the root-adjacent (WAN) links in the tail —
    /// all of them on a flat topology, only the top-tier legs on a
    /// grouped one.  The cross-WAN metric of `parrot exp toposcale`.
    pub cross_group_bytes: u64,
    /// Heap pops the engine processed for this round (deterministic —
    /// a pure function of the virtual timeline, identical for every
    /// `--threads` value).  The events/sec numerator of `parrot exp
    /// megascale`; 0 for per-flush async rows (the dispatcher's total
    /// lands on [`VirtualSim::engine_events`] instead).
    pub engine_events: u64,
}

impl VRound {
    /// Device utilization: busy / (K · makespan of compute phase).
    pub fn utilization(&self) -> f64 {
        let k = self.device_busy.len().max(1) as f64;
        let makespan = self
            .device_busy
            .iter()
            .cloned()
            .fold(0.0, f64::max)
            .max(1e-12);
        self.device_busy.iter().sum::<f64>() / (k * makespan)
    }

    fn empty(round: usize, unavailable: usize) -> VRound {
        VRound {
            round,
            total_secs: 0.0,
            compute_secs: 0.0,
            comm_secs: 0.0,
            bytes: 0,
            trips: 0,
            sched_secs: 0.0,
            device_busy: Vec::new(),
            device_comm: Vec::new(),
            est_err: None,
            scheduled_clients: 0,
            unavailable_clients: unavailable,
            dropped_clients: 0,
            wasted_secs: 0.0,
            departures: 0,
            joins: 0,
            state_bytes: 0,
            state_secs: 0.0,
            shard_transfer_bytes: 0,
            flush_updates: 0,
            flush_aggs: 0,
            stale_dropped: 0,
            staleness_hist: Vec::new(),
            group_aggs: 0,
            cross_group_bytes: 0,
            engine_events: 0,
        }
    }
}

/// Virtual client-state store attached to a [`VirtualSim`]: the
/// three-tier [`SimStore`] plus the plan-driven-prefetch switch.
pub struct StateSim {
    pub store: SimStore,
    pub prefetch: bool,
}

/// The virtual simulator: one scheme, one cluster, one workload.
pub struct VirtualSim {
    pub scheme: Scheme,
    pub cluster: ClusterProfile,
    pub cost: WorkloadCost,
    pub comm: CommModel,
    pub scheduler: Scheduler,
    pub partition: Partition,
    pub local_epochs: usize,
    /// Multiplicative measurement noise σ (0 = deterministic).
    pub noise: f64,
    /// Availability / churn / straggler injection (default: static).
    pub dynamics: DynamicsSpec,
    /// Client-state store simulation (None = stateless / legacy runs).
    /// Only schemes whose executors map 1:1 to persistent workers (SP,
    /// Parrot, Async) drive it; attach via [`VirtualSim::with_state_store`].
    pub state: Option<StateSim>,
    /// Buffered-async parameters (`Scheme::Async` only).  `buffer == 0`
    /// resolves to M_p at run time — the sync-degenerate default.
    pub async_spec: AsyncSpec,
    /// Worker-pool bound for the group-sharded engine path (grouped
    /// Parrot plans); the timeline is byte-identical for every value —
    /// see "Group-sharded execution" in [`engine`].
    pub threads: usize,
    /// Accumulated wallclock seconds inside [`engine::run_round_opts`]
    /// across all rounds — the `parscale` sweep's speedup numerator.
    pub engine_secs: f64,
    /// Accumulated engine heap pops across all rounds (and across the
    /// whole async dispatch) — the `megascale` events/sec numerator.
    /// Deterministic, unlike `engine_secs`.
    pub engine_events: u64,
    /// Typed span/event tracer (`--trace`): per-round engine buffers
    /// are absorbed onto one monotone run clock.  None (the default)
    /// is a no-op sink — the engine skips event construction entirely.
    /// Everything recorded here is *virtual* time, so the trace is
    /// byte-identical run-to-run and for every `--threads` value.
    pub tracer: Option<Tracer>,
    /// Injected wallclock for `engine_secs`/`overhead_secs` accounting
    /// (the `parscale` speedup numerator and Fig. 8's metric).  None —
    /// the default — books 0.0 everywhere: the engine itself never
    /// reads ambient time (enforced by `parrot lint`'s
    /// `ambient-entropy-transitive` rule), so same-seed timelines stay
    /// byte-identical; harnesses that report wallclock inject
    /// `util::timer::wall_secs` via [`VirtualSim::with_wall_clock`].
    clock: Option<fn() -> f64>,
    /// Run-clock offset for the next round's engine buffer (Σ of the
    /// previous rounds' `total_secs`).
    vclock: f64,
    /// Persistent per-device-slot alive mask (FA/Parrot executors map
    /// 1:1 to devices; RW/SD executors are fresh per round).
    device_alive: Vec<bool>,
    dyn_seed: u64,
    rng: Rng,
}

impl VirtualSim {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        scheme: Scheme,
        cluster: ClusterProfile,
        cost: WorkloadCost,
        comm: CommModel,
        sched: SchedulerKind,
        warmup: usize,
        partition: Partition,
        local_epochs: usize,
        seed: u64,
    ) -> VirtualSim {
        let k = cluster.n_devices();
        VirtualSim {
            scheme,
            cluster,
            cost,
            comm,
            scheduler: Scheduler::new(sched, warmup, k),
            partition,
            local_epochs,
            noise: 0.05,
            dynamics: DynamicsSpec::default(),
            state: None,
            async_spec: AsyncSpec {
                buffer: 0,
                max_staleness: 0,
                weight: crate::aggregation::StalenessWeight::Const,
            },
            threads: 1,
            engine_secs: 0.0,
            engine_events: 0,
            tracer: None,
            clock: None,
            vclock: 0.0,
            device_alive: vec![true; k],
            dyn_seed: seed ^ 0xD15C_0E7E,
            rng: Rng::new(seed ^ 0x51D_CAFE),
        }
    }

    /// Builder-style dynamics injection.
    pub fn with_dynamics(mut self, dynamics: DynamicsSpec) -> VirtualSim {
        self.dynamics = dynamics;
        self
    }

    /// Builder-style engine worker bound (`--threads`).  Purely a
    /// wall-clock knob: every value produces the same timeline.
    pub fn with_threads(mut self, threads: usize) -> VirtualSim {
        self.threads = threads.max(1);
        self
    }

    /// Builder-style wallclock injection: book real engine seconds
    /// into `engine_secs` and the scheduler's `overhead_secs`.  Only
    /// harnesses that *report* wallclock (parscale, figures) attach
    /// one; everything else keeps the 0.0-booking deterministic
    /// default.
    pub fn with_wall_clock(mut self, clock: fn() -> f64) -> VirtualSim {
        self.clock = Some(clock);
        self.scheduler.set_wall_clock(clock);
        self
    }

    /// Builder-style tracing switch (`--trace`): attach an empty
    /// [`Tracer`]; render it with [`crate::obs::chrome::render`] after
    /// the run.
    pub fn with_tracing(mut self) -> VirtualSim {
        self.tracer = Some(Tracer::new());
        self
    }

    /// Attach a client-state store.  When the store is sharded, the
    /// scheduler also receives the affinity context (ownership ring +
    /// remote-fetch cost) so a `SchedulerKind::StateAffinity` kind can
    /// bias placement toward state owners.
    pub fn with_state_store(mut self, store: SimStore, prefetch: bool) -> VirtualSim {
        self.state = Some(StateSim { store, prefetch });
        self.refresh_affinity();
        self
    }

    /// (Re)derive the scheduler's affinity context from the store's
    /// current ring — called on attach and after every ring change, so
    /// the scheduler never steers clients toward a retired owner.
    fn refresh_affinity(&mut self) {
        let Some(st) = self.state.as_ref() else { return };
        if let Some(map) = st.store.shard_map() {
            let cfg = st.store.cfg();
            let remote =
                2.0 * (cfg.net_latency + cfg.state_bytes as f64 / cfg.net_bandwidth);
            self.scheduler.set_affinity(Some(AffinityCtx {
                map: map.clone(),
                n_workers: cfg.n_workers,
                remote_secs: remote,
            }));
        }
    }

    /// Which device slots are currently alive (shaped by churn).
    pub fn device_alive(&self) -> &[bool] {
        &self.device_alive
    }

    /// Pre-drawn multiplicative noise factor (legacy `realize` law).
    fn draw_noise(&mut self) -> f64 {
        (1.0 + self.noise * self.rng.normal()).max(0.2)
    }

    /// Simulate one round for the selected clients; feeds realized times
    /// back into the scheduler history exactly like the real path.
    pub fn round(&mut self, r: usize, selected: &[usize]) -> VRound {
        let avail_seed = self.dyn_seed ^ 0xA11A;
        let scheduled: Vec<usize> = selected
            .iter()
            .cloned()
            .filter(|&c| self.dynamics.availability.is_available(r, c, avail_seed))
            .collect();
        let unavailable = selected.len() - scheduled.len();
        let sizes: Vec<(usize, usize)> = scheduled
            .iter()
            .map(|&c| (c, self.partition.sizes[c] * self.local_epochs))
            .collect();
        if sizes.is_empty() {
            return self.idle_round(r, unavailable);
        }
        let k = self.cluster.n_devices();
        let (plan, sched_secs) = match self.scheme {
            Scheme::SP => (self.plan_sp(r, &sizes), 0.0),
            Scheme::RwDist | Scheme::SdDist => (self.plan_sd(&sizes), 0.0),
            Scheme::FaDist => (self.plan_fa(&sizes, k), 0.0),
            Scheme::Parrot => self.plan_parrot(r, &sizes, k),
            Scheme::Async => unreachable!(
                "the async scheme has no round barrier — run_virtual routes it \
                 through run_async_virtual"
            ),
        };
        let prev_alive = self.device_alive.clone();
        let mut tbuf: Vec<Ev> = Vec::new();
        let wall0 = self.clock.map(|c| c());
        let outcome = engine::run_round_opts(
            plan,
            &self.cluster,
            &self.cost,
            r,
            &self.dynamics,
            self.dyn_seed,
            Some(&mut self.scheduler),
            self.threads,
            self.tracer.is_some().then_some(&mut tbuf),
        );
        if let (Some(c), Some(w0)) = (self.clock, wall0) {
            self.engine_secs += (c() - w0).max(0.0);
        }
        self.engine_events += outcome.events;
        // Absorb the round's engine events onto the monotone run clock
        // and frame them with the round span + placement marker.  The
        // Sched instant carries only virtual facts (placed count), never
        // the wallclock `sched_secs` — the trace must be replayable.
        let t0 = self.vclock;
        if let Some(tr) = self.tracer.as_mut() {
            tr.span(t0, t0 + outcome.end, Track::Run, EvKind::Round { round: r });
            tr.instant(t0, Track::Run, EvKind::Sched { round: r, placed: sizes.len() });
            tr.absorb(&tbuf, t0);
        }
        self.vclock += outcome.end;
        // Device slots persist across rounds for the schemes whose
        // executors map 1:1 to physical devices.
        let mut transfer = 0u64;
        if matches!(self.scheme, Scheme::FaDist | Scheme::Parrot) {
            self.device_alive.clone_from_slice(&outcome.alive);
            transfer = self.shard_churn(&prev_alive);
        }
        self.assemble(r, sizes.len(), unavailable, sched_secs, transfer, outcome)
    }

    /// Shard handoff on device churn: every slot that died this round
    /// hands its shard (and hosted states) to the survivors; rejoining
    /// slots pull their shard back — the PR-1 `DeviceLeave` machinery
    /// extended to state ownership.  Returns the ShardTransfer bytes.
    fn shard_churn(&mut self, prev_alive: &[bool]) -> u64 {
        if self.state.is_none() {
            return 0;
        }
        let mut bytes = 0u64;
        let mut ring_changed = false;
        for slot in 0..prev_alive.len().min(self.device_alive.len()) {
            let (was, is) = (prev_alive[slot], self.device_alive[slot]);
            if was == is {
                continue;
            }
            let st = self.state.as_mut().expect("checked above");
            let moved = if was { st.store.handoff(slot) } else { st.store.rejoin(slot) };
            bytes += moved;
            if moved > 0 {
                if let Some(tr) = self.tracer.as_mut() {
                    tr.instant(self.vclock, Track::Server, EvKind::ShardTransfer {
                        worker: slot,
                        bytes: moved,
                    });
                }
            }
            // The ring may change even when no state moved yet (e.g. a
            // departure before the shard hosted anything) — the
            // scheduler's view must follow the ring, not the bytes.
            ring_changed = true;
        }
        if ring_changed {
            self.refresh_affinity();
        }
        bytes
    }

    /// A round where no selected client was available: no work runs,
    /// but scripted churn still lands on the persistent device slots —
    /// otherwise a `leave@r` whose round happens to be empty would be
    /// silently lost for the rest of the run.
    fn idle_round(&mut self, r: usize, unavailable: usize) -> VRound {
        let mut v = VRound::empty(r, unavailable);
        if let Some(tr) = self.tracer.as_mut() {
            // Zero-width marker: the round happened, nothing ran.
            tr.instant(self.vclock, Track::Run, EvKind::Round { round: r });
        }
        if matches!(self.scheme, Scheme::FaDist | Scheme::Parrot) {
            let prev_alive = self.device_alive.clone();
            let events: Vec<ChurnEvent> = self.dynamics.churn.scripted(r).copied().collect();
            for ev in events {
                if ev.device >= self.device_alive.len() {
                    continue;
                }
                match ev.kind {
                    ChurnKind::Leave => {
                        let alive_count = self.device_alive.iter().filter(|&&a| a).count();
                        if self.device_alive[ev.device] && alive_count > 1 {
                            self.device_alive[ev.device] = false;
                            if self.scheme == Scheme::Parrot {
                                self.scheduler.prune_device(ev.device);
                            }
                            v.departures += 1;
                        }
                    }
                    ChurnKind::Join => {
                        if !self.device_alive[ev.device] {
                            self.device_alive[ev.device] = true;
                            v.joins += 1;
                        }
                    }
                }
            }
            // Churn landing in an empty round still moves shards.
            v.shard_transfer_bytes = self.shard_churn(&prev_alive);
        }
        v
    }

    fn assemble(
        &self,
        r: usize,
        n_scheduled: usize,
        unavailable: usize,
        sched_secs: f64,
        shard_transfer_bytes: u64,
        outcome: RoundOutcome,
    ) -> VRound {
        let compute_secs = outcome.busy.iter().cloned().fold(0.0, f64::max);
        let comm_secs = match self.scheme {
            Scheme::SP => 0.0,
            Scheme::FaDist => outcome.comm_occ.iter().sum(),
            _ => outcome.end - outcome.work_end,
        };
        let (mut act, mut pred) = (Vec::new(), Vec::new());
        for i in 0..outcome.tasks.len() {
            if outcome.tasks.state[i] == TaskState::Done {
                if let Some(p) = outcome.tasks.predicted[i] {
                    act.push(outcome.tasks.realized[i]);
                    pred.push(p);
                }
            }
        }
        let est_err = if act.is_empty() {
            None
        } else {
            Some(crate::util::stats::mape(&act, &pred))
        };
        VRound {
            round: r,
            total_secs: outcome.end,
            compute_secs,
            comm_secs,
            bytes: outcome.bytes,
            trips: outcome.trips,
            sched_secs,
            device_busy: outcome.busy,
            device_comm: outcome.comm_occ,
            est_err,
            scheduled_clients: n_scheduled,
            unavailable_clients: unavailable,
            dropped_clients: outcome.dropped_tasks,
            wasted_secs: outcome.wasted_secs,
            departures: outcome.departures,
            joins: outcome.joins,
            state_bytes: outcome.state_bytes,
            state_secs: outcome.state_secs,
            shard_transfer_bytes,
            flush_updates: 0,
            flush_aggs: 0,
            stale_dropped: 0,
            staleness_hist: Vec::new(),
            group_aggs: outcome.group_aggs,
            cross_group_bytes: outcome.cross_group_bytes,
            engine_events: outcome.events,
        }
    }

    /// Plan this round's state traffic on the attached store: mutates
    /// the store in the planned access order (plan-driven prefetch) and
    /// scatters its per-worker legs into task-index order.  Returns the
    /// empty plan when no store is attached or the executor space does
    /// not map 1:1 onto the store's workers.
    fn plan_state(
        &mut self,
        r: usize,
        n_exec: usize,
        assigned: &[Vec<usize>],
        tasks: &TaskTable,
    ) -> StatePlan {
        let Some(st) = self.state.as_mut() else { return StatePlan::default() };
        if st.store.cfg().n_workers != n_exec {
            return StatePlan::default();
        }
        st.store.plan_for_tasks(
            r as u64,
            assigned,
            |t| tasks.client[t] as u64,
            tasks.len(),
            st.prefetch,
        )
    }

    /// SP: one executor, all tasks back-to-back, no comm.
    fn plan_sp(&mut self, r: usize, sizes: &[(usize, usize)]) -> RoundPlan {
        let tasks: TaskTable = sizes
            .iter()
            .map(|&(c, n)| SimTask::new(c, n, self.draw_noise()))
            .collect();
        let assigned: Vec<Vec<usize>> = vec![(0..tasks.len()).collect()];
        let state = self.plan_state(r, 1, &assigned, &tasks);
        RoundPlan {
            n_exec: 1,
            alive: vec![true],
            assigned,
            pull: Vec::new(),
            refill: RefillPolicy::Assigned,
            reassign: ReassignPolicy::LeastLoaded,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail: TailComm::None,
            state,
            record_history: false,
            tasks,
        }
    }

    /// RW/SD: each selected client on its own executor, fully parallel;
    /// the server talks to each of the M_p executors (down + up),
    /// uploads serialized into the server NIC.
    fn plan_sd(&mut self, sizes: &[(usize, usize)]) -> RoundPlan {
        let tasks: TaskTable = sizes
            .iter()
            .map(|&(c, n)| SimTask::new(c, n, self.draw_noise()))
            .collect();
        let m_p = tasks.len();
        RoundPlan {
            n_exec: m_p,
            alive: vec![true; m_p],
            assigned: (0..m_p).map(|i| vec![i]).collect(),
            pull: Vec::new(),
            refill: RefillPolicy::Assigned,
            reassign: ReassignPolicy::LeastLoaded,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail: TailComm::PerExecutor {
                down: self.comm.s_a + self.comm.s_e,
                up: self.comm.s_a_up() + self.comm.s_e,
            },
            state: StatePlan::default(),
            record_history: false,
            tasks,
        }
    }

    /// FA: greedy pull from a size-descending shared queue, params per
    /// task (FedScale/Flower timeline).
    fn plan_fa(&mut self, sizes: &[(usize, usize)], k: usize) -> RoundPlan {
        let mut order: Vec<(usize, usize)> = sizes.to_vec();
        order.sort_by(|a, b| b.1.cmp(&a.1)); // FedScale: biggest first
        let tasks: TaskTable = order
            .iter()
            .map(|&(c, n)| SimTask::new(c, n, self.draw_noise()))
            .collect();
        let down = self.comm.s_a + self.comm.s_e;
        let up = self.comm.s_a_up() + self.comm.s_e;
        RoundPlan {
            pull: (0..tasks.len()).collect(),
            n_exec: k,
            alive: self.device_alive.clone(),
            assigned: vec![Vec::new(); k],
            refill: RefillPolicy::SharedPull,
            reassign: ReassignPolicy::Requeue,
            per_task_comm: (
                self.cluster.comm_time(down as usize),
                self.cluster.comm_time(up as usize),
            ),
            per_task_bytes: (down, up),
            tail: TailComm::None,
            state: StatePlan::default(),
            record_history: false,
            tasks,
        }
    }

    /// Parrot: Alg. 3 schedule over the alive devices, hierarchical
    /// aggregation comm model, history fed back per task.  On a grouped
    /// topology the schedule runs two-stage (client→group by
    /// affinity+load, then client→device within the group) and the tail
    /// prices intra-group vs cross-group legs separately.
    fn plan_parrot(&mut self, r: usize, sizes: &[(usize, usize)], k: usize) -> (RoundPlan, f64) {
        let alive = self.device_alive.clone();
        let topo = self.cluster.topology.clone();
        let mut schedule = if topo.is_flat() {
            self.scheduler.schedule_masked(r, sizes, &alive)
        } else {
            let groups = topo.members(k);
            self.scheduler.schedule_grouped(r, sizes, &alive, &groups)
        };
        // The estimates the greedy pass used — predictions are fixed
        // at plan time, before any of this round's records land.
        let est = schedule.estimates.take();
        let size_of = crate::scheduler::greedy::size_table(sizes);
        let mut tasks = TaskTable::with_capacity(sizes.len());
        let mut assigned = vec![Vec::new(); k];
        for (dev, clients) in schedule.assignment.iter().enumerate() {
            for &c in clients {
                let n = size_of[c];
                let mut task = SimTask::new(c, n, self.draw_noise());
                if let Some(est) = &est {
                    task.predicted = Some(est[dev].predict(n));
                }
                let id = tasks.push(task);
                assigned[dev].push(id);
            }
        }
        let m_p = sizes.len() as u64;
        let state = self.plan_state(r, k, &assigned, &tasks);
        let tail = if topo.is_flat() {
            TailComm::Hierarchical {
                s_a_down: self.comm.s_a,
                s_a_up: self.comm.s_a_up(),
                s_e_total: self.comm.s_e * m_p,
            }
        } else {
            let (wan_bw, wan_lat) = topo.wan_link(self.cluster.bandwidth, self.cluster.latency);
            TailComm::Tiered(TieredTail {
                s_a_down: self.comm.s_a,
                s_a_up: self.comm.s_a_up(),
                s_e_total: self.comm.s_e * m_p,
                group_of: (0..k).map(|d| topo.group_of(d)).collect(),
                n_groups: topo.n_groups(),
                levels: topo.levels.clone(),
                wan_bandwidth: wan_bw,
                wan_latency: wan_lat,
                lan_bandwidth: self.cluster.bandwidth,
                lan_latency: self.cluster.latency,
            })
        };
        let plan = RoundPlan {
            tasks,
            n_exec: k,
            alive,
            assigned,
            pull: Vec::new(),
            refill: RefillPolicy::Assigned,
            reassign: ReassignPolicy::Greedy,
            per_task_comm: (0.0, 0.0),
            per_task_bytes: (0, 0),
            tail,
            state,
            record_history: true,
        };
        (plan, schedule.overhead_secs)
    }
}

/// Run `rounds` rounds selecting `m_p` clients uniformly per round;
/// returns per-round outcomes.  The shared driver for every timing
/// figure harness.  `Scheme::Async` routes through the work-conserving
/// dispatcher ([`run_async_virtual`]): same selection stream, one
/// `VRound` per buffer flush instead of per round.
#[allow(clippy::too_many_arguments)]
pub fn run_virtual(sim: &mut VirtualSim, rounds: usize, m_p: usize, seed: u64) -> Vec<VRound> {
    if sim.scheme == Scheme::Async {
        return run_async_virtual(sim, rounds, m_p, seed);
    }
    let selector = Rng::new(seed ^ 0xF1A_C0DE);
    let m = sim.partition.n_clients();
    (0..rounds)
        .map(|r| {
            let mut rng = selector.derive(r as u64);
            let selected = rng.choose(m, m_p.min(m));
            sim.round(r, &selected)
        })
        .collect()
}

/// [`run_async_detailed`] keeping only the per-flush `VRound`s.
pub fn run_async_virtual(sim: &mut VirtualSim, rounds: usize, m_p: usize, seed: u64) -> Vec<VRound> {
    run_async_detailed(sim, rounds, m_p, seed).0
}

/// Asynchronous buffered execution of `rounds` cohorts × `m_p` clients
/// on the work-conserving dispatcher: identical selection, availability
/// filter, noise draws and (at zero base load) greedy placement as the
/// synchronous driver, but cohorts are admitted on demand and the
/// server flushes every `buffer` client updates with staleness-weighted
/// aggregation.  Returns one `VRound` per flush plus the raw
/// [`AsyncOutcome`] (arrival sequence, per-flush records) for the
/// sim-vs-deploy flush-ledger differential.
pub fn run_async_detailed(
    sim: &mut VirtualSim,
    rounds: usize,
    m_p: usize,
    seed: u64,
) -> (Vec<VRound>, AsyncOutcome) {
    assert_eq!(sim.scheme, Scheme::Async, "run_async_detailed needs Scheme::Async");
    let m = sim.partition.n_clients();
    let m_p_eff = m_p.min(m).max(1);
    let spec = AsyncSpec {
        buffer: if sim.async_spec.buffer == 0 { m_p_eff } else { sim.async_spec.buffer },
        ..sim.async_spec
    };
    let k = sim.cluster.n_devices();
    let topo = sim.cluster.topology.clone();
    let tier = if topo.is_flat() {
        None
    } else {
        let (wan_bw, wan_lat) = topo.wan_link(sim.cluster.bandwidth, sim.cluster.latency);
        Some(AsyncTier {
            n_groups: topo.n_groups(),
            group_of: (0..k).map(|d| topo.group_of(d)).collect(),
            wan_bandwidth: wan_bw,
            wan_latency: wan_lat,
            lan_bandwidth: sim.cluster.bandwidth,
            lan_latency: sim.cluster.latency,
        })
    };
    let groups = topo.members(k);
    let comm = AsyncComm {
        s_a_down: sim.comm.s_a,
        s_a_up: sim.comm.s_a_up(),
        s_e: sim.comm.s_e,
        tier,
    };
    let avail_seed = sim.dyn_seed ^ 0xA11A;
    let dyn_seed = sim.dyn_seed;
    let noise_sigma = sim.noise;
    let selector = Rng::new(seed ^ 0xF1A_C0DE);

    let VirtualSim {
        ref cluster,
        ref cost,
        ref mut scheduler,
        ref partition,
        local_epochs,
        ref dynamics,
        ref mut state,
        ref mut rng,
        ref mut tracer,
        ..
    } = *sim;
    let availability = &dynamics.availability;

    let mut source = move |sched: &mut Scheduler,
                           c: usize,
                           alive: &[bool],
                           base: &[f64]|
          -> Option<AsyncCohort> {
        if c >= rounds {
            return None;
        }
        let mut sel = selector.derive(c as u64);
        let selected = sel.choose(m, m_p_eff);
        let scheduled: Vec<usize> = selected
            .iter()
            .cloned()
            .filter(|&cl| availability.is_available(c, cl, avail_seed))
            .collect();
        let unavailable = selected.len() - scheduled.len();
        let sizes: Vec<(usize, usize)> = scheduled
            .iter()
            .map(|&cl| (cl, partition.sizes[cl] * local_epochs))
            .collect();
        if sizes.is_empty() {
            return Some(AsyncCohort {
                tasks: TaskTable::new(),
                assigned: vec![Vec::new(); k],
                state: StatePlan::default(),
                sched_secs: 0.0,
                unavailable,
            });
        }
        // Incremental Alg. 3: greedy placement from the executors'
        // current projected loads (all zero exactly at a flush
        // boundary, where this equals the barrier schedule).  Grouped
        // topologies place two-stage (client→group, then →device).
        let mut schedule = if groups.is_empty() {
            sched.schedule_from(c, &sizes, alive, base)
        } else {
            sched.schedule_grouped_from(c, &sizes, alive, base, &groups)
        };
        let est = schedule.estimates.take();
        let size_of = crate::scheduler::greedy::size_table(&sizes);
        let mut tasks = TaskTable::with_capacity(sizes.len());
        let mut assigned = vec![Vec::new(); k];
        for (dev, clients) in schedule.assignment.iter().enumerate() {
            for &cl in clients {
                let n = size_of[cl];
                let mut task =
                    SimTask::new(cl, n, (1.0 + noise_sigma * rng.normal()).max(0.2));
                if let Some(est) = &est {
                    task.predicted = Some(est[dev].predict(n));
                }
                let id = tasks.push(task);
                assigned[dev].push(id);
            }
        }
        // State prefetch follows the dispatcher's rolling horizon: the
        // cohort is planned on the store at admission time, not from a
        // fixed whole-round plan.
        let splan = match state.as_mut() {
            Some(st) if st.store.cfg().n_workers == k => st.store.plan_for_tasks(
                c as u64,
                &assigned,
                |t| tasks.client[t] as u64,
                tasks.len(),
                st.prefetch,
            ),
            _ => StatePlan::default(),
        };
        Some(AsyncCohort {
            tasks,
            assigned,
            state: splan,
            sched_secs: schedule.overhead_secs,
            unavailable,
        })
    };

    let mut tbuf: Vec<Ev> = Vec::new();
    let want_trace = tracer.is_some();
    let outcome = engine::run_async(
        k,
        cluster,
        cost,
        dynamics,
        dyn_seed,
        spec,
        comm,
        scheduler,
        &mut source,
        want_trace.then_some(&mut tbuf),
    );
    // The dispatcher owns the whole timeline (no per-round restart), so
    // its events land on the run clock at offset 0.
    if let Some(tr) = tracer.as_mut() {
        tr.absorb(&tbuf, 0.0);
    }
    sim.engine_events += outcome.events;

    let vrounds = outcome
        .flushes
        .iter()
        .map(|f| VRound {
            round: f.flush,
            total_secs: f.interval,
            compute_secs: f.busy.iter().cloned().fold(0.0, f64::max),
            comm_secs: f.chain_secs,
            bytes: f.bytes,
            trips: f.trips,
            sched_secs: f.sched_secs,
            device_busy: f.busy.clone(),
            device_comm: vec![0.0; k],
            est_err: f.est_err,
            scheduled_clients: f.completed + f.dropped,
            unavailable_clients: f.unavailable,
            dropped_clients: f.dropped,
            wasted_secs: f.wasted_secs,
            departures: 0,
            joins: 0,
            state_bytes: f.state_bytes,
            state_secs: f.state_secs,
            shard_transfer_bytes: 0,
            flush_updates: f.updates,
            flush_aggs: f.aggs,
            stale_dropped: f.stale_dropped,
            staleness_hist: f.staleness_hist.clone(),
            group_aggs: f.group_aggs,
            cross_group_bytes: f.cross_group_bytes,
            engine_events: 0,
        })
        .collect();
    (vrounds, outcome)
}

/// Fold per-round rows into an [`obs::Registry`](crate::obs::Registry)
/// snapshot — the `metrics` block of a `--trace` export, and one side
/// of the sim-vs-deploy counter-parity differential (`parrot exp
/// asyncscale --smoke`).  Names follow the dotted `area.metric` scheme
/// documented in the README's Observability section.
pub fn registry_from_rounds(rs: &[VRound]) -> Registry {
    let mut reg = Registry::new();
    for r in rs {
        reg.inc("sim.rounds");
        reg.add("sim.bytes", r.bytes);
        reg.add("sim.trips", r.trips);
        reg.add("sim.state_bytes", r.state_bytes);
        reg.add("sim.cross_group_bytes", r.cross_group_bytes);
        reg.add("sim.shard_transfer_bytes", r.shard_transfer_bytes);
        reg.add("sim.scheduled_clients", r.scheduled_clients as u64);
        reg.add("sim.unavailable_clients", r.unavailable_clients as u64);
        reg.add("sim.dropped_clients", r.dropped_clients as u64);
        reg.add("sim.departures", r.departures as u64);
        reg.add("sim.joins", r.joins as u64);
        reg.add("sim.flush_applied", r.flush_updates as u64);
        reg.add("sim.stale_dropped", r.stale_dropped as u64);
        reg.observe_secs("sim.round_secs", r.total_secs);
        for (s, &n) in r.staleness_hist.iter().enumerate() {
            for _ in 0..n {
                reg.observe("sim.staleness", s as u64);
            }
        }
    }
    reg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::PartitionKind;
    use crate::scheduler::TaskRecord;

    fn mk(scheme: Scheme, k: usize, sched: SchedulerKind) -> VirtualSim {
        let partition =
            Partition::generate(PartitionKind::Natural, 200, 62, 100, 7);
        VirtualSim::new(
            scheme,
            ClusterProfile::homogeneous(k),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            sched,
            2,
            partition,
            1,
            3,
        )
    }

    #[test]
    fn sp_is_serial_sum() {
        let mut sim = mk(Scheme::SP, 1, SchedulerKind::Uniform);
        sim.noise = 0.0;
        let rounds = run_virtual(&mut sim, 3, 50, 1);
        for r in &rounds {
            assert_eq!(r.trips, 0);
            assert_eq!(r.bytes, 0);
            assert!(r.total_secs > 40.0 * 0.15, "50 tasks × b at least");
        }
    }

    #[test]
    fn parrot_beats_fa_and_sd_on_time() {
        // The headline Fig. 5 shape at one configuration.
        let (mut fa, mut sd, mut parrot) = (
            mk(Scheme::FaDist, 8, SchedulerKind::Uniform),
            mk(Scheme::SdDist, 8, SchedulerKind::Uniform),
            mk(Scheme::Parrot, 8, SchedulerKind::Greedy),
        );
        let t = |sim: &mut VirtualSim| {
            let rs = run_virtual(sim, 8, 100, 1);
            rs[3..].iter().map(|r| r.total_secs).sum::<f64>() / 5.0
        };
        let (tf, ts, tp) = (t(&mut fa), t(&mut sd), t(&mut parrot));
        assert!(tp < tf, "parrot {tp} !< fa {tf}");
        // SD has M_p=100 parallel devices, so pure compute is fast — but
        // Parrot on only 8 devices must still be within a small factor,
        // and must crush it on bytes.
        let rb = run_virtual(&mut parrot, 1, 100, 2)[0].bytes;
        let sb = run_virtual(&mut sd, 1, 100, 2)[0].bytes;
        assert!(rb * 5 < sb, "parrot bytes {rb} vs sd {sb}");
        let _ = ts;
    }

    #[test]
    fn parrot_comm_is_o_k() {
        let mut p = mk(Scheme::Parrot, 8, SchedulerKind::Greedy);
        let r = run_virtual(&mut p, 1, 100, 1);
        assert_eq!(r[0].trips, 16); // 2K
        assert_eq!(r[0].bytes, 2 * CommModel::femnist().s_a * 8);
        let mut fa = mk(Scheme::FaDist, 8, SchedulerKind::Uniform);
        let rf = run_virtual(&mut fa, 1, 100, 1);
        assert_eq!(rf[0].trips, 200); // 2·M_p
    }

    #[test]
    fn codec_shrinks_comm_bytes_and_round_time() {
        // Engine byte columns book *encoded* upload sizes, so a codec
        // must shrink both the bytes and the comm tail of every scheme
        // that uploads params, leaving broadcast and compute untouched.
        let at = |scheme, sched, codec: Codec| {
            let partition = Partition::generate(PartitionKind::Natural, 200, 62, 100, 7);
            let mut sim = VirtualSim::new(
                scheme,
                ClusterProfile::homogeneous(8),
                WorkloadCost::femnist(),
                CommModel::femnist().with_codec(codec),
                sched,
                2,
                partition,
                1,
                3,
            );
            sim.noise = 0.0;
            let r = run_virtual(&mut sim, 1, 60, 1).remove(0);
            (r.bytes, r.total_secs)
        };
        for (scheme, sched) in [
            (Scheme::Parrot, SchedulerKind::Greedy),
            (Scheme::SdDist, SchedulerKind::Uniform),
            (Scheme::FaDist, SchedulerKind::Uniform),
        ] {
            let (b_raw, t_raw) = at(scheme, sched, Codec::None);
            for codec in [Codec::Fp16, Codec::QInt8, Codec::TopK(0.1)] {
                let (b, t) = at(scheme, sched, codec);
                assert!(b < b_raw, "{scheme:?}/{codec:?}: bytes {b} !< {b_raw}");
                assert!(t < t_raw, "{scheme:?}/{codec:?}: time {t} !< {t_raw}");
            }
            // qint8 upload is ~4x smaller; with the raw broadcast in
            // the column too the total must drop below ~5/8 of raw.
            let (bq, _) = at(scheme, sched, Codec::QInt8);
            assert!(
                (bq as f64) < 0.7 * b_raw as f64,
                "{scheme:?}: qint8 bytes {bq} vs raw {b_raw}"
            );
        }
    }

    #[test]
    fn scheduling_beats_uniform_under_heterogeneity() {
        let partition = Partition::generate(PartitionKind::Natural, 300, 62, 100, 9);
        let mut with = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::Greedy,
            2,
            partition.clone(),
            1,
            5,
        );
        let mut without = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::Uniform,
            2,
            partition,
            1,
            5,
        );
        let mean = |rs: &[VRound]| {
            rs.iter().skip(3).map(|r| r.total_secs).sum::<f64>() / (rs.len() - 3) as f64
        };
        let rw = run_virtual(&mut with, 12, 100, 4);
        let ro = run_virtual(&mut without, 12, 100, 4);
        assert!(
            mean(&rw) < 0.8 * mean(&ro),
            "sched {:.2} !< 0.8 × unsched {:.2}",
            mean(&rw),
            mean(&ro)
        );
    }

    #[test]
    fn estimation_error_small_when_stable() {
        let mut sim = mk(Scheme::Parrot, 4, SchedulerKind::Greedy);
        let rs = run_virtual(&mut sim, 10, 60, 6);
        let last = rs.last().unwrap();
        let err = last.est_err.expect("model in use by round 10");
        assert!(err < 0.15, "estimation error {err}");
    }

    #[test]
    fn time_window_wins_in_dynamic_env() {
        // Fig. 11: under cos-dynamics, windowed estimation must beat
        // full-history estimation on round time.
        let partition = Partition::generate(PartitionKind::Natural, 300, 62, 100, 11);
        let mk_dyn = |sched: SchedulerKind| {
            VirtualSim::new(
                Scheme::Parrot,
                ClusterProfile::dynamic(8, 25.0),
                WorkloadCost::femnist(),
                CommModel::femnist(),
                sched,
                2,
                partition.clone(),
                1,
                13,
            )
        };
        let mean_tail = |rs: &[VRound]| {
            rs.iter().skip(20).map(|r| r.total_secs).sum::<f64>() / (rs.len() - 20) as f64
        };
        let mut full = mk_dyn(SchedulerKind::Greedy);
        let mut windowed = mk_dyn(SchedulerKind::TimeWindow(3));
        let rf = run_virtual(&mut full, 60, 100, 17);
        let rw = run_virtual(&mut windowed, 60, 100, 17);
        assert!(
            mean_tail(&rw) < mean_tail(&rf) * 1.02,
            "window {:.2} !< full {:.2}",
            mean_tail(&rw),
            mean_tail(&rf)
        );
        // and its estimation error must be lower
        let err = |rs: &[VRound]| {
            let v: Vec<f64> = rs.iter().skip(20).filter_map(|r| r.est_err).collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(err(&rw) < err(&rf), "window err {} !< full err {}", err(&rw), err(&rf));
    }

    #[test]
    fn more_devices_scale_down_round_time() {
        // Fig. 7: near-linear scaling.
        let t_at = |k: usize| {
            let mut sim = mk(Scheme::Parrot, k, SchedulerKind::Greedy);
            let rs = run_virtual(&mut sim, 8, 100, 3);
            rs.iter().skip(3).map(|r| r.total_secs).sum::<f64>() / 5.0
        };
        let (t4, t16) = (t_at(4), t_at(16));
        assert!(
            t16 < t4 / 2.5,
            "16 devices should be ≳2.5x faster than 4: {t4:.2} vs {t16:.2}"
        );
    }

    #[test]
    fn utilization_bounded() {
        let mut sim = mk(Scheme::Parrot, 8, SchedulerKind::Greedy);
        for r in run_virtual(&mut sim, 6, 100, 9) {
            let u = r.utilization();
            assert!((0.0..=1.0 + 1e-9).contains(&u), "utilization {u}");
        }
    }

    #[test]
    fn tracing_is_timeline_neutral_and_exports_well_formed() {
        // Attaching the tracer must not perturb a single virtual bit,
        // and the absorbed run must expand to a well-formed Chrome
        // trace with monotone per-track timestamps.
        let mut plain = mk(Scheme::Parrot, 4, SchedulerKind::Greedy);
        let mut traced = mk(Scheme::Parrot, 4, SchedulerKind::Greedy).with_tracing();
        let a = run_virtual(&mut plain, 3, 40, 1);
        let b = run_virtual(&mut traced, 3, 40, 1);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.total_secs.to_bits(), y.total_secs.to_bits());
            assert_eq!(x.bytes, y.bytes);
            assert_eq!(x.device_busy.len(), y.device_busy.len());
        }
        let tr = traced.tracer.take().expect("tracer attached");
        assert!(!tr.is_empty(), "a 3-round Parrot run must record events");
        let rows = crate::obs::chrome::expand(&tr);
        crate::obs::chrome::check_well_formed(&rows).unwrap();
        let reg = registry_from_rounds(&b);
        assert_eq!(reg.get("sim.rounds"), 3);
        let s = crate::obs::chrome::render(&tr, Some(&reg));
        assert!(s.starts_with("{\"traceEvents\":["), "{}", &s[..s.len().min(80)]);
        assert!(s.contains("\"sim.bytes\""), "registry snapshot rides along");
    }

    // ------------------------------------------------ event-core tests

    /// The pre-rewrite `round_parrot` closed-form loop, replicated
    /// verbatim: schedule, realize tasks in device order with the same
    /// noise draws, record history, add the hierarchical comm tail.
    fn legacy_parrot_total(sim: &mut VirtualSim, r: usize, selected: &[usize]) -> (f64, Vec<f64>) {
        let k = sim.cluster.n_devices();
        let sizes: Vec<(usize, usize)> = selected
            .iter()
            .map(|&c| (c, sim.partition.sizes[c] * sim.local_epochs))
            .collect();
        let schedule = sim.scheduler.schedule(r, &sizes);
        let size_of = crate::scheduler::greedy::size_table(&sizes);
        let mut busy = vec![0.0f64; k];
        for (dev, clients) in schedule.assignment.iter().enumerate() {
            for &c in clients {
                let n = size_of[c];
                let base = sim.cluster.task_time(&sim.cost, dev, r, n, 1);
                let t = base * sim.draw_noise();
                busy[dev] += t;
                sim.scheduler.record(TaskRecord {
                    round: r,
                    device: dev,
                    n_samples: n,
                    secs: t,
                });
            }
        }
        let makespan = busy.iter().cloned().fold(0.0, f64::max);
        let m_p = sizes.len() as u64;
        let comm = sim.cluster.comm_time(sim.comm.s_a as usize) * 2.0
            + (k as f64 - 1.0) * sim.cluster.latency
            + (sim.comm.s_e * m_p) as f64 / sim.cluster.bandwidth;
        (makespan + comm, busy)
    }

    #[test]
    fn prop_event_parrot_reproduces_legacy_totals() {
        // Same ctor args twice: one instance runs the event core, the
        // other replays the legacy loop. Identical seeds => identical
        // noise draws, schedules, busy vectors, and totals.
        for (k, m_p, hetero, seed) in
            [(4usize, 60usize, false, 3u64), (8, 100, true, 5), (16, 200, true, 11), (2, 30, false, 23)]
        {
            let cluster = if hetero {
                ClusterProfile::heterogeneous(k)
            } else {
                ClusterProfile::homogeneous(k)
            };
            let partition = Partition::generate(PartitionKind::Natural, 400, 62, 100, 17);
            let build = || {
                VirtualSim::new(
                    Scheme::Parrot,
                    cluster.clone(),
                    WorkloadCost::femnist(),
                    CommModel::femnist(),
                    SchedulerKind::Greedy,
                    2,
                    partition.clone(),
                    1,
                    seed,
                )
            };
            let mut event_sim = build();
            let mut legacy_sim = build();
            let selector = Rng::new(99 ^ seed);
            for r in 0..6 {
                let mut rng = selector.derive(r as u64);
                let selected = rng.choose(400, m_p);
                let v = event_sim.round(r, &selected);
                let (legacy_total, legacy_busy) = legacy_parrot_total(&mut legacy_sim, r, &selected);
                assert!(
                    (v.total_secs - legacy_total).abs() < 1e-6 * legacy_total.max(1.0),
                    "k={k} m_p={m_p} r={r}: event {} vs legacy {legacy_total}",
                    v.total_secs
                );
                for (a, b) in v.device_busy.iter().zip(&legacy_busy) {
                    assert!((a - b).abs() < 1e-9, "busy mismatch: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn prop_grouped_vrounds_are_thread_invariant() {
        // The headline sharded-engine invariant at the VirtualSim
        // level: a grouped Parrot run under full dynamics (availability
        // + scripted and random churn + stragglers/drops) must produce
        // byte-identical VRound rows for every worker-pool size.
        // (`sched_secs` is real wallclock and is deliberately excluded;
        // every other column is virtual and must match to the bit.)
        use crate::cluster::Topology;
        let dynamics = DynamicsSpec {
            availability: AvailabilityModel::Bernoulli(0.85),
            churn: ChurnSpec {
                events: vec![
                    ChurnEvent { round: 1, device: 2, secs: 1.0, kind: ChurnKind::Leave },
                    ChurnEvent { round: 3, device: 2, secs: 0.0, kind: ChurnKind::Join },
                ],
                leave_prob: 0.05,
                join_prob: 0.05,
            },
            straggler: StragglerSpec {
                prob: 0.2,
                law: SlowdownLaw::Fixed(4.0),
                drop_prob: 0.05,
            },
        };
        let row = |r: &VRound| {
            format!(
                "{} {:x} {:x} {:x} {} {} {} {} {} {} {} {:x} {} {:x} {} {} {:x?}",
                r.round,
                r.total_secs.to_bits(),
                r.compute_secs.to_bits(),
                r.comm_secs.to_bits(),
                r.bytes,
                r.trips,
                r.scheduled_clients,
                r.unavailable_clients,
                r.dropped_clients,
                r.departures,
                r.joins,
                r.wasted_secs.to_bits(),
                r.state_bytes,
                r.state_secs.to_bits(),
                r.cross_group_bytes,
                r.group_aggs,
                r.device_busy.iter().map(|b| b.to_bits()).collect::<Vec<_>>()
            )
        };
        let rows_at = |threads: usize| -> Vec<String> {
            let partition = Partition::generate(PartitionKind::Natural, 300, 62, 100, 7);
            let mut sim = VirtualSim::new(
                Scheme::Parrot,
                ClusterProfile::heterogeneous(8).with_topology(Topology::groups(4)),
                WorkloadCost::femnist(),
                CommModel::femnist(),
                SchedulerKind::TimeWindow(5),
                2,
                partition,
                1,
                31,
            )
            .with_dynamics(dynamics.clone())
            .with_threads(threads);
            run_virtual(&mut sim, 5, 60, 31 ^ 0xDD).iter().map(row).collect()
        };
        let reference = rows_at(1);
        assert!(!reference.is_empty());
        for threads in [2usize, 8] {
            assert_eq!(
                reference,
                rows_at(threads),
                "grouped VRound rows diverged between --threads 1 and --threads {threads}"
            );
        }
    }

    // ------------------------------------------------ async scheme

    use crate::aggregation::StalenessWeight;

    #[test]
    fn prop_async_degenerate_reproduces_sync_parrot_timeline() {
        // The acceptance pin: `buffer == M_p` + `max_staleness == 0`
        // closes the admission gate after every cohort and ships the
        // buffered aggregates through the exact hierarchical tail, so
        // the work-conserving dispatcher must replay the synchronous
        // Parrot timeline event-for-event on any seed — same noise
        // draws, same straggler draws, same placements, same byte and
        // busy columns — including under straggler injection on a
        // heterogeneous cluster.
        for (k, m_p, hetero, stragglers, seed) in [
            (4usize, 60usize, false, false, 3u64),
            (8, 100, true, false, 5),
            (8, 80, true, true, 11),
            (3, 40, false, true, 23),
        ] {
            let cluster = if hetero {
                ClusterProfile::heterogeneous(k)
            } else {
                ClusterProfile::homogeneous(k)
            };
            let partition = Partition::generate(PartitionKind::Natural, 400, 62, 100, 17);
            let dynamics = if stragglers {
                DynamicsSpec {
                    straggler: StragglerSpec {
                        prob: 0.2,
                        law: SlowdownLaw::Fixed(5.0),
                        drop_prob: 0.0,
                    },
                    ..Default::default()
                }
            } else {
                DynamicsSpec::default()
            };
            let build = |scheme| {
                VirtualSim::new(
                    scheme,
                    cluster.clone(),
                    WorkloadCost::femnist(),
                    CommModel::femnist(),
                    SchedulerKind::Greedy,
                    2,
                    partition.clone(),
                    1,
                    seed,
                )
                .with_dynamics(dynamics.clone())
            };
            let mut sync = build(Scheme::Parrot);
            let mut asy = build(Scheme::Async);
            // buffer 0 resolves to M_p; staleness window 0.
            asy.async_spec =
                AsyncSpec { buffer: 0, max_staleness: 0, weight: StalenessWeight::Const };
            let rs = run_virtual(&mut sync, 6, m_p, 99 ^ seed);
            let ra = run_virtual(&mut asy, 6, m_p, 99 ^ seed);
            assert_eq!(ra.len(), rs.len(), "k={k} m_p={m_p}: one flush per round");
            for (s, a) in rs.iter().zip(&ra) {
                assert!(
                    (s.total_secs - a.total_secs).abs() < 1e-6 * s.total_secs.max(1.0),
                    "k={k} m_p={m_p} stragglers={stragglers} r={}: sync {} vs async {}",
                    s.round,
                    s.total_secs,
                    a.total_secs
                );
                assert_eq!(s.bytes, a.bytes, "r={}", s.round);
                assert_eq!(s.trips, a.trips, "r={}", s.round);
                assert!((s.comm_secs - a.comm_secs).abs() < 1e-9);
                assert_eq!(s.device_busy.len(), a.device_busy.len());
                for (b, c) in s.device_busy.iter().zip(&a.device_busy) {
                    assert!((b - c).abs() < 1e-9, "busy mismatch r={}: {b} vs {c}", s.round);
                }
                // Degenerate flushes apply the whole cohort at staleness 0.
                assert_eq!(a.flush_updates, s.scheduled_clients, "r={}", s.round);
                assert_eq!(a.stale_dropped, 0);
                assert_eq!(a.staleness_hist[0], a.flush_updates);
            }
        }
    }

    #[test]
    fn async_buffering_cuts_makespan_under_stragglers() {
        // The asyncscale acceptance shape at test scale: under heavy
        // straggler injection on a heterogeneous cluster, buffered
        // async with staleness room must strictly beat the synchronous
        // Parrot makespan on the identical selection stream — the
        // straggler no longer holds the whole cluster at a barrier.
        let partition = Partition::generate(PartitionKind::Natural, 300, 62, 100, 9);
        let dynamics = DynamicsSpec {
            straggler: StragglerSpec {
                prob: 0.2,
                law: SlowdownLaw::Fixed(8.0),
                drop_prob: 0.0,
            },
            ..Default::default()
        };
        let build = |scheme| {
            VirtualSim::new(
                scheme,
                ClusterProfile::heterogeneous(8),
                WorkloadCost::femnist(),
                CommModel::femnist(),
                SchedulerKind::Greedy,
                2,
                partition.clone(),
                1,
                7,
            )
            .with_dynamics(dynamics.clone())
        };
        let mut sync = build(Scheme::Parrot);
        let sync_total: f64 =
            run_virtual(&mut sync, 6, 64, 13).iter().map(|r| r.total_secs).sum();
        let mut asy = build(Scheme::Async);
        asy.async_spec =
            AsyncSpec { buffer: 32, max_staleness: 2, weight: StalenessWeight::Poly(0.5) };
        let ra = run_virtual(&mut asy, 6, 64, 13);
        let async_total: f64 = ra.iter().map(|r| r.total_secs).sum();
        assert!(
            async_total < sync_total,
            "async buffered {async_total:.2}s !< sync Parrot {sync_total:.2}s"
        );
        // Flush ledger sanity on the same run.
        let applied: usize = ra.iter().map(|r| r.flush_updates).sum();
        let stale: usize = ra.iter().map(|r| r.stale_dropped).sum();
        let completed: usize =
            ra.iter().map(|r| r.scheduled_clients - r.dropped_clients).sum();
        assert_eq!(applied + stale, completed, "every update flushed exactly once");
        assert!(ra.iter().all(|r| r.flush_aggs <= 8));
    }

    #[test]
    fn async_state_accounting_balances_engine_vs_store() {
        use crate::statestore::{SimStore, SimStoreCfg};
        // The PR-3 booking invariant under overlapped flushes: the
        // engine's independently booked StateLoad/StateFlush columns
        // must equal the store's own counters even though cohorts are
        // admitted mid-stream and tails ride later flush chains.
        let partition = Partition::generate(PartitionKind::Natural, 60, 62, 100, 7);
        let s_d: u64 = 1 << 16;
        let mut sim = VirtualSim::new(
            Scheme::Async,
            ClusterProfile::homogeneous(4),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::Greedy,
            2,
            partition,
            1,
            3,
        )
        .with_state_store(
            SimStore::new(SimStoreCfg::new(4, 4, s_d, 64 * s_d as usize).write_back(true)),
            true,
        );
        sim.noise = 0.0;
        sim.async_spec =
            AsyncSpec { buffer: 10, max_staleness: 2, weight: StalenessWeight::Poly(0.5) };
        let rs = run_virtual(&mut sim, 6, 30, 11);
        let engine_bytes: u64 = rs.iter().map(|r| r.state_bytes).sum();
        let m = sim.state.as_ref().expect("store attached").store.metrics;
        assert_eq!(
            engine_bytes,
            m.total_bytes(),
            "async engine state bytes must equal the store's counters"
        );
        assert!(engine_bytes > 0);
        let total_secs: f64 = rs.iter().map(|r| r.total_secs).sum();
        assert!(total_secs.is_finite() && total_secs > 0.0);
    }

    #[test]
    fn sd_utilization_is_non_degenerate_per_executor() {
        // The old loop reported a length-1 vector holding the mean busy
        // time, making utilization() identically 1.0. Each executor
        // must now report its own busy time.
        let partition = Partition::generate(PartitionKind::Natural, 300, 62, 100, 7);
        let mut sim = VirtualSim::new(
            Scheme::SdDist,
            ClusterProfile::heterogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::Uniform,
            2,
            partition,
            1,
            9,
        );
        let rs = run_virtual(&mut sim, 3, 50, 5);
        for r in &rs {
            assert_eq!(r.device_busy.len(), 50, "one entry per executor");
            let u = r.utilization();
            assert!(u < 0.999, "RW/SD utilization must be non-degenerate: {u}");
            assert!(u > 0.05, "utilization {u}");
            // totals decompose: slowest executor + serialized comm tail
            assert!((r.total_secs - r.compute_secs - r.comm_secs).abs() < 1e-9);
        }
    }

    #[test]
    fn fa_accounting_separates_compute_and_comm() {
        let mut sim = mk(Scheme::FaDist, 8, SchedulerKind::Uniform);
        let rs = run_virtual(&mut sim, 3, 100, 5);
        for r in &rs {
            assert_eq!(r.device_busy.len(), 8);
            // busy is compute-only; comm occupancy is tracked separately
            let makespan: f64 = r
                .device_busy
                .iter()
                .zip(&r.device_comm)
                .map(|(b, c)| b + c)
                .fold(0.0, f64::max);
            assert!(
                (r.total_secs - makespan).abs() < 1e-9,
                "round end {} != slowest executor occupancy {makespan}",
                r.total_secs
            );
            // overlap model: comm neither vanishes into compute nor
            // double-counts — the round is bounded by both sides.
            assert!(r.total_secs >= r.compute_secs - 1e-9);
            assert!(r.total_secs <= r.compute_secs + r.comm_secs + 1e-9);
            let comm_sum: f64 = r.device_comm.iter().sum();
            assert!((r.comm_secs - comm_sum).abs() < 1e-9);
            assert!(r.utilization() < 0.999, "FA utilization must be non-degenerate");
        }
    }

    #[test]
    fn unavailable_clients_are_never_scheduled() {
        let mut sim = mk(Scheme::Parrot, 4, SchedulerKind::Greedy);
        let mut trace = std::collections::BTreeMap::new();
        trace.insert(0usize, [5usize, 6, 7].into_iter().collect());
        sim.dynamics.availability = AvailabilityModel::Trace(trace);
        let v = sim.round(0, &[5, 6, 7, 8, 9]);
        assert_eq!(v.unavailable_clients, 3);
        assert_eq!(v.scheduled_clients, 2);
        assert_eq!(v.dropped_clients, 0);
        // next round the trace is clear again
        let v1 = sim.round(1, &[5, 6, 7]);
        assert_eq!(v1.scheduled_clients, 3);
        // a fully-unavailable round degrades to an empty VRound
        sim.dynamics.availability = AvailabilityModel::Bernoulli(0.0);
        let v2 = sim.round(2, &[1, 2, 3]);
        assert_eq!(v2.scheduled_clients, 0);
        assert_eq!(v2.total_secs, 0.0);
    }

    #[test]
    fn scripted_churn_survives_an_empty_round() {
        // A departure scripted for a round in which no selected client
        // is available must still land on the persistent slot state.
        let mut sim = mk(Scheme::Parrot, 4, SchedulerKind::Greedy);
        sim.dynamics.availability = AvailabilityModel::Bernoulli(0.0);
        sim.dynamics.churn = ChurnSpec {
            events: vec![ChurnEvent { round: 0, device: 2, secs: 0.0, kind: ChurnKind::Leave }],
            leave_prob: 0.0,
            join_prob: 0.0,
        };
        let v0 = sim.round(0, &[1, 2, 3]);
        assert_eq!(v0.scheduled_clients, 0);
        assert_eq!(v0.departures, 1, "churn must fire even in an empty round");
        assert!(!sim.device_alive()[2]);
        // with clients available again, the dead slot stays unscheduled
        sim.dynamics.availability = AvailabilityModel::Always;
        let v1 = sim.round(1, &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(v1.device_busy[2], 0.0, "{:?}", v1.device_busy);
        assert!(v1.total_secs > 0.0);
    }

    #[test]
    fn mid_round_departure_reassigns_and_persists() {
        let mut sim = mk(Scheme::Parrot, 4, SchedulerKind::Greedy);
        sim.dynamics.churn = ChurnSpec {
            events: vec![ChurnEvent {
                round: 1,
                device: 0,
                secs: 0.05,
                kind: ChurnKind::Leave,
            }],
            leave_prob: 0.0,
            join_prob: 0.0,
        };
        let rs = run_virtual(&mut sim, 4, 80, 5);
        assert_eq!(rs[1].departures, 1);
        assert_eq!(rs[1].dropped_clients, 0, "orphans must be re-placed");
        assert!(!sim.device_alive()[0], "departure persists across rounds");
        // rounds after the departure never schedule the dead slot
        assert_eq!(rs[2].device_busy[0], 0.0, "{:?}", rs[2].device_busy);
        assert!(rs[2].device_busy[1] > 0.0);
        // history for the departed device was pruned
        assert!(sim.scheduler.history.records().iter().all(|t| t.device != 0 || t.round > 1));
    }

    // ------------------------------------------------ state-store tests

    fn state_sim_sized(
        s_d: u64,
        n_shards: usize,
        write_back: bool,
        prefetch: bool,
        sched: SchedulerKind,
    ) -> VirtualSim {
        use crate::statestore::{SimStore, SimStoreCfg};
        let partition = Partition::generate(PartitionKind::Natural, 60, 62, 100, 7);
        let mut sim = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::homogeneous(4),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            sched,
            2,
            partition,
            1,
            3,
        )
        .with_state_store(
            SimStore::new(
                SimStoreCfg::new(4, n_shards, s_d, 64 * s_d as usize).write_back(write_back),
            ),
            prefetch,
        );
        sim.noise = 0.0;
        sim
    }

    fn state_sim(
        n_shards: usize,
        write_back: bool,
        prefetch: bool,
        sched: SchedulerKind,
    ) -> VirtualSim {
        state_sim_sized(1 << 16, n_shards, write_back, prefetch, sched) // 64 KB states
    }

    /// Run, assert the engine's state columns equal the store's own
    /// counters, and return (total time, peak cache bytes, remote bytes).
    fn run_state_sim(sim: &mut VirtualSim, rounds: usize) -> (f64, u64, u64) {
        let rs = run_virtual(sim, rounds, 30, 11);
        let total: f64 = rs.iter().map(|r| r.total_secs).sum();
        let engine_bytes: u64 = rs.iter().map(|r| r.state_bytes).sum();
        let transfer: u64 = rs.iter().map(|r| r.shard_transfer_bytes).sum();
        let m = sim.state.as_ref().expect("store attached").store.metrics;
        assert_eq!(
            engine_bytes + transfer,
            m.total_bytes(),
            "engine-booked state bytes must equal the store's counters"
        );
        (total, m.peak_cache_bytes, m.remote_bytes)
    }

    #[test]
    fn sharded_store_with_prefetch_dominates_local_baseline_on_peak_ram() {
        // The statescale acceptance shape at test scale: same budget,
        // sharded ownership must strictly beat the local-only baseline
        // on peak cache-resident bytes (no duplicate caching) without
        // giving up the makespan.
        let mut base = state_sim(0, false, false, SchedulerKind::Greedy);
        let (t_base, peak_base, _) = run_state_sim(&mut base, 6);
        let mut shard = state_sim(
            4,
            true,
            true,
            SchedulerKind::StateAffinity { window: 0, weight_pct: 100 },
        );
        let (t_shard, peak_shard, _) = run_state_sim(&mut shard, 6);
        assert!(
            peak_shard < peak_base,
            "sharded peak {peak_shard} must beat local-only {peak_base}"
        );
        assert!(
            t_shard <= t_base * 1.05 + 1.0,
            "sharded makespan {t_shard:.2} vs baseline {t_base:.2}"
        );
        // Write-back + single ownership also cuts disk writes.
        let m_base = base.state.as_ref().unwrap().store.metrics;
        let m_shard = shard.state.as_ref().unwrap().store.metrics;
        assert!(
            m_shard.disk_writes < m_base.disk_writes,
            "write-back must defer writes: {} vs {}",
            m_shard.disk_writes,
            m_base.disk_writes
        );
        assert!(m_shard.avoided_writes > 0);
    }

    #[test]
    fn affinity_scheduling_cuts_remote_state_traffic() {
        // Heavy states (512 MB-class, think full optimizer mirrors):
        // moving one is comparable to a task, so the affinity term must
        // visibly pull clients toward their owners once the model kicks
        // in — the plain greedy kind ignores ownership entirely.
        let s_d: u64 = 1 << 29;
        let mut plain = state_sim_sized(s_d, 4, true, true, SchedulerKind::Greedy);
        let (_, _, remote_plain) = run_state_sim(&mut plain, 8);
        let mut aff = state_sim_sized(
            s_d,
            4,
            true,
            true,
            SchedulerKind::StateAffinity { window: 0, weight_pct: 100 },
        );
        let (_, _, remote_aff) = run_state_sim(&mut aff, 8);
        assert!(
            remote_aff < remote_plain,
            "affinity must reduce remote fetches: {remote_aff} vs {remote_plain}"
        );
    }

    #[test]
    fn state_accounting_stays_exact_under_churn_with_handoff() {
        let mut sim = state_sim(
            4,
            true,
            true,
            SchedulerKind::StateAffinity { window: 0, weight_pct: 100 },
        );
        sim.dynamics.churn = ChurnSpec {
            events: vec![
                ChurnEvent { round: 1, device: 2, secs: 0.05, kind: ChurnKind::Leave },
                ChurnEvent { round: 3, device: 2, secs: 0.0, kind: ChurnKind::Join },
            ],
            leave_prob: 0.0,
            join_prob: 0.0,
        };
        let rs = run_virtual(&mut sim, 5, 30, 11);
        let transfer: u64 = rs.iter().map(|r| r.shard_transfer_bytes).sum();
        assert!(transfer > 0, "departure + rejoin must move shard state");
        let engine_bytes: u64 = rs.iter().map(|r| r.state_bytes).sum();
        let m = sim.state.as_ref().unwrap().store.metrics;
        assert_eq!(engine_bytes + transfer, m.total_bytes());
        assert!(m.shard_transfers > 0);
        // No state was lost across the handoffs: every client trained
        // in some round still has a live snapshot.
        let snap = sim.state.as_ref().unwrap().store.snapshot();
        assert!(!snap.is_empty());
    }

    #[test]
    fn full_dynamics_round_completes_with_sane_accounting() {
        let partition = Partition::generate(PartitionKind::Natural, 500, 62, 100, 13);
        let mut sim = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::TimeWindow(4),
            1,
            partition,
            1,
            21,
        );
        sim.dynamics = DynamicsSpec {
            availability: AvailabilityModel::Bernoulli(0.8),
            churn: ChurnSpec {
                events: vec![
                    ChurnEvent { round: 2, device: 1, secs: 1.0, kind: ChurnKind::Leave },
                    ChurnEvent { round: 4, device: 1, secs: 0.0, kind: ChurnKind::Join },
                ],
                leave_prob: 0.0,
                join_prob: 0.0,
            },
            straggler: StragglerSpec {
                prob: 0.1,
                law: SlowdownLaw::Fixed(3.0),
                drop_prob: 0.05,
            },
        };
        let rs = run_virtual(&mut sim, 6, 100, 7);
        let departures: usize = rs.iter().map(|r| r.departures).sum();
        let joins: usize = rs.iter().map(|r| r.joins).sum();
        assert_eq!(departures, 1);
        assert_eq!(joins, 1);
        let unavailable: usize = rs.iter().map(|r| r.unavailable_clients).sum();
        assert!(unavailable > 0, "Bernoulli(0.8) must filter someone over 6 rounds");
        for r in &rs {
            assert!(r.total_secs.is_finite() && r.total_secs > 0.0);
            let u = r.utilization();
            assert!((0.0..=1.0 + 1e-9).contains(&u), "utilization {u}");
            assert!(r.scheduled_clients + r.unavailable_clients == 100);
            assert!(r.dropped_clients <= r.scheduled_clients);
        }
        // stragglers + drops must register somewhere across the run
        assert!(rs.iter().any(|r| r.dropped_clients > 0 || r.wasted_secs > 0.0));
    }
}
