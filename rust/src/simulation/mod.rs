//! Virtual-time (discrete-event) engine for the timing experiments.
//!
//! The paper's scale/timing figures (Fig. 5, 7, 8, 9, 10, 11) sweep
//! configurations — 1000 concurrent clients, 32 devices, three cluster
//! profiles, five schemes — that would take days of wallclock if every
//! point ran real training.  The engine executes the *same scheduler,
//! aggregation-size and heterogeneity code* as the real-compute path,
//! but advances a virtual clock with modeled task durations
//! (Eq. 2 × the Appendix-A slowdown laws) instead of running PJRT, plus
//! multiplicative measurement noise.  Workload constants are calibrated
//! per paper workload in [`crate::cluster::WorkloadCost`]; the
//! communication model is trips·latency + bytes/bandwidth (Table 1's
//! columns, measured per scheme).
//!
//! Scheme timelines reproduce Fig. 2:
//! - **SP** — one device runs all M_p tasks back-to-back, no comm.
//! - **RW/SD Dist.** — one task per device in parallel; round time =
//!   slowest client + per-client comm (M_p trips).
//! - **FA Dist.** — K devices pull tasks greedily (event loop); params
//!   move per task.
//! - **Parrot** — Alg. 3 schedules task sets; one down + one up message
//!   per device; devices locally aggregate (upload = s_a·K + s_e·M_p).

use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::config::{Scheme, SchedulerKind};
use crate::data::Partition;
use crate::scheduler::{Scheduler, TaskRecord};
use crate::util::rng::Rng;

/// Byte sizes of the communicated quantities (paper model sizes, so the
/// comm:compute ratio matches the evaluated systems).
#[derive(Debug, Clone, Copy)]
pub struct CommModel {
    /// Averaged-params bytes (s_a): full model, e.g. 44 MB for ResNet-18.
    pub s_a: u64,
    /// Special-params bytes per client (s_e), 0 for most algorithms.
    pub s_e: u64,
}

impl CommModel {
    pub fn femnist() -> CommModel {
        CommModel { s_a: 11_000_000 * 4, s_e: 0 } // ResNet-18, 11M params
    }

    pub fn imagenet() -> CommModel {
        CommModel { s_a: 23_000_000 * 4, s_e: 0 } // ResNet-50
    }

    pub fn reddit() -> CommModel {
        CommModel { s_a: 11_000_000 * 4, s_e: 0 } // Albert-base
    }

    pub fn by_name(name: &str) -> CommModel {
        match name {
            "imagenet" | "cnn" => CommModel::imagenet(),
            "reddit" | "tinylm" => CommModel::reddit(),
            _ => CommModel::femnist(),
        }
    }
}

/// One simulated round's outcome.
#[derive(Debug, Clone)]
pub struct VRound {
    pub round: usize,
    /// Virtual seconds for the whole round (compute ∥ + comm).
    pub total_secs: f64,
    pub compute_secs: f64,
    pub comm_secs: f64,
    pub bytes: u64,
    pub trips: u64,
    /// Scheduler wallclock overhead (real, not virtual — Fig. 8).
    pub sched_secs: f64,
    /// Per-device busy virtual seconds.
    pub device_busy: Vec<f64>,
    /// Mean absolute relative error of the workload prediction vs the
    /// realized task times (Fig. 6 / Fig. 11a).
    pub est_err: Option<f64>,
}

impl VRound {
    /// Device utilization: busy / (K · makespan of compute phase).
    pub fn utilization(&self) -> f64 {
        let k = self.device_busy.len().max(1) as f64;
        let makespan = self
            .device_busy
            .iter()
            .cloned()
            .fold(0.0, f64::max)
            .max(1e-12);
        self.device_busy.iter().sum::<f64>() / (k * makespan)
    }
}

/// The virtual simulator: one scheme, one cluster, one workload.
pub struct VirtualSim {
    pub scheme: Scheme,
    pub cluster: ClusterProfile,
    pub cost: WorkloadCost,
    pub comm: CommModel,
    pub scheduler: Scheduler,
    pub partition: Partition,
    pub local_epochs: usize,
    /// Multiplicative measurement noise σ (0 = deterministic).
    pub noise: f64,
    rng: Rng,
}

impl VirtualSim {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        scheme: Scheme,
        cluster: ClusterProfile,
        cost: WorkloadCost,
        comm: CommModel,
        sched: SchedulerKind,
        warmup: usize,
        partition: Partition,
        local_epochs: usize,
        seed: u64,
    ) -> VirtualSim {
        let k = cluster.n_devices();
        VirtualSim {
            scheme,
            cluster,
            cost,
            comm,
            scheduler: Scheduler::new(sched, warmup, k),
            partition,
            local_epochs,
            noise: 0.05,
            rng: Rng::new(seed ^ 0x51D_CAFE),
        }
    }

    /// Realized (noisy) duration of one task on device k at round r.
    fn realize(&mut self, k: usize, r: usize, n_eff: usize) -> f64 {
        let base = self.cluster.task_time(&self.cost, k, r, n_eff, 1);
        let noise = 1.0 + self.noise * self.rng.normal();
        base * noise.max(0.2)
    }

    /// Simulate one round for the selected clients; feeds realized times
    /// back into the scheduler history exactly like the real path.
    pub fn round(&mut self, r: usize, selected: &[usize]) -> VRound {
        let k = self.cluster.n_devices();
        let sizes: Vec<(usize, usize)> = selected
            .iter()
            .map(|&c| (c, self.partition.sizes[c] * self.local_epochs))
            .collect();
        match self.scheme {
            Scheme::SP => self.round_sp(r, &sizes),
            Scheme::RwDist | Scheme::SdDist => self.round_sd(r, &sizes),
            Scheme::FaDist => self.round_fa(r, &sizes, k),
            Scheme::Parrot => self.round_parrot(r, &sizes, k),
        }
    }

    fn round_sp(&mut self, r: usize, sizes: &[(usize, usize)]) -> VRound {
        let mut busy = 0.0;
        for &(_, n) in sizes {
            busy += self.realize(0, r, n);
        }
        VRound {
            round: r,
            total_secs: busy,
            compute_secs: busy,
            comm_secs: 0.0,
            bytes: 0,
            trips: 0,
            sched_secs: 0.0,
            device_busy: vec![busy],
            est_err: None,
        }
    }

    /// RW/SD: each selected client on its own executor, fully parallel;
    /// server talks to each of the M_p executors (down + up).
    fn round_sd(&mut self, r: usize, sizes: &[(usize, usize)]) -> VRound {
        let k_model = self.cluster.n_devices();
        let mut slowest = 0.0f64;
        let mut busy_total = 0.0;
        for (i, &(_, n)) in sizes.iter().enumerate() {
            // Executors cycle through the cluster's device models so
            // heterogeneity still matters when simulated on cluster C.
            let t = self.realize(i % k_model, r, n);
            slowest = slowest.max(t);
            busy_total += t;
        }
        let m_p = sizes.len();
        let per_client = self.comm.s_a + self.comm.s_e;
        let bytes = 2 * per_client * m_p as u64;
        // Down broadcasts overlap; uploads serialize into the server NIC
        // (the paper's trips argument): latency per trip + payload time.
        let comm = self.cluster.comm_time(per_client as usize)
            + m_p as f64 * self.cluster.latency
            + (per_client * m_p as u64) as f64 / self.cluster.bandwidth;
        VRound {
            round: r,
            total_secs: slowest + comm,
            compute_secs: slowest,
            comm_secs: comm,
            bytes,
            trips: 2 * m_p as u64,
            sched_secs: 0.0,
            device_busy: vec![busy_total / m_p.max(1) as f64; m_p.min(1).max(1)],
            est_err: None,
        }
    }

    /// FA: greedy pull, params per task (FedScale/Flower timeline).
    fn round_fa(&mut self, r: usize, sizes: &[(usize, usize)], k: usize) -> VRound {
        // Event loop: device free-times; next task goes to the earliest
        // free device (server reassigns on completion).
        let mut free = vec![0.0f64; k];
        let mut busy = vec![0.0f64; k];
        let per_task_comm =
            2.0 * self.cluster.comm_time((self.comm.s_a + self.comm.s_e) as usize);
        let mut queue: Vec<&(usize, usize)> = sizes.iter().collect();
        queue.sort_by(|a, b| b.1.cmp(&a.1)); // FedScale: biggest first
        for &&(_, n) in &queue {
            let dev = (0..k)
                .min_by(|&a, &b| free[a].partial_cmp(&free[b]).unwrap())
                .unwrap();
            let t = self.realize(dev, r, n) + per_task_comm;
            free[dev] += t;
            busy[dev] += t;
        }
        let makespan = free.iter().cloned().fold(0.0, f64::max);
        let m_p = sizes.len() as u64;
        VRound {
            round: r,
            total_secs: makespan,
            compute_secs: makespan - per_task_comm,
            comm_secs: per_task_comm * m_p as f64,
            bytes: 2 * (self.comm.s_a + self.comm.s_e) * m_p,
            trips: 2 * m_p,
            sched_secs: 0.0,
            device_busy: busy,
            est_err: None,
        }
    }

    /// Parrot: Alg. 3 schedule, hierarchical aggregation comm model.
    fn round_parrot(&mut self, r: usize, sizes: &[(usize, usize)], k: usize) -> VRound {
        let schedule = self.scheduler.schedule(r, sizes);
        let size_of: std::collections::HashMap<usize, usize> =
            sizes.iter().cloned().collect();
        let mut busy = vec![0.0f64; k];
        let mut realized: Vec<(usize, f64, f64)> = Vec::new(); // (dev, predicted, actual)
        for (dev, clients) in schedule.assignment.iter().enumerate() {
            for &c in clients {
                let n = size_of[&c];
                let t = self.realize(dev, r, n);
                busy[dev] += t;
                // Feed history back (devices piggyback records).
                self.scheduler.record(TaskRecord {
                    round: r,
                    device: dev,
                    n_samples: n,
                    secs: t,
                });
                if schedule.used_model {
                    let predicted = self.scheduler.estimates(r)[dev].predict(n);
                    realized.push((dev, predicted, t));
                }
            }
        }
        let est_err = if realized.is_empty() {
            None
        } else {
            let (pred, act): (Vec<f64>, Vec<f64>) =
                realized.iter().map(|&(_, p, a)| (p, a)).unzip();
            Some(crate::util::stats::mape(&act, &pred))
        };
        let makespan = busy.iter().cloned().fold(0.0, f64::max);
        // Comm: broadcast s_a down per device (+ assignments, negligible),
        // one aggregated upload s_a per device, plus s_e per client.
        let m_p = sizes.len() as u64;
        let bytes = 2 * self.comm.s_a * k as u64 + self.comm.s_e * m_p;
        let comm = self.cluster.comm_time(self.comm.s_a as usize) * 2.0
            + (k as f64 - 1.0) * self.cluster.latency
            + (self.comm.s_e * m_p) as f64 / self.cluster.bandwidth;
        VRound {
            round: r,
            total_secs: makespan + comm,
            compute_secs: makespan,
            comm_secs: comm,
            bytes,
            trips: 2 * k as u64,
            sched_secs: schedule.overhead_secs,
            device_busy: busy,
            est_err,
        }
    }
}

/// Run `rounds` rounds selecting `m_p` clients uniformly per round;
/// returns per-round outcomes.  The shared driver for every timing
/// figure harness.
#[allow(clippy::too_many_arguments)]
pub fn run_virtual(sim: &mut VirtualSim, rounds: usize, m_p: usize, seed: u64) -> Vec<VRound> {
    let selector = Rng::new(seed ^ 0xF1A_C0DE);
    let m = sim.partition.n_clients();
    (0..rounds)
        .map(|r| {
            let mut rng = selector.derive(r as u64);
            let selected = rng.choose(m, m_p.min(m));
            sim.round(r, &selected)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::PartitionKind;

    fn mk(scheme: Scheme, k: usize, sched: SchedulerKind) -> VirtualSim {
        let partition =
            Partition::generate(PartitionKind::Natural, 200, 62, 100, 7);
        VirtualSim::new(
            scheme,
            ClusterProfile::homogeneous(k),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            sched,
            2,
            partition,
            1,
            3,
        )
    }

    #[test]
    fn sp_is_serial_sum() {
        let mut sim = mk(Scheme::SP, 1, SchedulerKind::Uniform);
        sim.noise = 0.0;
        let rounds = run_virtual(&mut sim, 3, 50, 1);
        for r in &rounds {
            assert_eq!(r.trips, 0);
            assert_eq!(r.bytes, 0);
            assert!(r.total_secs > 40.0 * 0.15, "50 tasks × b at least");
        }
    }

    #[test]
    fn parrot_beats_fa_and_sd_on_time() {
        // The headline Fig. 5 shape at one configuration.
        let (mut fa, mut sd, mut parrot) = (
            mk(Scheme::FaDist, 8, SchedulerKind::Uniform),
            mk(Scheme::SdDist, 8, SchedulerKind::Uniform),
            mk(Scheme::Parrot, 8, SchedulerKind::Greedy),
        );
        let t = |sim: &mut VirtualSim| {
            let rs = run_virtual(sim, 8, 100, 1);
            rs[3..].iter().map(|r| r.total_secs).sum::<f64>() / 5.0
        };
        let (tf, ts, tp) = (t(&mut fa), t(&mut sd), t(&mut parrot));
        assert!(tp < tf, "parrot {tp} !< fa {tf}");
        // SD has M_p=100 parallel devices, so pure compute is fast — but
        // Parrot on only 8 devices must still be within a small factor,
        // and must crush it on bytes.
        let rb = run_virtual(&mut parrot, 1, 100, 2)[0].bytes;
        let sb = run_virtual(&mut sd, 1, 100, 2)[0].bytes;
        assert!(rb * 5 < sb, "parrot bytes {rb} vs sd {sb}");
        let _ = ts;
    }

    #[test]
    fn parrot_comm_is_o_k() {
        let mut p = mk(Scheme::Parrot, 8, SchedulerKind::Greedy);
        let r = run_virtual(&mut p, 1, 100, 1);
        assert_eq!(r[0].trips, 16); // 2K
        assert_eq!(r[0].bytes, 2 * CommModel::femnist().s_a * 8);
        let mut fa = mk(Scheme::FaDist, 8, SchedulerKind::Uniform);
        let rf = run_virtual(&mut fa, 1, 100, 1);
        assert_eq!(rf[0].trips, 200); // 2·M_p
    }

    #[test]
    fn scheduling_beats_uniform_under_heterogeneity() {
        let partition = Partition::generate(PartitionKind::Natural, 300, 62, 100, 9);
        let mut with = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::Greedy,
            2,
            partition.clone(),
            1,
            5,
        );
        let mut without = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::Uniform,
            2,
            partition,
            1,
            5,
        );
        let mean = |rs: &[VRound]| {
            rs.iter().skip(3).map(|r| r.total_secs).sum::<f64>() / (rs.len() - 3) as f64
        };
        let rw = run_virtual(&mut with, 12, 100, 4);
        let ro = run_virtual(&mut without, 12, 100, 4);
        assert!(
            mean(&rw) < 0.8 * mean(&ro),
            "sched {:.2} !< 0.8 × unsched {:.2}",
            mean(&rw),
            mean(&ro)
        );
    }

    #[test]
    fn estimation_error_small_when_stable() {
        let mut sim = mk(Scheme::Parrot, 4, SchedulerKind::Greedy);
        let rs = run_virtual(&mut sim, 10, 60, 6);
        let last = rs.last().unwrap();
        let err = last.est_err.expect("model in use by round 10");
        assert!(err < 0.15, "estimation error {err}");
    }

    #[test]
    fn time_window_wins_in_dynamic_env() {
        // Fig. 11: under cos-dynamics, windowed estimation must beat
        // full-history estimation on round time.
        let partition = Partition::generate(PartitionKind::Natural, 300, 62, 100, 11);
        let mk_dyn = |sched: SchedulerKind| {
            VirtualSim::new(
                Scheme::Parrot,
                ClusterProfile::dynamic(8, 25.0),
                WorkloadCost::femnist(),
                CommModel::femnist(),
                sched,
                2,
                partition.clone(),
                1,
                13,
            )
        };
        let mean_tail = |rs: &[VRound]| {
            rs.iter().skip(20).map(|r| r.total_secs).sum::<f64>() / (rs.len() - 20) as f64
        };
        let mut full = mk_dyn(SchedulerKind::Greedy);
        let mut windowed = mk_dyn(SchedulerKind::TimeWindow(3));
        let rf = run_virtual(&mut full, 60, 100, 17);
        let rw = run_virtual(&mut windowed, 60, 100, 17);
        assert!(
            mean_tail(&rw) < mean_tail(&rf) * 1.02,
            "window {:.2} !< full {:.2}",
            mean_tail(&rw),
            mean_tail(&rf)
        );
        // and its estimation error must be lower
        let err = |rs: &[VRound]| {
            let v: Vec<f64> = rs.iter().skip(20).filter_map(|r| r.est_err).collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(err(&rw) < err(&rf), "window err {} !< full err {}", err(&rw), err(&rf));
    }

    #[test]
    fn more_devices_scale_down_round_time() {
        // Fig. 7: near-linear scaling.
        let t_at = |k: usize| {
            let mut sim = mk(Scheme::Parrot, k, SchedulerKind::Greedy);
            let rs = run_virtual(&mut sim, 8, 100, 3);
            rs.iter().skip(3).map(|r| r.total_secs).sum::<f64>() / 5.0
        };
        let (t4, t16) = (t_at(4), t_at(16));
        assert!(
            t16 < t4 / 2.5,
            "16 devices should be ≳2.5x faster than 4: {t4:.2} vs {t16:.2}"
        );
    }

    #[test]
    fn utilization_bounded() {
        let mut sim = mk(Scheme::Parrot, 8, SchedulerKind::Greedy);
        for r in run_virtual(&mut sim, 6, 100, 9) {
            let u = r.utilization();
            assert!((0.0..=1.0 + 1e-9).contains(&u), "utilization {u}");
        }
    }
}
