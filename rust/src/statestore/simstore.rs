//! The virtual-path client-state store: plan-level accounting of the
//! three-tier path (write-back LRU cache → local disk → remote owner
//! fetch) for the discrete-event engine.
//!
//! No payload bytes exist here — a client's state is a size + a version
//! stamp — but the *policy* is byte-for-byte the deployable one: the
//! per-worker caches run the same [`WriteBackCache`] the real
//! [`StateManager`](crate::state::StateManager) uses, so the metrics a
//! virtual sweep reports are the metrics a real sharded cluster would
//! measure on the same access sequence (`parrot exp statescale --smoke`
//! asserts exactly that differential).
//!
//! ## Plan-level semantics
//!
//! Parrot plans every round up front (Alg. 3), so the state-access
//! order per worker is fixed at plan time; [`SimStore::plan_round`]
//! walks that order, mutates the tiers, and returns per-task
//! [`StateLeg`]s plus a round-tail flush leg for the engine to price in
//! virtual time.  Consequences, by design:
//!
//! - prefetch `ready` times assume one fetch channel per worker issuing
//!   loads in task order from round start;
//! - a task dropped mid-round still pays its planned state traffic (the
//!   prefetch already moved the bytes) — the engine books every planned
//!   leg, which is what keeps the engine's byte columns and this
//!   store's counters equal on any seed, dynamic or not;
//! - remote legs ride the star topology (owner → server → executor),
//!   so every remote move costs two network legs of `s_d`.
//!
//! ## Modes
//!
//! `n_shards = 0` is the **local-only baseline**: no ownership, one
//! shared disk, every worker caches whatever it touches (the seed
//! system's behavior — duplicated cache copies and all).  With
//! `n_shards ≥ 1`, shard `s` is hosted by worker `s`; only owners cache
//! and persist state, executors stream non-owned state through the
//! remote path and return it at round end.

use super::lru::{CacheCost, Evicted, WriteBackCache};
use super::shard::ShardMap;
use super::{StateLeg, StatePlan};
use std::collections::BTreeMap;

/// Disk-host tag for the unsharded shared-disk baseline.
const SHARED: usize = usize::MAX;

/// The one owner→worker mapping: shard `s` is hosted by worker
/// `s % n_workers`.  Every ownership decision (load routing, handoff
/// rescans, rejoin pulls, the misplaced-cache audit) must go through
/// this helper — four call sites used to inline the `% n` expression
/// independently, which is exactly how a remap-rule drift between the
/// handoff and rejoin scans would strand state at the wrong worker.
pub fn home_worker(map: &ShardMap, n_workers: usize, client: u64) -> usize {
    map.owner(client) as usize % n_workers
}

/// Size + version stand-in for a client-state blob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blob {
    pub bytes: usize,
    /// Round-stamp of the last save (round + 1; 0 never happens).
    pub version: u64,
}

impl CacheCost for Blob {
    fn cost(&self) -> usize {
        self.bytes
    }
}

/// One store configuration point of the `statescale` sweep.
#[derive(Debug, Clone, Copy)]
pub struct SimStoreCfg {
    pub n_workers: usize,
    /// Consistent-hash shards (0 = local-only baseline; otherwise
    /// clamped to ≤ n_workers, shard s hosted by worker s).
    pub n_shards: usize,
    /// Client state size s_d in bytes.
    pub state_bytes: u64,
    /// Per-worker cache budget in bytes.
    pub cache_budget: usize,
    /// Dirty write-back (spill on eviction / explicit flush) vs
    /// write-through (every save pays a disk write immediately).
    pub write_back: bool,
    /// Force a flush of all dirty entries at every round boundary
    /// (consistency points) instead of only on eviction/handoff.
    pub flush_every_round: bool,
    /// Disk tier bandwidth, bytes/sec.
    pub disk_bandwidth: f64,
    /// Network bandwidth/latency for remote legs (match the cluster).
    pub net_bandwidth: f64,
    pub net_latency: f64,
}

impl SimStoreCfg {
    pub fn new(n_workers: usize, n_shards: usize, state_bytes: u64, cache_budget: usize) -> Self {
        SimStoreCfg {
            n_workers,
            n_shards: n_shards.min(n_workers),
            state_bytes,
            cache_budget,
            write_back: n_shards > 0,
            flush_every_round: false,
            disk_bandwidth: 2e9,
            net_bandwidth: 10e9 / 8.0,
            net_latency: 1e-3,
        }
    }

    pub fn write_back(mut self, on: bool) -> Self {
        self.write_back = on;
        self
    }

    pub fn flush_every_round(mut self, on: bool) -> Self {
        self.flush_every_round = on;
        self
    }

    pub fn network(mut self, bandwidth: f64, latency: f64) -> Self {
        self.net_bandwidth = bandwidth;
        self.net_latency = latency;
        self
    }
}

/// Traffic counters; [`StoreMetrics::total_bytes`] is the quantity the
/// engine's independent leg sum must reproduce exactly.
#[derive(Debug, Default, Clone, Copy)]
pub struct StoreMetrics {
    pub loads: u64,
    pub cache_hits: u64,
    pub disk_reads: u64,
    pub disk_writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub remote_fetches: u64,
    pub remote_returns: u64,
    /// Network bytes of remote fetch/return legs (2·s_d per move).
    pub remote_bytes: u64,
    pub shard_transfers: u64,
    /// Network bytes of ownership handoffs (2·s_d per moved state).
    pub shard_transfer_bytes: u64,
    /// Saves absorbed by an already-dirty cache entry — disk writes a
    /// write-through store would have paid.
    pub avoided_writes: u64,
    /// High-water mark of cache residency summed over all workers.
    pub peak_cache_bytes: u64,
}

impl StoreMetrics {
    /// Every byte of state movement, all tiers.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written + self.remote_bytes + self.shard_transfer_bytes
    }
}

/// The store (see module docs).
pub struct SimStore {
    cfg: SimStoreCfg,
    shards: Option<ShardMap>,
    caches: Vec<WriteBackCache<Blob>>,
    /// client → (blob, hosting worker; [`SHARED`] in local-only mode).
    /// Ordered so handoff/rejoin scans move states deterministically.
    disk: BTreeMap<u64, (Blob, usize)>,
    pub metrics: StoreMetrics,
}

impl SimStore {
    pub fn new(cfg: SimStoreCfg) -> SimStore {
        assert!(cfg.n_workers > 0, "SimStore needs at least one worker");
        let cfg = SimStoreCfg { n_shards: cfg.n_shards.min(cfg.n_workers), ..cfg };
        SimStore {
            shards: if cfg.n_shards > 0 { Some(ShardMap::new(cfg.n_shards)) } else { None },
            caches: (0..cfg.n_workers).map(|_| WriteBackCache::new(cfg.cache_budget)).collect(),
            disk: BTreeMap::new(),
            metrics: StoreMetrics::default(),
            cfg,
        }
    }

    pub fn cfg(&self) -> &SimStoreCfg {
        &self.cfg
    }

    pub fn shard_map(&self) -> Option<&ShardMap> {
        self.shards.as_ref()
    }

    /// The worker hosting `client`'s state, None in local-only mode.
    pub fn owner_worker(&self, client: u64) -> Option<usize> {
        self.shards.as_ref().map(|m| home_worker(m, self.cfg.n_workers, client))
    }

    pub fn cache_resident_bytes(&self) -> u64 {
        self.caches.iter().map(|c| c.resident_bytes() as u64).sum()
    }

    pub fn disk_states(&self) -> usize {
        self.disk.len()
    }

    pub fn disk_bytes(&self) -> u64 {
        self.disk.values().map(|(b, _)| b.bytes as u64).sum()
    }

    /// Latest known version per client across all tiers (differential
    /// handoff test: a handoff must not lose or regress any of these).
    pub fn snapshot(&self) -> BTreeMap<u64, u64> {
        let mut out: BTreeMap<u64, u64> =
            self.disk.iter().map(|(&c, &(b, _))| (c, b.version)).collect();
        for cache in &self.caches {
            for (c, blob) in cache.iter() {
                let v = out.entry(c).or_insert(0);
                *v = (*v).max(blob.version);
            }
        }
        out
    }

    fn disk_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / self.cfg.disk_bandwidth
    }

    fn net_secs(&self, bytes: u64) -> f64 {
        self.cfg.net_latency + bytes as f64 / self.cfg.net_bandwidth
    }

    fn touch_peak(&mut self) {
        let total = self.cache_resident_bytes();
        self.metrics.peak_cache_bytes = self.metrics.peak_cache_bytes.max(total);
    }

    fn disk_write(&mut self, client: u64, blob: Blob, host: usize) -> (u64, f64) {
        self.metrics.disk_writes += 1;
        self.metrics.bytes_written += blob.bytes as u64;
        self.disk.insert(client, (blob, host));
        (blob.bytes as u64, self.disk_secs(blob.bytes as u64))
    }

    /// Spill displaced dirty entries to disk at `host`.
    fn spill(&mut self, host: usize, evicted: Vec<Evicted<Blob>>) -> (u64, f64) {
        let (mut bytes, mut secs) = (0, 0.0);
        for e in evicted {
            if e.dirty {
                let (b, s) = self.disk_write(e.client, e.value, host);
                bytes += b;
                secs += s;
            }
        }
        (bytes, secs)
    }

    /// Tier walk for one load at `worker`; returns `(bytes, secs)`.
    fn load_for(&mut self, worker: usize, client: u64) -> (u64, f64) {
        self.metrics.loads += 1;
        let owner = self.owner_worker(client);
        let host = owner.unwrap_or(worker);
        let (mut bytes, mut secs) = (0u64, 0.0f64);
        if self.caches[host].get(client).is_some() {
            self.metrics.cache_hits += 1;
        } else if let Some(&(blob, _)) = self.disk.get(&client) {
            self.metrics.disk_reads += 1;
            self.metrics.bytes_read += blob.bytes as u64;
            bytes += blob.bytes as u64;
            secs += self.disk_secs(blob.bytes as u64);
            let (_, ev) = self.caches[host].insert(client, blob, false);
            let (b, s) = self.spill(host, ev);
            bytes += b;
            secs += s;
            self.touch_peak();
        } else {
            // First selection: no state anywhere, nothing moves.
            return (0, 0.0);
        }
        if let Some(o) = owner {
            if o != worker {
                // owner → server → executor.
                self.metrics.remote_fetches += 1;
                let wire = 2 * self.cfg.state_bytes;
                self.metrics.remote_bytes += wire;
                bytes += wire;
                secs += 2.0 * self.net_secs(self.cfg.state_bytes);
            }
        }
        (bytes, secs)
    }

    /// One post-training save at `worker`; returns `(bytes, secs)` —
    /// the seconds land in the round tail (saves never stall compute).
    fn save_for(&mut self, worker: usize, client: u64, round: u64) -> (u64, f64) {
        let blob = Blob {
            bytes: usize::try_from(self.cfg.state_bytes)
                .expect("state_bytes exceeds the address space"),
            version: round + 1,
        };
        let owner = self.owner_worker(client);
        let host = owner.unwrap_or(worker);
        let (mut bytes, mut secs) = (0u64, 0.0f64);
        if let Some(o) = owner {
            if o != worker {
                // Write-back return leg: executor → server → owner.
                self.metrics.remote_returns += 1;
                let wire = 2 * self.cfg.state_bytes;
                self.metrics.remote_bytes += wire;
                bytes += wire;
                secs += 2.0 * self.net_secs(self.cfg.state_bytes);
            }
        }
        if self.cfg.write_back {
            if self.caches[host].is_dirty(client) {
                self.metrics.avoided_writes += 1;
            }
            let (resident, ev) = self.caches[host].insert(client, blob, true);
            let (b, s) = self.spill(host, ev);
            bytes += b;
            secs += s;
            if !resident {
                let (b, s) = self.disk_write(client, blob, host);
                bytes += b;
                secs += s;
            }
        } else {
            let (b, s) = self.disk_write(client, blob, host);
            bytes += b;
            secs += s;
            let (_, ev) = self.caches[host].insert(client, blob, false);
            let (b, s) = self.spill(host, ev);
            bytes += b;
            secs += s;
        }
        self.touch_peak();
        (bytes, secs)
    }

    /// Flush every dirty cache entry to disk; `(bytes, secs)`.
    pub fn flush_all(&mut self) -> (u64, f64) {
        let (mut bytes, mut secs) = (0u64, 0.0f64);
        for w in 0..self.cfg.n_workers {
            let host = if self.shards.is_some() { w } else { SHARED };
            for c in self.caches[w].dirty_ids() {
                let blob = *self.caches[w].peek(c).expect("dirty entry present");
                self.caches[w].mark_clean(c);
                let (b, s) = self.disk_write(c, blob, host);
                bytes += b;
                secs += s;
            }
        }
        (bytes, secs)
    }

    /// Account one planned round: `assigned[w]` is worker w's client
    /// list in execution order.  Returns legs mirroring the input shape
    /// plus the round-tail `(bytes, secs)` flush leg.  This mutates the
    /// tiers — it IS the round's state traffic (module docs).
    pub fn plan_round(
        &mut self,
        round: u64,
        assigned: &[Vec<u64>],
    ) -> (Vec<Vec<StateLeg>>, u64, f64) {
        assert_eq!(assigned.len(), self.cfg.n_workers, "one client list per worker");
        let mut legs = Vec::with_capacity(assigned.len());
        let (mut tail_bytes, mut tail_secs) = (0u64, 0.0f64);
        for (w, clients) in assigned.iter().enumerate() {
            let mut chan = 0.0f64;
            let mut ws = Vec::with_capacity(clients.len());
            for &c in clients {
                let (lb, ls) = self.load_for(w, c);
                chan += ls;
                let (sb, ss) = self.save_for(w, c, round);
                tail_secs += ss;
                ws.push(StateLeg { bytes: lb + sb, secs: ls, ready: chan });
            }
            legs.push(ws);
        }
        if self.cfg.write_back && self.cfg.flush_every_round {
            let (b, s) = self.flush_all();
            tail_bytes += b;
            tail_secs += s;
        }
        (legs, tail_bytes, tail_secs)
    }

    /// [`SimStore::plan_round`] packaged for the engine: scatters the
    /// per-worker legs into task-index order via `assigned_tasks` (the
    /// plan's per-worker task-id queues).
    pub fn plan_for_tasks(
        &mut self,
        round: u64,
        assigned_tasks: &[Vec<usize>],
        client_of: impl Fn(usize) -> u64,
        n_tasks: usize,
        prefetch: bool,
    ) -> StatePlan {
        let lists: Vec<Vec<u64>> = assigned_tasks
            .iter()
            .map(|q| q.iter().map(|&t| client_of(t)).collect())
            .collect();
        let (legs, tail_bytes, tail_secs) = self.plan_round(round, &lists);
        let mut out = vec![StateLeg::default(); n_tasks];
        for (w, q) in assigned_tasks.iter().enumerate() {
            for (i, &t) in q.iter().enumerate() {
                out[t] = legs[w][i];
            }
        }
        StatePlan { legs: out, prefetch, tail_bytes, tail_secs }
    }

    /// Device `worker` departed: flush its dirty cache, retire its
    /// shard, and hand every state it hosted to the new owners (the
    /// ShardTransfer path: two network legs per state through the
    /// server).  Returns the handoff bytes (flush spills + transfers);
    /// 0 when unsharded, when the worker hosts no shard, or when it
    /// hosts the last shard (which must stay).
    pub fn handoff(&mut self, worker: usize) -> u64 {
        let shard = u32::try_from(worker).expect("worker index exceeds u32");
        let removed = match self.shards.as_mut() {
            None => return 0,
            Some(m) => m.contains_shard(shard) && m.remove_shard(shard),
        };
        if !removed {
            return 0;
        }
        let mut bytes = 0u64;
        // No dirty state may die with the device: spill, then move.
        for (c, blob, dirty) in self.caches[worker].drain() {
            if dirty {
                let (b, _) = self.disk_write(c, blob, worker);
                bytes += b;
            }
        }
        let hosted: Vec<u64> = self
            .disk
            .iter()
            .filter(|(_, &(_, h))| h == worker)
            .map(|(&c, _)| c)
            .collect();
        for c in hosted {
            let (blob, _) = self.disk[&c];
            let new_host = self.owner_worker(c).expect("sharded");
            self.disk.insert(c, (blob, new_host));
            self.metrics.shard_transfers += 1;
            let wire = 2 * blob.bytes as u64;
            self.metrics.shard_transfer_bytes += wire;
            bytes += wire;
        }
        bytes
    }

    /// Device `worker` (re)joined: restore its shard and pull the
    /// states it now owns from their interim hosts — whether they live
    /// on an interim owner's disk, in an interim owner's cache (dirty
    /// and never flushed — these MUST move or they'd be stranded at a
    /// worker that no longer owns them), or both.  Returns the transfer
    /// bytes; 0 when unsharded or already present.
    pub fn rejoin(&mut self, worker: usize) -> u64 {
        if worker >= self.cfg.n_shards {
            // Outside the configured shard universe (a non-owner device
            // rejoining): ownership is unaffected.
            return 0;
        }
        let shard = u32::try_from(worker).expect("worker index exceeds u32");
        let added = match self.shards.as_mut() {
            None => return 0,
            Some(m) => m.add_shard(shard),
        };
        if !added {
            return 0;
        }
        // Collect first (immutable scans), mutate after.
        let mut moving: BTreeMap<u64, Option<usize>> = BTreeMap::new();
        let mut cache_host: BTreeMap<u64, usize> = BTreeMap::new();
        {
            let map = self.shards.as_ref().expect("sharded");
            let n = self.cfg.n_workers;
            for (&c, &(_, h)) in self.disk.iter() {
                if h != worker && home_worker(map, n, c) == worker {
                    moving.insert(c, Some(h));
                }
            }
            for (w, cache) in self.caches.iter().enumerate() {
                if w == worker {
                    continue;
                }
                for (c, _) in cache.iter() {
                    if home_worker(map, n, c) == worker {
                        cache_host.insert(c, w);
                        moving.entry(c).or_insert(None);
                    }
                }
            }
        }
        let mut bytes = 0u64;
        for (c, disk_host) in moving {
            let cached = cache_host.get(&c).copied().and_then(|w| self.caches[w].remove(c));
            let blob = match cached {
                Some((b, dirty)) if dirty || disk_host.is_none() => {
                    // The interim cache held the newest (or only) copy:
                    // persist it at the new owner.
                    let (wb, _) = self.disk_write(c, b, worker);
                    bytes += wb;
                    b
                }
                _ => {
                    if disk_host.is_none() {
                        continue; // nothing survives anywhere (can't happen)
                    }
                    let blob = self.disk[&c].0;
                    self.disk.insert(c, (blob, worker));
                    blob
                }
            };
            self.metrics.shard_transfers += 1;
            let wire = 2 * blob.bytes as u64;
            self.metrics.shard_transfer_bytes += wire;
            bytes += wire;
        }
        bytes
    }

    /// Invariant audit: in sharded mode every cache-resident state must
    /// sit at its current owner (handoff/rejoin would otherwise strand
    /// never-flushed copies at workers that no longer own them).
    /// Returns the number of misplaced entries (always 0 unsharded).
    pub fn misplaced_cache_entries(&self) -> usize {
        let Some(map) = self.shards.as_ref() else { return 0 };
        let n = self.cfg.n_workers;
        let mut misplaced = 0;
        for (w, cache) in self.caches.iter().enumerate() {
            for (c, _) in cache.iter() {
                if home_worker(map, n, c) != w {
                    misplaced += 1;
                }
            }
        }
        misplaced
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SD: u64 = 1000;

    fn store(workers: usize, shards: usize, budget_states: usize) -> SimStore {
        SimStore::new(SimStoreCfg::new(
            workers,
            shards,
            SD,
            budget_states * SD as usize,
        ))
    }

    #[test]
    fn first_round_moves_nothing_then_tiers_kick_in() {
        let mut s = store(2, 2, 4);
        let (legs, _, _) = s.plan_round(0, &[vec![1, 2], vec![3]]);
        // No state exists yet: loads are free, saves mark cache dirty.
        assert!(legs[0].iter().all(|l| l.bytes == 0 || l.bytes >= SD));
        assert_eq!(s.metrics.disk_reads, 0);
        assert_eq!(s.metrics.loads, 3);
        // Same clients again, owners unchanged: all cache hits.
        let before = s.metrics.total_bytes();
        s.plan_round(1, &[vec![1, 2], vec![3]]);
        let after = s.metrics.total_bytes();
        assert!(s.metrics.cache_hits >= 3, "{:?}", s.metrics);
        // Owned, cache-resident retraining moves bytes only for clients
        // whose owner is the other worker (remote legs).
        assert!(after >= before);
    }

    #[test]
    fn write_back_avoids_disk_writes_until_flush() {
        let mut s = store(1, 1, 8);
        s.plan_round(0, &[vec![7]]);
        s.plan_round(1, &[vec![7]]);
        s.plan_round(2, &[vec![7]]);
        assert_eq!(s.metrics.disk_writes, 0, "write-back must defer");
        assert_eq!(s.metrics.avoided_writes, 2, "rounds 1 and 2 coalesced");
        let (bytes, _) = s.flush_all();
        assert_eq!(bytes, SD);
        assert_eq!(s.metrics.disk_writes, 1);
        assert_eq!(s.snapshot().get(&7), Some(&3));
    }

    #[test]
    fn write_through_pays_per_save() {
        let mut s = SimStore::new(SimStoreCfg::new(1, 0, SD, 8 * SD as usize).write_back(false));
        s.plan_round(0, &[vec![7]]);
        s.plan_round(1, &[vec![7]]);
        assert_eq!(s.metrics.disk_writes, 2);
        assert_eq!(s.metrics.avoided_writes, 0);
    }

    #[test]
    fn remote_execution_pays_four_network_legs() {
        let mut s = store(2, 2, 8);
        // Find a client owned by worker 1, run it on worker 0.
        let c = (0..100u64).find(|&c| s.owner_worker(c) == Some(1)).unwrap();
        s.plan_round(0, &[vec![], vec![c]]); // trained at home first
        s.flush_all();
        let before = s.metrics.remote_bytes;
        let (legs, _, _) = s.plan_round(1, &[vec![c], vec![]]);
        // fetch (2·s_d) + return (2·s_d)
        assert_eq!(s.metrics.remote_bytes - before, 4 * SD);
        assert_eq!(s.metrics.remote_fetches, 1);
        assert_eq!(s.metrics.remote_returns, 1);
        assert_eq!(legs[0][0].bytes, 4 * SD, "legs carry the remote traffic");
        // The executor never caches non-owned state.
        assert_eq!(s.caches[0].len(), 0);
    }

    #[test]
    fn eviction_spills_dirty_states_and_counts_bytes() {
        let mut s = store(1, 1, 2); // room for two states
        s.plan_round(0, &[vec![1, 2, 3]]); // 3rd save evicts client 1 dirty
        assert_eq!(s.metrics.disk_writes, 1, "one spill");
        assert_eq!(s.metrics.bytes_written, SD);
        assert_eq!(s.snapshot().len(), 3, "no state lost");
    }

    #[test]
    fn prefetch_ready_times_pipeline_per_worker() {
        let mut s = store(1, 1, 4);
        s.plan_round(0, &[vec![1, 2]]);
        s.flush_all();
        // Drop cache so the next round's loads hit disk.
        s.caches[0].clear();
        let (legs, _, _) = s.plan_round(1, &[vec![1, 2]]);
        assert!(legs[0][0].secs > 0.0);
        let eps = 1e-12;
        assert!((legs[0][0].ready - legs[0][0].secs).abs() < eps);
        assert!(
            (legs[0][1].ready - (legs[0][0].secs + legs[0][1].secs)).abs() < eps,
            "channel serializes loads in task order"
        );
    }

    #[test]
    fn handoff_preserves_every_state_and_counts_transfer() {
        let mut s = store(3, 3, 64);
        let lists: Vec<Vec<u64>> =
            (0..3).map(|w| (0..10u64).map(|i| w as u64 * 10 + i).collect()).collect();
        s.plan_round(0, &lists);
        let before = s.snapshot();
        assert_eq!(before.len(), 30);
        let moved = s.handoff(1);
        assert!(moved > 0, "worker 1 hosted someone's state");
        assert_eq!(s.snapshot(), before, "handoff must lose nothing");
        assert!(s.metrics.shard_transfer_bytes > 0);
        assert_eq!(s.owner_worker(2).map(|o| o == 1), Some(false));
        // Rejoin restores ownership and pulls the states back.
        let back = s.rejoin(1);
        assert!(back > 0);
        assert_eq!(s.snapshot(), before);
    }

    #[test]
    fn rejoin_recovers_states_trained_during_the_outage() {
        // A client owned by worker 1 trains while worker 1 is away: its
        // newest state lives dirty in the interim owner's cache (write-
        // back — no disk copy of that version).  Rejoin must carry it
        // home instead of stranding it (regression: the old path only
        // scanned the disk tier).
        let mut s = store(3, 3, 16);
        let c = (0..100u64).find(|&c| s.owner_worker(c) == Some(1)).unwrap();
        s.plan_round(0, &[vec![], vec![c], vec![]]);
        s.handoff(1);
        let interim = s.owner_worker(c).unwrap();
        assert_ne!(interim, 1);
        s.plan_round(1, &[vec![c], vec![], vec![]]);
        assert_eq!(s.snapshot().get(&c), Some(&2));
        s.rejoin(1);
        assert_eq!(s.misplaced_cache_entries(), 0, "no stranded copies");
        assert_eq!(s.owner_worker(c), Some(1));
        assert_eq!(s.snapshot().get(&c), Some(&2), "newest version must survive");
        // And the recovered copy serves the next round at the owner.
        s.plan_round(2, &[vec![], vec![c], vec![]]);
        assert_eq!(s.snapshot().get(&c), Some(&3));
        assert_eq!(s.misplaced_cache_entries(), 0);
    }

    #[test]
    fn home_worker_is_the_single_owner_mapping_across_churn() {
        // Handoff + rejoin round-trip: every ownership answer the store
        // gives must equal the one `home_worker` helper at every stage
        // (the four former inline `% n` sites can no longer drift), and
        // the round-trip must preserve every state version.
        let mut s = store(3, 3, 64);
        let lists: Vec<Vec<u64>> =
            (0..3).map(|w| (0..10u64).map(|i| w as u64 * 10 + i).collect()).collect();
        s.plan_round(0, &lists);
        let check = |s: &SimStore| {
            let map = s.shard_map().expect("sharded");
            for c in 0..30u64 {
                assert_eq!(
                    s.owner_worker(c),
                    Some(home_worker(map, s.cfg().n_workers, c)),
                    "client {c} routed off the canonical mapping"
                );
            }
        };
        check(&s);
        let before = s.snapshot();
        let moved = s.handoff(1);
        assert!(moved > 0, "worker 1 hosted shard-1 states");
        check(&s);
        assert_eq!(s.snapshot(), before, "handoff must lose nothing");
        let back = s.rejoin(1);
        assert!(back > 0, "rejoin pulls shard-1 states home");
        check(&s);
        assert_eq!(s.snapshot(), before, "round-trip must be lossless");
        assert_eq!(s.misplaced_cache_entries(), 0, "no stranded cache copies");
    }

    #[test]
    fn engine_equality_invariant_bytes_all_bucketed() {
        // Σ leg bytes + tail bytes + handoff returns == metric total.
        let mut s = store(2, 2, 2);
        let mut booked = 0u64;
        for r in 0..5u64 {
            let (legs, tb, _) =
                s.plan_round(r, &[vec![r, r + 10, r + 20], vec![r + 1, r + 11]]);
            booked += legs.iter().flatten().map(|l| l.bytes).sum::<u64>() + tb;
        }
        booked += s.handoff(0);
        booked += s.rejoin(0);
        assert_eq!(booked, s.metrics.total_bytes());
    }
}
