//! Consistent-hash shard ownership for client state.
//!
//! Each shard contributes `vnodes` points on a 2⁶⁴ hash ring; a client
//! is owned by the shard whose point is the ring successor of the
//! client's hash.  The property that matters for churn (and that the
//! property suite pins): adding or removing ONE shard only remaps the
//! clients adjacent to that shard's points — everyone else keeps their
//! owner, so a device departure moves ≈ M/n states instead of
//! rehashing the world (the Pollen/FLUTE placement-stability argument).
//!
//! Determinism: the ring is a pure function of the shard id set and
//! the vnode count — every participant (server, workers, the virtual
//! store, the scheduler's affinity term) reconstructs the identical
//! ring from the run config, so ownership never crosses the wire.

use std::collections::BTreeSet;

/// splitmix64 finalizer — deterministic, dependency-free 64-bit mixing.
fn hash64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

const CLIENT_SALT: u64 = 0xC11E_17D5_7A7E_5EED;
const POINT_SALT: u64 = 0x5EED_0F5A_11D0_1E75;

/// The ring (see module docs).
#[derive(Debug, Clone)]
pub struct ShardMap {
    /// Sorted `(point, shard)` pairs.
    ring: Vec<(u64, u32)>,
    shards: BTreeSet<u32>,
    vnodes: usize,
}

impl ShardMap {
    /// Points per shard: enough that shard loads concentrate within a
    /// few percent of M/n without making rebuilds noticeable.
    pub const DEFAULT_VNODES: usize = 128;

    /// Ring over shards `0..n`.
    pub fn new(n_shards: usize) -> ShardMap {
        ShardMap::with_vnodes(n_shards, ShardMap::DEFAULT_VNODES)
    }

    pub fn with_vnodes(n_shards: usize, vnodes: usize) -> ShardMap {
        let mut map = ShardMap {
            ring: Vec::new(),
            shards: (0..n_shards as u32).collect(),
            vnodes: vnodes.max(1),
        };
        map.rebuild();
        map
    }

    fn rebuild(&mut self) {
        self.ring.clear();
        self.ring.reserve(self.shards.len() * self.vnodes);
        for &s in &self.shards {
            let base = hash64(s as u64 ^ POINT_SALT);
            for r in 0..self.vnodes {
                self.ring.push((hash64(base.wrapping_add(r as u64)), s));
            }
        }
        self.ring.sort_unstable();
        // 64-bit point collisions are ~impossible at this scale; dedup
        // keeps the lower shard id deterministically if one ever lands.
        self.ring.dedup_by_key(|e| e.0);
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard_ids(&self) -> Vec<u32> {
        self.shards.iter().copied().collect()
    }

    pub fn contains_shard(&self, shard: u32) -> bool {
        self.shards.contains(&shard)
    }

    /// Add a shard; false when it already exists.
    pub fn add_shard(&mut self, shard: u32) -> bool {
        if !self.shards.insert(shard) {
            return false;
        }
        self.rebuild();
        true
    }

    /// Remove a shard; false when absent — or when it is the LAST
    /// shard (state must always have somewhere to live).
    pub fn remove_shard(&mut self, shard: u32) -> bool {
        if self.shards.len() <= 1 || !self.shards.remove(&shard) {
            return false;
        }
        self.rebuild();
        true
    }

    /// The owning shard of `client` (ring successor of its hash).
    pub fn owner(&self, client: u64) -> u32 {
        assert!(!self.ring.is_empty(), "ShardMap with no shards");
        let h = hash64(client ^ CLIENT_SALT);
        let i = match self.ring.binary_search_by(|e| e.0.cmp(&h)) {
            Ok(i) => i,
            Err(i) => {
                if i == self.ring.len() {
                    0
                } else {
                    i
                }
            }
        };
        self.ring[i].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_total() {
        let a = ShardMap::new(4);
        let b = ShardMap::new(4);
        for c in 0..500u64 {
            let o = a.owner(c);
            assert_eq!(o, b.owner(c), "same config must give same owners");
            assert!(o < 4);
        }
    }

    #[test]
    fn single_shard_owns_everything() {
        let m = ShardMap::new(1);
        for c in 0..100u64 {
            assert_eq!(m.owner(c), 0);
        }
    }

    #[test]
    fn load_is_roughly_balanced() {
        let m = ShardMap::new(8);
        let mut counts = [0usize; 8];
        let total = 8000u64;
        for c in 0..total {
            counts[m.owner(c) as usize] += 1;
        }
        let expect = total as usize / 8;
        for (s, &n) in counts.iter().enumerate() {
            assert!(
                n > expect / 2 && n < expect * 2,
                "shard {s} owns {n}, expected ≈{expect}"
            );
        }
    }

    #[test]
    fn removal_only_remaps_the_removed_shards_clients() {
        let before = ShardMap::new(5);
        let mut after = before.clone();
        assert!(after.remove_shard(2));
        for c in 0..2000u64 {
            let (o0, o1) = (before.owner(c), after.owner(c));
            if o0 != 2 {
                assert_eq!(o0, o1, "client {c} moved without owning-shard change");
            } else {
                assert_ne!(o1, 2);
            }
        }
    }

    #[test]
    fn addition_only_pulls_clients_to_the_new_shard() {
        let before = ShardMap::new(4);
        let mut after = before.clone();
        assert!(after.add_shard(4));
        for c in 0..2000u64 {
            let (o0, o1) = (before.owner(c), after.owner(c));
            if o0 != o1 {
                assert_eq!(o1, 4, "client {c} remapped to an old shard");
            }
        }
    }

    #[test]
    fn last_shard_cannot_be_removed() {
        let mut m = ShardMap::new(2);
        assert!(m.remove_shard(0));
        assert!(!m.remove_shard(1), "the last shard must stay");
        assert_eq!(m.n_shards(), 1);
        assert!(!m.remove_shard(7), "absent shard");
        assert!(m.add_shard(0));
        assert!(!m.add_shard(0), "duplicate add");
    }

    #[test]
    fn remove_then_readd_restores_ownership() {
        let orig = ShardMap::new(6);
        let mut m = orig.clone();
        m.remove_shard(3);
        m.add_shard(3);
        for c in 0..1000u64 {
            assert_eq!(orig.owner(c), m.owner(c));
        }
    }
}
