//! Distributed client-state store (paper §3.4 scaled out): sharded
//! ownership, write-back tiering, and plan-driven prefetch.
//!
//! The seed system's [`StateManager`](crate::state::StateManager) is a
//! single-worker write-through LRU + disk store; at 1000+ stateful
//! clients (SCAFFOLD control variates, FedDyn h-terms) across many
//! workers, *state movement* — not compute — bounds the simulation.
//! This subsystem promotes client state to a first-class, placement-
//! aware layer:
//!
//! - [`shard::ShardMap`] — consistent-hash ownership: each worker owns
//!   a shard of client ids; adding/removing one shard remaps only that
//!   shard's clients (property-tested), so device churn hands off
//!   ≈ M/n states instead of rehashing the world.
//! - [`lru::WriteBackCache`] — the dirty-bit LRU shared by the real
//!   and virtual stores: O(log n) eviction, displaced dirty entries
//!   surfaced for spilling, explicit flush at consistency points.
//! - [`simstore::SimStore`] — the virtual three-tier store (cache →
//!   disk → remote owner) that the discrete-event engine prices via
//!   [`StateLeg`]s/[`StatePlan`]s: per-task `StateLoad` legs (prefetch-
//!   pipelined in task order, because Parrot plans rounds up front) and
//!   a round-tail `StateFlush` leg.
//!
//! On the real-compute path the same ownership ring drives the
//! coordinator protocol (`StateFetch`/`StatePut`/`ShardTransfer`
//! messages): the server prefetches non-owned states to executors ahead
//! of each `Round`, and executors return updated state to owners at
//! round end (write-back).  The scheduler closes the loop with a
//! state-affinity term
//! ([`SchedulerKind::StateAffinity`](crate::config::SchedulerKind))
//! that prefers placing a client's task on the worker owning its state.

// Determinism-critical module: re-enable the workspace-wide clippy
// bans on unordered collections and ambient clocks (see clippy.toml
// and the crate-root allow in lib.rs).
#![deny(clippy::disallowed_types, clippy::disallowed_methods)]

pub mod lru;
pub mod shard;
pub mod simstore;

pub use lru::{CacheCost, Evicted, WriteBackCache};
pub use shard::ShardMap;
pub use simstore::{home_worker, Blob, SimStore, SimStoreCfg, StoreMetrics};

/// One task's state-movement leg, priced by the engine at `TaskStart`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StateLeg {
    /// Bytes of state movement attributable to this task: fetch legs,
    /// write-back return legs, and eviction spills.
    pub bytes: u64,
    /// Load stall seconds when NOT prefetched (serialized before the
    /// task's compute).
    pub secs: f64,
    /// Virtual time at which the prefetch pipeline has this state ready
    /// (per-worker channel issuing loads in task order from round
    /// start); with prefetch on, the task stalls `max(0, ready - now)`.
    pub ready: f64,
}

/// A round's state traffic, index-aligned with the engine's task
/// vector; the tail is the round-boundary `StateFlush` leg (dirty
/// write-back plus remote write-back returns).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatePlan {
    /// Per-task legs; empty = no state store attached.
    pub legs: Vec<StateLeg>,
    pub prefetch: bool,
    pub tail_bytes: u64,
    pub tail_secs: f64,
}

impl StatePlan {
    pub fn is_empty(&self) -> bool {
        self.legs.is_empty() && self.tail_bytes == 0 && self.tail_secs == 0.0
    }
}
