//! Ordered write-back LRU — the cache tier shared by the real
//! [`StateManager`](crate::state::StateManager) and the virtual
//! [`SimStore`](super::simstore::SimStore).
//!
//! Two structural properties matter here:
//!
//! - **O(log n) eviction.** The old `StateManager` scanned the whole
//!   cache with `min_by_key` for every evicted entry, turning a rotate
//!   over a large resident set into an O(n²) eviction storm
//!   (`benches/bench_state.rs` pins the fix at 10k clients).  Recency
//!   lives in a `BTreeMap<tick, client>` side index kept in lock-step
//!   with the entry map, so the LRU victim is a `first_key_value` pop.
//! - **Dirty bits.** Entries remember whether they hold data newer than
//!   the tier below; eviction surfaces displaced dirty entries to the
//!   caller (who must spill them) instead of silently dropping them —
//!   the write-back contract that makes deferred flushing safe.
//!
//! The cache never does I/O itself: values are opaque [`CacheCost`]
//! payloads, so the same policy runs over real byte blobs (disk tier
//! behind it) and over size-only accounting blobs (virtual tier).

use std::collections::BTreeMap;

/// Anything the cache can budget: real bytes, or a size-only stand-in.
pub trait CacheCost {
    fn cost(&self) -> usize;
}

impl CacheCost for Vec<u8> {
    fn cost(&self) -> usize {
        self.len()
    }
}

#[derive(Debug)]
struct Entry<V> {
    value: V,
    tick: u64,
    dirty: bool,
}

/// An entry displaced by [`WriteBackCache::insert`]; the caller must
/// persist it when `dirty` (its data is newer than the tier below).
#[derive(Debug)]
pub struct Evicted<V> {
    pub client: u64,
    pub value: V,
    pub dirty: bool,
}

/// Budget-bounded LRU with dirty-bit write-back (see module docs).
#[derive(Debug)]
pub struct WriteBackCache<V: CacheCost> {
    budget: usize,
    /// Keyed by client id; ordered so every whole-cache walk (iter,
    /// dirty scan, drain) is deterministic without a sort pass.
    entries: BTreeMap<u64, Entry<V>>,
    /// Recency index: tick → client. Ticks are unique (monotone clock),
    /// so the least-recently-used entry is always `first_key_value`.
    order: BTreeMap<u64, u64>,
    resident: usize,
    peak: usize,
    tick: u64,
}

impl<V: CacheCost> WriteBackCache<V> {
    /// `budget` caps resident bytes; 0 disables caching entirely.
    pub fn new(budget: usize) -> WriteBackCache<V> {
        WriteBackCache {
            budget,
            entries: BTreeMap::new(),
            order: BTreeMap::new(),
            resident: 0,
            peak: 0,
            tick: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn contains(&self, client: u64) -> bool {
        self.entries.contains_key(&client)
    }

    pub fn is_dirty(&self, client: u64) -> bool {
        self.entries.get(&client).map(|e| e.dirty).unwrap_or(false)
    }

    /// Recency-refreshing lookup.
    pub fn get(&mut self, client: u64) -> Option<&V> {
        if !self.entries.contains_key(&client) {
            return None;
        }
        self.tick += 1;
        let t = self.tick;
        let e = self.entries.get_mut(&client).expect("checked above");
        let old = e.tick;
        e.tick = t;
        self.order.remove(&old);
        self.order.insert(t, client);
        self.entries.get(&client).map(|e| &e.value)
    }

    /// Non-touching lookup (flush paths must not perturb recency).
    pub fn peek(&self, client: u64) -> Option<&V> {
        self.entries.get(&client).map(|e| &e.value)
    }

    /// Insert `value`, evicting LRU entries until it fits.  Returns
    /// `(resident, evicted)`: `resident` is false when the value can
    /// never fit (zero budget or oversized) — the caller must persist
    /// it itself — and `evicted` lists every displaced entry (spill the
    /// dirty ones).  A same-key previous copy is released first and is
    /// NOT reported: the new value supersedes it.
    pub fn insert(&mut self, client: u64, value: V, dirty: bool) -> (bool, Vec<Evicted<V>>) {
        let sz = value.cost();
        if let Some(old) = self.entries.remove(&client) {
            self.order.remove(&old.tick);
            self.resident -= old.value.cost();
        }
        if self.budget == 0 || sz > self.budget {
            return (false, Vec::new());
        }
        let mut evicted = Vec::new();
        while self.resident + sz > self.budget {
            let victim = match self.order.iter().next() {
                Some((&t, &c)) => (t, c),
                None => break,
            };
            self.order.remove(&victim.0);
            let e = self.entries.remove(&victim.1).expect("order/entries in sync");
            self.resident -= e.value.cost();
            evicted.push(Evicted { client: victim.1, value: e.value, dirty: e.dirty });
        }
        self.tick += 1;
        let t = self.tick;
        self.resident += sz;
        self.peak = self.peak.max(self.resident);
        self.order.insert(t, client);
        self.entries.insert(client, Entry { value, tick: t, dirty });
        (true, evicted)
    }

    /// Remove one entry; returns `(value, dirty)`.
    pub fn remove(&mut self, client: u64) -> Option<(V, bool)> {
        let e = self.entries.remove(&client)?;
        self.order.remove(&e.tick);
        self.resident -= e.value.cost();
        Some((e.value, e.dirty))
    }

    pub fn mark_clean(&mut self, client: u64) {
        if let Some(e) = self.entries.get_mut(&client) {
            e.dirty = false;
        }
    }

    /// Dirty entry ids in ascending client order (deterministic flush).
    pub fn dirty_ids(&self) -> Vec<u64> {
        self.entries.iter().filter(|(_, e)| e.dirty).map(|(&c, _)| c).collect()
    }

    /// Iterate resident entries (no recency effect, ascending client id).
    pub fn iter(&self) -> impl Iterator<Item = (u64, &V)> {
        self.entries.iter().map(|(&c, e)| (c, &e.value))
    }

    /// Take everything out (shard handoff): `(client, value, dirty)`,
    /// ascending client id.
    pub fn drain(&mut self) -> Vec<(u64, V, bool)> {
        self.order.clear();
        self.resident = 0;
        std::mem::take(&mut self.entries)
            .into_iter()
            .map(|(c, e)| (c, e.value, e.dirty))
            .collect()
    }

    /// Reset contents, recency clock, and the peak watermark.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.order.clear();
        self.resident = 0;
        self.peak = 0;
        self.tick = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(n: usize, fill: u8) -> Vec<u8> {
        vec![fill; n]
    }

    #[test]
    fn evicts_in_recency_order() {
        let mut c: WriteBackCache<Vec<u8>> = WriteBackCache::new(100);
        c.insert(1, blob(40, 1), false);
        c.insert(2, blob(40, 2), false);
        c.get(1); // refresh 1 → 2 is now LRU
        let (res, ev) = c.insert(3, blob(40, 3), false);
        assert!(res);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].client, 2);
        assert!(c.contains(1) && c.contains(3) && !c.contains(2));
        assert_eq!(c.resident_bytes(), 80);
    }

    #[test]
    fn dirty_entries_surface_on_eviction() {
        let mut c: WriteBackCache<Vec<u8>> = WriteBackCache::new(100);
        c.insert(1, blob(60, 1), true);
        let (_, ev) = c.insert(2, blob(60, 2), false);
        assert_eq!(ev.len(), 1);
        assert!(ev[0].dirty, "dirty eviction must be reported for spilling");
        assert_eq!(ev[0].value, blob(60, 1));
    }

    #[test]
    fn oversized_and_zero_budget_bypass_without_churn() {
        let mut c: WriteBackCache<Vec<u8>> = WriteBackCache::new(100);
        c.insert(1, blob(40, 1), false);
        c.insert(2, blob(40, 2), false);
        let (res, ev) = c.insert(3, blob(500, 3), true);
        assert!(!res, "oversized value must not become resident");
        assert!(ev.is_empty(), "oversized insert must not evict residents");
        assert_eq!(c.resident_bytes(), 80);
        let mut z: WriteBackCache<Vec<u8>> = WriteBackCache::new(0);
        let (res, ev) = z.insert(1, blob(1, 0), false);
        assert!(!res && ev.is_empty());
        assert_eq!(z.resident_bytes(), 0);
    }

    #[test]
    fn same_key_reinsert_releases_old_copy_first() {
        let mut c: WriteBackCache<Vec<u8>> = WriteBackCache::new(100);
        c.insert(1, blob(30, 1), false);
        c.insert(2, blob(40, 2), true);
        // Growing 2 to 50 fits once its own 40 bytes are released.
        let (res, ev) = c.insert(2, blob(50, 9), true);
        assert!(res && ev.is_empty(), "no innocent eviction: {ev:?}");
        assert_eq!(c.resident_bytes(), 80);
        assert_eq!(c.peak_bytes(), 80, "no transient double-count");
        // Growing past the whole budget: stale copy must not linger.
        let (res, _) = c.insert(2, blob(500, 7), true);
        assert!(!res);
        assert_eq!(c.resident_bytes(), 30);
        assert!(!c.contains(2));
    }

    #[test]
    fn dirty_bookkeeping_and_flush_protocol() {
        let mut c: WriteBackCache<Vec<u8>> = WriteBackCache::new(1000);
        c.insert(3, blob(10, 3), true);
        c.insert(1, blob(10, 1), true);
        c.insert(2, blob(10, 2), false);
        assert!(c.is_dirty(1) && !c.is_dirty(2));
        assert_eq!(c.dirty_ids(), vec![1, 3]);
        for id in c.dirty_ids() {
            assert!(c.peek(id).is_some());
            c.mark_clean(id);
        }
        assert!(c.dirty_ids().is_empty());
        // peek must not perturb recency: 3 was peeked last but is still LRU
        let (_, ev) = c.insert(4, blob(990, 4), false);
        assert_eq!(ev[0].client, 3, "{ev:?}");
    }

    #[test]
    fn drain_and_clear() {
        let mut c: WriteBackCache<Vec<u8>> = WriteBackCache::new(100);
        c.insert(1, blob(10, 1), true);
        c.insert(2, blob(10, 2), false);
        let mut d = c.drain();
        d.sort_by_key(|e| e.0);
        assert_eq!(d.len(), 2);
        assert!(d[0].2 && !d[1].2);
        assert!(c.is_empty() && c.resident_bytes() == 0);
        c.insert(5, blob(10, 5), false);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.peak_bytes(), 0);
    }

    #[test]
    fn order_index_stays_in_sync_under_churn() {
        // Rotate far more keys than fit; the order index must shrink
        // with the entry map (a desync would panic the in-sync expect).
        let mut c: WriteBackCache<Vec<u8>> = WriteBackCache::new(10 * 8);
        for i in 0..1000u64 {
            c.insert(i % 37, blob(8, i as u8), i % 3 == 0);
            if i % 5 == 0 {
                c.get(i % 37);
            }
            if i % 11 == 0 {
                c.remove((i + 3) % 37);
            }
            assert!(c.len() <= 10);
            assert_eq!(c.len(), c.iter().count());
            assert!(c.resident_bytes() <= 80);
        }
    }
}
