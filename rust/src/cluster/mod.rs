//! Device/cluster heterogeneity models (paper §5.1 + Appendix A).
//!
//! The paper evaluates on three GPU clusters (A: homogeneous 2080 Ti,
//! B: homogeneous RTX 5000, C: heterogeneous K80/P40) and additionally
//! *simulates* heterogeneous and unstable devices on cluster A by
//! sleeping η_k·T̂ after each task.  This module reproduces exactly that
//! machinery:
//!
//! - [`DeviceModel`] — per-device speed multiplier over the baseline
//!   (η_k = slowdown − 1) plus the cos-based dynamic instability law
//!   `(1 + cos(πr/R + k))` from Appendix A.
//! - [`ClusterProfile`] — named device collections: `homo`, `hete`,
//!   `dyn`, and the paper's clusters `a`/`b`/`c` with speed ratios
//!   matching the public relative DL throughput of those GPUs.
//!
//! Both execution modes consume it: the real-compute coordinator sleeps
//! the extra (slowdown−1)·T̂ exactly as the paper does; the virtual-time
//! engine multiplies modeled task durations.

// Determinism-critical module: re-enable the workspace-wide clippy
// bans on unordered collections and ambient clocks (see clippy.toml
// and the crate-root allow in lib.rs).
#![deny(clippy::disallowed_types, clippy::disallowed_methods)]

use anyhow::{bail, Result};

/// The aggregation/communication topology of the cluster
/// (`--topology flat | groups:G | tree:SPEC`).
///
/// Parrot's two-tier `LocalAgg → GlobalAgg` pipeline generalizes to an
/// arbitrary-depth tree: devices live in leaf *groups* (edge
/// aggregators / sub-clusters), groups merge their members' aggregates
/// exactly like devices merge clients' (see
/// [`TierAgg`](crate::aggregation::TierAgg)), and only the merged
/// group aggregate crosses the root-adjacent (WAN) link.  The tree is
/// described by per-level fanouts from the server down: `tree:4x2` =
/// 4 edge sites each split into 2 sub-groups (depth 2);
/// `groups:G` == `tree:G` (depth 1); `flat` = no aggregator tier (the
/// legacy device→server pair, byte-identical to the pre-topology
/// engine).  Devices are assigned to leaf groups round-robin.
///
/// Link model: intra-group legs ride the cluster's base (LAN) link;
/// root-adjacent legs ride the WAN link — by default the same as the
/// base link (so grouping is compared at equal link speed), overridable
/// via `groups:G:BW:LAT` / `tree:SPEC:BW:LAT` with `BW` in Gbps and
/// `LAT` in milliseconds.  Per-group compute profiles
/// ([`Topology::group_compute`]) multiply the members' task times —
/// unequal edge sites, the FedHC/Pollen-style heterogeneous
/// infrastructure knob.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    /// Aggregation-level fanouts from the server down; empty = flat.
    /// Leaf-group count = the product of all fanouts.
    pub levels: Vec<usize>,
    /// Root-adjacent (WAN) link override (bytes/sec, secs); None = the
    /// cluster's base link.
    pub wan: Option<(f64, f64)>,
    /// Per-leaf-group compute multiplier (1.0 = neutral); empty = all
    /// groups neutral.
    pub group_compute: Vec<f64>,
}

impl Topology {
    /// The legacy device→server pair (no aggregator tier).
    pub fn flat() -> Topology {
        Topology { levels: Vec::new(), wan: None, group_compute: Vec::new() }
    }

    /// `g` edge groups directly under the server (depth 1).
    pub fn groups(g: usize) -> Topology {
        Topology { levels: vec![g], wan: None, group_compute: Vec::new() }
    }

    /// Arbitrary-depth tree from per-level fanouts.
    pub fn tree(levels: Vec<usize>) -> Topology {
        Topology { levels, wan: None, group_compute: Vec::new() }
    }

    /// Builder: per-leaf-group compute multipliers.
    pub fn with_group_compute(mut self, scales: Vec<f64>) -> Topology {
        self.group_compute = scales;
        self
    }

    pub fn is_flat(&self) -> bool {
        self.levels.is_empty()
    }

    /// Number of aggregation levels between devices and server.
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Leaf-group count (0 when flat).
    pub fn n_groups(&self) -> usize {
        if self.is_flat() {
            0
        } else {
            self.levels.iter().product()
        }
    }

    /// Root-adjacent node count (the aggregates the server merges).
    pub fn n_top(&self) -> usize {
        *self.levels.first().unwrap_or(&0)
    }

    /// Leaf group hosting device `slot` (round-robin placement).
    pub fn group_of(&self, device: usize) -> usize {
        let g = self.n_groups();
        if g == 0 {
            0
        } else {
            device % g
        }
    }

    /// Root-adjacent ancestor of leaf group `leaf`.
    pub fn top_of(&self, leaf: usize) -> usize {
        let g = self.n_groups();
        let top = self.n_top();
        if g == 0 || top == 0 {
            0
        } else {
            leaf / (g / top)
        }
    }

    /// Per-leaf-group member device lists over `k` device slots.
    pub fn members(&self, k: usize) -> Vec<Vec<usize>> {
        let g = self.n_groups();
        let mut out = vec![Vec::new(); g];
        if g == 0 {
            return out;
        }
        for d in 0..k {
            out[d % g].push(d);
        }
        out
    }

    /// Compute multiplier for device `slot` (per-group profile).
    pub fn compute_scale(&self, device: usize) -> f64 {
        if self.is_flat() || self.group_compute.is_empty() {
            return 1.0;
        }
        self.group_compute
            .get(self.group_of(device))
            .copied()
            .unwrap_or(1.0)
    }

    /// The WAN link given the cluster's base link.
    pub fn wan_link(&self, base_bandwidth: f64, base_latency: f64) -> (f64, f64) {
        self.wan.unwrap_or((base_bandwidth, base_latency))
    }

    /// Parse `flat | groups:G[:BW:LAT] | tree:F1xF2[x...][:BW:LAT]`
    /// (BW in Gbps, LAT in milliseconds — the WAN link override).
    pub fn parse(s: &str) -> Result<Topology> {
        if s == "flat" {
            return Ok(Topology::flat());
        }
        let (kind, rest) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("unknown topology {s:?} (flat|groups:G|tree:SPEC)"))?;
        let mut parts = rest.split(':');
        let spec = parts.next().unwrap_or("");
        let levels: Vec<usize> = match kind {
            "groups" => vec![spec
                .parse()
                .map_err(|_| anyhow::anyhow!("bad group count {spec:?}"))?],
            "tree" => spec
                .split('x')
                .map(|f| {
                    f.parse()
                        .map_err(|_| anyhow::anyhow!("bad tree fanout {f:?} in {spec:?}"))
                })
                .collect::<Result<Vec<usize>>>()?,
            _ => bail!("unknown topology {s:?} (flat|groups:G|tree:SPEC)"),
        };
        let mut topo = Topology::tree(levels);
        if let Some(bw) = parts.next() {
            let lat = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("topology WAN override needs BW:LAT, got {s:?}"))?;
            let bw_gbps: f64 = bw
                .parse()
                .map_err(|_| anyhow::anyhow!("bad WAN bandwidth {bw:?} (Gbps)"))?;
            let lat_ms: f64 = lat
                .parse()
                .map_err(|_| anyhow::anyhow!("bad WAN latency {lat:?} (ms)"))?;
            if bw_gbps <= 0.0 || !bw_gbps.is_finite() || lat_ms < 0.0 || !lat_ms.is_finite() {
                bail!("WAN override must have BW > 0 and LAT >= 0, got {s:?}");
            }
            topo.wan = Some((bw_gbps * 1e9 / 8.0, lat_ms * 1e-3));
        }
        if parts.next().is_some() {
            bail!("trailing topology fields in {s:?}");
        }
        topo.validate_shape()?;
        Ok(topo)
    }

    pub fn name(&self) -> String {
        if self.is_flat() {
            return "flat".into();
        }
        let spec = if self.depth() == 1 {
            format!("groups:{}", self.levels[0])
        } else {
            format!(
                "tree:{}",
                self.levels
                    .iter()
                    .map(|f| f.to_string())
                    .collect::<Vec<_>>()
                    .join("x")
            )
        };
        match self.wan {
            None => spec,
            Some((bw, lat)) => format!("{spec}:{}:{}", bw * 8.0 / 1e9, lat * 1e3),
        }
    }

    /// Structural checks independent of the device count.
    fn validate_shape(&self) -> Result<()> {
        if self.levels.iter().any(|&f| f == 0) {
            bail!("topology fanouts must be >= 1: {:?}", self.levels);
        }
        for &s in &self.group_compute {
            if s <= 0.0 || !s.is_finite() {
                bail!("group compute multipliers must be finite and > 0, got {s}");
            }
        }
        Ok(())
    }

    /// Full validation against a device count.
    pub fn validate(&self, n_devices: usize) -> Result<()> {
        self.validate_shape()?;
        if self.is_flat() {
            return Ok(());
        }
        let g = self.n_groups();
        if g > n_devices {
            bail!("topology has {g} leaf groups but only {n_devices} devices");
        }
        if !self.group_compute.is_empty() && self.group_compute.len() != g {
            bail!(
                "group_compute has {} entries for {g} groups",
                self.group_compute.len()
            );
        }
        Ok(())
    }
}

/// How a device's effective speed varies over rounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Dynamics {
    /// Constant speed.
    Stable,
    /// Appendix A's unstable-device law: extra slowdown factor
    /// `(1 + cos(π·r/period + k))` — phase-shifted per device.
    Cosine { period: f64 },
}

/// One simulated device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceModel {
    /// Static slowdown multiplier (1.0 = cluster-A 2080 Ti baseline).
    /// The paper's η_k equals `static_slowdown - 1`.
    pub static_slowdown: f64,
    pub dynamics: Dynamics,
}

impl DeviceModel {
    pub fn uniform() -> DeviceModel {
        DeviceModel { static_slowdown: 1.0, dynamics: Dynamics::Stable }
    }

    /// Effective slowdown at round `r` for device index `k`.
    pub fn slowdown(&self, r: usize, k: usize) -> f64 {
        let dynamic = match self.dynamics {
            Dynamics::Stable => 1.0,
            Dynamics::Cosine { period } => {
                // Paper: sleep ratio (1 + cos(3.14 r / R + k)) ∈ [0, 2]
                // applied on top of the measured time -> factor in [1, 3].
                1.0 + (1.0 + (std::f64::consts::PI * r as f64 / period + k as f64).cos())
            }
        };
        self.static_slowdown * dynamic
    }
}

/// Baseline per-sample / per-task constants for the virtual-time model,
/// calibrated per workload (DESIGN.md §2: relative — not absolute —
/// costs are what the figures compare).
#[derive(Debug, Clone, Copy)]
pub struct WorkloadCost {
    /// Seconds per training sample on the baseline device (Eq. 1 t^sample).
    pub t_sample: f64,
    /// Constant per-task seconds on the baseline device (Eq. 1 b):
    /// model load + weight copy + task switch.
    pub b_fixed: f64,
}

impl WorkloadCost {
    /// FEMNIST/ResNet-18-analog on a 2080 Ti-class device.
    pub fn femnist() -> WorkloadCost {
        WorkloadCost { t_sample: 2.0e-3, b_fixed: 0.15 }
    }

    /// ImageNet/ResNet-50-analog (bigger model, bigger images).
    pub fn imagenet() -> WorkloadCost {
        WorkloadCost { t_sample: 9.0e-3, b_fixed: 0.35 }
    }

    /// Reddit/Albert-analog.
    pub fn reddit() -> WorkloadCost {
        WorkloadCost { t_sample: 4.0e-3, b_fixed: 0.25 }
    }

    pub fn by_name(name: &str) -> Result<WorkloadCost> {
        Ok(match name {
            "femnist" | "mlp" => WorkloadCost::femnist(),
            "imagenet" | "cnn" => WorkloadCost::imagenet(),
            "reddit" | "tinylm" => WorkloadCost::reddit(),
            _ => bail!("unknown workload cost profile {name:?}"),
        })
    }
}

/// A collection of devices — one experiment's hardware.
#[derive(Debug, Clone)]
pub struct ClusterProfile {
    pub name: String,
    pub devices: Vec<DeviceModel>,
    /// Network bandwidth in bytes/sec (10 Gbps default, Table 5).
    pub bandwidth: f64,
    /// Per-message latency in seconds (one communication trip).
    pub latency: f64,
    /// Aggregation/communication topology (`--topology`); flat default
    /// keeps the legacy device→server pair byte-identical.
    pub topology: Topology,
}

impl ClusterProfile {
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// All devices identical (paper clusters A and B).
    pub fn homogeneous(k: usize) -> ClusterProfile {
        ClusterProfile {
            name: "homo".into(),
            devices: vec![DeviceModel::uniform(); k],
            bandwidth: 10e9 / 8.0,
            latency: 1e-3,
            topology: Topology::flat(),
        }
    }

    /// Simulated heterogeneous GPUs (Appendix A): pre-assigned η ratios
    /// spread over [0, 1.5] — device k gets slowdown 1 + 1.5·k/(K−1).
    pub fn heterogeneous(k: usize) -> ClusterProfile {
        let devices = (0..k)
            .map(|i| DeviceModel {
                static_slowdown: 1.0
                    + if k > 1 { 1.5 * i as f64 / (k - 1) as f64 } else { 0.0 },
                dynamics: Dynamics::Stable,
            })
            .collect();
        ClusterProfile {
            name: "hete".into(),
            devices,
            bandwidth: 10e9 / 8.0,
            latency: 1e-3,
            topology: Topology::flat(),
        }
    }

    /// Simulated unstable devices (Appendix A cos law).
    pub fn dynamic(k: usize, period: f64) -> ClusterProfile {
        ClusterProfile {
            name: "dyn".into(),
            devices: vec![
                DeviceModel {
                    static_slowdown: 1.0,
                    dynamics: Dynamics::Cosine { period },
                };
                k
            ],
            bandwidth: 10e9 / 8.0,
            latency: 1e-3,
            topology: Topology::flat(),
        }
    }

    /// Paper cluster C: genuinely heterogeneous (4×K80 + 4×P40 speeds).
    /// Relative DL throughputs: 2080Ti≈1.0, P40≈1.8, K80≈4.0 slower.
    pub fn cluster_c(k: usize) -> ClusterProfile {
        let devices = (0..k)
            .map(|i| DeviceModel {
                static_slowdown: if i % 2 == 0 { 4.0 } else { 1.8 },
                dynamics: Dynamics::Stable,
            })
            .collect();
        ClusterProfile {
            name: "cluster_c".into(),
            devices,
            bandwidth: 10e9 / 8.0,
            latency: 1e-3,
            topology: Topology::flat(),
        }
    }

    pub fn parse(s: &str, k: usize) -> Result<ClusterProfile> {
        Ok(match s {
            "homo" | "a" | "b" => ClusterProfile::homogeneous(k),
            "hete" => ClusterProfile::heterogeneous(k),
            "dyn" => ClusterProfile::dynamic(k, 50.0),
            "c" | "cluster_c" => ClusterProfile::cluster_c(k),
            _ => bail!("unknown cluster profile {s:?} (homo|hete|dyn|c)"),
        })
    }

    /// Builder: attach an aggregation topology.
    pub fn with_topology(mut self, topology: Topology) -> ClusterProfile {
        self.topology = topology;
        self
    }

    /// Seconds to move `bytes` one way, including one trip latency.
    pub fn comm_time(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Device-model index backing executor `slot`.  Schemes that spin
    /// up more executors than the profile has physical devices (RW/SD
    /// launch one executor per selected client) cycle through the
    /// profile's models so heterogeneity still shapes their timeline.
    pub fn executor_model(&self, slot: usize) -> usize {
        slot % self.devices.len()
    }

    /// Modeled runtime of a task of `n_samples`·`epochs` on device `k`
    /// at round `r` (Eq. 2 with the heterogeneity multipliers applied).
    /// A grouped topology's per-group compute profile multiplies on top
    /// (1.0 for flat topologies and neutral groups).
    pub fn task_time(
        &self,
        cost: &WorkloadCost,
        k: usize,
        r: usize,
        n_samples: usize,
        epochs: usize,
    ) -> f64 {
        let slow = self.devices[k].slowdown(r, k) * self.topology.compute_scale(k);
        (cost.t_sample * (n_samples * epochs) as f64 + cost.b_fixed) * slow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_equal_speeds() {
        let c = ClusterProfile::homogeneous(8);
        assert_eq!(c.n_devices(), 8);
        for (k, d) in c.devices.iter().enumerate() {
            assert_eq!(d.slowdown(10, k), 1.0);
        }
    }

    #[test]
    fn heterogeneous_spread() {
        let c = ClusterProfile::heterogeneous(4);
        let s: Vec<f64> = c.devices.iter().enumerate().map(|(k, d)| d.slowdown(0, k)).collect();
        assert_eq!(s[0], 1.0);
        assert_eq!(*s.last().unwrap(), 2.5);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn cosine_dynamics_oscillate_in_bounds() {
        let d = DeviceModel { static_slowdown: 1.0, dynamics: Dynamics::Cosine { period: 50.0 } };
        let vals: Vec<f64> = (0..200).map(|r| d.slowdown(r, 0)).collect();
        assert!(vals.iter().all(|&v| (1.0..=3.0 + 1e-9).contains(&v)));
        let spread = vals.iter().cloned().fold(0.0, f64::max)
            - vals.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1.5, "dynamics should swing, spread={spread}");
    }

    #[test]
    fn phase_shift_decorrelates_devices() {
        let c = ClusterProfile::dynamic(2, 50.0);
        let a = c.devices[0].slowdown(0, 0);
        let b = c.devices[1].slowdown(0, 1);
        assert!((a - b).abs() > 0.1);
    }

    #[test]
    fn task_time_scales_linearly() {
        let c = ClusterProfile::homogeneous(1);
        let w = WorkloadCost::femnist();
        let t1 = c.task_time(&w, 0, 0, 100, 1);
        let t2 = c.task_time(&w, 0, 0, 200, 1);
        assert!((t2 - t1 - 100.0 * w.t_sample).abs() < 1e-12);
        // epochs multiply the sample term only
        let te = c.task_time(&w, 0, 0, 100, 2);
        assert!((te - (w.t_sample * 200.0 + w.b_fixed)).abs() < 1e-12);
    }

    #[test]
    fn comm_time_includes_latency_and_bandwidth() {
        let c = ClusterProfile::homogeneous(1);
        let t = c.comm_time(1_250_000_000); // 1.25 GB at 1.25 GB/s = 1s
        assert!((t - 1.001).abs() < 1e-6);
    }

    #[test]
    fn parse_profiles() {
        assert_eq!(ClusterProfile::parse("homo", 4).unwrap().n_devices(), 4);
        assert_eq!(ClusterProfile::parse("c", 8).unwrap().name, "cluster_c");
        assert!(ClusterProfile::parse("wat", 4).is_err());
    }

    #[test]
    fn topology_parse_round_trips_and_validates() {
        assert!(Topology::parse("flat").unwrap().is_flat());
        let g = Topology::parse("groups:8").unwrap();
        assert_eq!(g.n_groups(), 8);
        assert_eq!(g.depth(), 1);
        assert_eq!(g.n_top(), 8);
        let t = Topology::parse("tree:4x2").unwrap();
        assert_eq!(t.n_groups(), 8);
        assert_eq!(t.depth(), 2);
        assert_eq!(t.n_top(), 4);
        // round trips through name()
        for s in ["flat", "groups:8", "tree:4x2", "tree:2x3x2"] {
            let topo = Topology::parse(s).unwrap();
            assert_eq!(Topology::parse(&topo.name()).unwrap(), topo, "{s}");
        }
        // WAN override: 1 Gbps, 20 ms
        let w = Topology::parse("groups:4:1:20").unwrap();
        let (bw, lat) = w.wan_link(1.0, 1.0);
        assert!((bw - 1e9 / 8.0).abs() < 1.0, "{bw}");
        assert!((lat - 0.02).abs() < 1e-12, "{lat}");
        // default WAN == base link
        assert_eq!(g.wan_link(7.0, 0.5), (7.0, 0.5));
        // rejects
        assert!(Topology::parse("groups:x").is_err());
        assert!(Topology::parse("tree:4x0").is_err());
        assert!(Topology::parse("rings:3").is_err());
        assert!(Topology::parse("groups:4:1").is_err());
        assert!(Topology::parse("groups:4:0:20").is_err());
        assert!(Topology::parse("groups:4:1:20:9").is_err());
    }

    #[test]
    fn topology_membership_round_robin_and_ancestry() {
        let t = Topology::parse("tree:2x2").unwrap(); // 4 leaf groups
        let members = t.members(10);
        assert_eq!(members.len(), 4);
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 10);
        for (g, mem) in members.iter().enumerate() {
            assert!(!mem.is_empty(), "round-robin leaves no group empty at k >= groups");
            for &d in mem {
                assert_eq!(t.group_of(d), g);
            }
        }
        // leaf -> top ancestry: leaves 0,1 under top 0; 2,3 under top 1
        assert_eq!(t.top_of(0), 0);
        assert_eq!(t.top_of(1), 0);
        assert_eq!(t.top_of(2), 1);
        assert_eq!(t.top_of(3), 1);
        // validation against device counts
        assert!(t.validate(4).is_ok());
        assert!(t.validate(3).is_err(), "more groups than devices");
        assert!(Topology::flat().validate(1).is_ok());
    }

    #[test]
    fn group_compute_profile_scales_task_time() {
        let mut c = ClusterProfile::homogeneous(4);
        let w = WorkloadCost::femnist();
        let base = c.task_time(&w, 0, 0, 100, 1);
        c.topology =
            Topology::groups(2).with_group_compute(vec![1.0, 2.0]);
        // devices 0,2 in group 0 (neutral); 1,3 in group 1 (2x slower)
        assert!((c.task_time(&w, 0, 0, 100, 1) - base).abs() < 1e-12);
        assert!((c.task_time(&w, 1, 0, 100, 1) - 2.0 * base).abs() < 1e-12);
        assert!((c.task_time(&w, 2, 0, 100, 1) - base).abs() < 1e-12);
        // group_compute length mismatch rejected
        let bad = Topology::groups(2).with_group_compute(vec![1.0]);
        assert!(bad.validate(4).is_err());
        assert!(Topology::groups(2)
            .with_group_compute(vec![1.0, 0.0])
            .validate(4)
            .is_err());
    }

    #[test]
    fn cluster_c_two_tiers() {
        let c = ClusterProfile::cluster_c(8);
        let slow: Vec<f64> = c.devices.iter().map(|d| d.static_slowdown).collect();
        assert!(slow.contains(&4.0) && slow.contains(&1.8));
    }
}
