//! Device/cluster heterogeneity models (paper §5.1 + Appendix A).
//!
//! The paper evaluates on three GPU clusters (A: homogeneous 2080 Ti,
//! B: homogeneous RTX 5000, C: heterogeneous K80/P40) and additionally
//! *simulates* heterogeneous and unstable devices on cluster A by
//! sleeping η_k·T̂ after each task.  This module reproduces exactly that
//! machinery:
//!
//! - [`DeviceModel`] — per-device speed multiplier over the baseline
//!   (η_k = slowdown − 1) plus the cos-based dynamic instability law
//!   `(1 + cos(πr/R + k))` from Appendix A.
//! - [`ClusterProfile`] — named device collections: `homo`, `hete`,
//!   `dyn`, and the paper's clusters `a`/`b`/`c` with speed ratios
//!   matching the public relative DL throughput of those GPUs.
//!
//! Both execution modes consume it: the real-compute coordinator sleeps
//! the extra (slowdown−1)·T̂ exactly as the paper does; the virtual-time
//! engine multiplies modeled task durations.

use anyhow::{bail, Result};

/// How a device's effective speed varies over rounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Dynamics {
    /// Constant speed.
    Stable,
    /// Appendix A's unstable-device law: extra slowdown factor
    /// `(1 + cos(π·r/period + k))` — phase-shifted per device.
    Cosine { period: f64 },
}

/// One simulated device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceModel {
    /// Static slowdown multiplier (1.0 = cluster-A 2080 Ti baseline).
    /// The paper's η_k equals `static_slowdown - 1`.
    pub static_slowdown: f64,
    pub dynamics: Dynamics,
}

impl DeviceModel {
    pub fn uniform() -> DeviceModel {
        DeviceModel { static_slowdown: 1.0, dynamics: Dynamics::Stable }
    }

    /// Effective slowdown at round `r` for device index `k`.
    pub fn slowdown(&self, r: usize, k: usize) -> f64 {
        let dynamic = match self.dynamics {
            Dynamics::Stable => 1.0,
            Dynamics::Cosine { period } => {
                // Paper: sleep ratio (1 + cos(3.14 r / R + k)) ∈ [0, 2]
                // applied on top of the measured time -> factor in [1, 3].
                1.0 + (1.0 + (std::f64::consts::PI * r as f64 / period + k as f64).cos())
            }
        };
        self.static_slowdown * dynamic
    }
}

/// Baseline per-sample / per-task constants for the virtual-time model,
/// calibrated per workload (DESIGN.md §2: relative — not absolute —
/// costs are what the figures compare).
#[derive(Debug, Clone, Copy)]
pub struct WorkloadCost {
    /// Seconds per training sample on the baseline device (Eq. 1 t^sample).
    pub t_sample: f64,
    /// Constant per-task seconds on the baseline device (Eq. 1 b):
    /// model load + weight copy + task switch.
    pub b_fixed: f64,
}

impl WorkloadCost {
    /// FEMNIST/ResNet-18-analog on a 2080 Ti-class device.
    pub fn femnist() -> WorkloadCost {
        WorkloadCost { t_sample: 2.0e-3, b_fixed: 0.15 }
    }

    /// ImageNet/ResNet-50-analog (bigger model, bigger images).
    pub fn imagenet() -> WorkloadCost {
        WorkloadCost { t_sample: 9.0e-3, b_fixed: 0.35 }
    }

    /// Reddit/Albert-analog.
    pub fn reddit() -> WorkloadCost {
        WorkloadCost { t_sample: 4.0e-3, b_fixed: 0.25 }
    }

    pub fn by_name(name: &str) -> Result<WorkloadCost> {
        Ok(match name {
            "femnist" | "mlp" => WorkloadCost::femnist(),
            "imagenet" | "cnn" => WorkloadCost::imagenet(),
            "reddit" | "tinylm" => WorkloadCost::reddit(),
            _ => bail!("unknown workload cost profile {name:?}"),
        })
    }
}

/// A collection of devices — one experiment's hardware.
#[derive(Debug, Clone)]
pub struct ClusterProfile {
    pub name: String,
    pub devices: Vec<DeviceModel>,
    /// Network bandwidth in bytes/sec (10 Gbps default, Table 5).
    pub bandwidth: f64,
    /// Per-message latency in seconds (one communication trip).
    pub latency: f64,
}

impl ClusterProfile {
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// All devices identical (paper clusters A and B).
    pub fn homogeneous(k: usize) -> ClusterProfile {
        ClusterProfile {
            name: "homo".into(),
            devices: vec![DeviceModel::uniform(); k],
            bandwidth: 10e9 / 8.0,
            latency: 1e-3,
        }
    }

    /// Simulated heterogeneous GPUs (Appendix A): pre-assigned η ratios
    /// spread over [0, 1.5] — device k gets slowdown 1 + 1.5·k/(K−1).
    pub fn heterogeneous(k: usize) -> ClusterProfile {
        let devices = (0..k)
            .map(|i| DeviceModel {
                static_slowdown: 1.0
                    + if k > 1 { 1.5 * i as f64 / (k - 1) as f64 } else { 0.0 },
                dynamics: Dynamics::Stable,
            })
            .collect();
        ClusterProfile {
            name: "hete".into(),
            devices,
            bandwidth: 10e9 / 8.0,
            latency: 1e-3,
        }
    }

    /// Simulated unstable devices (Appendix A cos law).
    pub fn dynamic(k: usize, period: f64) -> ClusterProfile {
        ClusterProfile {
            name: "dyn".into(),
            devices: vec![
                DeviceModel {
                    static_slowdown: 1.0,
                    dynamics: Dynamics::Cosine { period },
                };
                k
            ],
            bandwidth: 10e9 / 8.0,
            latency: 1e-3,
        }
    }

    /// Paper cluster C: genuinely heterogeneous (4×K80 + 4×P40 speeds).
    /// Relative DL throughputs: 2080Ti≈1.0, P40≈1.8, K80≈4.0 slower.
    pub fn cluster_c(k: usize) -> ClusterProfile {
        let devices = (0..k)
            .map(|i| DeviceModel {
                static_slowdown: if i % 2 == 0 { 4.0 } else { 1.8 },
                dynamics: Dynamics::Stable,
            })
            .collect();
        ClusterProfile {
            name: "cluster_c".into(),
            devices,
            bandwidth: 10e9 / 8.0,
            latency: 1e-3,
        }
    }

    pub fn parse(s: &str, k: usize) -> Result<ClusterProfile> {
        Ok(match s {
            "homo" | "a" | "b" => ClusterProfile::homogeneous(k),
            "hete" => ClusterProfile::heterogeneous(k),
            "dyn" => ClusterProfile::dynamic(k, 50.0),
            "c" | "cluster_c" => ClusterProfile::cluster_c(k),
            _ => bail!("unknown cluster profile {s:?} (homo|hete|dyn|c)"),
        })
    }

    /// Seconds to move `bytes` one way, including one trip latency.
    pub fn comm_time(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Device-model index backing executor `slot`.  Schemes that spin
    /// up more executors than the profile has physical devices (RW/SD
    /// launch one executor per selected client) cycle through the
    /// profile's models so heterogeneity still shapes their timeline.
    pub fn executor_model(&self, slot: usize) -> usize {
        slot % self.devices.len()
    }

    /// Modeled runtime of a task of `n_samples`·`epochs` on device `k`
    /// at round `r` (Eq. 2 with the heterogeneity multipliers applied).
    pub fn task_time(
        &self,
        cost: &WorkloadCost,
        k: usize,
        r: usize,
        n_samples: usize,
        epochs: usize,
    ) -> f64 {
        let slow = self.devices[k].slowdown(r, k);
        (cost.t_sample * (n_samples * epochs) as f64 + cost.b_fixed) * slow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_equal_speeds() {
        let c = ClusterProfile::homogeneous(8);
        assert_eq!(c.n_devices(), 8);
        for (k, d) in c.devices.iter().enumerate() {
            assert_eq!(d.slowdown(10, k), 1.0);
        }
    }

    #[test]
    fn heterogeneous_spread() {
        let c = ClusterProfile::heterogeneous(4);
        let s: Vec<f64> = c.devices.iter().enumerate().map(|(k, d)| d.slowdown(0, k)).collect();
        assert_eq!(s[0], 1.0);
        assert_eq!(*s.last().unwrap(), 2.5);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn cosine_dynamics_oscillate_in_bounds() {
        let d = DeviceModel { static_slowdown: 1.0, dynamics: Dynamics::Cosine { period: 50.0 } };
        let vals: Vec<f64> = (0..200).map(|r| d.slowdown(r, 0)).collect();
        assert!(vals.iter().all(|&v| (1.0..=3.0 + 1e-9).contains(&v)));
        let spread = vals.iter().cloned().fold(0.0, f64::max)
            - vals.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1.5, "dynamics should swing, spread={spread}");
    }

    #[test]
    fn phase_shift_decorrelates_devices() {
        let c = ClusterProfile::dynamic(2, 50.0);
        let a = c.devices[0].slowdown(0, 0);
        let b = c.devices[1].slowdown(0, 1);
        assert!((a - b).abs() > 0.1);
    }

    #[test]
    fn task_time_scales_linearly() {
        let c = ClusterProfile::homogeneous(1);
        let w = WorkloadCost::femnist();
        let t1 = c.task_time(&w, 0, 0, 100, 1);
        let t2 = c.task_time(&w, 0, 0, 200, 1);
        assert!((t2 - t1 - 100.0 * w.t_sample).abs() < 1e-12);
        // epochs multiply the sample term only
        let te = c.task_time(&w, 0, 0, 100, 2);
        assert!((te - (w.t_sample * 200.0 + w.b_fixed)).abs() < 1e-12);
    }

    #[test]
    fn comm_time_includes_latency_and_bandwidth() {
        let c = ClusterProfile::homogeneous(1);
        let t = c.comm_time(1_250_000_000); // 1.25 GB at 1.25 GB/s = 1s
        assert!((t - 1.001).abs() < 1e-6);
    }

    #[test]
    fn parse_profiles() {
        assert_eq!(ClusterProfile::parse("homo", 4).unwrap().n_devices(), 4);
        assert_eq!(ClusterProfile::parse("c", 8).unwrap().name, "cluster_c");
        assert!(ClusterProfile::parse("wat", 4).is_err());
    }

    #[test]
    fn cluster_c_two_tiers() {
        let c = ClusterProfile::cluster_c(8);
        let slow: Vec<f64> = c.devices.iter().map(|d| d.static_slowdown).collect();
        assert!(slow.contains(&4.0) && slow.contains(&1.8));
    }
}
