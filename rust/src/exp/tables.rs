//! Table harnesses: complexity (Table 1), feature matrix (Table 2),
//! GPU memory (Table 3).

use crate::aggregation::{AggOp, ClientUpdate, LocalAgg, Payload};
use crate::config::Scheme;
use crate::coordinator::metrics::MemoryModel;
use crate::model::ParamSet;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::Result;

const MB: u64 = 1 << 20;
const SCHEMES: [Scheme; 5] =
    [Scheme::SP, Scheme::RwDist, Scheme::SdDist, Scheme::FaDist, Scheme::Parrot];

/// Table 1 — complexity comparison: the analytic rows, *validated* by a
/// measured mini-federation (comm size/trips counted on real encoded
/// aggregates).
pub fn table1(args: &Args) -> Result<()> {
    let m = args.usize_or("clients", 256)?;
    let m_p = args.usize_or("per-round", 64)?;
    let k = args.usize_or("devices", 8)?;
    let s_m = 1122 * MB; // paper's FEMNIST per-client sim footprint
    let s_d = 4 * MB; // SCAFFOLD control variate (11M f32 ≈ 44MB in paper; small here)
    let s_a = 44 * MB; // ResNet-18 params
    let s_e = 0u64;

    println!("Table 1 — per-round complexity (M={m}, M_p={m_p}, K={k})");
    println!(
        "{:<14} {:>9} {:>14} {:>16} {:>14} {:>12} {:>8}",
        "Scheme", "Devices", "Memory(MB)", "Mem+mgr(MB)", "Comm(MB)", "Trips", "Disk(MB)"
    );
    let mm = MemoryModel { s_m, s_d };
    let mut rows = Vec::new();
    for scheme in SCHEMES {
        let devices = match scheme {
            Scheme::SP => 1,
            Scheme::RwDist => m,
            Scheme::SdDist => m_p,
            Scheme::FaDist | Scheme::Parrot | Scheme::Async => k,
        };
        let mem = mm.memory(scheme, m, m_p, k) / MB;
        let mem_mgr = mm.memory_with_manager(scheme, m, m_p, k) / MB;
        let comm = MemoryModel::comm_size(scheme, s_a, s_e, m_p, k) / MB;
        let trips = MemoryModel::comm_trips(scheme, m_p, k);
        let disk = mm.disk_with_manager(scheme, m) / MB;
        println!(
            "{:<14} {:>9} {:>14} {:>16} {:>14} {:>12} {:>8}",
            scheme.name(),
            devices,
            mem,
            mem_mgr,
            comm,
            trips,
            disk
        );
        rows.push(format!("{},{devices},{mem},{mem_mgr},{comm},{trips},{disk}", scheme.name()));
    }

    // Measured validation: encode real device aggregates vs raw updates.
    let shapes = vec![vec![256, 64], vec![64]];
    let mut rng = Rng::new(7);
    let updates: Vec<ClientUpdate> = (0..m_p)
        .map(|c| {
            let tensors = shapes
                .iter()
                .map(|s| {
                    (0..s.iter().product::<usize>())
                        .map(|_| rng.normal_f32(0.0, 1.0))
                        .collect()
                })
                .collect();
            ClientUpdate {
                client: c,
                weight: 1.0,
                entries: vec![(
                    "delta".into(),
                    AggOp::WeightedAvg,
                    Payload::Params(ParamSet { shapes: shapes.clone(), tensors }),
                )],
            }
        })
        .collect();
    let flat_bytes: usize = updates
        .iter()
        .map(|u| u.entries.iter().map(|(_, _, p)| p.size_bytes()).sum::<usize>())
        .sum();
    let mut parrot_bytes = 0usize;
    for dev in 0..k {
        let mut la = LocalAgg::new(dev);
        for (i, u) in updates.iter().enumerate() {
            if i % k == dev {
                la.add(u);
            }
        }
        parrot_bytes += la.finish().size_bytes();
    }
    let ratio = flat_bytes as f64 / parrot_bytes as f64;
    println!(
        "\nmeasured upload: FA/SD-style {:.1} MB vs Parrot {:.1} MB  (ratio {:.1}x; model predicts M_p/K = {:.1}x)",
        flat_bytes as f64 / MB as f64,
        parrot_bytes as f64 / MB as f64,
        ratio,
        m_p as f64 / k as f64
    );

    super::save_csv(
        args,
        "table1",
        "scheme,devices,memory_mb,memory_mgr_mb,comm_mb,trips,disk_mb",
        &rows,
    )?;
    super::save_json(
        args,
        "table1_measured",
        &Json::obj()
            .set("flat_upload_bytes", flat_bytes)
            .set("parrot_upload_bytes", parrot_bytes)
            .set("measured_ratio", ratio)
            .set("predicted_ratio", m_p as f64 / k as f64),
    )?;
    Ok(())
}

/// Table 2 — framework feature matrix, reproduced as *this repo's*
/// capability row with the test/harness that proves each feature.
pub fn table2(args: &Args) -> Result<()> {
    let rows = [
        ("SP", "coordinator::server (scheme sp)", "integration_training::sp_scheme_single_device"),
        ("RW Dist.", "simulation::engine (per-client executors)", "simulation tests"),
        ("SD Dist.", "simulation::engine (per-client executors)", "simulation tests"),
        ("FA Dist.", "coordinator::server::round_fa", "integration_training::fa_mode_*"),
        ("Scalability", "virtual engine @ 10k clients", "exp fig10"),
        ("Flexible Hardware Conf.", "cluster profiles homo/hete/dyn/c", "exp fig9"),
        ("Dynamic Availability/Churn", "simulation::availability + event engine", "exp dynamics"),
        ("Real-world Deployment", "transport::tcp", "examples/deploy_tcp.rs"),
        ("Task Scheduling", "scheduler (Alg. 3)", "exp fig7/fig8"),
        ("Client State Manager", "state::StateManager", "integration_training::stateful_*"),
    ];
    println!("Table 2 — FedML Parrot feature matrix (this reproduction)");
    println!("{:<26} {:<38} {}", "Feature", "Implementation", "Evidence");
    let mut csv = Vec::new();
    for (f, i, e) in rows {
        println!("{f:<26} {i:<38} {e}");
        csv.push(format!("{f},{i},{e}"));
    }
    super::save_csv(args, "table2", "feature,implementation,evidence", &csv)
}

/// Table 3 — GPU memory costs of the FL tasks.
pub fn table3(args: &Args) -> Result<()> {
    println!("Table 3 — GPU memory costs (MB)");
    println!(
        "{:<10} {:>6} {:>4} {:>10} {:>12} {:>14}",
        "Dataset", "M_p", "K", "SP", "SD Dist.", "FA&Parrot"
    );
    // (dataset, M, M_p, K, s_m MB) — s_m from the paper's measured
    // per-client footprints (Table 3), which our analytic model consumes.
    let cases = [
        ("FEMNIST", 3400, 100, 8, 1122u64),
        ("FEMNIST", 3400, 100, 16, 1122),
        ("ImageNet", 10_000, 1000, 8, 3305),
        ("ImageNet", 10_000, 1000, 16, 3305),
    ];
    let mut csv = Vec::new();
    for (ds, m, m_p, k, s_m) in cases {
        let mm = MemoryModel { s_m: s_m * MB, s_d: 0 };
        let sp = mm.memory_with_manager(Scheme::SP, m, m_p, k) / MB;
        let sd = mm.memory(Scheme::SdDist, m, m_p, k) / MB;
        let fa = mm.memory(Scheme::FaDist, m, m_p, k) / MB;
        println!("{ds:<10} {m_p:>6} {k:>4} {sp:>10} {sd:>12} {fa:>14}");
        csv.push(format!("{ds},{m_p},{k},{sp},{sd},{fa}"));
    }

    // Calibration note: measured RSS of one real mlp client task.
    let man = std::path::Path::new(&args.get_or("artifacts", "artifacts").to_string())
        .join("mlp_train.manifest.txt");
    if man.exists() {
        let m = crate::model::Manifest::load(&man)?;
        // params + anchors + corrs + grads + activations(≈2x params f32)
        let est = (m.param_bytes() * 6) as f64 / MB as f64;
        println!(
            "\ncalibration: this repo's mlp task footprint ≈ {est:.1} MB/client \
             (params {:.2} MB × 6 resident copies); paper's ResNet-18 row is 1122 MB — \
             same formula, bigger model.",
            m.param_bytes() as f64 / MB as f64
        );
    }
    super::save_csv(args, "table3", "dataset,mp,k,sp_mb,sd_mb,fa_parrot_mb", &csv)
}
