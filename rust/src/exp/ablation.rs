//! Ablation benches for the design choices DESIGN.md calls out:
//! (a) hierarchical aggregation on/off, (b) LPT ordering inside Alg. 3,
//! (c) warm-up length R_w, (d) Time-Window width τ, (e) state-manager
//! cache budget.  `parrot exp ablate`.

use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::config::{Scheme, SchedulerKind};
use crate::data::{Partition, PartitionKind};
use crate::model::ParamSet;
use crate::scheduler::{greedy_assign, DeviceEstimate};
use crate::simulation::{run_virtual, CommModel, VirtualSim};
use crate::state::StateManager;
use crate::util::cli::Args;
use anyhow::Result;

fn mean_tail(rs: &[crate::simulation::VRound], skip: usize) -> f64 {
    rs.iter().skip(skip).map(|r| r.total_secs).sum::<f64>() / (rs.len() - skip) as f64
}

pub fn ablate(args: &Args) -> Result<()> {
    let mut csv = Vec::new();

    // (a) hierarchical aggregation: Parrot scheduling with FA-style
    // per-client comm vs Parrot comm — isolates §4.2 from §4.4.
    println!("(a) hierarchical aggregation ablation (K=8, M_p=100, femnist comm)");
    let part = Partition::generate(PartitionKind::Natural, 600, 62, 100, 5);
    let mk = |scheme, sched| {
        VirtualSim::new(
            scheme,
            ClusterProfile::homogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            sched,
            2,
            part.clone(),
            1,
            7,
        )
    };
    let mut parrot = mk(Scheme::Parrot, SchedulerKind::Greedy);
    let mut fa_sched = mk(Scheme::FaDist, SchedulerKind::Uniform);
    let rp = run_virtual(&mut parrot, 10, 100, 3);
    let rf = run_virtual(&mut fa_sched, 10, 100, 3);
    println!(
        "   with hierarchy: {:.2}s/round, {:.0} MB, {} trips",
        mean_tail(&rp, 3),
        rp[5].bytes as f64 / (1 << 20) as f64,
        rp[5].trips
    );
    println!(
        "   without (per-client comm): {:.2}s/round, {:.0} MB, {} trips",
        mean_tail(&rf, 3),
        rf[5].bytes as f64 / (1 << 20) as f64,
        rf[5].trips
    );
    csv.push(format!(
        "hierarchy,{:.3},{},{:.3},{}",
        mean_tail(&rp, 3),
        rp[5].bytes,
        mean_tail(&rf, 3),
        rf[5].bytes
    ));

    // (b) LPT (descending) vs arrival order inside the greedy pass.
    println!("\n(b) LPT ordering inside Alg. 3 (K=8, heterogeneous estimates)");
    let est: Vec<DeviceEstimate> = (0..8)
        .map(|i| DeviceEstimate {
            t_sample: 0.002 * (1.0 + 0.3 * i as f64),
            b: 0.15,
            r2: 1.0,
            n_points: 20,
        })
        .collect();
    let mut rng = crate::util::rng::Rng::new(11);
    let clients: Vec<(usize, usize)> =
        (0..100).map(|i| (i, 20 + rng.below(400) as usize)).collect();
    let sizes = crate::scheduler::greedy::size_table(&clients);
    let (sorted_asg, _) = greedy_assign(&clients, &est);
    // unsorted variant: same placement rule, arrival order
    let mut w = vec![0.0f64; 8];
    let mut unsorted_asg = vec![Vec::new(); 8];
    for &(c, n) in &clients {
        let k = (0..8)
            .min_by(|&a, &b| {
                (w[a] + est[a].predict(n))
                    .partial_cmp(&(w[b] + est[b].predict(n)))
                    .unwrap()
            })
            .unwrap();
        w[k] += est[k].predict(n);
        unsorted_asg[k].push(c);
    }
    let ms_sorted = crate::scheduler::greedy::makespan(&sorted_asg, &sizes, &est);
    let ms_unsorted = crate::scheduler::greedy::makespan(&unsorted_asg, &sizes, &est);
    println!(
        "   LPT order: {ms_sorted:.2}s  |  arrival order: {ms_unsorted:.2}s  ({:.1}% better)",
        100.0 * (ms_unsorted - ms_sorted) / ms_unsorted
    );
    csv.push(format!("lpt,{ms_sorted:.3},{ms_unsorted:.3},,"));

    // (c) warm-up length R_w.
    println!("\n(c) warm-up rounds R_w (heterogeneous cluster, 20 rounds)");
    for rw in [0usize, 2, 5, 10] {
        let mut sim = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(8),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::Greedy,
            rw,
            part.clone(),
            1,
            9,
        );
        let rs = run_virtual(&mut sim, 20, 100, 5);
        let total: f64 = rs.iter().map(|r| r.total_secs).sum();
        println!("   R_w={rw:<3} total 20-round time {total:.1}s");
        csv.push(format!("warmup,{rw},{total:.2},,"));
    }

    // (d) Time-Window width in the dynamic environment.
    println!("\n(d) Time-Window width τ (cos dynamics, 60 rounds)");
    for tau in [1usize, 3, 5, 10, 30] {
        let mut sim = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::dynamic(8, 25.0),
            WorkloadCost::femnist(),
            CommModel::femnist(),
            SchedulerKind::TimeWindow(tau),
            2,
            part.clone(),
            1,
            13,
        );
        let rs = run_virtual(&mut sim, 60, 100, 7);
        let t = mean_tail(&rs, 20);
        let errs: Vec<f64> = rs.iter().skip(20).filter_map(|r| r.est_err).collect();
        let err = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
        println!("   τ={tau:<3} round {t:.2}s  est-MAPE {:.1}%", 100.0 * err);
        csv.push(format!("tau,{tau},{t:.3},{err:.4},"));
    }

    // (e) state-manager cache budget (hit rate on a SCAFFOLD-like trace).
    println!("\n(e) state-manager cache budget (64 clients, 1MB state, zipf-ish reuse)");
    let shapes = vec![vec![784usize, 256], vec![256]];
    let state = ParamSet::init_he(&shapes, 1);
    let sz = state.size_bytes();
    for budget_states in [0usize, 2, 8, 32, 64] {
        let dir = std::env::temp_dir()
            .join(format!("parrot_ablate_{}_{budget_states}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut sm = StateManager::new(&dir, budget_states * (sz + 1024))?;
        let mut rng = crate::util::rng::Rng::new(17);
        for _ in 0..400 {
            // zipf-ish: low ids much hotter
            let c = (rng.next_f64().powi(3) * 64.0) as u64;
            if sm.load(c)?.is_none() {
                sm.save(c, &state.to_bytes()?)?;
            }
        }
        let hit = sm.metrics.cache_hits as f64 / sm.metrics.loads as f64;
        println!(
            "   budget {budget_states:>2} states: hit-rate {:.0}%, disk reads {}",
            100.0 * hit,
            sm.metrics.disk_reads
        );
        csv.push(format!("cache,{budget_states},{hit:.4},{},", sm.metrics.disk_reads));
        let _ = std::fs::remove_dir_all(&dir);
    }

    super::save_csv(args, "ablation", "ablation,x,a,b,c", &csv)
}
