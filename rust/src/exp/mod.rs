//! Experiment harnesses: one generator per paper table/figure
//! (DESIGN.md §4's per-experiment index).
//!
//! Every harness prints the same rows/series the paper reports and
//! writes machine-readable JSON + CSV under `results/`.  Invoke through
//! the launcher: `parrot exp <id>` (ids: table1 table2 table3 fig4 fig5
//! fig6 fig7 fig8 fig9 fig10 fig11 dynamics compression statescale
//! asyncscale toposcale ablate all).  `dynamics` sweeps the §4.4
//! availability/churn/straggler scenarios on the discrete-event engine;
//! `compression` sweeps the `--compress` codecs (bytes / round time /
//! reconstruction error) across schemes; `statescale` sweeps the
//! distributed client-state store (1000 stateful clients × cache budget
//! × shard count) against the local-only baseline; `asyncscale` sweeps
//! asynchronous buffered execution (buffer × staleness law) against
//! sync Parrot under straggler injection, with the degenerate
//! configuration pinned equal to the sync timeline; `toposcale` sweeps
//! multi-level hierarchical topologies (`--topology
//! flat|groups:G|tree:SPEC`) and asserts cross-WAN bytes shrink with
//! grouping at (near-)equal makespan; `parscale` sweeps the
//! group-sharded parallel engine (`--threads` 1/2/4/8 × topology),
//! asserts byte-identical rows at every thread count, and reports the
//! wall-clock speedup (`BENCH_parscale.json`); `megascale` sweeps the
//! SoA-table engine at population scale (100k smoke / 1M full clients
//! × devices × topology × threads), asserts byte-identical rows —
//! including the deterministic heap-pop count — and reports events/sec
//! plus peak RSS (`BENCH_megascale.json`).

pub mod ablation;
pub mod asyncscale;
pub mod compression;
pub mod convergence;
pub mod dynamics;
pub mod figures;
pub mod megascale;
pub mod parscale;
pub mod statescale;
pub mod tables;
pub mod toposcale;

use crate::util::cli::Args;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// Where results land (override with --results).
pub fn results_dir(args: &Args) -> Result<PathBuf> {
    let dir = PathBuf::from(args.get_or("results", "results"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// Write both a rendered text table (stdout already printed) and JSON.
pub fn save_json(args: &Args, name: &str, json: &crate::util::json::Json) -> Result<()> {
    let path = results_dir(args)?.join(format!("{name}.json"));
    std::fs::write(&path, json.render())?;
    println!("[saved {}]", path.display());
    Ok(())
}

pub fn save_csv(args: &Args, name: &str, header: &str, rows: &[String]) -> Result<()> {
    let path = results_dir(args)?.join(format!("{name}.csv"));
    let mut body = String::from(header);
    body.push('\n');
    for r in rows {
        body.push_str(r);
        body.push('\n');
    }
    std::fs::write(&path, body)?;
    println!("[saved {}]", path.display());
    Ok(())
}

/// Dispatch one experiment id.
pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "table1" => tables::table1(args),
        "table2" => tables::table2(args),
        "table3" => tables::table3(args),
        "fig4" => convergence::fig4(args),
        "fig5" => figures::fig5(args),
        "fig6" => figures::fig6(args),
        "fig7" => figures::fig7(args),
        "fig8" => figures::fig8(args),
        "fig9" => figures::fig9(args),
        "fig10" => figures::fig10(args),
        "fig11" => figures::fig11(args),
        "dynamics" => dynamics::dynamics(args),
        "compression" => compression::compression(args),
        "statescale" => statescale::statescale(args),
        "asyncscale" => asyncscale::asyncscale(args),
        "toposcale" => toposcale::toposcale(args),
        "parscale" => parscale::parscale(args),
        "megascale" => megascale::megascale(args),
        "ablate" => ablation::ablate(args),
        "all" => {
            for id in [
                "table1", "table2", "table3", "fig5", "fig6", "fig7", "fig8", "fig9",
                "fig10", "fig11", "dynamics", "compression", "statescale", "asyncscale",
                "toposcale", "parscale", "fig4",
            ] {
                println!("\n################ {id} ################");
                run(id, args)?;
            }
            Ok(())
        }
        _ => bail!(
            "unknown experiment {id:?}; ids: table1 table2 table3 fig4..fig11 dynamics \
             compression statescale asyncscale toposcale parscale megascale ablate all"
        ),
    }
}
