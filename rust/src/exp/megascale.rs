//! `parrot exp megascale` — the SoA-table engine at population scale:
//! 100k (smoke) to 1M (full) simulated clients, sweeping
//! clients × devices × {flat, groups:16} × `--threads` {1, 2, 8} on
//! the identical seed.
//!
//! This is the acceptance harness for the megascale restructuring
//! (struct-of-arrays client/task tables, arena-batched cohort events,
//! pooled aggregation buffers): the population no longer materializes
//! one heap object per client, so the sweep's footprint is bounded by
//! the dense per-client columns plus the round's task table.
//!
//! Two things are measured, one is asserted:
//!
//! - **thread invariance (hard check)**: for every cell the per-round
//!   engine rows — every virtual-time/byte column *plus* the
//!   deterministic heap-pop count (`VRound::engine_events`) — must be
//!   byte-identical across `--threads` {1, 2, 8}.  Any divergence
//!   fails the harness and prints the seed.
//! - **throughput and footprint (reported)**: events/sec (heap pops
//!   over engine-only wall seconds) per thread count, and the
//!   process's peak RSS (`VmHWM`) after each cell.  Both are
//!   host-dependent, so they live in the JSON only and never in the
//!   byte-compared rows.
//!
//! `--smoke` (wired into `scripts/ci.sh`) runs the 100k-client cell
//! set only.  Results land in `BENCH_megascale.json`; the committed
//! copy at the repo root records the reference host's numbers.

use crate::cluster::{ClusterProfile, Topology, WorkloadCost};
use crate::config::{Scheme, SchedulerKind};
use crate::data::{Partition, PartitionKind};
use crate::obs::chrome;
use crate::simulation::{registry_from_rounds, run_virtual, CommModel, VRound, VirtualSim};
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::{ensure, Result};

/// Peak resident set size (`VmHWM`) in KiB from `/proc/self/status`;
/// 0 when procfs is unavailable (non-Linux hosts).  JSON-only — peak
/// RSS is a host fact, not an engine output, so it is never part of
/// the byte-compared rows.
pub fn peak_rss_kib() -> u64 {
    let Ok(s) = std::fs::read_to_string("/proc/self/status") else { return 0 };
    for line in s.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches("kB").trim().parse().unwrap_or(0);
        }
    }
    0
}

/// One engine row per round: parscale's virtual-time/byte columns plus
/// the deterministic event count.  Byte-compared across thread counts.
fn row(spec: &str, r: &VRound) -> String {
    format!(
        "{spec},{},{:.9},{:.9},{:.9},{},{},{},{},{},{},{:.9},{}",
        r.round,
        r.total_secs,
        r.compute_secs,
        r.comm_secs,
        r.bytes,
        r.trips,
        r.cross_group_bytes,
        r.group_aggs,
        r.scheduled_clients,
        r.dropped_clients,
        r.wasted_secs,
        r.engine_events
    )
}

/// Run one (clients, devices, topology, threads) cell; returns the
/// per-round rows, the engine-only wall seconds, and the total heap
/// pops (the deterministic events/sec numerator).
#[allow(clippy::too_many_arguments)]
fn run_cell(
    spec: &str,
    topo: &Topology,
    partition: &Partition,
    m_p: usize,
    k: usize,
    rounds: usize,
    seed: u64,
    threads: usize,
) -> (Vec<String>, f64, u64) {
    let cluster = ClusterProfile::heterogeneous(k).with_topology(topo.clone());
    let mut sim = VirtualSim::new(
        Scheme::Parrot,
        cluster,
        WorkloadCost::femnist(),
        CommModel::femnist(),
        SchedulerKind::Greedy,
        2,
        partition.clone(),
        1,
        seed,
    )
    .with_threads(threads)
    // events/sec needs a real denominator: inject the clock so
    // engine_secs books engine-only wall seconds.
    .with_wall_clock(crate::util::timer::wall_secs);
    let rs = run_virtual(&mut sim, rounds, m_p, seed ^ 0x3E6A);
    (rs.iter().map(|r| row(spec, r)).collect(), sim.engine_secs, sim.engine_events)
}

/// The determinism-suite smoke cell (`tests/determinism.rs`): a
/// 100k-client grouped Parrot sim — the grouped plan always takes the
/// sharded engine path — whose per-round rows (including the event
/// count) must be byte-identical for every `threads` value on one seed.
pub fn smoke_rows(seed: u64, threads: usize) -> Result<Vec<String>> {
    let topo = Topology::parse("groups:16")?;
    let partition = Partition::generate(PartitionKind::Natural, 100_000, 62, 100, seed);
    let (rows, _, _) =
        run_cell("megascale-smoke", &topo, &partition, 2048, 64, 2, seed, threads);
    ensure!(!rows.is_empty(), "megascale smoke cell produced no rounds");
    Ok(rows)
}

/// The traced variant of the smoke cell: returns the rendered Chrome
/// trace bytes (registry snapshot included), which must be identical
/// across runs and thread counts on one seed.
pub fn smoke_trace(seed: u64, threads: usize) -> Result<String> {
    let topo = Topology::parse("groups:16")?;
    let partition = Partition::generate(PartitionKind::Natural, 100_000, 62, 100, seed);
    let cluster = ClusterProfile::heterogeneous(64).with_topology(topo);
    let mut sim = VirtualSim::new(
        Scheme::Parrot,
        cluster,
        WorkloadCost::femnist(),
        CommModel::femnist(),
        SchedulerKind::Greedy,
        2,
        partition,
        1,
        seed,
    )
    .with_threads(threads)
    .with_tracing();
    let rs = run_virtual(&mut sim, 2, 2048, seed ^ 0x3E6A);
    ensure!(!rs.is_empty(), "traced megascale cell produced no rounds");
    let tracer = sim.tracer.take().expect("tracing was enabled");
    ensure!(!tracer.is_empty(), "traced megascale cell recorded no events");
    let rows = chrome::expand(&tracer);
    chrome::check_well_formed(&rows)
        .map_err(|e| anyhow::anyhow!("malformed trace (--seed {seed:#x}): {e}"))?;
    Ok(chrome::render_events(&rows, Some(&registry_from_rounds(&rs))))
}

pub fn megascale(args: &Args) -> Result<()> {
    let smoke = args.flag("smoke");
    let rounds = args.usize_or("rounds", 2)?;
    let seed = args.u64_or("seed", 47)?;
    let m_p = args.usize_or("per-round", if smoke { 4096 } else { 8192 })?;
    let thread_counts: &[usize] = &[1, 2, 8];
    let client_counts: &[usize] = if smoke { &[100_000] } else { &[100_000, 1_000_000] };
    let device_counts: &[usize] = if smoke { &[64] } else { &[64, 256] };
    let topologies: &[&str] = &["flat", "groups:16"];
    println!(
        "Megascale SoA engine — M={client_counts:?}, M_p={m_p}, K={device_counts:?}, \
         R={rounds}{}",
        if smoke { " (smoke scale)" } else { "" }
    );
    println!(
        "{:<26} {:>7} {:>12} {:>12} {:>12}  {}",
        "cell", "threads", "engine(s)", "events/s", "peakRSS(MiB)", "rows"
    );

    let mut cells = Vec::new();
    for &m in client_counts {
        // One deterministic partition per population, shared by every
        // cell at that scale (the sweep axes must not perturb it).
        let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
        for &k in device_counts {
            for spec in topologies {
                let topo = Topology::parse(spec)?;
                let cell = format!("m{m}-k{k}-{spec}");
                let mut reference: Option<Vec<String>> = None;
                let mut secs_at = Vec::new();
                let mut events_per_sec = Vec::new();
                let mut total_events = 0u64;
                for &t in thread_counts {
                    let (rows, secs, events) =
                        run_cell(&cell, &topo, &partition, m_p, k, rounds, seed, t);
                    if let Some(base) = reference.as_ref() {
                        ensure!(
                            base == &rows,
                            "{cell}: rows diverged between --threads {} and --threads \
                             {t} — the SoA engine leaked thread-count dependence \
                             (replay with --seed {seed})",
                            thread_counts[0]
                        );
                    } else {
                        reference = Some(rows);
                    }
                    total_events = events;
                    let eps = if secs > 0.0 { events as f64 / secs } else { 0.0 };
                    secs_at.push(secs);
                    events_per_sec.push(eps);
                    println!(
                        "{:<26} {:>7} {:>12.4} {:>12.0} {:>12.1}  {}",
                        cell,
                        t,
                        secs,
                        eps,
                        peak_rss_kib() as f64 / 1024.0,
                        if t == thread_counts[0] { "reference" } else { "identical" }
                    );
                }
                let rows = reference.unwrap_or_default();
                ensure!(!rows.is_empty(), "{cell}: engine produced no rounds");
                cells.push(
                    Json::obj()
                        .set("clients", m)
                        .set("devices", k)
                        .set("topology", *spec)
                        .set("rows_identical", true)
                        .set("engine_events", total_events as i64)
                        .set("engine_secs", secs_at)
                        .set("events_per_sec", events_per_sec)
                        .set("peak_rss_kib", peak_rss_kib() as i64)
                        .set("rows", rows),
                );
            }
        }
    }
    println!(
        "\n(same seed, same rows — including the heap-pop count — at every thread"
    );
    println!(" count; events/sec and peak RSS are host facts and live in the JSON only.)");

    if let Some(path) = args.get("trace") {
        let bytes = smoke_trace(seed, *thread_counts.last().unwrap())?;
        std::fs::write(path, bytes)?;
        println!("[saved {path} (Chrome trace; open in Perfetto)]");
    }

    let json = Json::obj()
        .set("name", "megascale")
        .set("smoke", smoke)
        .set("per_round", m_p)
        .set("rounds", rounds)
        .set("seed", format!("{seed:#x}"))
        .set("threads", thread_counts.to_vec())
        .set("peak_rss_kib", peak_rss_kib() as i64)
        .set("cells", Json::Arr(cells));
    super::save_json(args, "BENCH_megascale", &json)
}
