//! Dynamic-scenario sweep (`parrot exp dynamics`): the §4.4
//! dynamic-hardware claims exercised end-to-end on the discrete-event
//! engine — scenarios the old per-scheme virtual-clock loops could not
//! represent at all.
//!
//! Defaults match the acceptance configuration: 1000 clients, 32
//! devices, M_p = 100, with client availability < 1, a scripted
//! mid-round device departure (+ later rejoin), and injected
//! stragglers/drops.  For every scheme × scenario the harness reports
//! steady-state round time, device utilization (now per-executor and
//! non-degenerate for RW/SD and FA), dropped clients, wasted compute,
//! and churn counts.

use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::compress::Codec;
use crate::config::{Scheme, SchedulerKind};
use crate::data::{Partition, PartitionKind};
use crate::simulation::{
    run_virtual, AvailabilityModel, ChurnEvent, ChurnKind, ChurnSpec, CommModel, DynamicsSpec,
    SlowdownLaw, StragglerSpec, VRound, VirtualSim,
};
use crate::util::cli::Args;
use anyhow::Result;

fn mean_tail(rs: &[VRound], skip: usize) -> f64 {
    let tail: Vec<f64> = rs.iter().skip(skip).map(|r| r.total_secs).collect();
    if tail.is_empty() {
        return 0.0;
    }
    tail.iter().sum::<f64>() / tail.len() as f64
}

fn scenarios(rounds: usize) -> Vec<(&'static str, DynamicsSpec)> {
    let churn = ChurnSpec {
        events: vec![
            // one departure mid-round, one rejoin a few rounds later
            ChurnEvent { round: rounds / 3, device: 1, secs: 2.0, kind: ChurnKind::Leave },
            ChurnEvent { round: 2 * rounds / 3, device: 1, secs: 0.0, kind: ChurnKind::Join },
        ],
        leave_prob: 0.0,
        join_prob: 0.0,
    };
    let stragglers =
        StragglerSpec { prob: 0.1, law: SlowdownLaw::Fixed(4.0), drop_prob: 0.02 };
    vec![
        ("static", DynamicsSpec::default()),
        (
            "avail-0.8",
            DynamicsSpec {
                availability: AvailabilityModel::Bernoulli(0.8),
                ..Default::default()
            },
        ),
        ("churn", DynamicsSpec { churn: churn.clone(), ..Default::default() }),
        ("stragglers", DynamicsSpec { straggler: stragglers, ..Default::default() }),
        (
            "full-dynamic",
            DynamicsSpec {
                availability: AvailabilityModel::Bernoulli(0.8),
                churn,
                straggler: stragglers,
            },
        ),
    ]
}

/// One sweep over scheme × scenario: CSV-formatted summary rows (the
/// table the golden-trace suite pins), optionally printed as a table.
/// Every column is virtual-time-deterministic for a fixed seed — no
/// wallclock leaks in.
#[allow(clippy::too_many_arguments)]
pub fn sweep_rows(
    rounds: usize,
    m: usize,
    m_p: usize,
    k: usize,
    seed: u64,
    codec: Codec,
    threads: usize,
    print: bool,
) -> Vec<String> {
    let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
    let mut csv = Vec::new();
    for (scheme, sched) in [
        (Scheme::SdDist, SchedulerKind::Uniform),
        (Scheme::FaDist, SchedulerKind::Uniform),
        (Scheme::Parrot, SchedulerKind::TimeWindow(5)),
    ] {
        for (tag, dynamics) in scenarios(rounds) {
            let mut sim = VirtualSim::new(
                scheme,
                ClusterProfile::heterogeneous(k),
                WorkloadCost::femnist(),
                CommModel::femnist().with_codec(codec),
                sched,
                2,
                partition.clone(),
                1,
                seed,
            )
            .with_dynamics(dynamics)
            .with_threads(threads);
            let rs = run_virtual(&mut sim, rounds, m_p, seed ^ 0xDD);
            let t = mean_tail(&rs, rounds / 3);
            let util = rs.iter().map(|r| r.utilization()).sum::<f64>() / rs.len() as f64;
            let dropped: usize = rs.iter().map(|r| r.dropped_clients).sum();
            let wasted: f64 = rs.iter().map(|r| r.wasted_secs).sum();
            let leaves: usize = rs.iter().map(|r| r.departures).sum();
            let joins: usize = rs.iter().map(|r| r.joins).sum();
            if print {
                println!(
                    "{:<10} {:<14} {:>10.2} {:>7.1}% {:>9} {:>10.1} {:>7} {:>6}",
                    scheme.name(),
                    tag,
                    t,
                    100.0 * util,
                    dropped,
                    wasted,
                    leaves,
                    joins
                );
            }
            csv.push(format!(
                "{},{tag},{t:.3},{util:.4},{dropped},{wasted:.2},{leaves},{joins}",
                scheme.name()
            ));
        }
    }
    csv
}

/// The fixed-seed reduced-scale table `--smoke` prints and the
/// golden-trace regression suite pins against its committed snapshot.
/// `threads` sizes the sharded engine's worker pool; rows must be
/// byte-identical for every value (the determinism suite pins 1/2/8).
pub fn smoke_rows(seed: u64, threads: usize) -> Vec<String> {
    sweep_rows(6, 120, 24, 8, seed, Codec::None, threads, false)
}

pub fn dynamics(args: &Args) -> Result<()> {
    let smoke = args.flag("smoke");
    let rounds = args.usize_or("rounds", if smoke { 6 } else { 9 })?;
    let m = args.usize_or("clients", if smoke { 120 } else { 1000 })?;
    let m_p = args.usize_or("per-round", if smoke { 24 } else { 100 })?;
    let k = args.usize_or("devices", if smoke { 8 } else { 32 })?;
    let seed = args.u64_or("seed", 51)?;
    let threads = args.usize_or("threads", 1)?;
    // Upload codec (--compress): comm-byte/time columns book *encoded*
    // upload sizes, so the sweep reflects compression too.
    let codec = Codec::parse(args.get_or("compress", "none"))?;
    println!(
        "Dynamic scenarios — M={m}, M_p={m_p}, K={k}, R={rounds}, compress={} \
         (discrete-event engine{})",
        codec.name(),
        if smoke { ", smoke scale" } else { "" }
    );
    println!(
        "{:<10} {:<14} {:>10} {:>8} {:>9} {:>10} {:>7} {:>6}",
        "scheme", "scenario", "round(s)", "util", "dropped", "wasted(s)", "leaves", "joins"
    );
    let csv = sweep_rows(rounds, m, m_p, k, seed, codec, threads, true);
    println!("\n(expected: availability < 1 shrinks effective M_p; churn re-places the");
    println!(" departed device's tasks via the greedy step; stragglers stretch FA/SD");
    println!(" rounds more than Parrot's, whose scheduler re-learns the slow devices.)");
    if let Some(path) = args.get("trace") {
        // Re-run the richest cell (Parrot × full-dynamic) with tracing
        // on: churn instants, aborted tasks and straggler-stretched
        // spans all land in the timeline.
        let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
        let (_, dyn_spec) = scenarios(rounds).pop().expect("full-dynamic is the last scenario");
        let mut sim = VirtualSim::new(
            Scheme::Parrot,
            ClusterProfile::heterogeneous(k),
            WorkloadCost::femnist(),
            CommModel::femnist().with_codec(codec),
            SchedulerKind::TimeWindow(5),
            2,
            partition,
            1,
            seed,
        )
        .with_dynamics(dyn_spec)
        .with_threads(threads)
        .with_tracing();
        let rs = run_virtual(&mut sim, rounds, m_p, seed ^ 0xDD);
        let tracer = sim.tracer.take().expect("tracing was enabled");
        let reg = crate::simulation::registry_from_rounds(&rs);
        std::fs::write(path, crate::obs::chrome::render(&tracer, Some(&reg)))?;
        println!("[saved {path} (Chrome trace; open in Perfetto)]");
    }
    super::save_csv(
        args,
        "dynamics",
        "scheme,scenario,round_s,utilization,dropped,wasted_s,leaves,joins",
        &csv,
    )
}
