//! `parrot exp parscale` — the group-sharded parallel engine at
//! acceptance scale: 1000 clients × 32 devices, sweeping
//! {flat, groups:16, tree:4x4} × `--threads` {1, 2, 4, 8} on the
//! identical seed.
//!
//! Two things are measured, one is asserted:
//!
//! - **thread invariance (hard check)**: for every topology the
//!   per-round engine rows (virtual time, bytes, cross-WAN bytes,
//!   group aggregates, drops, waste) must be *byte-identical* across
//!   every swept thread count — the headline invariant of the sharded
//!   engine.  Any divergence fails the harness and prints the seed.
//! - **wall-clock speedup (reported)**: the engine-only wall seconds
//!   (`VirtualSim::engine_secs` — scheduler and row bookkeeping
//!   excluded) per thread count, and the speedup over `--threads 1`.
//!   On a multi-core host the full sweep asserts the grouped topology
//!   gains (>1×) at 8 threads; on a single-core host the parallel
//!   workers only interleave, so the assertion is skipped (and says
//!   so) — the invariance check is the part that must hold anywhere.
//!
//! `--smoke` (wired into `scripts/ci.sh`) shrinks the sweep to
//! {flat, groups:16} × threads {1, 2} and reports without the speedup
//! assertion.  Results land in `BENCH_parscale.json`; the committed
//! copy at the repo root records the reference host's numbers.

use crate::cluster::{ClusterProfile, Topology, WorkloadCost};
use crate::config::{Scheme, SchedulerKind};
use crate::data::{Partition, PartitionKind};
use crate::obs::chrome;
use crate::simulation::{registry_from_rounds, run_virtual, CommModel, VRound, VirtualSim};
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::{ensure, Result};

fn host_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One engine row per round: every virtual-time column the sharded
/// merge could plausibly perturb.  Byte-compared across thread counts.
fn row(spec: &str, r: &VRound) -> String {
    format!(
        "{spec},{},{:.9},{:.9},{:.9},{},{},{},{},{},{},{:.9}",
        r.round,
        r.total_secs,
        r.compute_secs,
        r.comm_secs,
        r.bytes,
        r.trips,
        r.cross_group_bytes,
        r.group_aggs,
        r.scheduled_clients,
        r.dropped_clients,
        r.wasted_secs
    )
}

/// Run one (topology, threads) cell; returns the per-round rows and
/// the engine-only wall seconds.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    spec: &str,
    topo: &Topology,
    partition: &Partition,
    m_p: usize,
    k: usize,
    rounds: usize,
    seed: u64,
    threads: usize,
) -> (Vec<String>, f64) {
    let cluster = ClusterProfile::heterogeneous(k).with_topology(topo.clone());
    let mut sim = VirtualSim::new(
        Scheme::Parrot,
        cluster,
        WorkloadCost::femnist(),
        CommModel::femnist(),
        SchedulerKind::Greedy,
        2,
        partition.clone(),
        1,
        seed,
    )
    .with_threads(threads)
    // parscale's whole point is the engine wall-clock per thread
    // count: inject the clock so engine_secs books real seconds.
    .with_wall_clock(crate::util::timer::wall_secs);
    let rs = run_virtual(&mut sim, rounds, m_p, seed ^ 0x70F0);
    (rs.iter().map(|r| row(spec, r)).collect(), sim.engine_secs)
}

/// One traced grouped smoke cell for the determinism suite
/// (`tests/determinism.rs`): run a `groups:4` Parrot sim — the grouped
/// plan always takes the sharded engine path — with tracing on, check
/// the expanded rows are well formed, and return the rendered Chrome
/// trace bytes (registry snapshot included).  The bytes must be
/// identical for every `threads` value on one seed.
pub fn smoke_trace(seed: u64, threads: usize) -> Result<String> {
    let topo = Topology::parse("groups:4")?;
    let partition = Partition::generate(PartitionKind::Natural, 200, 62, 100, seed);
    let cluster = ClusterProfile::heterogeneous(8).with_topology(topo);
    let mut sim = VirtualSim::new(
        Scheme::Parrot,
        cluster,
        WorkloadCost::femnist(),
        CommModel::femnist(),
        SchedulerKind::Greedy,
        2,
        partition,
        1,
        seed,
    )
    .with_threads(threads)
    .with_tracing();
    let rs = run_virtual(&mut sim, 3, 32, seed ^ 0x70F0);
    ensure!(!rs.is_empty(), "traced smoke cell produced no rounds");
    let tracer = sim.tracer.take().expect("tracing was enabled");
    ensure!(!tracer.is_empty(), "traced smoke cell recorded no events");
    let rows = chrome::expand(&tracer);
    chrome::check_well_formed(&rows)
        .map_err(|e| anyhow::anyhow!("malformed trace (--seed {seed:#x}): {e}"))?;
    Ok(chrome::render_events(&rows, Some(&registry_from_rounds(&rs))))
}

pub fn parscale(args: &Args) -> Result<()> {
    let smoke = args.flag("smoke");
    let m = args.usize_or("clients", 1000)?;
    let m_p = args.usize_or("per-round", if smoke { 50 } else { 100 })?;
    let k = args.usize_or("devices", 32)?;
    let rounds = args.usize_or("rounds", if smoke { 2 } else { 3 })?;
    let seed = args.u64_or("seed", 41)?;
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let topologies: &[&str] =
        if smoke { &["flat", "groups:16"] } else { &["flat", "groups:16", "tree:4x4"] };
    let hp = host_parallelism();
    println!(
        "Parallel sharded engine — M={m}, M_p={m_p}, K={k}, R={rounds}, \
         host parallelism {hp}{}",
        if smoke { " (smoke scale)" } else { "" }
    );
    println!(
        "{:<10} {:>7} {:>12} {:>9}  {}",
        "topology", "threads", "engine(s)", "speedup", "rows"
    );

    let mut topo_reports = Vec::new();
    let mut grouped_speedup_at_max = 1.0f64;
    for spec in topologies {
        let topo = Topology::parse(spec)?;
        let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
        let mut reference: Option<Vec<String>> = None;
        let mut secs_at = Vec::new();
        let mut speedups = Vec::new();
        for &t in thread_counts {
            let (rows, secs) = run_cell(spec, &topo, &partition, m_p, k, rounds, seed, t);
            if let Some(base) = reference.as_ref() {
                ensure!(
                    base == &rows,
                    "{spec}: rows diverged between --threads {} and --threads {t} — \
                     the sharded engine leaked thread-count dependence \
                     (replay with --seed {seed})",
                    thread_counts[0]
                );
            } else {
                reference = Some(rows);
            }
            let base_secs = secs_at.first().copied().unwrap_or(secs);
            let speedup = if secs > 0.0 { base_secs / secs } else { 1.0 };
            secs_at.push(secs);
            speedups.push(speedup);
            println!(
                "{:<10} {:>7} {:>12.4} {:>8.2}x  {}",
                spec,
                t,
                secs,
                speedup,
                if t == thread_counts[0] { "reference" } else { "identical" }
            );
        }
        if *spec == "groups:16" {
            grouped_speedup_at_max = *speedups.last().unwrap_or(&1.0);
        }
        let rows = reference.unwrap_or_default();
        ensure!(!rows.is_empty(), "{spec}: engine produced no rounds");
        topo_reports.push(
            Json::obj()
                .set("topology", *spec)
                .set("rows_identical", true)
                .set("engine_secs", secs_at)
                .set("speedup_vs_1", speedups)
                .set("rows", rows),
        );
    }

    if !smoke {
        if hp >= 2 {
            ensure!(
                grouped_speedup_at_max > 1.0,
                "groups:16 at {} threads must beat --threads 1 on a {hp}-way host: \
                 speedup {grouped_speedup_at_max:.2}x",
                thread_counts.last().unwrap()
            );
        } else {
            println!(
                "(single-core host: workers interleave, skipping the >1x speedup \
                 assertion; thread invariance checked above)"
            );
        }
    }
    println!(
        "\n(same seed, same rows at every thread count — the shard decomposition and"
    );
    println!(" merge order are fixed by the topology and seed, threads only size the");
    println!(" worker pool; speedup comes from running leaf-group shards in parallel.)");

    if let Some(path) = args.get("trace") {
        let bytes = smoke_trace(seed, *thread_counts.last().unwrap())?;
        std::fs::write(path, bytes)?;
        println!("[saved {path} (Chrome trace; open in Perfetto)]");
    }

    let json = Json::obj()
        .set("name", "parscale")
        .set("smoke", smoke)
        .set("clients", m)
        .set("per_round", m_p)
        .set("devices", k)
        .set("rounds", rounds)
        .set("seed", format!("{seed:#x}"))
        .set("host_parallelism", hp)
        .set("threads", thread_counts.to_vec())
        .set("topologies", Json::Arr(topo_reports));
    super::save_json(args, "BENCH_parscale", &json)
}
