//! `parrot exp asyncscale` — asynchronous buffered execution at
//! acceptance scale: 1000 clients × 32 devices under straggler
//! injection, sweeping (buffer, staleness bound, staleness law) against
//! the synchronous Parrot baseline on the identical selection stream.
//!
//! Two hard checks run inline (the harness fails loudly if either
//! breaks):
//!
//! - **degenerate pin**: `buffer == M_p`, `max_staleness == 0` must
//!   reproduce the synchronous Parrot timeline exactly — per-flush
//!   interval, bytes and trips equal to the sync per-round columns on
//!   the same seed;
//! - **work conservation**: at least one buffered configuration must
//!   strictly reduce the total makespan vs sync Parrot — the straggler
//!   no longer holds the whole cluster at a barrier.
//!
//! `--smoke` (wired into `scripts/ci.sh`) shrinks the run and adds the
//! sim-vs-deploy flush differential: the virtual engine's recorded
//! arrival sequence is replayed through the deploy-side
//! [`FlushLedger`] (the exact bookkeeping the streaming server runs),
//! and flush counts, per-staleness histograms, applied and
//! stale-dropped counters must all agree.

use crate::aggregation::StalenessWeight;
use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::config::{Scheme, SchedulerKind};
use crate::coordinator::asyncbuf::{FlushLedger, FlushPolicy};
use crate::data::{Partition, PartitionKind};
use crate::obs::Registry;
use crate::simulation::{
    run_async_detailed, run_virtual, AsyncSpec, CommModel, DynamicsSpec, SlowdownLaw,
    StragglerSpec, VRound, VirtualSim,
};
use crate::util::cli::Args;
use anyhow::{ensure, Result};

fn sim_for(scheme: Scheme, m: usize, k: usize, seed: u64, partition: &Partition) -> VirtualSim {
    VirtualSim::new(
        scheme,
        ClusterProfile::heterogeneous(k),
        WorkloadCost::femnist(),
        CommModel::femnist(),
        SchedulerKind::Greedy,
        2,
        partition.clone(),
        1,
        seed,
    )
    .with_dynamics(DynamicsSpec {
        straggler: StragglerSpec { prob: 0.15, law: SlowdownLaw::Fixed(6.0), drop_prob: 0.0 },
        ..Default::default()
    })
}

fn totals(rs: &[VRound]) -> (f64, u64, u64) {
    (
        rs.iter().map(|r| r.total_secs).sum(),
        rs.iter().map(|r| r.bytes).sum(),
        rs.iter().map(|r| r.trips).sum(),
    )
}

fn mean_staleness(rs: &[VRound]) -> f64 {
    let (mut weighted, mut n) = (0usize, 0usize);
    for r in rs {
        for (s, &cnt) in r.staleness_hist.iter().enumerate() {
            weighted += s * cnt;
            n += cnt;
        }
    }
    if n == 0 {
        0.0
    } else {
        weighted as f64 / n as f64
    }
}

/// The degenerate pin: one flush per round, every column equal.
fn ensure_degenerate_matches(sync: &[VRound], degenerate: &[VRound]) -> Result<()> {
    ensure!(
        sync.len() == degenerate.len(),
        "degenerate async produced {} flushes for {} sync rounds",
        degenerate.len(),
        sync.len()
    );
    for (s, a) in sync.iter().zip(degenerate) {
        ensure!(
            (s.total_secs - a.total_secs).abs() <= 1e-9 * s.total_secs.max(1.0),
            "round {}: sync {}s vs degenerate async {}s",
            s.round,
            s.total_secs,
            a.total_secs
        );
        ensure!(s.bytes == a.bytes, "round {}: bytes {} vs {}", s.round, s.bytes, a.bytes);
        ensure!(s.trips == a.trips, "round {}: trips {} vs {}", s.round, s.trips, a.trips);
        ensure!(a.stale_dropped == 0, "round {}: degenerate mode dropped updates", s.round);
    }
    Ok(())
}

pub fn asyncscale(args: &Args) -> Result<()> {
    if args.flag("smoke") {
        return smoke(args);
    }
    let m = args.usize_or("clients", 1000)?;
    let m_p = args.usize_or("per-round", 100)?;
    let k = args.usize_or("devices", 32)?;
    let rounds = args.usize_or("rounds", 8)?;
    let seed = args.u64_or("seed", 29)?;
    let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
    println!(
        "Async buffered execution — M={m}, M_p={m_p}, K={k}, R={rounds} cohorts, \
         stragglers 0.15:x6 on a heterogeneous cluster (vs sync Parrot)"
    );
    println!(
        "{:<26} {:>10} {:>8} {:>8} {:>9} {:>8} {:>9}",
        "config", "total(s)", "flushes", "applied", "stale-dr", "mean-s", "util"
    );
    let util = |rs: &[VRound]| {
        let u: f64 = rs.iter().map(|r| r.utilization()).sum();
        u / rs.len().max(1) as f64
    };

    let mut sync = sim_for(Scheme::Parrot, m, k, seed, &partition);
    let rs_sync = run_virtual(&mut sync, rounds, m_p, seed ^ 0xA5);
    let (sync_total, _, _) = totals(&rs_sync);
    println!(
        "{:<26} {:>10.2} {:>8} {:>8} {:>9} {:>8} {:>8.1}%",
        "sync parrot (baseline)",
        sync_total,
        rounds,
        rounds * m_p,
        "-",
        "-",
        100.0 * util(&rs_sync)
    );
    let mut csv = vec![format!("sync,,,{sync_total:.3},{rounds},{},0,0", rounds * m_p)];

    // Degenerate pin: buffer == M_p, S == 0 must equal sync exactly.
    let mut deg = sim_for(Scheme::Async, m, k, seed, &partition);
    deg.async_spec =
        AsyncSpec { buffer: 0, max_staleness: 0, weight: StalenessWeight::Const };
    let rs_deg = run_virtual(&mut deg, rounds, m_p, seed ^ 0xA5);
    ensure_degenerate_matches(&rs_sync, &rs_deg)?;
    let (deg_total, _, _) = totals(&rs_deg);
    println!(
        "{:<26} {:>10.2} {:>8} {:>8} {:>9} {:>8.2} {:>8.1}%  (== sync, pinned)",
        format!("async b={m_p} S=0 const"),
        deg_total,
        rs_deg.len(),
        rs_deg.iter().map(|r| r.flush_updates).sum::<usize>(),
        rs_deg.iter().map(|r| r.stale_dropped).sum::<usize>(),
        mean_staleness(&rs_deg),
        100.0 * util(&rs_deg)
    );

    let grid: [(usize, usize, StalenessWeight); 4] = [
        (m_p / 2, 2, StalenessWeight::Poly(0.5)),
        (m_p / 4, 3, StalenessWeight::Poly(0.5)),
        (m_p / 4, 3, StalenessWeight::Const),
        (m_p / 2, 4, StalenessWeight::Const),
    ];
    let mut best = f64::INFINITY;
    for (buffer, max_staleness, weight) in grid {
        let buffer = buffer.max(1);
        let mut sim = sim_for(Scheme::Async, m, k, seed, &partition);
        sim.async_spec = AsyncSpec { buffer, max_staleness, weight };
        let rs = run_virtual(&mut sim, rounds, m_p, seed ^ 0xA5);
        let (total, _, _) = totals(&rs);
        best = best.min(total);
        let applied: usize = rs.iter().map(|r| r.flush_updates).sum();
        let stale: usize = rs.iter().map(|r| r.stale_dropped).sum();
        println!(
            "{:<26} {:>10.2} {:>8} {:>8} {:>9} {:>8.2} {:>8.1}%",
            format!("async b={buffer} S={max_staleness} {}", weight.name()),
            total,
            rs.len(),
            applied,
            stale,
            mean_staleness(&rs),
            100.0 * util(&rs)
        );
        csv.push(format!(
            "async,{buffer},{max_staleness},{total:.3},{},{applied},{stale},{}",
            rs.len(),
            weight.name()
        ));
    }
    ensure!(
        best < sync_total,
        "no buffered configuration beat sync Parrot: best {best:.2}s vs {sync_total:.2}s"
    );
    println!(
        "\n(buffered async removes the round barrier: the straggler only delays its own"
    );
    println!(" flush, the other executors keep pulling cohorts inside the staleness window;");
    println!(" the degenerate configuration is pinned equal to the sync timeline.)");
    super::save_csv(
        args,
        "asyncscale",
        "config,buffer,max_staleness,total_s,flushes,applied,stale_dropped,weight",
        &csv,
    )
}

/// The `--smoke` differential (scripts/ci.sh): a small async run whose
/// engine-side flush counters must be reproduced by the deploy-side
/// [`FlushLedger`] replaying the identical arrival sequence, plus the
/// degenerate sync pin at smoke scale.
pub fn smoke(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 19)?;
    let m = args.usize_or("clients", 60)?;
    let rounds = args.usize_or("rounds", 5)?;
    let threads = args.usize_or("threads", 1)?;
    let _ = smoke_rows(seed, m, rounds, threads)?;
    if let Some(path) = args.get("trace") {
        // One traced async cell on the differential's knobs: the flush
        // chains, staleness decisions and admissions land as spans.
        let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
        let mut sim =
            sim_for(Scheme::Async, m, 4, seed, &partition).with_threads(threads).with_tracing();
        sim.async_spec =
            AsyncSpec { buffer: 8, max_staleness: 1, weight: StalenessWeight::Poly(0.5) };
        let (rs, _) = run_async_detailed(&mut sim, rounds, 16, seed ^ 0x55);
        let tracer = sim.tracer.take().expect("tracing was enabled");
        let reg = crate::simulation::registry_from_rounds(&rs);
        std::fs::write(path, crate::obs::chrome::render(&tracer, Some(&reg)))?;
        println!("[saved {path} (Chrome trace; open in Perfetto)]");
    }
    Ok(())
}

/// The smoke differential proper, returning its deterministic summary
/// rows (`config,buffer,max_staleness,total_s,flushes,applied,
/// stale_dropped,hist`) — every column is virtual-time, so a fixed
/// seed pins the table exactly; the golden-trace regression suite
/// compares these against a committed snapshot.  All inline agreement
/// checks (ledger differential + degenerate sync pin) still run.
/// `threads` sizes the engine's worker pool; the async path is
/// inherently single-streamed, so only the sync pin ever shards — the
/// rows must be byte-identical for every value regardless.
pub fn smoke_rows(seed: u64, m: usize, rounds: usize, threads: usize) -> Result<Vec<String>> {
    let m_p = 16usize;
    let k = 4usize;
    let (buffer, max_staleness) = (8usize, 1usize);
    let weight = StalenessWeight::Poly(0.5);
    let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);

    // (1) virtual async run, arrival sequence recorded by the engine.
    let mut sim = sim_for(Scheme::Async, m, k, seed, &partition).with_threads(threads);
    sim.async_spec = AsyncSpec { buffer, max_staleness, weight };
    let (rs, outcome) = run_async_detailed(&mut sim, rounds, m_p, seed ^ 0x55);

    // (2) deploy-side replay: the same arrivals through the ledger the
    // streaming server runs.
    let mut ledger = FlushLedger::new(FlushPolicy { buffer, max_staleness, weight });
    for &born in &outcome.arrivals {
        let _ = ledger.on_update(born);
    }
    let _ = ledger.finalize();

    let eng_flushes = rs
        .iter()
        .filter(|r| r.flush_updates + r.stale_dropped > 0)
        .count();
    let eng_applied: usize = rs.iter().map(|r| r.flush_updates).sum();
    let eng_stale: usize = rs.iter().map(|r| r.stale_dropped).sum();
    let mut eng_hist = vec![0usize; max_staleness + 1];
    for r in &rs {
        for (s, &n) in r.staleness_hist.iter().enumerate() {
            eng_hist[s] += n;
        }
    }
    ensure!(
        ledger.flushes == eng_flushes,
        "flush count mismatch: engine {eng_flushes} vs ledger {}",
        ledger.flushes
    );
    ensure!(
        ledger.applied == eng_applied,
        "applied mismatch: engine {eng_applied} vs ledger {}",
        ledger.applied
    );
    ensure!(
        ledger.stale_dropped == eng_stale,
        "stale-drop mismatch: engine {eng_stale} vs ledger {}",
        ledger.stale_dropped
    );
    ensure!(
        ledger.staleness_hist == eng_hist,
        "staleness histogram mismatch: engine {eng_hist:?} vs ledger {:?}",
        ledger.staleness_hist
    );
    ensure!(eng_applied + eng_stale == outcome.completed, "arrivals lost");

    // (2b) Counter parity as rendered bytes: both sides publish the
    // same metric names into an obs Registry — the engine side
    // incrementally per flush interval, the ledger side from its run
    // totals in a different insertion order — and the rendered JSON
    // must be byte-equal (the registry's render-time name sort is what
    // makes cross-path parity a byte comparison).
    let mut eng_reg = Registry::new();
    for r in &rs {
        if r.flush_updates + r.stale_dropped > 0 {
            eng_reg.inc("async.flushes");
        }
        eng_reg.add("async.applied", r.flush_updates as u64);
        eng_reg.add("async.stale_dropped", r.stale_dropped as u64);
        for (s, &n) in r.staleness_hist.iter().enumerate() {
            for _ in 0..n {
                eng_reg.observe("async.staleness", s as u64);
            }
        }
    }
    let mut led_reg = Registry::new();
    for (s, &n) in ledger.staleness_hist.iter().enumerate() {
        for _ in 0..n {
            led_reg.observe("async.staleness", s as u64);
        }
    }
    led_reg.add("async.stale_dropped", ledger.stale_dropped as u64);
    led_reg.add("async.flushes", ledger.flushes as u64);
    led_reg.add("async.applied", ledger.applied as u64);
    ensure!(
        eng_reg.to_json().render() == led_reg.to_json().render(),
        "rendered metrics registries diverged between engine and ledger:\n  engine: {}\n  ledger: {}",
        eng_reg.to_json().render(),
        led_reg.to_json().render()
    );

    // (3) degenerate pin at smoke scale.
    let mut sync = sim_for(Scheme::Parrot, m, k, seed, &partition).with_threads(threads);
    let rs_sync = run_virtual(&mut sync, rounds, m_p, seed ^ 0x55);
    let mut deg = sim_for(Scheme::Async, m, k, seed, &partition).with_threads(threads);
    deg.async_spec =
        AsyncSpec { buffer: 0, max_staleness: 0, weight: StalenessWeight::Const };
    let rs_deg = run_virtual(&mut deg, rounds, m_p, seed ^ 0x55);
    ensure_degenerate_matches(&rs_sync, &rs_deg)?;

    println!(
        "asyncscale smoke: sim/deploy agree on {} flushes ({} applied, {} stale-dropped, \
         hist {:?}) incl. rendered registry parity; degenerate pin == sync over {} rounds — OK",
        ledger.flushes, ledger.applied, ledger.stale_dropped, ledger.staleness_hist, rounds
    );
    let hist = eng_hist
        .iter()
        .map(|n| n.to_string())
        .collect::<Vec<_>>()
        .join("|");
    let (sync_total, sync_bytes, sync_trips) = totals(&rs_sync);
    let (deg_total, _, _) = totals(&rs_deg);
    let (buf_total, buf_bytes, buf_trips) = totals(&rs);
    Ok(vec![
        format!(
            "sync,,,{sync_total:.6},{},{},0,,{sync_bytes},{sync_trips}",
            rs_sync.len(),
            rs_sync.iter().map(|r| r.scheduled_clients).sum::<usize>()
        ),
        format!(
            "degenerate,{m_p},0,{deg_total:.6},{},{},0,,,",
            rs_deg.len(),
            rs_deg.iter().map(|r| r.flush_updates).sum::<usize>()
        ),
        format!(
            "buffered,{buffer},{max_staleness},{buf_total:.6},{eng_flushes},{eng_applied},\
             {eng_stale},{hist},{buf_bytes},{buf_trips}"
        ),
    ])
}
