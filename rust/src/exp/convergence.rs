//! Fig. 4 — real-compute convergence of the six FL algorithms, plus the
//! per-round running-time comparison (Fig. 4d).
//!
//! Runs genuine FL training through PJRT: stateless algorithms
//! (Fig. 4a), special-params algorithms (Fig. 4b), stateful algorithms
//! (Fig. 4c).  Parrot's hierarchical path is additionally checked
//! against the flat FA path (the SD-reference of the paper's plots) for
//! identical numerics by the integration tests; here we record accuracy
//! curves and round times.

use crate::algorithms::ALL_ALGORITHMS;
use crate::config::RunConfig;
use crate::coordinator::run_simulation;
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::Result;

pub fn fig4(args: &Args) -> Result<()> {
    let rounds = args.usize_or("rounds", 12)?;
    let clients = args.usize_or("clients", 60)?;
    let per_round = args.usize_or("per-round", 12)?;
    let devices = args.usize_or("devices", 2)?;
    println!(
        "Fig. 4 — algorithm convergence on real compute \
         (M={clients}, M_p={per_round}, K={devices}, R={rounds})"
    );

    let mut curves = Vec::new();
    let mut csv = Vec::new();
    let mut time_rows = Vec::new();
    for algo in ALL_ALGORITHMS {
        let cfg = RunConfig {
            algorithm: algo.into(),
            n_clients: clients,
            clients_per_round: per_round,
            n_devices: devices,
            rounds,
            mean_client_size: 40,
            eval_every: 2,
            eval_batches: 8,
            mu: 0.01,
            seed: 777,
            warmup_rounds: 1,
            cluster: crate::cluster::ClusterProfile::homogeneous(devices),
            artifact_dir: args.get_or("artifacts", "artifacts").to_string(),
            state_dir: std::env::temp_dir()
                .join(format!("parrot_fig4_{}", std::process::id()))
                .to_string_lossy()
                .into_owned(),
            ..Default::default()
        };
        let summary = run_simulation(cfg)?;
        let accs: Vec<(usize, f64)> = summary
            .metrics
            .rounds
            .iter()
            .filter_map(|r| r.eval_acc.map(|a| (r.round, a)))
            .collect();
        let mean_round = summary.metrics.mean_round_secs_after(1);
        let last_acc = accs.last().map(|x| x.1).unwrap_or(f64::NAN);
        println!(
            "{:<10} final-acc {:.3}  curve {:?}  mean-round {:.2}s",
            algo,
            last_acc,
            accs.iter().map(|(_, a)| (a * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
            mean_round
        );
        for (r, a) in &accs {
            csv.push(format!("{algo},{r},{a:.4}"));
        }
        time_rows.push((algo, mean_round));
        curves.push((algo.to_string(), accs, mean_round));
    }

    println!("\nFig. 4(d) — mean running time per round (s):");
    for (algo, t) in &time_rows {
        println!("{algo:<10} {t:.2}");
    }

    super::save_csv(args, "fig4_accuracy", "algorithm,round,accuracy", &csv)?;
    super::save_json(
        args,
        "fig4",
        &Json::obj().set(
            "algorithms",
            Json::Arr(
                curves
                    .into_iter()
                    .map(|(algo, accs, t)| {
                        Json::obj()
                            .set("algorithm", algo)
                            .set("mean_round_secs", t)
                            .set(
                                "accuracy",
                                Json::Arr(
                                    accs.into_iter()
                                        .map(|(r, a)| {
                                            Json::obj().set("round", r).set("acc", a)
                                        })
                                        .collect(),
                                ),
                            )
                    })
                    .collect(),
            ),
        ),
    )
}
