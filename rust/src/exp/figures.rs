//! Figure harnesses on the virtual-time engine (Figs. 5–11).
//!
//! Each prints the series the paper plots and writes CSV/JSON under
//! `results/`.  Scale parameters default to the paper's but are
//! overridable (e.g. `--rounds 20 --devices 4,8,16,32`).

use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::config::{Scheme, SchedulerKind};
use crate::data::{Partition, PartitionKind};
use crate::simulation::{run_virtual, CommModel, VRound, VirtualSim};
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::Result;

fn mean_tail(rs: &[VRound], skip: usize) -> f64 {
    let tail: Vec<f64> = rs.iter().skip(skip).map(|r| r.total_secs).collect();
    if tail.is_empty() {
        return 0.0;
    }
    tail.iter().sum::<f64>() / tail.len() as f64
}

fn dataset_partition(name: &str, m: usize, seed: u64) -> Partition {
    let kind = match name {
        "imagenet" => PartitionKind::Dirichlet(0.1),
        "imagenet_b" => PartitionKind::QuantitySkew(5.0),
        _ => PartitionKind::Natural,
    };
    Partition::generate(kind, m, 62, 100, seed)
}

#[allow(clippy::too_many_arguments)]
fn sim_for(
    dataset: &str,
    scheme: Scheme,
    cluster: ClusterProfile,
    sched: SchedulerKind,
    m: usize,
    epochs: usize,
    seed: u64,
) -> VirtualSim {
    VirtualSim::new(
        scheme,
        cluster,
        WorkloadCost::by_name(dataset.trim_end_matches("_b")).unwrap(),
        CommModel::by_name(dataset),
        sched,
        2,
        dataset_partition(dataset, m, seed),
        epochs,
        seed,
    )
    // Fig. 8 reports real scheduling overhead: this harness consumes
    // wallclock, so it injects the clock the engine never reads itself.
    .with_wall_clock(crate::util::timer::wall_secs)
}

/// Fig. 5 — round time of frameworks (= schemes) × device counts × datasets.
pub fn fig5(args: &Args) -> Result<()> {
    let rounds = args.usize_or("rounds", 20)?;
    let devices = args.usize_list_or("devices", &[4, 8, 16, 32])?;
    let m_p = args.usize_or("per-round", 100)?;
    println!("Fig. 5 — mean round time (s) by framework scheme and #devices (M_p={m_p})");
    let mut csv = Vec::new();
    for dataset in ["femnist", "imagenet", "reddit"] {
        println!("\n[{dataset}]");
        println!(
            "{:<8} {:>14} {:>16} {:>14} {:>10}",
            "K", "FedScale(FA)", "Flower(FA+pull)", "FedML(SD)", "Parrot"
        );
        for &k in &devices {
            let mut row = vec![format!("{dataset}"), k.to_string()];
            let mut cells = Vec::new();
            for (scheme, sched) in [
                (Scheme::FaDist, SchedulerKind::Uniform),   // FedScale
                (Scheme::FaDist, SchedulerKind::Uniform),   // Flower (same scheme class)
                (Scheme::SdDist, SchedulerKind::Uniform),   // FedML SD (Mp devices)
                (Scheme::Parrot, SchedulerKind::Greedy),
            ] {
                let mut sim = sim_for(
                    dataset,
                    scheme,
                    ClusterProfile::homogeneous(k),
                    sched,
                    1000,
                    1,
                    41 + k as u64,
                );
                let rs = run_virtual(&mut sim, rounds, m_p, 13);
                let t = mean_tail(&rs, rounds / 4);
                cells.push(t);
                row.push(format!("{t:.2}"));
            }
            println!(
                "{:<8} {:>14.2} {:>16.2} {:>14.2} {:>10.2}",
                k, cells[0], cells[1], cells[2], cells[3]
            );
            csv.push(row.join(","));
        }
    }
    println!("\n(expected shape: Parrot fastest at every K; FA pays per-task comm; SD's");
    println!(" compute is parallel over M_p executors but pays M_p trips + stragglers.)");
    super::save_csv(args, "fig5", "dataset,k,fedscale,flower,fedml_sd,parrot", &csv)
}

/// Fig. 6 — workload model fit: per-device scatter + fitted line.
pub fn fig6(args: &Args) -> Result<()> {
    let rounds = args.usize_or("rounds", 12)?;
    println!("Fig. 6 — workload estimation quality (t_k, b_k fits vs samples)");
    let mut csv = Vec::new();
    for (tag, cluster, dataset) in [
        ("homoA", ClusterProfile::homogeneous(8), "femnist"),
        ("heteA", ClusterProfile::heterogeneous(8), "femnist"),
        ("heteA-imagenet", ClusterProfile::heterogeneous(8), "imagenet"),
        ("clusterC", ClusterProfile::cluster_c(8), "femnist"),
    ] {
        let mut sim = sim_for(
            dataset,
            Scheme::Parrot,
            cluster,
            SchedulerKind::Greedy,
            500,
            1,
            61,
        );
        let rs = run_virtual(&mut sim, rounds, 100, 19);
        let est = sim.scheduler.estimates(rounds);
        println!("\n[{tag}] per-device fitted models (first 4 devices):");
        println!("{:<6} {:>12} {:>10} {:>8} {:>8}", "dev", "t_k (ms/sample)", "b_k (s)", "r2", "points");
        for (d, e) in est.iter().take(4).enumerate() {
            println!(
                "{:<6} {:>12.3} {:>10.3} {:>8.3} {:>8}",
                d,
                e.t_sample * 1e3,
                e.b,
                e.r2,
                e.n_points
            );
            csv.push(format!(
                "{tag},{d},{:.6},{:.4},{:.4},{}",
                e.t_sample, e.b, e.r2, e.n_points
            ));
        }
        let final_err = rs.iter().rev().find_map(|r| r.est_err).unwrap_or(f64::NAN);
        println!("estimation MAPE (last modeled round): {:.1}%", 100.0 * final_err);
    }
    super::save_csv(args, "fig6", "config,device,t_sample,b,r2,points", &csv)
}

/// Fig. 7 — round time vs number of devices (w/ and w/o scheduling).
pub fn fig7(args: &Args) -> Result<()> {
    let rounds = args.usize_or("rounds", 20)?;
    let devices = args.usize_list_or("devices", &[4, 8, 16, 32])?;
    println!("Fig. 7 — Parrot round time vs #devices (M_p=100)");
    let mut csv = Vec::new();
    for dataset in ["femnist", "imagenet"] {
        println!("\n[{dataset}]");
        println!("{:<6} {:>12} {:>14} {:>10}", "K", "w/ sched", "w/o sched", "speedup");
        for &k in &devices {
            let run = |sched| {
                let mut sim = sim_for(
                    dataset,
                    Scheme::Parrot,
                    ClusterProfile::homogeneous(k),
                    sched,
                    1000,
                    1,
                    71,
                );
                mean_tail(&run_virtual(&mut sim, rounds, 100, 23), rounds / 4)
            };
            let with = run(SchedulerKind::Greedy);
            let without = run(SchedulerKind::Uniform);
            println!(
                "{:<6} {:>12.2} {:>14.2} {:>9.2}x",
                k,
                with,
                without,
                without / with
            );
            csv.push(format!("{dataset},{k},{with:.3},{without:.3}"));
        }
    }
    super::save_csv(args, "fig7", "dataset,k,with_sched,without_sched", &csv)
}

/// Fig. 8 — workload-estimation + scheduling wallclock vs #devices.
pub fn fig8(args: &Args) -> Result<()> {
    let rounds = args.usize_or("rounds", 30)?;
    let devices = args.usize_list_or("devices", &[4, 8, 16, 32])?;
    println!("Fig. 8 — scheduler overhead per round (real wallclock, ms)");
    println!("{:<6} {:>16} {:>22}", "K", "sched (ms)", "vs round time (%)");
    let mut csv = Vec::new();
    for &k in &devices {
        let mut sim = sim_for(
            "femnist",
            Scheme::Parrot,
            ClusterProfile::homogeneous(k),
            SchedulerKind::Greedy,
            1000,
            1,
            81,
        );
        let rs = run_virtual(&mut sim, rounds, 100, 29);
        let sched_ms: f64 =
            rs.iter().map(|r| r.sched_secs).sum::<f64>() / rs.len() as f64 * 1e3;
        let round_s = mean_tail(&rs, rounds / 4);
        println!(
            "{:<6} {:>16.3} {:>21.4}%",
            k,
            sched_ms,
            100.0 * sched_ms / 1e3 / round_s
        );
        csv.push(format!("{k},{sched_ms:.4},{round_s:.3}"));
    }
    println!("(scheduling cost grows ~linearly in K and stays ≪ the round time)");
    super::save_csv(args, "fig8", "k,sched_ms,round_s", &csv)
}

/// Fig. 9 — round time under different hardware configurations.
pub fn fig9(args: &Args) -> Result<()> {
    let rounds = args.usize_or("rounds", 24)?;
    println!("Fig. 9 — round time by hardware config (K=8, M_p=100)");
    println!(
        "{:<10} {:<16} {:>12} {:>14} {:>10}",
        "dataset", "config", "w/ sched", "w/o sched", "speedup"
    );
    let mut csv = Vec::new();
    for dataset in ["femnist", "imagenet"] {
        for (tag, cluster) in [
            ("homo", ClusterProfile::homogeneous(8)),
            ("hete", ClusterProfile::heterogeneous(8)),
            ("dyn", ClusterProfile::dynamic(8, 25.0)),
            ("clusterC", ClusterProfile::cluster_c(8)),
        ] {
            let run = |sched| {
                let mut sim =
                    sim_for(dataset, Scheme::Parrot, cluster.clone(), sched, 1000, 1, 91);
                mean_tail(&run_virtual(&mut sim, rounds, 100, 31), rounds / 3)
            };
            let with = run(SchedulerKind::TimeWindow(5));
            let without = run(SchedulerKind::Uniform);
            println!(
                "{:<10} {:<16} {:>12.2} {:>14.2} {:>9.2}x",
                dataset,
                tag,
                with,
                without,
                without / with
            );
            csv.push(format!("{dataset},{tag},{with:.3},{without:.3}"));
        }
    }
    super::save_csv(args, "fig9", "dataset,config,with_sched,without_sched", &csv)
}

/// Fig. 10 — round time vs number of concurrent clients (100 vs 1000).
pub fn fig10(args: &Args) -> Result<()> {
    let rounds = args.usize_or("rounds", 16)?;
    println!("Fig. 10 — round time vs concurrent clients (K=8)");
    println!(
        "{:<10} {:>8} {:>12} {:>14} {:>10}",
        "dataset", "M_p", "w/ sched", "w/o sched", "speedup"
    );
    let mut csv = Vec::new();
    for dataset in ["femnist", "imagenet"] {
        for m_p in [100usize, 1000] {
            let run = |sched| {
                let mut sim = sim_for(
                    dataset,
                    Scheme::Parrot,
                    ClusterProfile::heterogeneous(8),
                    sched,
                    10_000,
                    1,
                    101,
                );
                mean_tail(&run_virtual(&mut sim, rounds, m_p, 37), rounds / 4)
            };
            let with = run(SchedulerKind::Greedy);
            let without = run(SchedulerKind::Uniform);
            println!(
                "{:<10} {:>8} {:>12.2} {:>14.2} {:>9.2}x",
                dataset,
                m_p,
                with,
                without,
                without / with
            );
            csv.push(format!("{dataset},{m_p},{with:.3},{without:.3}"));
        }
    }
    super::save_csv(args, "fig10", "dataset,mp,with_sched,without_sched", &csv)
}

/// Fig. 11 — estimation error + round time in dynamic environments.
pub fn fig11(args: &Args) -> Result<()> {
    let rounds = args.usize_or("rounds", 60)?;
    println!("Fig. 11 — dynamic environment: full-history vs Time-Window vs none");
    let mk = |sched| {
        sim_for(
            "femnist",
            Scheme::Parrot,
            ClusterProfile::dynamic(8, 25.0),
            sched,
            500,
            1,
            111,
        )
    };
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (tag, sched) in [
        ("all-history", SchedulerKind::Greedy),
        ("time-window(3)", SchedulerKind::TimeWindow(3)),
        ("no-sched", SchedulerKind::Uniform),
    ] {
        let mut sim = mk(sched);
        let rs = run_virtual(&mut sim, rounds, 100, 43);
        let t = mean_tail(&rs, 20);
        let errs: Vec<f64> = rs.iter().skip(20).filter_map(|r| r.est_err).collect();
        let err = if errs.is_empty() {
            f64::NAN
        } else {
            errs.iter().sum::<f64>() / errs.len() as f64
        };
        rows.push((tag, t, err));
        csv.push(format!("{tag},{t:.3},{err:.4}"));
    }
    println!("{:<16} {:>14} {:>18}", "scheduler", "round time (s)", "est. MAPE (%)");
    for (tag, t, err) in &rows {
        println!(
            "{:<16} {:>14.2} {:>17.1}%",
            tag,
            t,
            if err.is_nan() { f64::NAN } else { 100.0 * err }
        );
    }
    println!("(expected: time-window ≈ best time & lowest error; all-history mis-estimates");
    println!(" under the cos-law dynamics; no-sched is slowest)");
    super::save_json(
        args,
        "fig11",
        &Json::obj()
            .set("rounds", rounds)
            .set(
                "series",
                Json::Arr(
                    rows.iter()
                        .map(|(tag, t, err)| {
                            Json::obj()
                                .set("scheduler", *tag)
                                .set("round_secs", *t)
                                .set("est_mape", *err)
                        })
                        .collect(),
                ),
            ),
    )?;
    super::save_csv(args, "fig11", "scheduler,round_s,mape", &csv)
}
