//! Update-compression sweep (`parrot exp compression`): codec × scheme
//! at paper scale (1000 clients, 32 devices) on the discrete-event
//! engine, plus measured encoded sizes and reconstruction error on a
//! synthetic model.
//!
//! Two tables:
//! 1. **Codec microbench** — for a synthetic ParamSet the measured
//!    encoded bytes, compression ratio vs raw f32, the measured max
//!    reconstruction error, and the codec's documented worst-case bound
//!    (the accuracy-error column: how far aggregated updates can drift).
//! 2. **Scheme sweep** — steady-state round seconds and total comm
//!    bytes for SD/FA/Parrot under each codec; the engine books
//!    *encoded* upload sizes, so the byte column is the wire truth.

use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::compress::{self, Codec};
use crate::config::{Scheme, SchedulerKind};
use crate::data::{Partition, PartitionKind};
use crate::model::ParamSet;
use crate::simulation::{run_virtual, CommModel, VirtualSim};
use crate::util::cli::Args;
use anyhow::Result;

fn codecs() -> Vec<Codec> {
    vec![Codec::None, Codec::Fp16, Codec::QInt8, Codec::TopK(0.1)]
}

/// A model-shaped ParamSet standing in for the real update tensors.
fn synthetic_params(seed: u64) -> ParamSet {
    ParamSet::init_he(
        &[vec![256, 128], vec![128], vec![128, 62], vec![62]],
        seed,
    )
}

pub fn compression(args: &Args) -> Result<()> {
    let rounds = args.usize_or("rounds", 6)?;
    let m = args.usize_or("clients", 1000)?;
    let m_p = args.usize_or("per-round", 100)?;
    let k = args.usize_or("devices", 32)?;
    let seed = args.u64_or("seed", 77)?;

    // ---- 1) measured encoded sizes + reconstruction error ----------
    let params = synthetic_params(seed);
    let raw_bytes: usize = params.tensors.iter().map(|t| t.len() * 4).sum();
    println!("Codec microbench — synthetic model, {} params", params.numel());
    println!(
        "{:<10} {:>12} {:>8} {:>13} {:>13}",
        "codec", "enc bytes", "ratio", "max err", "doc bound"
    );
    let mut micro_csv = Vec::new();
    for codec in codecs() {
        let mut enc_bytes = 0usize;
        let mut max_err = 0.0f64;
        let mut bound = 0.0f64;
        for t in &params.tensors {
            let mut e = crate::util::codec::Encoder::new();
            compress::encode_f32s(&mut e, t, codec)?;
            let buf = e.finish();
            enc_bytes += buf.len();
            let back =
                compress::decode_f32s(&mut crate::util::codec::Decoder::new(&buf))?;
            for (a, b) in t.iter().zip(&back) {
                max_err = max_err.max((*a as f64 - *b as f64).abs());
            }
            bound = bound.max(codec.bound(t));
        }
        let ratio = raw_bytes as f64 / enc_bytes as f64;
        println!(
            "{:<10} {:>12} {:>7.2}x {:>13.3e} {:>13.3e}",
            codec.name(),
            enc_bytes,
            ratio,
            max_err,
            bound
        );
        micro_csv.push(format!(
            "{},{enc_bytes},{ratio:.4},{max_err:.6e},{bound:.6e}",
            codec.name()
        ));
    }
    super::save_csv(
        args,
        "compression_codecs",
        "codec,encoded_bytes,ratio,max_err,doc_bound",
        &micro_csv,
    )?;

    // ---- 2) scheme × codec sweep on the engine ---------------------
    println!(
        "\nScheme sweep — M={m}, M_p={m_p}, K={k}, R={rounds} (encoded bytes booked)"
    );
    println!(
        "{:<10} {:<10} {:>10} {:>12} {:>8}",
        "scheme", "codec", "round(s)", "comm (MB)", "vs raw"
    );
    let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
    let mut csv = Vec::new();
    for (scheme, sched) in [
        (Scheme::SdDist, SchedulerKind::Uniform),
        (Scheme::FaDist, SchedulerKind::Uniform),
        (Scheme::Parrot, SchedulerKind::Greedy),
    ] {
        let mut raw_mb = 0.0f64;
        for codec in codecs() {
            let mut sim = VirtualSim::new(
                scheme,
                ClusterProfile::heterogeneous(k),
                WorkloadCost::femnist(),
                CommModel::femnist().with_codec(codec),
                sched,
                2,
                partition.clone(),
                1,
                seed,
            );
            let rs = run_virtual(&mut sim, rounds, m_p, seed ^ 0xC0);
            let skip = rounds / 3;
            let t = rs.iter().skip(skip).map(|r| r.total_secs).sum::<f64>()
                / (rounds - skip).max(1) as f64;
            let mb = rs.iter().map(|r| r.bytes).sum::<u64>() as f64 / (1 << 20) as f64;
            if codec == Codec::None {
                raw_mb = mb;
            }
            let rel = if raw_mb > 0.0 { mb / raw_mb } else { 1.0 };
            println!(
                "{:<10} {:<10} {:>10.2} {:>12.1} {:>7.2}x",
                scheme.name(),
                codec.name(),
                t,
                mb,
                rel
            );
            csv.push(format!(
                "{},{},{t:.3},{mb:.2},{rel:.4}",
                scheme.name(),
                codec.name()
            ));
        }
    }
    println!("\n(broadcast stays raw f32; uploads ship the codec's encoded size —");
    println!(" qint8 and topk:0.1 cut the s_a·K upload term ~4x and ~5x.)");
    super::save_csv(
        args,
        "compression",
        "scheme,codec,round_s,comm_mb,vs_raw",
        &csv,
    )
}
