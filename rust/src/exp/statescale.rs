//! `parrot exp statescale` — the distributed client-state store at
//! acceptance scale: 1000 stateful clients (SCAFFOLD-style per-client
//! state) × per-worker cache budget × shard count, sharded
//! write-back + plan-driven prefetch + state-affinity scheduling
//! against the seed's local-only write-through baseline.
//!
//! Reported per configuration: steady round time, peak cache-resident
//! bytes (the O(s_d·K) RAM term), remote-fetch bytes, disk traffic,
//! avoided writes, and shard-handoff bytes.  Two hard checks run
//! inline (the harness fails loudly if either breaks):
//!
//! - **engine == store**: the discrete-event engine's independently
//!   booked `StateLoad`/`StateFlush` byte columns must equal the
//!   store's own [`StoreMetrics`] counters on identical seeds;
//! - **domination**: at equal budget the sharded store must strictly
//!   beat the baseline on peak cache bytes at (near-)equal makespan,
//!   or beat it on makespan outright.
//!
//! `--smoke` (wired into `scripts/ci.sh`) shrinks the grid to
//! 50 clients / 2 shards / write-back on and adds the sim-vs-deploy
//! differential: the same access sequence drives the virtual
//! [`SimStore`] and a cluster of real [`StateManager`]s (the store the
//! deployed workers run), and every shared counter must agree.

use crate::cluster::{ClusterProfile, WorkloadCost};
use crate::config::{Scheme, SchedulerKind};
use crate::data::{Partition, PartitionKind};
use crate::simulation::{run_virtual, CommModel, VirtualSim};
use crate::state::StateManager;
use crate::statestore::{SimStore, SimStoreCfg};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

struct RunOut {
    round_secs: f64,
    peak_cache: u64,
    remote_mb: f64,
    disk_writes: u64,
    avoided: u64,
    transfer: u64,
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    m: usize,
    m_p: usize,
    k: usize,
    rounds: usize,
    seed: u64,
    s_d: u64,
    budget_states: usize,
    n_shards: usize,
    affinity: u32,
) -> Result<RunOut> {
    let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
    let cluster = ClusterProfile::heterogeneous(k);
    let sharded = n_shards > 0;
    let cfg = SimStoreCfg::new(k, n_shards, s_d, budget_states * s_d as usize)
        .write_back(sharded)
        .network(cluster.bandwidth, cluster.latency);
    let sched = if sharded && affinity > 0 {
        SchedulerKind::StateAffinity { window: 0, weight_pct: affinity }
    } else {
        SchedulerKind::Greedy
    };
    let mut sim = VirtualSim::new(
        Scheme::Parrot,
        cluster,
        WorkloadCost::femnist(),
        CommModel::femnist(),
        sched,
        2,
        partition,
        1,
        seed,
    )
    .with_state_store(SimStore::new(cfg), sharded);
    let rs = run_virtual(&mut sim, rounds, m_p, seed ^ 0x57A7);
    let round_secs = rs.iter().map(|r| r.total_secs).sum::<f64>() / rs.len().max(1) as f64;
    let engine_bytes: u64 = rs.iter().map(|r| r.state_bytes).sum();
    let transfer: u64 = rs.iter().map(|r| r.shard_transfer_bytes).sum();
    let metrics = sim.state.as_ref().expect("store attached").store.metrics;
    ensure!(
        engine_bytes + transfer == metrics.total_bytes(),
        "engine state bytes {} + transfer {} != store counters {} (shards={n_shards}, \
         budget={budget_states})",
        engine_bytes,
        transfer,
        metrics.total_bytes()
    );
    Ok(RunOut {
        round_secs,
        peak_cache: metrics.peak_cache_bytes,
        remote_mb: metrics.remote_bytes as f64 / (1 << 20) as f64,
        disk_writes: metrics.disk_writes,
        avoided: metrics.avoided_writes,
        transfer,
    })
}

pub fn statescale(args: &Args) -> Result<()> {
    if args.flag("smoke") {
        return smoke(args);
    }
    let m = args.usize_or("clients", 1000)?;
    let m_p = args.usize_or("per-round", 100)?;
    let k = args.usize_or("devices", 32)?;
    let rounds = args.usize_or("rounds", 8)?;
    let seed = args.u64_or("seed", 33)?;
    // SCAFFOLD control variate for the repo's model is ~164 KB; default
    // a round 256 KB so byte columns are easy to eyeball.
    let s_d = (args.usize_or("state-kb", 256)? as u64) << 10;
    let budgets = args.usize_list_or("cache-states", &[4, 16, 64])?;
    let shard_counts = args.usize_list_or("shards", &[k / 4, k])?;
    let affinity = args.usize_or("affinity", 100)? as u32;
    println!(
        "State-store scale — M={m} stateful clients, M_p={m_p}, K={k}, R={rounds}, \
         s_d={} KB (sharded write-back+prefetch+affinity:{affinity} vs local-only baseline)",
        s_d >> 10
    );
    println!(
        "{:<22} {:>7} {:>10} {:>12} {:>10} {:>10} {:>9} {:>10}",
        "store", "budget", "round(s)", "peak-RAM", "remote", "disk-wr", "avoided", "handoff"
    );
    let mb = |b: u64| b as f64 / (1 << 20) as f64;
    let mut csv = Vec::new();
    for &budget in &budgets {
        let base = run_one(m, m_p, k, rounds, seed, s_d, budget, 0, 0)?;
        println!(
            "{:<22} {:>7} {:>10.2} {:>9.1} MB {:>7.1} MB {:>10} {:>9} {:>7.1} MB",
            "local-only (seed)",
            budget,
            base.round_secs,
            mb(base.peak_cache),
            base.remote_mb,
            base.disk_writes,
            base.avoided,
            mb(base.transfer),
        );
        csv.push(format!(
            "local,{budget},{:.3},{},{:.2},{},{},{}",
            base.round_secs, base.peak_cache, base.remote_mb, base.disk_writes, base.avoided,
            base.transfer
        ));
        for &n_shards in &shard_counts {
            let n_shards = n_shards.clamp(1, k);
            let s = run_one(m, m_p, k, rounds, seed, s_d, budget, n_shards, affinity)?;
            println!(
                "{:<22} {:>7} {:>10.2} {:>9.1} MB {:>7.1} MB {:>10} {:>9} {:>7.1} MB",
                format!("sharded n={n_shards}"),
                budget,
                s.round_secs,
                mb(s.peak_cache),
                s.remote_mb,
                s.disk_writes,
                s.avoided,
                mb(s.transfer),
            );
            csv.push(format!(
                "shards{n_shards},{budget},{:.3},{},{:.2},{},{},{}",
                s.round_secs, s.peak_cache, s.remote_mb, s.disk_writes, s.avoided, s.transfer
            ));
            if n_shards == k {
                // Acceptance: never worse on peak RAM at (near-)equal
                // makespan — and STRICTLY better at the generous budget
                // where both stores stop saturating their caches (tight
                // budgets pin both at K·B resident, so equality there
                // is the correct outcome, not a regression).
                ensure!(
                    s.peak_cache <= base.peak_cache,
                    "sharded peak {} > local-only {} at budget {budget}",
                    s.peak_cache,
                    base.peak_cache
                );
                ensure!(
                    s.round_secs <= base.round_secs * 1.10 + 0.5
                        || s.round_secs < base.round_secs,
                    "sharded makespan {:.2}s not comparable to local-only {:.2}s at \
                     budget {budget}",
                    s.round_secs,
                    base.round_secs
                );
                if Some(&budget) == budgets.iter().max() {
                    ensure!(
                        s.peak_cache < base.peak_cache,
                        "at the largest budget the baseline's duplicate caching must \
                         show: sharded peak {} !< local-only {}",
                        s.peak_cache,
                        base.peak_cache
                    );
                }
            }
        }
    }
    println!(
        "\n(engine-booked StateLoad/StateFlush bytes matched the store's counters on every"
    );
    println!(" run; sharded ownership caches each state once globally — the baseline's");
    println!(" duplicate copies are the peak-RAM gap — and write-back turns per-save disk");
    println!(" writes into round-boundary flushes.)");
    super::save_csv(
        args,
        "statescale",
        "store,budget_states,round_s,peak_cache_bytes,remote_mb,disk_writes,avoided,handoff_bytes",
        &csv,
    )
}

/// The `--smoke` differential (scripts/ci.sh): one small sharded sim
/// run with the engine==store check, then the same access sequence
/// driven through the virtual store AND real `StateManager`s — the
/// sim's accounting and the deployable store must agree counter for
/// counter.
pub fn smoke(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 77)?;
    let m = args.usize_or("clients", 50)?;
    let k = 4usize;
    let n_shards = args.usize_or("shards", 2)?.clamp(1, k);
    let rounds = args.usize_or("rounds", 6)?;
    let s_d: u64 = 2048;
    let budget_states = 4usize;

    // (1) the virtual path: engine columns == store counters.
    let sim_out = run_one(m, 16, k, rounds, seed, s_d, budget_states, n_shards, 100)?;
    println!(
        "statescale smoke: sim round {:.3}s, peak cache {} B, remote {:.1} KB, \
         engine==store bytes OK",
        sim_out.round_secs,
        sim_out.peak_cache,
        sim_out.remote_mb * 1024.0
    );

    // (2) sim vs deploy: identical access sequences through the
    // accounting store and through real write-back StateManagers.
    let cfg = SimStoreCfg::new(k, n_shards, s_d, budget_states * s_d as usize).write_back(true);
    let mut store = SimStore::new(cfg);
    let map = store.shard_map().expect("sharded").clone();
    let dir = std::env::temp_dir().join(format!("parrot_statescale_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut sms: Vec<StateManager> = (0..k)
        .map(|w| {
            StateManager::new(dir.join(format!("shard_{w}")), budget_states * s_d as usize)
                .map(|s| s.with_write_back(true))
        })
        .collect::<Result<_>>()?;

    let mut rng = Rng::new(seed ^ 0x5307E);
    for round in 0..rounds as u64 {
        // One plan: distinct clients, split over the workers in order.
        let picked = rng.choose(m, (3 * k).min(m));
        let per = (picked.len() / k).max(1);
        let mut lists: Vec<Vec<u64>> = vec![Vec::new(); k];
        for (i, &c) in picked.iter().enumerate() {
            lists[(i / per).min(k - 1)].push(c as u64);
        }
        store.plan_round(round, &lists);
        for (w, clients) in lists.iter().enumerate() {
            for &c in clients {
                // The deployable path: loads and saves land on the
                // owner's StateManager (remote legs are network-only).
                let host = if n_shards > 0 { map.owner(c) as usize % k } else { w };
                let _ = sms[host].load(c)?;
                sms[host].save(c, &vec![(round + 1) as u8; s_d as usize])?;
            }
        }
    }
    // Final consistency point on both sides.
    store.flush_all();
    for sm in &mut sms {
        sm.flush()?;
    }

    let sm_loads: u64 = sms.iter().map(|s| s.metrics.loads).sum();
    let sm_hits: u64 = sms.iter().map(|s| s.metrics.cache_hits).sum();
    let sm_reads: u64 = sms.iter().map(|s| s.metrics.disk_reads).sum();
    let sm_writes: u64 = sms.iter().map(|s| s.metrics.disk_writes).sum();
    let sm_avoided: u64 = sms.iter().map(|s| s.metrics.avoided_writes).sum();
    let sm_bytes_rd: u64 = sms.iter().map(|s| s.metrics.bytes_read).sum();
    let sm_bytes_wr: u64 = sms.iter().map(|s| s.metrics.bytes_written).sum();
    let sm_disk: u64 = sms.iter().map(|s| s.disk_bytes()).sum();
    let vm = store.metrics;
    let pairs: [(&str, u64, u64); 8] = [
        ("loads", vm.loads, sm_loads),
        ("cache_hits", vm.cache_hits, sm_hits),
        ("disk_reads", vm.disk_reads, sm_reads),
        ("disk_writes", vm.disk_writes, sm_writes),
        ("avoided_writes", vm.avoided_writes, sm_avoided),
        ("bytes_read", vm.bytes_read, sm_bytes_rd),
        ("bytes_written", vm.bytes_written, sm_bytes_wr),
        ("disk_bytes", store.disk_bytes(), sm_disk),
    ];
    for (name, sim_v, real_v) in pairs {
        ensure!(
            sim_v == real_v,
            "sim/deploy state metric mismatch: {name} sim={sim_v} deploy={real_v}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "statescale smoke: sim/deploy agree on {} counters over {} rounds \
         ({} loads, {} disk writes, {} avoided) — OK",
        pairs.len(),
        rounds,
        sm_loads,
        sm_writes,
        sm_avoided
    );
    Ok(())
}
