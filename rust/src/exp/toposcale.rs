//! `parrot exp toposcale` — multi-level hierarchical topologies at
//! acceptance scale: 1000 clients × 32 devices, sweeping
//! {flat, groups:4, groups:8, groups:16} × {sync Parrot, async
//! buffered} on the discrete-event engine.  Two hard checks run inline
//! (the harness fails loudly if either breaks):
//!
//! - **cross-WAN shrinkage**: every grouped topology must move strictly
//!   fewer cross-group (root-adjacent) bytes than flat, monotonically
//!   shrinking with the group count — the Table-1 comm argument applied
//!   one tier up (s_a·G instead of s_a·K across the WAN);
//! - **(near-)equal makespan**: at equal link speed, grouping must not
//!   cost more than a few percent of total virtual time (the extra LAN
//!   hop is small next to the compute phase).
//!
//! `--smoke` (wired into `scripts/ci.sh`) shrinks the sweep and adds
//! the sim-vs-deploy group-aggregate differential: the deploy-side
//! `LocalAgg → TierAgg → GlobalAgg` pipeline — with a wire
//! encode/decode at every tier boundary, per codec — must agree with
//! the engine on the group-aggregate structure and reproduce the flat
//! aggregation's model state within the codec's analytic tolerance at
//! 1000 clients (`--topology groups:8`), the depth-invariance
//! acceptance check on the deploy path.

use crate::aggregation::{
    flat_aggregate, AggOp, ClientUpdate, DeviceAggregate, GlobalAgg, LocalAgg, Payload,
    StalenessWeight, TierAgg,
};
use crate::cluster::{ClusterProfile, Topology, WorkloadCost};
use crate::compress::Codec;
use crate::config::{Scheme, SchedulerKind};
use crate::data::{Partition, PartitionKind};
use crate::model::ParamSet;
use crate::simulation::{run_virtual, AsyncSpec, CommModel, VRound, VirtualSim};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

/// One swept configuration's totals.
struct TopoRun {
    total_secs: f64,
    bytes: u64,
    cross_bytes: u64,
    min_group_aggs: usize,
    max_group_aggs: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    scheme: Scheme,
    topo: &Topology,
    partition: &Partition,
    m_p: usize,
    k: usize,
    rounds: usize,
    seed: u64,
    threads: usize,
) -> TopoRun {
    let cluster = ClusterProfile::heterogeneous(k).with_topology(topo.clone());
    let mut sim = VirtualSim::new(
        scheme,
        cluster,
        WorkloadCost::femnist(),
        CommModel::femnist(),
        SchedulerKind::Greedy,
        2,
        partition.clone(),
        1,
        seed,
    )
    .with_threads(threads);
    if scheme == Scheme::Async {
        sim.async_spec = AsyncSpec {
            buffer: (m_p / 2).max(1),
            max_staleness: 2,
            weight: StalenessWeight::Poly(0.5),
        };
    }
    let rs = run_virtual(&mut sim, rounds, m_p, seed ^ 0x70F0);
    summarize(&rs)
}

fn summarize(rs: &[VRound]) -> TopoRun {
    // Zero-update async tail records carry no tail chain; skip them for
    // the group-structure extrema.
    let tails: Vec<&VRound> = rs.iter().filter(|r| r.group_aggs > 0).collect();
    TopoRun {
        total_secs: rs.iter().map(|r| r.total_secs).sum(),
        bytes: rs.iter().map(|r| r.bytes).sum(),
        cross_bytes: rs.iter().map(|r| r.cross_group_bytes).sum(),
        min_group_aggs: tails.iter().map(|r| r.group_aggs).min().unwrap_or(0),
        max_group_aggs: tails.iter().map(|r| r.group_aggs).max().unwrap_or(0),
    }
}

pub fn toposcale(args: &Args) -> Result<()> {
    if args.flag("smoke") {
        return smoke(args);
    }
    let m = args.usize_or("clients", 1000)?;
    let m_p = args.usize_or("per-round", 100)?;
    let k = args.usize_or("devices", 32)?;
    let rounds = args.usize_or("rounds", 6)?;
    let seed = args.u64_or("seed", 37)?;
    let threads = args.usize_or("threads", 1)?;
    let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
    println!(
        "Hierarchical topologies — M={m}, M_p={m_p}, K={k}, R={rounds} \
         (heterogeneous cluster, equal LAN/WAN link speed)"
    );
    println!(
        "{:<8} {:<12} {:>10} {:>12} {:>14} {:>10}",
        "mode", "topology", "total(s)", "bytes(MB)", "cross-WAN(MB)", "grp-aggs"
    );
    let mb = |b: u64| b as f64 / (1 << 20) as f64;
    let mut csv = Vec::new();
    for (mode, scheme) in [("sync", Scheme::Parrot), ("async", Scheme::Async)] {
        let mut sweep: Vec<(String, usize, TopoRun)> = Vec::new();
        for spec in ["flat", "groups:16", "groups:8", "groups:4"] {
            let topo = Topology::parse(spec)?;
            let groups = topo.n_groups();
            let run = run_one(scheme, &topo, &partition, m_p, k, rounds, seed, threads);
            println!(
                "{:<8} {:<12} {:>10.2} {:>12.1} {:>14.1} {:>7}-{:<3}",
                mode,
                spec,
                run.total_secs,
                mb(run.bytes),
                mb(run.cross_bytes),
                run.min_group_aggs,
                run.max_group_aggs
            );
            csv.push(format!(
                "{mode},{spec},{:.3},{},{},{}",
                run.total_secs, run.bytes, run.cross_bytes, run.max_group_aggs
            ));
            sweep.push((spec.to_string(), groups, run));
        }
        // Inline acceptance: cross-WAN bytes shrink strictly and
        // monotonically with grouping, at (near-)equal makespan.
        let flat = &sweep[0].2;
        for w in sweep.windows(2) {
            let (a_name, _, a) = &w[0];
            let (b_name, _, b) = &w[1];
            ensure!(
                b.cross_bytes < a.cross_bytes,
                "{mode}: cross-WAN bytes must shrink {a_name} -> {b_name}: {} !> {}",
                a.cross_bytes,
                b.cross_bytes
            );
        }
        for (name, _, run) in sweep.iter().skip(1) {
            ensure!(
                run.total_secs <= flat.total_secs * 1.15 + 1.0,
                "{mode}/{name}: grouping must keep (near-)equal makespan: \
                 {:.2}s vs flat {:.2}s",
                run.total_secs,
                flat.total_secs
            );
        }
    }
    println!("\n(grouping moves the K member uploads onto intra-site LAN links; only the");
    println!(" merged group aggregates — s_a·G instead of s_a·K — cross the WAN, so the");
    println!(" cross-WAN column shrinks with the group count at near-equal round time.)");
    super::save_csv(
        args,
        "toposcale",
        "mode,topology,total_s,bytes,cross_group_bytes,group_aggs",
        &csv,
    )
}

/// Synthetic client updates for the deploy-side differential: all four
/// OPs (WeightedAvg / Avg / Sum / Collect), params + scalars.
fn mk_updates(m: usize, seed: u64) -> Vec<ClientUpdate> {
    let shapes = vec![vec![8, 4], vec![6]];
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|c| {
            let mk_params = |rng: &mut Rng| {
                let tensors = shapes
                    .iter()
                    .map(|s| {
                        (0..s.iter().product::<usize>())
                            .map(|_| rng.normal_f32(0.0, 1.0))
                            .collect()
                    })
                    .collect();
                ParamSet { shapes: shapes.clone(), tensors }
            };
            ClientUpdate {
                client: c,
                weight: rng.range_f64(1.0, 50.0),
                entries: vec![
                    ("delta".into(), AggOp::WeightedAvg, Payload::Params(mk_params(&mut rng))),
                    ("delta_c".into(), AggOp::Avg, Payload::Params(mk_params(&mut rng))),
                    ("h".into(), AggOp::Sum, Payload::Params(mk_params(&mut rng))),
                    ("gsq".into(), AggOp::Sum, Payload::Scalar(rng.next_f64())),
                    ("tau".into(), AggOp::Collect, Payload::Scalar(rng.next_f64())),
                ],
            }
        })
        .collect()
}

/// The reduced engine sweep behind `--smoke`: flat vs groups:8 at
/// 1000 clients with the inline shrinkage / makespan / group-structure
/// checks applied.  Split out so the double-run determinism harness
/// (`rust/tests/determinism.rs`) can drive it without the deploy leg.
fn smoke_engine(seed: u64, threads: usize) -> Result<(TopoRun, TopoRun)> {
    let (m, m_p, k, rounds) = (1000usize, 100usize, 32usize, 3usize);
    let n_groups = 8usize;
    let partition = Partition::generate(PartitionKind::Natural, m, 62, 100, seed);
    let topo = Topology::groups(n_groups);
    let flat =
        run_one(Scheme::Parrot, &Topology::flat(), &partition, m_p, k, rounds, seed, threads);
    let grouped = run_one(Scheme::Parrot, &topo, &partition, m_p, k, rounds, seed, threads);
    ensure!(
        grouped.cross_bytes < flat.cross_bytes,
        "cross-WAN bytes must shrink with grouping: {} !< {}",
        grouped.cross_bytes,
        flat.cross_bytes
    );
    ensure!(
        grouped.total_secs <= flat.total_secs * 1.15 + 1.0,
        "grouped makespan {:.2}s vs flat {:.2}s",
        grouped.total_secs,
        flat.total_secs
    );
    ensure!(
        grouped.min_group_aggs == n_groups && grouped.max_group_aggs == n_groups,
        "engine must merge exactly {n_groups} group aggregates per round, saw {}-{}",
        grouped.min_group_aggs,
        grouped.max_group_aggs
    );
    Ok((flat, grouped))
}

/// Deterministic engine rows for the double-run differential: two runs
/// under the same seed must produce byte-identical rows — and, since
/// the grouped leg runs the sharded engine, identical across every
/// `threads` value too (the 1-vs-2-vs-8 differential pins this).
pub fn smoke_rows(seed: u64, threads: usize) -> Result<Vec<String>> {
    let (flat, grouped) = smoke_engine(seed, threads)?;
    let row = |name: &str, r: &TopoRun| {
        format!(
            "{name},{:.6},{},{},{}-{}",
            r.total_secs, r.bytes, r.cross_bytes, r.min_group_aggs, r.max_group_aggs
        )
    };
    Ok(vec![row("flat", &flat), row("grouped", &grouped)])
}

/// The `--smoke` differential (scripts/ci.sh): a reduced engine sweep
/// (cross-WAN shrinkage + near-equal makespan + group-aggregate
/// structure) plus the deploy-side tier pipeline at 1000 clients.
pub fn smoke(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 23)?;
    let threads = args.usize_or("threads", 1)?;
    let (m, k) = (1000usize, 32usize);
    let n_groups = 8usize;
    let topo = Topology::groups(n_groups);

    // (1) engine: flat vs groups:8 on the identical stream.
    let (flat, grouped) = smoke_engine(seed, threads)?;

    // (2) deploy-side group-aggregate differential at 1000 clients:
    // member LocalAggs merge into per-group TierAggs, the merged group
    // aggregates re-encode for the WAN leg, and the global result must
    // match flat aggregation within the codec's analytic tolerance —
    // with the group structure agreeing with the engine's column.
    let updates = mk_updates(m, seed ^ 0x0770);
    let members = topo.members(k);
    let flat_result = flat_aggregate(&updates);
    let total_weight: f64 = updates.iter().map(|u| u.weight).sum();
    for codec in [Codec::None, Codec::QInt8] {
        let mut bounds: BTreeMap<String, f64> = BTreeMap::new();
        let mut member_wire = 0u64;
        let mut group_wire = 0u64;
        let mut global = GlobalAgg::new();
        let mut n_group_aggs = 0usize;
        for (g, devs) in members.iter().enumerate() {
            let mut tier = TierAgg::new(g);
            for &d in devs {
                let mut local = LocalAgg::new(d);
                for u in &updates {
                    if u.client % k == d {
                        local.add(u);
                    }
                }
                let agg = local.finish();
                for (name, b) in agg.reconstruction_bounds(codec) {
                    *bounds.entry(name).or_insert(0.0) += b;
                }
                let wire = agg.encoded_with(codec)?;
                member_wire += wire.len() as u64;
                tier.merge(DeviceAggregate::decode(&wire)?);
            }
            let merged = tier.finish();
            for (name, b) in merged.reconstruction_bounds(codec) {
                *bounds.entry(name).or_insert(0.0) += b;
            }
            let wire = merged.encoded_with(codec)?;
            group_wire += wire.len() as u64;
            n_group_aggs += 1;
            global.merge(DeviceAggregate::decode(&wire)?);
        }
        let hier = global.finish();
        ensure!(
            n_group_aggs == n_groups && grouped.max_group_aggs == n_group_aggs,
            "sim/deploy group-aggregate structure disagrees: engine {} vs deploy {}",
            grouped.max_group_aggs,
            n_group_aggs
        );
        ensure!(
            group_wire < member_wire,
            "{}: merged group aggregates must cross the WAN smaller than the \
             member uploads: {group_wire} !< {member_wire}",
            codec.name()
        );
        ensure!(hier.n_clients == m, "client count lost in the tier pipeline");
        let slack = 1e-3;
        for (name, denom) in [("delta", total_weight), ("delta_c", m as f64), ("h", 1.0)] {
            let tol = bounds.get(name).copied().unwrap_or(0.0) / denom + slack;
            let d = flat_result.params[name].max_abs_diff(&hier.params[name]) as f64;
            ensure!(
                d <= tol,
                "{}: {name} drifted {d} > tolerance {tol} through the tiers",
                codec.name()
            );
        }
        ensure!(
            (flat_result.scalars["gsq"] - hier.scalars["gsq"]).abs() < 1e-9,
            "{}: scalar sums must survive the tiers exactly",
            codec.name()
        );
        ensure!(
            flat_result.collected["tau"].len() == hier.collected["tau"].len(),
            "{}: Collect entries lost in the tiers",
            codec.name()
        );
    }
    println!(
        "toposcale smoke: groups:{n_groups} at {m} clients — cross-WAN {:.1} MB vs flat \
         {:.1} MB at makespan {:.2}s vs {:.2}s; deploy tier pipeline matches flat \
         aggregation per codec and the engine's {n_groups} group aggregates — OK",
        grouped.cross_bytes as f64 / (1 << 20) as f64,
        flat.cross_bytes as f64 / (1 << 20) as f64,
        grouped.total_secs,
        flat.total_secs,
    );
    Ok(())
}
