//! `parrot` — the launcher.
//!
//! Subcommands:
//!   run            one FL simulation (all knobs via flags; see --help)
//!   exp <id>       regenerate a paper table/figure (table1..3, fig4..11, all)
//!   worker         TCP worker process (used by examples/deploy_tcp.rs)
//!   serve          TCP server (deployment mode)
//!   info           print artifact + environment summary
//!
//! Examples:
//!   parrot run --algorithm scaffold --clients 1000 --per-round 100 \
//!              --devices 8 --rounds 20 --scheduler window:5
//!   parrot exp fig7 --devices 4,8,16,32
//!   parrot serve --addr 127.0.0.1:7700 --devices 2 &
//!   parrot worker --addr 127.0.0.1:7700 --id 1 &

use anyhow::{bail, Context, Result};
use parrot::config::RunConfig;
use parrot::coordinator::{run_simulation, Server, Worker};
use parrot::transport::{TcpServerEndpoint, TcpWorkerEndpoint};
use parrot::util::cli::Args;

const USAGE: &str = "\
parrot — FedML Parrot reproduction (heterogeneity-aware FL simulation)

USAGE:
  parrot run   [--config FILE] [--algorithm A] [--model M] [--clients N] [--per-round P]
               [--devices K] [--rounds R] [--epochs E] [--lr F] [--mu F]
               [--partition natural|dirichlet:A|qskew:S] [--scheme sp|fa|parrot|async]
               [--scheduler uniform|greedy|window:T] [--cluster homo|hete|dyn|c]
               [--seed S] [--artifacts DIR] [--state-dir DIR]
               [--availability always|P|periodic:T:O] [--churn leave@R:D[:T],join@R:D[:T],rand:PL:PJ]
               [--stragglers off|P:xS|P:u:LO:HI|P:p:A] [--drop-prob Q]
               [--compress none|fp16|qint8|topk:F]
               [--state-shards N] [--state-writeback [on|off]] [--state-affinity PCT]
               [--state-cache-mb MB] [--scheduler ...|affinity:P|window:T+affinity:P]
               [--buffer K] [--max-staleness S] [--staleness-weight const|poly:A]
               [--topology flat|groups:G[:BW:LAT]|tree:F1xF2[:BW:LAT]] [--threads N]
               [--trace PATH]  (Chrome trace-event JSON; load in Perfetto)
  parrot exp <table1|table2|table3|fig4|...|fig11|dynamics|compression|statescale|asyncscale|toposcale|parscale|megascale|ablate|all> [--results DIR] [--trace PATH] [...]
  parrot serve  --addr HOST:PORT --devices K [run flags]
  parrot worker --addr HOST:PORT --id I      [run flags]
  parrot info   [--artifacts DIR]
  parrot lint   [--root DIR] [--format human|json] [--baseline FILE] [--write-baseline]
                [--out PATH] (archive the JSON-lines report) [--explain RULE|all]
";

fn main() {
    // Quiet the TfrtCpuClient banner on every worker.
    if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
        std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    }
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    let sub = match args.subcommand() {
        Ok(s) => s.to_string(),
        Err(_) => {
            println!("{USAGE}");
            return Ok(());
        }
    };
    match sub.as_str() {
        "run" => cmd_run(&args),
        "exp" => {
            let id = args
                .positional
                .get(1)
                .context("usage: parrot exp <id>")?
                .clone();
            parrot::exp::run(&id, &args)
        }
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "info" => cmd_info(&args),
        "lint" => cmd_lint(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn load_cfg(args: &Args) -> Result<RunConfig> {
    let base = match args.get("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    base.apply_args(args)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    if !cfg.dynamics.is_static() {
        println!(
            "note: --availability/--churn/--stragglers shape the virtual-time engine \
             (`parrot exp dynamics`); the real-compute round loop runs all selected clients."
        );
    }
    println!(
        "parrot run: {} on {} | M={} M_p={} K={} R={} scheme={} scheduler={} cluster={}",
        cfg.algorithm,
        cfg.model,
        cfg.n_clients,
        cfg.clients_per_round,
        cfg.n_devices,
        cfg.rounds,
        cfg.scheme.name(),
        cfg.scheduler.name(),
        cfg.cluster.name,
    );
    let summary = run_simulation(cfg)?;
    for r in &summary.metrics.rounds {
        print!(
            "round {:>3}  wall {:>7.2}s  util {:>5.1}%  loss {:>7.4}",
            r.round,
            r.wall_secs,
            100.0 * r.utilization,
            r.train_loss
        );
        if let (Some(l), Some(a)) = (r.eval_loss, r.eval_acc) {
            print!("  eval loss {l:.4} acc {:.1}%", 100.0 * a);
        }
        println!();
    }
    println!(
        "done: mean round {:.2}s, total {:.1} MB comm, {} trips",
        summary.metrics.mean_round_secs(),
        summary.metrics.total_bytes() as f64 / (1 << 20) as f64,
        summary.metrics.total_trips()
    );
    let state_bytes = summary.metrics.total_state_bytes();
    if state_bytes > 0 {
        println!(
            "sharded state traffic: {:.2} MB (prefetch + write-back returns)",
            state_bytes as f64 / (1 << 20) as f64
        );
    }
    if let (Some(l), Some(a)) = (summary.final_loss, summary.final_acc) {
        println!("final eval: loss {l:.4}, accuracy {:.2}%", 100.0 * a);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.require("addr")?;
    let cfg = load_cfg(args)?;
    println!("parrot server on {addr}, waiting for {} workers...", cfg.n_devices);
    let transport = TcpServerEndpoint::bind(addr, cfg.n_devices)?;
    let summary = Server::new(transport, cfg)?.run()?;
    println!(
        "deployment run done: mean round {:.2}s, final acc {:?}",
        summary.metrics.mean_round_secs(),
        summary.final_acc
    );
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.require("addr")?;
    let id = args.usize_or("id", 1)?;
    anyhow::ensure!(id >= 1, "worker id must be >= 1");
    let cfg = load_cfg(args)?;
    println!("parrot worker {id} connecting to {addr}");
    let transport = TcpWorkerEndpoint::connect(addr, id)?;
    Worker::new(transport, cfg)?.run()
}

/// Determinism & wire-safety static analysis over `rust/src` with the
/// committed `lint.baseline` ratchet (see README "Determinism
/// discipline").  Exits nonzero on any non-baselined finding.
fn cmd_lint(args: &Args) -> Result<()> {
    if let Some(rule) = args.get("explain") {
        return parrot::analysis::explain(rule);
    }
    parrot::analysis::run_cli(
        args.get_or("root", "."),
        args.get_or("format", "human"),
        args.get_or("baseline", "lint.baseline"),
        args.flag("write-baseline"),
        args.get("out"),
    )
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    println!("parrot — FedML Parrot reproduction");
    println!("artifact dir: {dir}");
    for model in parrot::model::MODEL_NAMES {
        for kind in parrot::model::STEP_KINDS {
            let p = std::path::Path::new(dir).join(format!("{model}_{kind}.manifest.txt"));
            match parrot::model::Manifest::load(&p) {
                Ok(m) => println!(
                    "  {model}_{kind}: {} params ({} KB), {} inputs, {} outputs",
                    m.param_numel(),
                    m.param_bytes() / 1024,
                    m.inputs.len(),
                    m.outputs.len()
                ),
                Err(_) => println!("  {model}_{kind}: NOT BUILT (run `make artifacts`)"),
            }
        }
    }
    let rt = parrot::runtime::Runtime::cpu(dir)?;
    println!("PJRT platform: {}", rt.platform());
    Ok(())
}
