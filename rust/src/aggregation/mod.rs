//! Hierarchical local/global aggregation (paper §4.2).
//!
//! Users declare, per algorithm, the *operations* (OPs) on each
//! communicated quantity (§3.2): weighted average, simple average, sum,
//! or collect-without-averaging ("Special Params").  Devices run a
//! [`LocalAgg`] over the clients they simulated and ship one
//! [`DeviceAggregate`] (G_k) to the server; the server merges the K
//! aggregates in a [`GlobalAgg`].  For the three averaging OPs this is
//! *exactly* equal to flat client-level aggregation (property-tested
//! below), while cutting communication from s_a·M_p to s_a·K and trips
//! from M_p to K (Table 1).  Collect entries are forwarded verbatim —
//! the s_e·M_p term the paper says cannot be optimized further.

// Determinism-critical module: re-enable the workspace-wide clippy
// bans on unordered collections and ambient clocks (see clippy.toml
// and the crate-root allow in lib.rs).
#![deny(clippy::disallowed_types, clippy::disallowed_methods)]

use crate::compress::Codec;
use crate::model::params::{AggPool, ParamSet, WeightedAccum};
use crate::util::codec::{Decoder, Encoder};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// The user-declared aggregation operation for one entry (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggOp {
    /// Σ w_m x_m / Σ w_m (FedAvg on Δw, weights = dataset sizes).
    WeightedAvg,
    /// Σ x_m / M_p (SCAFFOLD's Δc).
    Avg,
    /// Σ x_m (FedDyn's h update).
    Sum,
    /// Collected at the server without averaging (FedNova τ_m, Mime
    /// full-batch gradients) — the Special Params of §4.2.
    Collect,
}

impl AggOp {
    fn code(self) -> u8 {
        match self {
            AggOp::WeightedAvg => 0,
            AggOp::Avg => 1,
            AggOp::Sum => 2,
            AggOp::Collect => 3,
        }
    }

    fn from_code(c: u8) -> Result<AggOp> {
        Ok(match c {
            0 => AggOp::WeightedAvg,
            1 => AggOp::Avg,
            2 => AggOp::Sum,
            3 => AggOp::Collect,
            _ => bail!("bad AggOp code {c}"),
        })
    }
}

/// One communicated quantity.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    Params(ParamSet),
    Scalar(f64),
}

impl Payload {
    /// Raw (uncompressed) size — the s_a accounting unit of Table 1.
    pub fn size_bytes(&self) -> usize {
        match self {
            Payload::Params(p) => p.size_bytes(),
            Payload::Scalar(_) => 8,
        }
    }

    /// Wire size under a codec — what actually crosses the transport.
    pub fn encoded_size(&self, codec: Codec) -> usize {
        let mut enc = Encoder::new();
        self.encode_with(&mut enc, codec)
            .expect("payload exceeds wire limits");
        enc.len()
    }

    pub(crate) fn encode_with(&self, enc: &mut Encoder, codec: Codec) -> Result<()> {
        match self {
            Payload::Params(p) => {
                enc.put_u8(0);
                p.encode_with(enc, codec)?;
            }
            Payload::Scalar(x) => {
                enc.put_u8(1);
                enc.put_f64(*x);
            }
        }
        Ok(())
    }

    pub(crate) fn decode(dec: &mut Decoder) -> Result<Payload> {
        match dec.u8()? {
            0 => Ok(Payload::Params(ParamSet::decode(dec)?)),
            1 => Ok(Payload::Scalar(dec.f64()?)),
            t => bail!("bad payload tag {t}"),
        }
    }
}

/// Staleness discount law for asynchronous buffered aggregation
/// (FedBuff-style): an update applied `s` flushes after the model
/// version it was computed against is scaled by `weight(s)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StalenessWeight {
    /// Every update counts fully regardless of staleness.
    Const,
    /// Polynomial decay `(1 + s)^-a` (FedBuff's default family).
    Poly(f64),
}

impl StalenessWeight {
    pub fn parse(s: &str) -> Result<StalenessWeight> {
        if s == "const" {
            return Ok(StalenessWeight::Const);
        }
        if let Some(a) = s.strip_prefix("poly:") {
            let a: f64 = a
                .parse()
                .map_err(|_| anyhow::anyhow!("bad staleness exponent {a:?}"))?;
            if !a.is_finite() || a < 0.0 {
                bail!("staleness exponent must be finite and >= 0, got {a}");
            }
            return Ok(StalenessWeight::Poly(a));
        }
        bail!("unknown staleness weight {s:?} (const|poly:a)")
    }

    pub fn name(&self) -> String {
        match self {
            StalenessWeight::Const => "const".into(),
            StalenessWeight::Poly(a) => format!("poly:{a}"),
        }
    }

    /// Discount factor for an update `staleness` flushes old.
    pub fn weight(&self, staleness: usize) -> f64 {
        match self {
            StalenessWeight::Const => 1.0,
            StalenessWeight::Poly(a) => (1.0 + staleness as f64).powf(-a),
        }
    }
}

/// What one simulated client returns (C_{m,E-1} in Alg. 1/2).
#[derive(Debug, Clone)]
pub struct ClientUpdate {
    pub client: usize,
    /// Aggregation weight for WeightedAvg entries (= N_m by convention).
    pub weight: f64,
    pub entries: Vec<(String, AggOp, Payload)>,
}

impl ClientUpdate {
    /// The staleness-weighted copy of this update that enters a buffered
    /// flush: WeightedAvg entries are discounted through the aggregation
    /// weight, Avg/Sum entries through their payload values (there is no
    /// weight to discount), and Collect ("Special Params") entries ship
    /// verbatim — the server reads them raw, so discounting would
    /// corrupt them.
    pub fn staleness_scaled(&self, factor: f64) -> ClientUpdate {
        let entries = self
            .entries
            .iter()
            .map(|(name, op, payload)| {
                let p = match (*op, payload) {
                    (AggOp::Collect, p) | (AggOp::WeightedAvg, p) => p.clone(),
                    (_, Payload::Params(ps)) => {
                        let mut ps = ps.clone();
                        ps.scale(factor as f32);
                        Payload::Params(ps)
                    }
                    (_, Payload::Scalar(x)) => Payload::Scalar(*x * factor),
                };
                (name.clone(), *op, p)
            })
            .collect();
        ClientUpdate { client: self.client, weight: self.weight * factor, entries }
    }
}

/// Per-entry accumulator state inside a device/server aggregator.
#[derive(Debug, Clone)]
enum Slot {
    Params { op: AggOp, accum: WeightedAccum, count: usize },
    Scalar { op: AggOp, sum: f64, weight: f64, count: usize },
    Collected(Vec<(usize, Payload)>),
}

/// The pre-processed result a device returns to the server (G_k).
#[derive(Debug, Clone)]
pub struct DeviceAggregate {
    pub device: usize,
    entries: BTreeMap<String, Slot>,
    pub n_clients: usize,
}

/// LocalAggregate(...) of Alg. 2 — runs on each device.
pub struct LocalAgg {
    agg: DeviceAggregate,
}

impl LocalAgg {
    pub fn new(device: usize) -> LocalAgg {
        LocalAgg {
            agg: DeviceAggregate { device, entries: BTreeMap::new(), n_clients: 0 },
        }
    }

    /// Fold one finished client's update into the local aggregate.
    pub fn add(&mut self, update: &ClientUpdate) {
        self.add_in(update, None);
    }

    /// [`LocalAgg::add`] drawing new accumulator buffers from a pool —
    /// the megascale per-round path: entry accumulators reuse the
    /// previous round's recycled tensors instead of allocating per
    /// entry.  Numerically identical to `add` (property-tested below).
    pub fn add_pooled(&mut self, update: &ClientUpdate, pool: &mut AggPool) {
        self.add_in(update, Some(pool));
    }

    fn add_in(&mut self, update: &ClientUpdate, mut pool: Option<&mut AggPool>) {
        self.agg.n_clients += 1;
        for (name, op, payload) in &update.entries {
            let pool = pool.as_deref_mut();
            let slot = self.agg.entries.entry(name.clone()).or_insert_with(|| match (op, payload) {
                (AggOp::Collect, _) => Slot::Collected(Vec::new()),
                (_, Payload::Params(p)) => Slot::Params {
                    op: *op,
                    accum: match pool {
                        Some(pool) => WeightedAccum::new_in(&p.shapes, pool),
                        None => WeightedAccum::new(&p.shapes),
                    },
                    count: 0,
                },
                (_, Payload::Scalar(_)) => Slot::Scalar { op: *op, sum: 0.0, weight: 0.0, count: 0 },
            });
            match (slot, payload) {
                (Slot::Collected(v), p) => v.push((update.client, p.clone())),
                (Slot::Params { op, accum, count }, Payload::Params(p)) => {
                    let w = match op {
                        AggOp::WeightedAvg => update.weight,
                        _ => 1.0,
                    };
                    accum.add(p, w);
                    *count += 1;
                }
                (Slot::Scalar { op, sum, weight, count }, Payload::Scalar(x)) => {
                    let w = match op {
                        AggOp::WeightedAvg => update.weight,
                        _ => 1.0,
                    };
                    *sum += w * x;
                    *weight += w;
                    *count += 1;
                }
                _ => panic!("payload kind changed for entry {name}"),
            }
        }
    }

    pub fn finish(self) -> DeviceAggregate {
        self.agg
    }
}

impl DeviceAggregate {
    /// Serialized wire form (the comm-size metric of Table 1), raw f32.
    pub fn encoded(&self) -> Result<Vec<u8>> {
        self.encoded_with(Codec::None)
    }

    /// Serialized wire form under an update-compression codec.  Only
    /// averaged-OP parameter tensors are compressed; Collect ("Special
    /// Params") entries and all scalars ship verbatim — the s_e·M_p
    /// term the paper says cannot be optimized further.  The stream is
    /// self-describing (per-tensor codec tags), so `decode` needs no
    /// negotiation context.
    pub fn encoded_with(&self, codec: Codec) -> Result<Vec<u8>> {
        let mut enc = Encoder::new();
        enc.put_u32(self.device as u32);
        enc.put_u32(self.n_clients as u32);
        enc.put_len(self.entries.len())?;
        for (name, slot) in &self.entries {
            enc.put_str(name)?;
            match slot {
                Slot::Params { op, accum, count } => {
                    enc.put_u8(0);
                    enc.put_u8(op.code());
                    accum.sum.encode_with(&mut enc, codec)?;
                    enc.put_f64(accum.weight);
                    enc.put_u32(*count as u32);
                }
                Slot::Scalar { op, sum, weight, count } => {
                    enc.put_u8(1);
                    enc.put_u8(op.code());
                    enc.put_f64(*sum);
                    enc.put_f64(*weight);
                    enc.put_u32(*count as u32);
                }
                Slot::Collected(items) => {
                    enc.put_u8(2);
                    enc.put_len(items.len())?;
                    for (client, p) in items {
                        enc.put_u32(*client as u32);
                        p.encode_with(&mut enc, Codec::None)?;
                    }
                }
            }
        }
        Ok(enc.finish())
    }

    pub fn decode(buf: &[u8]) -> Result<DeviceAggregate> {
        let mut dec = Decoder::new(buf);
        let device = dec.u32()? as usize;
        let n_clients = dec.u32()? as usize;
        // Counts are bounds-checked against the remaining bytes before
        // allocation: an entry is at least name(4) + slot tag(1) + op
        // byte(1), a collected item at least client(4) + payload tag(1).
        let n = dec.count(6)?;
        let mut entries = BTreeMap::new();
        for _ in 0..n {
            let name = dec.str()?;
            let slot = match dec.u8()? {
                0 => {
                    let op = AggOp::from_code(dec.u8()?)?;
                    let sum = ParamSet::decode(&mut dec)?;
                    let weight = dec.f64()?;
                    let count = dec.u32()? as usize;
                    Slot::Params { op, accum: WeightedAccum { sum, weight }, count }
                }
                1 => {
                    let op = AggOp::from_code(dec.u8()?)?;
                    let sum = dec.f64()?;
                    let weight = dec.f64()?;
                    let count = dec.u32()? as usize;
                    Slot::Scalar { op, sum, weight, count }
                }
                2 => {
                    let k = dec.count(5)?;
                    let mut items = Vec::with_capacity(k);
                    for _ in 0..k {
                        let client = dec.u32()? as usize;
                        items.push((client, Payload::decode(&mut dec)?));
                    }
                    Slot::Collected(items)
                }
                t => bail!("bad slot tag {t}"),
            };
            entries.insert(name, slot);
        }
        Ok(DeviceAggregate { device, entries, n_clients })
    }

    pub fn size_bytes(&self) -> usize {
        self.encoded().expect("aggregate exceeds wire limits").len()
    }

    /// Encoded wire size under a codec — the measured per-upload byte
    /// count the compression experiments report.
    pub fn size_bytes_with(&self, codec: Codec) -> usize {
        self.encoded_with(codec)
            .expect("aggregate exceeds wire limits")
            .len()
    }

    /// Hand every averaged-entry accumulator buffer back to `pool` —
    /// called after the aggregate has been encoded to the wire, so the
    /// next round's [`LocalAgg`] accumulators reuse this round's
    /// allocations (Collect payloads and scalars carry no pooled
    /// buffers and are simply dropped).
    pub fn recycle_into(self, pool: &mut AggPool) {
        for (_, slot) in self.entries {
            if let Slot::Params { accum, .. } = slot {
                accum.sum.recycle_into(pool);
            }
        }
    }

    /// Per-Params-entry worst-case element error of `encoded_with
    /// (codec)` (max over the entry's tensors of the codec's documented
    /// bound on the *shipped sums*).  Collect entries ship verbatim and
    /// are omitted (their error is identically 0).
    pub fn reconstruction_bounds(&self, codec: Codec) -> BTreeMap<String, f64> {
        self.entries
            .iter()
            .filter_map(|(name, slot)| match slot {
                Slot::Params { accum, .. } => {
                    let b = accum
                        .sum
                        .tensors
                        .iter()
                        .map(|t| codec.bound(t))
                        .fold(0.0, f64::max);
                    Some((name.clone(), b))
                }
                _ => None,
            })
            .collect()
    }
}

/// Fold `src`'s per-entry slots into `dst` — the single merge law every
/// aggregation tier shares (device→server, device→group, group→group):
/// averaged accumulators add sums/weights/counts, Collect lists extend.
fn merge_entry_maps(dst: &mut BTreeMap<String, Slot>, src: BTreeMap<String, Slot>) {
    merge_entry_maps_in(dst, src, None)
}

/// [`merge_entry_maps`], recycling each consumed child accumulator's
/// tensor buffers into `pool` (the child's sums were just added into
/// `dst` and would otherwise be freed) — so a K-child merge feeds K−1
/// buffer sets back for the next round's aggregates.
fn merge_entry_maps_in(
    dst: &mut BTreeMap<String, Slot>,
    src: BTreeMap<String, Slot>,
    mut pool: Option<&mut AggPool>,
) {
    for (name, slot) in src {
        match (dst.get_mut(&name), slot) {
            (None, s) => {
                dst.insert(name, s);
            }
            (
                Some(Slot::Params { accum, count, .. }),
                Slot::Params { accum: a2, count: c2, .. },
            ) => {
                accum.merge(&a2);
                *count += c2;
                if let Some(pool) = pool.as_deref_mut() {
                    a2.sum.recycle_into(pool);
                }
            }
            (
                Some(Slot::Scalar { sum, weight, count, .. }),
                Slot::Scalar { sum: s2, weight: w2, count: c2, .. },
            ) => {
                *sum += s2;
                *weight += w2;
                *count += c2;
            }
            (Some(Slot::Collected(v)), Slot::Collected(v2)) => v.extend(v2),
            _ => panic!("slot kind mismatch for entry {name}"),
        }
    }
}

/// One intermediate aggregation tier (an edge/group aggregator in a
/// `--topology groups:G | tree:SPEC` run): merges [`DeviceAggregate`]s
/// and produces another [`DeviceAggregate`], so tiers compose to any
/// depth — a group aggregate merges upward *exactly* like a device
/// aggregate (all four [`AggOp`]s, every codec), which is what the
/// depth-invariance property harness pins.
pub struct TierAgg {
    agg: DeviceAggregate,
}

impl TierAgg {
    /// `id` labels the tier on the wire (its `DeviceAggregate::device`).
    pub fn new(id: usize) -> TierAgg {
        TierAgg {
            agg: DeviceAggregate { device: id, entries: BTreeMap::new(), n_clients: 0 },
        }
    }

    /// Fold one child aggregate (a device's, or a deeper tier's).
    pub fn merge(&mut self, child: DeviceAggregate) {
        self.agg.n_clients += child.n_clients;
        merge_entry_maps(&mut self.agg.entries, child.entries);
    }

    /// [`TierAgg::merge`], recycling the consumed child's accumulator
    /// buffers into `pool` once their sums have been folded in.
    pub fn merge_pooled(&mut self, child: DeviceAggregate, pool: &mut AggPool) {
        self.agg.n_clients += child.n_clients;
        merge_entry_maps_in(&mut self.agg.entries, child.entries, Some(pool));
    }

    /// Clients represented so far across all merged children.
    pub fn n_clients(&self) -> usize {
        self.agg.n_clients
    }

    /// The merged aggregate, ready to encode for the next tier up.
    pub fn finish(self) -> DeviceAggregate {
        self.agg
    }
}

/// The finalized round result at the server.
#[derive(Debug, Clone, Default)]
pub struct RoundAggregate {
    /// Entry name → aggregated ParamSet (already averaged per its OP).
    pub params: BTreeMap<String, ParamSet>,
    /// Entry name → aggregated scalar.
    pub scalars: BTreeMap<String, f64>,
    /// Entry name → collected (client, payload) list, Special Params.
    pub collected: BTreeMap<String, Vec<(usize, Payload)>>,
    pub n_clients: usize,
}

/// GlobalAggregate(...) of Alg. 2 — merges the K device aggregates.
#[derive(Default)]
pub struct GlobalAgg {
    entries: BTreeMap<String, Slot>,
    n_clients: usize,
}

impl GlobalAgg {
    pub fn new() -> GlobalAgg {
        GlobalAgg::default()
    }

    pub fn merge(&mut self, dev: DeviceAggregate) {
        self.n_clients += dev.n_clients;
        merge_entry_maps(&mut self.entries, dev.entries);
    }

    /// [`GlobalAgg::merge`], recycling the consumed aggregate's
    /// accumulator buffers into `pool` once their sums are folded in.
    pub fn merge_pooled(&mut self, dev: DeviceAggregate, pool: &mut AggPool) {
        self.n_clients += dev.n_clients;
        merge_entry_maps_in(&mut self.entries, dev.entries, Some(pool));
    }

    /// Apply each entry's OP and produce the round result.
    pub fn finish(self) -> RoundAggregate {
        let mut out = RoundAggregate { n_clients: self.n_clients, ..Default::default() };
        for (name, slot) in self.entries {
            match slot {
                Slot::Params { op, accum, count } => {
                    let p = match op {
                        AggOp::WeightedAvg | AggOp::Avg => {
                            let denom = match op {
                                AggOp::WeightedAvg => accum.weight,
                                _ => count as f64,
                            };
                            let mut m = accum.sum.clone();
                            if denom > 0.0 {
                                m.scale((1.0 / denom) as f32);
                            }
                            m
                        }
                        AggOp::Sum => accum.sum.clone(),
                        AggOp::Collect => unreachable!(),
                    };
                    out.params.insert(name, p);
                }
                Slot::Scalar { op, sum, weight, count } => {
                    let v = match op {
                        AggOp::WeightedAvg => {
                            if weight > 0.0 {
                                sum / weight
                            } else {
                                0.0
                            }
                        }
                        AggOp::Avg => {
                            if count > 0 {
                                sum / count as f64
                            } else {
                                0.0
                            }
                        }
                        AggOp::Sum => sum,
                        AggOp::Collect => unreachable!(),
                    };
                    out.scalars.insert(name, v);
                }
                Slot::Collected(items) => {
                    out.collected.insert(name, items);
                }
            }
        }
        out
    }
}

/// Flat (non-hierarchical) aggregation — the reference the paper's SD/FA
/// schemes use, and the oracle for the equivalence tests.
pub fn flat_aggregate(updates: &[ClientUpdate]) -> RoundAggregate {
    let mut local = LocalAgg::new(0);
    for u in updates {
        local.add(u);
    }
    let mut global = GlobalAgg::new();
    global.merge(local.finish());
    global.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn mk_params(rng: &mut Rng, shapes: &[Vec<usize>]) -> ParamSet {
        let tensors = shapes
            .iter()
            .map(|s| {
                (0..s.iter().product::<usize>().max(1))
                    .map(|_| rng.normal_f32(0.0, 1.0))
                    .collect()
            })
            .collect();
        ParamSet { shapes: shapes.to_vec(), tensors }
    }

    fn mk_update(rng: &mut Rng, client: usize, shapes: &[Vec<usize>]) -> ClientUpdate {
        ClientUpdate {
            client,
            weight: rng.range_f64(1.0, 100.0),
            entries: vec![
                ("delta".into(), AggOp::WeightedAvg, Payload::Params(mk_params(rng, shapes))),
                ("delta_c".into(), AggOp::Avg, Payload::Params(mk_params(rng, shapes))),
                ("h".into(), AggOp::Sum, Payload::Params(mk_params(rng, shapes))),
                ("snap".into(), AggOp::Collect, Payload::Params(mk_params(rng, shapes))),
                ("tau".into(), AggOp::Collect, Payload::Scalar(rng.next_f64())),
                ("gsq".into(), AggOp::Sum, Payload::Scalar(rng.next_f64())),
            ],
        }
    }

    #[test]
    fn prop_hierarchical_equals_flat() {
        // The §4.2 guarantee: local+global == original aggregation.
        prop::check("hierarchical == flat", 40, |g| {
            let shapes = vec![vec![g.int(1, 8), g.int(1, 8)], vec![g.int(1, 16)]];
            let m = g.int(1, 30);
            let k = g.int(1, 6);
            let mut rng = Rng::new(g.rng.next_u64());
            let updates: Vec<ClientUpdate> =
                (0..m).map(|c| mk_update(&mut rng, c, &shapes)).collect();

            let flat = flat_aggregate(&updates);

            // Hierarchical: round-robin clients over k devices.
            let mut global = GlobalAgg::new();
            for dev in 0..k {
                let mut local = LocalAgg::new(dev);
                for (i, u) in updates.iter().enumerate() {
                    if i % k == dev {
                        local.add(u);
                    }
                }
                // Serialize across the "network" like the real path does.
                let wire = local.finish().encoded().unwrap();
                global.merge(DeviceAggregate::decode(&wire).unwrap());
            }
            let hier = global.finish();

            let d = flat.params["delta"].max_abs_diff(&hier.params["delta"]);
            if d > 1e-5 {
                return Err(format!("delta diff {d}"));
            }
            let dc = flat.params["delta_c"].max_abs_diff(&hier.params["delta_c"]);
            if dc > 1e-5 {
                return Err(format!("delta_c diff {dc}"));
            }
            if (flat.scalars["gsq"] - hier.scalars["gsq"]).abs() > 1e-9 {
                return Err("gsq sum mismatch".into());
            }
            let mut f: Vec<usize> = flat.collected["tau"].iter().map(|x| x.0).collect();
            let mut h: Vec<usize> = hier.collected["tau"].iter().map(|x| x.0).collect();
            f.sort_unstable();
            h.sort_unstable();
            if f != h {
                return Err("collected set mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_hierarchical_equals_flat_under_compression() {
        // The §4.2 guarantee survives every wire codec within its
        // documented bound: errors across the K compressed device
        // uploads add, then shrink by the averaging denominator.
        // None/Fp16 stay bit-exact-or-ε; QInt8/TopK stay within the
        // analytic bound; Collect entries are forwarded verbatim.
        for codec in [Codec::None, Codec::Fp16, Codec::QInt8, Codec::TopK(0.4)] {
            prop::check(&format!("hier == flat under {}", codec.name()), 25, |g| {
                let shapes = vec![vec![g.int(1, 8), g.int(1, 8)], vec![g.int(1, 16)]];
                let m = g.int(1, 30);
                let k = g.int(1, 6);
                let mut rng = Rng::new(g.rng.next_u64());
                let updates: Vec<ClientUpdate> =
                    (0..m).map(|c| mk_update(&mut rng, c, &shapes)).collect();

                let flat = flat_aggregate(&updates);
                let total_weight: f64 = updates.iter().map(|u| u.weight).sum();

                let mut global = GlobalAgg::new();
                // Worst-case error each device upload contributes, per
                // averaged-params entry.
                let mut bounds: BTreeMap<String, f64> = BTreeMap::new();
                for dev in 0..k {
                    let mut local = LocalAgg::new(dev);
                    for (i, u) in updates.iter().enumerate() {
                        if i % k == dev {
                            local.add(u);
                        }
                    }
                    let agg = local.finish();
                    for (name, b) in agg.reconstruction_bounds(codec) {
                        *bounds.entry(name).or_insert(0.0) += b;
                    }
                    let wire = agg.encoded_with(codec).unwrap();
                    global.merge(DeviceAggregate::decode(&wire).unwrap());
                }
                let hier = global.finish();

                // f32 reassociation slack (flat and hierarchical sums
                // add in different orders; the un-divided Sum entry
                // feels it most)
                let slack = 1e-4;
                let checks = [
                    ("delta", bounds["delta"] / total_weight),
                    ("delta_c", bounds["delta_c"] / m as f64),
                    ("h", bounds["h"]),
                ];
                for (name, tol) in checks {
                    let d = flat.params[name].max_abs_diff(&hier.params[name]) as f64;
                    if d > tol + slack {
                        return Err(format!(
                            "{}: {name} diff {d} > bound {tol} + {slack}",
                            codec.name()
                        ));
                    }
                }
                if (flat.scalars["gsq"] - hier.scalars["gsq"]).abs() > 1e-9 {
                    return Err("gsq sum mismatch".into());
                }
                // Collect forwarding must be verbatim under every codec.
                for coll in ["tau", "snap"] {
                    let mut f: Vec<&(usize, Payload)> = flat.collected[coll].iter().collect();
                    let mut h: Vec<&(usize, Payload)> = hier.collected[coll].iter().collect();
                    f.sort_by_key(|x| x.0);
                    h.sort_by_key(|x| x.0);
                    if f.len() != h.len() {
                        return Err(format!("{coll}: collected count mismatch"));
                    }
                    for (a, b) in f.iter().zip(&h) {
                        if a.0 != b.0 {
                            return Err(format!("{coll}: client set mismatch"));
                        }
                        let exact = match (&a.1, &b.1) {
                            (Payload::Params(p), Payload::Params(q)) => {
                                p.max_abs_diff(q) == 0.0
                            }
                            (x, y) => x == y,
                        };
                        if !exact {
                            return Err(format!(
                                "{}: {coll} not forwarded verbatim",
                                codec.name()
                            ));
                        }
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn tier_agg_composes_to_any_depth() {
        // device -> group -> super-group -> server must equal flat for
        // every OP, with wire round trips at every tier boundary.
        let mut rng = Rng::new(17);
        let shapes = vec![vec![3, 2], vec![4]];
        let updates: Vec<ClientUpdate> =
            (0..12).map(|c| mk_update(&mut rng, c, &shapes)).collect();
        let flat = flat_aggregate(&updates);

        // 4 devices -> 2 groups -> 1 super-group.
        let mut groups: Vec<TierAgg> = (0..2).map(TierAgg::new).collect();
        for dev in 0..4 {
            let mut local = LocalAgg::new(dev);
            for (i, u) in updates.iter().enumerate() {
                if i % 4 == dev {
                    local.add(u);
                }
            }
            let wire = local.finish().encoded().unwrap();
            groups[dev % 2].merge(DeviceAggregate::decode(&wire).unwrap());
        }
        let mut root = TierAgg::new(9);
        for g in groups {
            assert_eq!(g.n_clients(), 6);
            let wire = g.finish().encoded().unwrap();
            root.merge(DeviceAggregate::decode(&wire).unwrap());
        }
        let mut global = GlobalAgg::new();
        let wire = root.finish().encoded().unwrap();
        global.merge(DeviceAggregate::decode(&wire).unwrap());
        let hier = global.finish();

        assert_eq!(hier.n_clients, 12);
        for name in ["delta", "delta_c", "h"] {
            let d = flat.params[name].max_abs_diff(&hier.params[name]);
            assert!(d < 1e-5, "{name} diff {d}");
        }
        assert!((flat.scalars["gsq"] - hier.scalars["gsq"]).abs() < 1e-9);
        let mut f: Vec<usize> = flat.collected["tau"].iter().map(|x| x.0).collect();
        let mut h: Vec<usize> = hier.collected["tau"].iter().map(|x| x.0).collect();
        f.sort_unstable();
        h.sort_unstable();
        assert_eq!(f, h, "Collect survives every tier verbatim");
    }

    #[test]
    fn prop_pooled_aggregation_is_byte_identical_to_unpooled() {
        // The megascale pooled path must be a pure allocation strategy:
        // running the identical device→tier→server pipeline through
        // `add_pooled`/`merge_pooled` (with recycled buffers hot from a
        // previous round) must produce byte-identical wire encodings at
        // every tier and an identical finished round aggregate.
        prop::check("pooled == unpooled aggregation", 20, |g| {
            let shapes = vec![vec![g.int(1, 8), g.int(1, 8)], vec![g.int(1, 16)]];
            let m = g.int(1, 24);
            let k = g.int(1, 5);
            let seed = g.rng.next_u64();
            let mk_updates = |seed: u64| -> Vec<ClientUpdate> {
                let mut rng = Rng::new(seed);
                (0..m).map(|c| mk_update(&mut rng, c, &shapes)).collect()
            };
            let mut pool = AggPool::new();
            // Warm the pool so the pooled run actually exercises reuse,
            // not just the miss path.
            ParamSet::zeros(&shapes).recycle_into(&mut pool);
            let warm_recycled = pool.recycled;

            let run = |pool: &mut Option<&mut AggPool>| -> (Vec<Vec<u8>>, RoundAggregate) {
                let updates = mk_updates(seed);
                let mut global = GlobalAgg::new();
                let mut wires = Vec::new();
                for dev in 0..k {
                    let mut local = LocalAgg::new(dev);
                    for (i, u) in updates.iter().enumerate() {
                        if i % k == dev {
                            match pool.as_deref_mut() {
                                Some(p) => local.add_pooled(u, p),
                                None => local.add(u),
                            }
                        }
                    }
                    let wire = local.finish().encoded().unwrap();
                    let decoded = DeviceAggregate::decode(&wire).unwrap();
                    match pool.as_deref_mut() {
                        Some(p) => global.merge_pooled(decoded, p),
                        None => global.merge(decoded),
                    }
                    wires.push(wire);
                }
                (wires, global.finish())
            };
            let (wires_plain, flat) = run(&mut None);
            let (wires_pooled, pooled) = run(&mut Some(&mut pool));
            if wires_plain != wires_pooled {
                return Err("per-device wire encodings diverged under pooling".into());
            }
            for name in flat.params.keys() {
                if flat.params[name] != pooled.params[name] {
                    return Err(format!("params entry {name} diverged under pooling"));
                }
            }
            if flat.scalars != pooled.scalars || flat.n_clients != pooled.n_clients {
                return Err("scalar/n_clients columns diverged under pooling".into());
            }
            // The pool genuinely cycled: with at least two non-empty
            // devices, the global merge recycled the later devices'
            // param buffers after folding them in.
            if m >= 2 && k >= 2 && pool.recycled == warm_recycled {
                return Err("pooled run never recycled a buffer".into());
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_tier_pipeline_reuses_buffers_across_rounds() {
        // Round-over-round reuse through the full device→tier→server
        // pipeline: after round 1 the pool holds the merged-away
        // buffers, and round 2's accumulators must be served from them
        // (hits, not misses) while still matching the unpooled result.
        let shapes = vec![vec![6, 4], vec![8]];
        let mut pool = AggPool::new();
        let mut rng = Rng::new(23);
        let updates: Vec<ClientUpdate> =
            (0..12).map(|c| mk_update(&mut rng, c, &shapes)).collect();
        let run_pooled = |pool: &mut AggPool, updates: &[ClientUpdate]| {
            let mut root = TierAgg::new(0);
            for dev in 0..4 {
                let mut local = LocalAgg::new(dev);
                for (i, u) in updates.iter().enumerate() {
                    if i % 4 == dev {
                        local.add_pooled(u, pool);
                    }
                }
                // Ship, then hand the shipped aggregate's buffers back
                // — the worker-side reuse loop.
                let agg = local.finish();
                let wire = agg.encoded().unwrap();
                agg.recycle_into(pool);
                root.merge_pooled(DeviceAggregate::decode(&wire).unwrap(), pool);
            }
            let mut global = GlobalAgg::new();
            let root_agg = root.finish();
            let wire = root_agg.encoded().unwrap();
            root_agg.recycle_into(pool);
            global.merge_pooled(DeviceAggregate::decode(&wire).unwrap(), pool);
            global.finish()
        };
        let r1 = run_pooled(&mut pool, &updates);
        let (misses_r1, recycled_r1) = (pool.misses, pool.recycled);
        assert!(recycled_r1 > 0, "tier merges must recycle consumed children");
        let r2 = run_pooled(&mut pool, &updates);
        assert!(pool.hits > 0, "round 2 must be served from round 1's buffers");
        assert_eq!(
            pool.misses, misses_r1,
            "round 2 must not touch the allocator for accumulators"
        );
        for name in ["delta", "delta_c", "h"] {
            assert_eq!(r1.params[name], r2.params[name], "{name}");
        }
        assert_eq!(flat_aggregate(&updates).params["delta"], r1.params["delta"]);
    }

    #[test]
    fn weighted_avg_math() {
        let shapes = vec![vec![1]];
        let mk = |v: f32, w: f64, c: usize| ClientUpdate {
            client: c,
            weight: w,
            entries: vec![(
                "x".into(),
                AggOp::WeightedAvg,
                Payload::Params(ParamSet { shapes: shapes.clone(), tensors: vec![vec![v]] }),
            )],
        };
        let agg = flat_aggregate(&[mk(1.0, 1.0, 0), mk(4.0, 3.0, 1)]);
        assert!((agg.params["x"].tensors[0][0] - 3.25).abs() < 1e-6);
    }

    #[test]
    fn avg_ignores_weights() {
        let shapes = vec![vec![1]];
        let mk = |v: f32, w: f64, c: usize| ClientUpdate {
            client: c,
            weight: w,
            entries: vec![(
                "x".into(),
                AggOp::Avg,
                Payload::Params(ParamSet { shapes: shapes.clone(), tensors: vec![vec![v]] }),
            )],
        };
        let agg = flat_aggregate(&[mk(1.0, 100.0, 0), mk(3.0, 1.0, 1)]);
        assert!((agg.params["x"].tensors[0][0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn sum_and_scalar_ops() {
        let mk = |v: f64, c: usize| ClientUpdate {
            client: c,
            weight: 2.0,
            entries: vec![
                ("s".into(), AggOp::Sum, Payload::Scalar(v)),
                ("a".into(), AggOp::Avg, Payload::Scalar(v)),
                ("w".into(), AggOp::WeightedAvg, Payload::Scalar(v)),
            ],
        };
        let agg = flat_aggregate(&[mk(1.0, 0), mk(5.0, 1)]);
        assert_eq!(agg.scalars["s"], 6.0);
        assert_eq!(agg.scalars["a"], 3.0);
        assert_eq!(agg.scalars["w"], 3.0); // equal weights
    }

    #[test]
    fn collect_preserves_clients_and_values() {
        let mk = |v: f64, c: usize| ClientUpdate {
            client: c,
            weight: 1.0,
            entries: vec![("tau".into(), AggOp::Collect, Payload::Scalar(v))],
        };
        let agg = flat_aggregate(&[mk(7.0, 3), mk(9.0, 5)]);
        let items = &agg.collected["tau"];
        assert_eq!(items.len(), 2);
        assert!(items.contains(&(3, Payload::Scalar(7.0))));
        assert!(items.contains(&(5, Payload::Scalar(9.0))));
    }

    #[test]
    fn device_aggregate_wire_round_trip() {
        let mut rng = Rng::new(8);
        let shapes = vec![vec![4, 2], vec![3]];
        let mut local = LocalAgg::new(2);
        for c in 0..5 {
            local.add(&mk_update(&mut rng, c, &shapes));
        }
        let agg = local.finish();
        let wire = agg.encoded().unwrap();
        let back = DeviceAggregate::decode(&wire).unwrap();
        assert_eq!(back.device, 2);
        assert_eq!(back.n_clients, 5);
        assert_eq!(back.encoded().unwrap(), wire);
    }

    #[test]
    fn comm_size_shrinks_with_hierarchy() {
        // K device aggregates must be ~K/M the size of M client updates
        // (for avg-only payloads) — the Table-1 comm claim.
        let mut rng = Rng::new(9);
        let shapes = vec![vec![64, 64]];
        let updates: Vec<ClientUpdate> = (0..32)
            .map(|c| ClientUpdate {
                client: c,
                weight: 1.0,
                entries: vec![(
                    "delta".into(),
                    AggOp::WeightedAvg,
                    Payload::Params(mk_params(&mut rng, &shapes)),
                )],
            })
            .collect();
        let flat_bytes: usize = updates
            .iter()
            .map(|u| u.entries.iter().map(|(_, _, p)| p.size_bytes()).sum::<usize>())
            .sum();
        let mut local = LocalAgg::new(0);
        for u in &updates {
            local.add(u);
        }
        let hier_bytes = local.finish().size_bytes();
        assert!(
            hier_bytes * 16 < flat_bytes,
            "hier {hier_bytes} vs flat {flat_bytes}"
        );
    }

    #[test]
    fn payload_encoded_size_tracks_codec() {
        let mut rng = Rng::new(13);
        let p = Payload::Params(mk_params(&mut rng, &[vec![32, 16], vec![16]]));
        let raw = p.encoded_size(Codec::None);
        // encoded_size is the measured wire length, codec-sensitive
        let mut enc = Encoder::new();
        p.encode_with(&mut enc, Codec::None).unwrap();
        assert_eq!(raw, enc.len());
        assert!(p.encoded_size(Codec::Fp16) < raw);
        assert!(p.encoded_size(Codec::QInt8) * 3 < raw);
        // scalars are codec-invariant
        let s = Payload::Scalar(4.0);
        assert_eq!(s.encoded_size(Codec::None), s.encoded_size(Codec::QInt8));
    }

    #[test]
    fn empty_global_agg_finishes_empty() {
        let agg = GlobalAgg::new().finish();
        assert!(agg.params.is_empty());
        assert_eq!(agg.n_clients, 0);
    }

    #[test]
    fn staleness_weight_parse_and_law() {
        assert_eq!(StalenessWeight::parse("const").unwrap(), StalenessWeight::Const);
        let p = StalenessWeight::parse("poly:0.5").unwrap();
        assert!(matches!(p, StalenessWeight::Poly(a) if (a - 0.5).abs() < 1e-12));
        assert!(StalenessWeight::parse("poly:-1").is_err());
        assert!(StalenessWeight::parse("exp:2").is_err());
        // const never discounts; poly decays monotonically from 1.
        assert_eq!(StalenessWeight::Const.weight(7), 1.0);
        assert_eq!(p.weight(0), 1.0);
        assert!((p.weight(3) - 0.5).abs() < 1e-12); // (1+3)^-0.5 = 0.5
        assert!(p.weight(4) < p.weight(3));
        // round-trip through name()
        for s in ["const", "poly:0.5", "poly:2"] {
            let w = StalenessWeight::parse(s).unwrap();
            assert_eq!(StalenessWeight::parse(&w.name()).unwrap(), w, "{s}");
        }
    }

    #[test]
    fn staleness_scaled_discounts_per_op() {
        let shapes = vec![vec![1]];
        let params = |v: f32| ParamSet { shapes: shapes.clone(), tensors: vec![vec![v]] };
        let u = ClientUpdate {
            client: 3,
            weight: 4.0,
            entries: vec![
                ("delta".into(), AggOp::WeightedAvg, Payload::Params(params(2.0))),
                ("delta_c".into(), AggOp::Avg, Payload::Params(params(2.0))),
                ("h".into(), AggOp::Sum, Payload::Scalar(2.0)),
                ("tau".into(), AggOp::Collect, Payload::Scalar(9.0)),
            ],
        };
        let s = u.staleness_scaled(0.5);
        assert_eq!(s.client, 3);
        assert!((s.weight - 2.0).abs() < 1e-12, "WeightedAvg discounts the weight");
        // WeightedAvg payload untouched (the weight carries the discount).
        assert_eq!(s.entries[0].2, Payload::Params(params(2.0)));
        // Avg/Sum have no weight: the payload itself shrinks.
        assert_eq!(s.entries[1].2, Payload::Params(params(1.0)));
        assert_eq!(s.entries[2].2, Payload::Scalar(1.0));
        // Collect ships verbatim.
        assert_eq!(s.entries[3].2, Payload::Scalar(9.0));
        // factor 1 is the identity on the aggregate result
        let id = u.staleness_scaled(1.0);
        let a = flat_aggregate(&[u.clone()]);
        let b = flat_aggregate(&[id]);
        assert_eq!(a.params["delta"], b.params["delta"]);
        assert_eq!(a.scalars["h"], b.scalars["h"]);
    }
}
