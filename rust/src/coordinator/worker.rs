//! `Device_Executes` (Alg. 2): the sequential device executor.
//!
//! Each worker owns a full PJRT runtime (compiled train/grad artifacts),
//! a state-manager handle, and a deterministic local view of the
//! federated dataset.  Per assigned client it: loads state → prepares
//! the task spec (algorithm OPs) → runs E local epochs through the
//! [`TaskRun`](crate::runtime::TaskRun) hot path → injects the
//! Appendix-A heterogeneity sleep → saves state → folds the result into
//! the local aggregate.  One `RoundDone` goes back per round (Parrot) or
//! one `TaskDone` per client (FA mode).
//!
//! ## Sharded client state (`--state-shards n`)
//!
//! With a stateful algorithm and `n ≥ 1`, each worker owns the
//! consistent-hash shard matching its device index (its own disk
//! directory — state never relies on a shared filesystem).  Non-owned
//! clients are served by the server's plan-driven prefetch: a
//! `StatePut` staging delivery lands before the `Round` that needs it,
//! updated state rides a `StatePut` back to the server (which routes it
//! to the owner), and the owner's write-back cache flushes at its next
//! round boundary / shutdown.

use crate::aggregation::LocalAgg;
use crate::algorithms::{Algo, Broadcast, TaskResult};
use crate::compress::Codec;
use crate::config::RunConfig;
use crate::coordinator::messages::Msg;
use crate::data::{FederatedDataset, Partition, SynthConfig};
use crate::model::params::AggPool;
use crate::model::ParamSet;
use crate::runtime::{Executable, Runtime};
use crate::scheduler::TaskRecord;
use crate::state::StateManager;
use crate::statestore::ShardMap;
use crate::transport::Transport;
use crate::util::timer::Stopwatch;
use anyhow::{Context, Result};
use std::collections::HashMap;

pub struct Worker<T: Transport> {
    transport: T,
    /// Device index 0..K (endpoint id − 1).
    device: usize,
    cfg: RunConfig,
    algo: Algo,
    train_exe: Executable,
    grad_exe: Option<Executable>,
    state: StateManager,
    /// Ownership ring when the sharded state store is on.
    shards: Option<ShardMap>,
    /// Prefetched non-owned states for the coming round (client → blob).
    staged: HashMap<u64, Vec<u8>>,
    /// Updated non-owned states awaiting the round-end return leg.
    returns: Vec<(u64, Vec<u8>)>,
    dataset: FederatedDataset,
    /// Cached broadcast + round codec for FA TaskCached messages.
    cached_bc: Option<(Broadcast, Codec)>,
    /// Current async-mode model + its version (set by `AsyncFlush`).
    async_bc: Option<(Broadcast, u64)>,
    /// Size-class buffer pool for per-client aggregation merges: shipped
    /// aggregates are recycled after encoding so steady-state rounds
    /// allocate no accumulator buffers.
    pool: AggPool,
}

/// Build the deterministic dataset every participant reconstructs
/// locally from the config (no data ever crosses the transport).
pub fn build_dataset(cfg: &RunConfig) -> FederatedDataset {
    let n_classes = if cfg.model == "tinylm" { 62 } else { 62 };
    let partition = Partition::generate(
        cfg.partition,
        cfg.n_clients,
        n_classes,
        cfg.mean_client_size,
        cfg.seed,
    );
    let synth = if cfg.model == "tinylm" {
        SynthConfig::language(cfg.seed)
    } else {
        SynthConfig::vision(cfg.seed)
    };
    FederatedDataset::new(synth, partition)
}

impl<T: Transport> Worker<T> {
    /// Construct inside the worker thread (PJRT handles are not Send).
    pub fn new(transport: T, cfg: RunConfig) -> Result<Worker<T>> {
        let device = transport.id() - 1;
        let algo = Algo::parse(&cfg.algorithm, cfg.mu)?;
        let rt = Runtime::cpu(&cfg.artifact_dir)?;
        let train_exe = rt.load(&cfg.artifact("train"))?;
        let grad_exe = if matches!(algo, Algo::Mime { .. }) {
            Some(rt.load(&cfg.artifact("grad"))?)
        } else {
            None
        };
        let sharded = cfg.state_shards > 0 && algo.stateful();
        let shards =
            sharded.then(|| ShardMap::new(cfg.state_shards.min(cfg.n_devices)));
        let run_dir = std::path::Path::new(&cfg.state_dir).join(format!("run_{}", cfg.seed));
        // Sharded mode: each worker owns its shard's directory, so
        // state never leans on a shared filesystem (TCP deployments run
        // workers on different machines).
        let state_dir =
            if sharded { run_dir.join(format!("shard_{device}")) } else { run_dir };
        let state = StateManager::new(state_dir, cfg.state_cache_mb << 20)?
            .with_write_back(cfg.state_writeback);
        let dataset = build_dataset(&cfg);
        Ok(Worker {
            transport,
            device,
            cfg,
            algo,
            train_exe,
            grad_exe,
            state,
            shards,
            staged: HashMap::new(),
            returns: Vec::new(),
            dataset,
            cached_bc: None,
            async_bc: None,
            pool: AggPool::new(),
        })
    }

    /// Does this worker own `client`'s state? (Always true unsharded.)
    fn owns(&self, client: u64) -> bool {
        match &self.shards {
            None => true,
            Some(m) => m.owner(client) as usize == self.device,
        }
    }

    /// Message loop until Shutdown.
    pub fn run(mut self) -> Result<()> {
        loop {
            let (_, raw) = self.transport.recv(None)?;
            match Msg::decode(&raw)? {
                Msg::Shutdown => {
                    // Round-boundary consistency: nothing dirty outlives
                    // the process (no-op in write-through mode).
                    self.state.flush()?;
                    return Ok(());
                }
                Msg::Round { round, broadcast, clients, codec } => {
                    let (aggregate, records, busy_secs) =
                        self.run_assigned_round(round, &broadcast, clients)?;
                    // Upload with the codec the server negotiated for
                    // this round.
                    let msg = Msg::RoundDone {
                        device: self.device,
                        aggregate,
                        records,
                        busy_secs,
                        codec,
                    };
                    let wire = msg.encode()?;
                    // The aggregate is on the wire; its buffers feed the
                    // next round's accumulators instead of the allocator.
                    if let Msg::RoundDone { aggregate, .. } = msg {
                        aggregate.recycle_into(&mut self.pool);
                    }
                    self.transport.send(0, wire)?;
                }
                Msg::GroupRound { round, group, broadcast, clients, codec } => {
                    // Grouped topology: identical round body, but the
                    // reply carries the device's edge group so the
                    // group-aggregator tier can merge it before the WAN.
                    let (aggregate, records, busy_secs) =
                        self.run_assigned_round(round, &broadcast, clients)?;
                    let msg = Msg::GroupDone {
                        group,
                        device: self.device,
                        aggregate,
                        records,
                        busy_secs,
                        codec,
                    };
                    let wire = msg.encode()?;
                    if let Msg::GroupDone { aggregate, .. } = msg {
                        aggregate.recycle_into(&mut self.pool);
                    }
                    self.transport.send(0, wire)?;
                }
                Msg::StateFetch { round, clients } => {
                    // The server wants these (owned) states for
                    // executors elsewhere; None = no state yet.
                    let mut states = Vec::with_capacity(clients.len());
                    for c in clients {
                        states.push((c, self.state.load(c)?));
                    }
                    self.transport.send(0, Msg::StatePut { round, states }.encode()?)?;
                }
                Msg::StatePut { states, .. } => {
                    for (c, bytes) in states {
                        match bytes {
                            None => {
                                self.staged.remove(&c);
                            }
                            Some(b) => {
                                if self.owns(c) {
                                    // Write-back return from an executor.
                                    self.state.save(c, &b)?;
                                } else {
                                    // Plan-driven prefetch for the
                                    // coming round.
                                    self.staged.insert(c, b);
                                }
                            }
                        }
                    }
                }
                Msg::ShardTransfer { states, .. } => {
                    // Bulk ownership move: persist immediately — the
                    // sender may already be gone.
                    for (c, b) in states {
                        self.state.save(c, &b)?;
                    }
                    self.state.flush()?;
                }
                Msg::AsyncFlush { version, broadcast } => {
                    // Flush boundary = write-back consistency point: the
                    // async analogue of the Parrot round boundary.
                    self.state.flush()?;
                    self.async_bc = Some((broadcast, version));
                }
                Msg::AsyncTask { round, client, version, codec } => {
                    let (bc, held) = self
                        .async_bc
                        .clone()
                        .context("AsyncTask before the initial AsyncFlush")?;
                    anyhow::ensure!(
                        held == version,
                        "async model skew: device holds v{held}, task dispatched against \
                         v{version}"
                    );
                    let (update, record) = self.run_task(round, &bc, client)?;
                    // Non-owned state rides back to its owner (via the
                    // server) ahead of the task result.
                    if !self.returns.is_empty() {
                        let states: Vec<(u64, Option<Vec<u8>>)> =
                            self.returns.drain(..).map(|(c, b)| (c, Some(b))).collect();
                        self.transport.send(0, Msg::StatePut { round, states }.encode()?)?;
                    }
                    self.staged.clear();
                    self.transport.send(
                        0,
                        Msg::TaskDone { device: self.device, update, record, codec }.encode()?,
                    )?;
                }
                Msg::Task { round, broadcast, client, codec } => {
                    self.cached_bc = Some((broadcast.clone(), codec));
                    let (update, record) = self.run_task(round, &broadcast, client)?;
                    self.transport.send(
                        0,
                        Msg::TaskDone { device: self.device, update, record, codec }.encode()?,
                    )?;
                }
                Msg::TaskCached { round, client } => {
                    let (bc, codec) = self
                        .cached_bc
                        .clone()
                        .context("TaskCached before any Task with broadcast")?;
                    let (update, record) = self.run_task(round, &bc, client)?;
                    self.transport.send(
                        0,
                        Msg::TaskDone { device: self.device, update, record, codec }.encode()?,
                    )?;
                }
                other => anyhow::bail!("worker got unexpected message {other:?}"),
            }
        }
    }

    /// One assigned Parrot round: train every client sequentially, fold
    /// into the local aggregate, return state write-backs, flush at the
    /// round boundary.  Shared by the flat (`Round`→`RoundDone`) and
    /// grouped (`GroupRound`→`GroupDone`) paths.
    fn run_assigned_round(
        &mut self,
        round: usize,
        broadcast: &Broadcast,
        clients: Vec<usize>,
    ) -> Result<(crate::aggregation::DeviceAggregate, Vec<TaskRecord>, f64)> {
        let sw = Stopwatch::start();
        let mut local = LocalAgg::new(self.device);
        let mut records = Vec::with_capacity(clients.len());
        for client in clients {
            let (update, rec) = self.run_task(round, broadcast, client)?;
            local.add_pooled(&update, &mut self.pool);
            records.push(rec);
        }
        // Ship updated non-owned states back to their owners (via the
        // server) before the round result.
        if !self.returns.is_empty() {
            let states: Vec<(u64, Option<Vec<u8>>)> =
                self.returns.drain(..).map(|(c, b)| (c, Some(b))).collect();
            self.transport.send(0, Msg::StatePut { round, states }.encode()?)?;
        }
        // Stale prefetches must not leak into later rounds.
        self.staged.clear();
        // Round boundary: write-back flush.
        self.state.flush()?;
        Ok((local.finish(), records, sw.elapsed_secs()))
    }

    /// Train one client sequentially (the paper's §3.3).
    fn run_task(
        &mut self,
        round: usize,
        bc: &Broadcast,
        client: usize,
    ) -> Result<(crate::aggregation::ClientUpdate, TaskRecord)> {
        let sw = Stopwatch::start();
        let shapes = self.train_exe.manifest.param_shapes();
        let old_state = if self.algo.stateful() {
            if self.owns(client as u64) {
                self.state.load_params(client as u64)?
            } else {
                // Non-owned state arrives via the server's plan-driven
                // prefetch; absent staging = first selection.
                match self.staged.remove(&(client as u64)) {
                    Some(b) => Some(ParamSet::from_bytes(&b)?),
                    None => None,
                }
            }
        } else {
            None
        };
        let spec = self.algo.prepare(bc, old_state.as_ref(), &shapes);

        // Mime needs a gradient at the *initial* params (full-batch proxy:
        // the client's first batch).
        let full_grad = if spec.wants_full_grad {
            let gexe = self.grad_exe.as_ref().context("grad artifact not loaded")?;
            let (g, _loss) = gexe.grad(&bc.params, &self.dataset.batch(client, 0))?;
            Some(g)
        } else {
            None
        };

        let mut run =
            self.train_exe
                .start_task(&bc.params, &spec.anchors, &spec.corrs, self.cfg.lr, spec.mu)?;
        let n_batches = self.dataset.n_batches(client);
        let mut loss_sum = 0.0f64;
        let mut steps = 0usize;
        for _epoch in 0..self.cfg.local_epochs {
            for j in 0..n_batches {
                let (loss, _gsq) = run.step(&self.dataset.batch(client, j))?;
                loss_sum += loss as f64;
                steps += 1;
            }
        }
        let finals = run.finish()?;

        // Appendix A: simulate heterogeneous / unstable devices by
        // sleeping η·T̂ on top of the measured time.  The server only
        // ever sees the total, exactly as in the paper.
        let measured = sw.elapsed_secs();
        let slowdown = self.cfg.cluster.devices[self.device].slowdown(round, self.device);
        let extra = measured * (slowdown - 1.0);
        if extra > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(extra));
        }

        let res = TaskResult {
            client,
            weight: self.dataset.client_size(client) as f64,
            initial: bc.params.clone(),
            finals,
            mean_loss: (loss_sum / steps.max(1) as f64) as f32,
            n_steps: steps,
            lr: self.cfg.lr,
            full_grad,
        };
        let (update, new_state) = self.algo.client_update(&res, bc, old_state.as_ref());
        if let Some(ns) = new_state {
            if self.owns(client as u64) {
                self.state.save_params(client as u64, &ns)?;
            } else {
                // Queue the write-back return for the round-end
                // StatePut to the owner (via the server).
                self.returns.push((client as u64, ns.to_bytes()?));
            }
        }
        let record = TaskRecord {
            round,
            device: self.device,
            n_samples: self.dataset.client_size(client) * self.cfg.local_epochs,
            secs: sw.elapsed_secs(),
        };
        Ok((update, record))
    }
}

/// Materialize a ParamSet with the He init the server uses at round 0 —
/// kept here so server and tests agree on the starting point.
pub fn initial_params(cfg: &RunConfig) -> Result<ParamSet> {
    let man = crate::model::Manifest::load(
        std::path::Path::new(&cfg.artifact_dir)
            .join(format!("{}.manifest.txt", cfg.artifact("train"))),
    )?;
    Ok(ParamSet::init_he(&man.param_shapes(), cfg.seed))
}
