//! Measured per-round / per-run accounting, plus the analytic memory
//! model behind Tables 1 and 3.

use crate::obs::Registry;
use crate::util::json::Json;

/// One round's measured numbers.
#[derive(Debug, Clone, Default)]
pub struct RoundMetrics {
    pub round: usize,
    /// Server-observed wallclock for the whole round.
    pub wall_secs: f64,
    /// Sum of device busy seconds (compute incl. simulated slowdown).
    pub busy_secs: f64,
    /// Bytes server → devices.
    pub bytes_down: u64,
    /// Bytes devices → server.
    pub bytes_up: u64,
    /// Message count in both directions (the "communication trips").
    pub trips: u64,
    /// Sharded-state traffic this round: StateFetch/StatePut/
    /// ShardTransfer frame bytes through the server (prefetch +
    /// write-back returns), metered separately from param comm.
    pub state_bytes: u64,
    pub state_msgs: u64,
    /// Scheduler estimation+assignment wallclock (Fig. 8).
    pub sched_secs: f64,
    /// Mean training loss reported by clients (weighted).
    pub train_loss: f64,
    /// Server-side eval results, if run this round.
    pub eval_loss: Option<f64>,
    pub eval_acc: Option<f64>,
    /// Device utilization = busy / (K · makespan).
    pub utilization: f64,
    /// Async scheme: client updates applied by this flush (one
    /// `RoundMetrics` per flush; 0 for the synchronous schemes).
    pub flush_updates: usize,
    /// Async scheme: updates discarded for exceeding `--max-staleness`.
    pub stale_dropped: usize,
    /// Async scheme: `staleness_hist[s]` = applied updates that were
    /// `s` flushes old (mirrors the sim's `VRound::staleness_hist`;
    /// empty for the synchronous schemes).
    pub staleness_hist: Vec<usize>,
    /// Grouped topology: group aggregates merged at the server this
    /// round (0 on a flat topology).
    pub group_aggs: usize,
    /// Grouped topology: measured bytes that crossed the root-adjacent
    /// (WAN) boundary — one `GroupRound` frame per active group down,
    /// one merged+encoded group aggregate per group up.
    pub cross_group_bytes: u64,
}

/// Whole-run accumulation.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub rounds: Vec<RoundMetrics>,
}

impl RunMetrics {
    pub fn push(&mut self, r: RoundMetrics) {
        self.rounds.push(r);
    }

    pub fn mean_round_secs(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.wall_secs).sum::<f64>() / self.rounds.len() as f64
    }

    /// Mean over rounds AFTER the warm-up prefix (the paper reports
    /// steady-state round times).
    pub fn mean_round_secs_after(&self, warmup: usize) -> f64 {
        let tail: Vec<f64> = self
            .rounds
            .iter()
            .filter(|r| r.round >= warmup)
            .map(|r| r.wall_secs)
            .collect();
        if tail.is_empty() {
            return self.mean_round_secs();
        }
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    pub fn total_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.bytes_down + r.bytes_up).sum()
    }

    pub fn total_trips(&self) -> u64 {
        self.rounds.iter().map(|r| r.trips).sum()
    }

    /// Sharded-state traffic across the run (0 for legacy state).
    pub fn total_state_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.state_bytes).sum()
    }

    /// Measured cross-WAN bytes across the run (0 on a flat topology).
    pub fn total_cross_group_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.cross_group_bytes).sum()
    }

    /// Mean device utilization across rounds (unweighted).
    pub fn mean_utilization(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.utilization).sum::<f64>() / self.rounds.len() as f64
    }

    pub fn final_eval(&self) -> (Option<f64>, Option<f64>) {
        for r in self.rounds.iter().rev() {
            if r.eval_acc.is_some() {
                return (r.eval_loss, r.eval_acc);
            }
        }
        (None, None)
    }

    /// Run counters/histograms under the `deploy.` namespace — the
    /// wallclock mirror of `simulation::registry_from_rounds` (same
    /// metric shapes, different clock).
    pub fn registry(&self) -> Registry {
        let mut reg = Registry::new();
        for r in &self.rounds {
            reg.inc("deploy.rounds");
            reg.add("deploy.bytes", r.bytes_down + r.bytes_up);
            reg.add("deploy.trips", r.trips);
            reg.add("deploy.state_bytes", r.state_bytes);
            reg.add("deploy.state_msgs", r.state_msgs);
            reg.add("deploy.cross_group_bytes", r.cross_group_bytes);
            reg.add("deploy.group_aggs", r.group_aggs as u64);
            reg.add("deploy.flush_applied", r.flush_updates as u64);
            reg.add("deploy.stale_dropped", r.stale_dropped as u64);
            reg.observe_secs("deploy.round_secs", r.wall_secs);
            for (s, &n) in r.staleness_hist.iter().enumerate() {
                for _ in 0..n {
                    reg.observe("deploy.staleness", s as u64);
                }
            }
        }
        reg
    }

    /// Render the run — per-round rows plus the run-level aggregates
    /// the sim side already reports (`warmup` feeds the steady-state
    /// mean, mirroring the paper's warm-up exclusion).
    pub fn to_json(&self, warmup: usize) -> Json {
        Json::Obj(vec![
            (
                "rounds".into(),
                Json::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::obj()
                                .set("round", r.round)
                                .set("wall_secs", r.wall_secs)
                                .set("busy_secs", r.busy_secs)
                                .set("bytes_down", r.bytes_down as i64)
                                .set("bytes_up", r.bytes_up as i64)
                                .set("trips", r.trips as i64)
                                .set("state_bytes", r.state_bytes as i64)
                                .set("state_msgs", r.state_msgs as i64)
                                .set("sched_secs", r.sched_secs)
                                .set("train_loss", r.train_loss)
                                .set("eval_loss", r.eval_loss.map(Json::Num).unwrap_or(Json::Null))
                                .set("eval_acc", r.eval_acc.map(Json::Num).unwrap_or(Json::Null))
                                .set("utilization", r.utilization)
                                .set("flush_updates", r.flush_updates)
                                .set("stale_dropped", r.stale_dropped)
                                .set(
                                    "staleness_hist",
                                    Json::Arr(
                                        r.staleness_hist
                                            .iter()
                                            .map(|&n| Json::Int(n as i64))
                                            .collect(),
                                    ),
                                )
                                .set("group_aggs", r.group_aggs)
                                .set("cross_group_bytes", r.cross_group_bytes as i64)
                        })
                        .collect(),
                ),
            ),
            ("mean_round_secs".into(), Json::Num(self.mean_round_secs())),
            (
                "mean_round_secs_after_warmup".into(),
                Json::Num(self.mean_round_secs_after(warmup)),
            ),
            ("mean_utilization".into(), Json::Num(self.mean_utilization())),
            ("total_bytes".into(), Json::Int(self.total_bytes() as i64)),
            ("total_trips".into(), Json::Int(self.total_trips() as i64)),
            ("total_state_bytes".into(), Json::Int(self.total_state_bytes() as i64)),
            (
                "total_cross_group_bytes".into(),
                Json::Int(self.total_cross_group_bytes() as i64),
            ),
        ])
    }
}

/// Analytic memory model — Table 1's rows and Table 3's numbers.
///
/// `s_m` = bytes to *simulate one client* (params + grads + optimizer +
/// activations), `s_d` = client state bytes.  The paper's Table 3 uses
/// the per-client footprint directly (e.g. FEMNIST: 1,122 MB), so the
/// harness calibrates s_m from the measured model and scales by the
/// paper's activation multiplier.
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    /// Bytes to simulate one client (s_m).
    pub s_m: u64,
    /// Client-state bytes (s_d); 0 for stateless algorithms.
    pub s_d: u64,
}

impl MemoryModel {
    /// Accelerator-memory bytes per scheme WITHOUT the state manager
    /// (Table 1 row "Memory").
    pub fn memory(
        &self,
        scheme: crate::config::Scheme,
        m: usize,
        m_p: usize,
        k: usize,
    ) -> u64 {
        use crate::config::Scheme::*;
        let (m, m_p, k) = (m as u64, m_p as u64, k as u64);
        match scheme {
            SP => self.s_m * m + self.s_d * m,
            RwDist => self.s_m * m + self.s_d * m,
            SdDist => self.s_m * m_p + self.s_d * m,
            FaDist => self.s_m * k + self.s_d * m,
            // Async keeps Parrot's executor shape (K resident sims).
            Parrot | Async => self.s_m * k + self.s_d * m / m.max(1), // s_d/M ≈ s_d
        }
    }

    /// Memory WITH the state manager (Table 1 row "Memory with state
    /// manager"): state spills to disk, K (or M_p) live copies remain.
    pub fn memory_with_manager(
        &self,
        scheme: crate::config::Scheme,
        m: usize,
        m_p: usize,
        k: usize,
    ) -> u64 {
        use crate::config::Scheme::*;
        let (m, m_p, k) = (m as u64, m_p as u64, k as u64);
        match scheme {
            SP => self.s_m + self.s_d,
            RwDist => self.s_m * m + self.s_d, // one resident state per active device lineage
            SdDist => self.s_m * m_p + self.s_d * m_p,
            FaDist | Parrot | Async => self.s_m * k + self.s_d * k,
        }
    }

    /// Disk bytes with the state manager (Table 1 row "Disk Cost").
    pub fn disk_with_manager(&self, scheme: crate::config::Scheme, m: usize) -> u64 {
        let _ = scheme;
        self.s_d * m as u64
    }

    /// Per-round communication volume (Table 1 "Comm. Size"), given the
    /// averaged-params bytes `s_a` and special-params bytes `s_e`.
    pub fn comm_size(
        scheme: crate::config::Scheme,
        s_a: u64,
        s_e: u64,
        m_p: usize,
        k: usize,
    ) -> u64 {
        use crate::config::Scheme::*;
        match scheme {
            SP => 0,
            RwDist | SdDist | FaDist => (s_a + s_e) * m_p as u64,
            // Async flushes the same hierarchical shape per M_p updates.
            Parrot | Async => s_a * k as u64 + s_e * m_p as u64,
        }
    }

    /// Per-round communication trips (Table 1 "Comm. Trips") — upload
    /// direction, matching the paper's counting.
    pub fn comm_trips(scheme: crate::config::Scheme, m_p: usize, k: usize) -> u64 {
        use crate::config::Scheme::*;
        match scheme {
            SP => 0,
            RwDist | SdDist | FaDist => m_p as u64,
            Parrot | Async => k as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;

    const MB: u64 = 1 << 20;

    #[test]
    fn table3_femnist_row() {
        // Paper Table 3: FEMNIST s_m = 1,122 MB; SP = 1,122; SD@Mp=100 =
        // 112,200; FA/Parrot@K=8 = 8,976.
        let mm = MemoryModel { s_m: 1122 * MB, s_d: 0 };
        assert_eq!(mm.memory(Scheme::SP, 3400, 100, 8) / MB, 1122 * 3400);
        assert_eq!(mm.memory_with_manager(Scheme::SP, 3400, 100, 8) / MB, 1122);
        assert_eq!(mm.memory(Scheme::SdDist, 3400, 100, 8) / MB, 112_200);
        assert_eq!(mm.memory(Scheme::FaDist, 3400, 100, 8) / MB, 8_976);
        assert_eq!(mm.memory(Scheme::FaDist, 3400, 100, 16) / MB, 17_952);
    }

    #[test]
    fn table3_imagenet_row() {
        let mm = MemoryModel { s_m: 3305 * MB, s_d: 0 };
        assert_eq!(mm.memory(Scheme::SdDist, 10_000, 1000, 8) / MB, 3_305_000);
        assert_eq!(mm.memory(Scheme::Parrot, 10_000, 1000, 8) / MB, 26_440);
        assert_eq!(mm.memory(Scheme::Parrot, 10_000, 1000, 16) / MB, 52_880);
    }

    #[test]
    fn state_manager_reduces_memory() {
        let mm = MemoryModel { s_m: 100 * MB, s_d: 10 * MB };
        // Schemes that hold all M client states in memory benefit from
        // spilling them to disk (Table 1, "Memory with state manager").
        for scheme in [Scheme::SP, Scheme::SdDist, Scheme::FaDist] {
            assert!(
                mm.memory_with_manager(scheme, 1000, 100, 8)
                    < mm.memory(scheme, 1000, 100, 8),
                "{scheme:?}"
            );
        }
        // Parrot's no-manager row is already O(s_m·K + s_d/M) in Table 1
        // (state assumed server-held): the manager trades that for
        // O(s_d·K) resident — both tiny; check the formulas directly.
        assert_eq!(
            mm.memory_with_manager(Scheme::Parrot, 1000, 100, 8),
            100 * MB * 8 + 10 * MB * 8
        );
        assert_eq!(mm.disk_with_manager(Scheme::Parrot, 1000), 10 * MB * 1000);
    }

    #[test]
    fn comm_table1_shape() {
        let s_a = 44 * MB;
        let s_e = 0;
        let (m_p, k) = (100, 8);
        let parrot = MemoryModel::comm_size(Scheme::Parrot, s_a, s_e, m_p, k);
        let fa = MemoryModel::comm_size(Scheme::FaDist, s_a, s_e, m_p, k);
        assert_eq!(parrot, s_a * 8);
        assert_eq!(fa, s_a * 100);
        assert_eq!(MemoryModel::comm_trips(Scheme::Parrot, m_p, k), 8);
        assert_eq!(MemoryModel::comm_trips(Scheme::SdDist, m_p, k), 100);
        // Special params can't be compressed below s_e * Mp:
        let with_special = MemoryModel::comm_size(Scheme::Parrot, s_a, MB, m_p, k);
        assert_eq!(with_special, s_a * 8 + MB * 100);
    }

    #[test]
    fn run_metrics_aggregation() {
        let mut rm = RunMetrics::default();
        for i in 0..4 {
            rm.push(RoundMetrics {
                round: i,
                wall_secs: (i + 1) as f64,
                bytes_up: 10,
                bytes_down: 5,
                trips: 3,
                state_bytes: 7,
                cross_group_bytes: 2,
                utilization: 0.5,
                eval_acc: if i == 3 { Some(0.9) } else { None },
                staleness_hist: vec![i, 1],
                ..Default::default()
            });
        }
        assert!((rm.mean_round_secs() - 2.5).abs() < 1e-12);
        assert!((rm.mean_round_secs_after(2) - 3.5).abs() < 1e-12);
        assert_eq!(rm.total_bytes(), 60);
        assert_eq!(rm.total_trips(), 12);
        assert_eq!(rm.total_state_bytes(), 28);
        assert_eq!(rm.total_cross_group_bytes(), 8);
        assert!((rm.mean_utilization() - 0.5).abs() < 1e-12);
        assert_eq!(rm.final_eval().1, Some(0.9));
        let js = rm.to_json(2).render();
        assert!(js.contains("\"mean_round_secs\":2.5"));
        assert!(js.contains("\"mean_round_secs_after_warmup\":3.5"));
        assert!(js.contains("\"mean_utilization\":0.5"));
        assert!(js.contains("\"total_state_bytes\":28"));
        assert!(js.contains("\"total_cross_group_bytes\":8"));
        assert!(js.contains("\"staleness_hist\":[3,1]"));
        let reg = rm.registry();
        assert_eq!(reg.get("deploy.rounds"), 4);
        assert_eq!(reg.get("deploy.bytes"), 60);
        assert_eq!(reg.hist("deploy.staleness").unwrap().count, 10);
    }
}
