//! Client selection strategies (Alg. 1/2: "server selects a set of
//! clients M^r") — §3.2 lists selection among the user-customizable
//! server-side functions, so it is a first-class pluggable here.
//!
//! All strategies are deterministic in `(seed, round)` so simulation
//! and TCP deployment pick identical cohorts (the zero-code-change
//! invariant extends to selection).

use crate::util::rng::Rng;
use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Selection {
    /// Uniform without replacement (the paper's default).
    Random,
    /// Deterministic sweep: round r takes clients [r·M_p, (r+1)·M_p) mod M.
    RoundRobin,
    /// Probability ∝ dataset size (importance-style sampling; favors
    /// big-data clients, stressing the scheduler's tail).
    SizeWeighted,
    /// Fixed cohort every round (debugging / convergence studies).
    Fixed(Vec<usize>),
}

impl Selection {
    pub fn parse(s: &str) -> Result<Selection> {
        if s == "random" {
            return Ok(Selection::Random);
        }
        if s == "round_robin" || s == "rr" {
            return Ok(Selection::RoundRobin);
        }
        if s == "size_weighted" || s == "size" {
            return Ok(Selection::SizeWeighted);
        }
        if let Some(list) = s.strip_prefix("fixed:") {
            let ids = list
                .split(',')
                .map(|t| t.trim().parse::<usize>())
                .collect::<Result<Vec<_>, _>>()?;
            if ids.is_empty() {
                bail!("fixed: needs at least one client id");
            }
            return Ok(Selection::Fixed(ids));
        }
        bail!("unknown selection {s:?} (random|round_robin|size_weighted|fixed:a,b,c)")
    }

    pub fn name(&self) -> String {
        match self {
            Selection::Random => "random".into(),
            Selection::RoundRobin => "round_robin".into(),
            Selection::SizeWeighted => "size_weighted".into(),
            Selection::Fixed(ids) => format!("fixed({})", ids.len()),
        }
    }

    /// Pick M^r for `round`. `sizes[m]` is client m's dataset size.
    pub fn select(
        &self,
        round: usize,
        m_total: usize,
        m_p: usize,
        sizes: &[usize],
        seed: u64,
    ) -> Vec<usize> {
        let m_p = m_p.min(m_total);
        match self {
            Selection::Random => {
                let mut rng = Rng::new(seed ^ 0x5E1E_C702).derive(round as u64);
                rng.choose(m_total, m_p)
            }
            Selection::RoundRobin => {
                (0..m_p).map(|i| (round * m_p + i) % m_total).collect()
            }
            Selection::SizeWeighted => {
                debug_assert_eq!(sizes.len(), m_total);
                let mut rng = Rng::new(seed ^ 0x512E_D0DE).derive(round as u64);
                // Weighted sampling without replacement via exponential
                // sort keys (Efraimidis–Spirakis): key = u^(1/w).
                let mut keyed: Vec<(f64, usize)> = sizes
                    .iter()
                    .enumerate()
                    .map(|(i, &w)| {
                        let u = rng.next_f64().max(1e-12);
                        (u.powf(1.0 / (w.max(1) as f64)), i)
                    })
                    .collect();
                keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                keyed.into_iter().take(m_p).map(|(_, i)| i).collect()
            }
            Selection::Fixed(ids) => ids.iter().take(m_p).cloned().collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sizes(m: usize) -> Vec<usize> {
        (0..m).map(|i| 10 + i * 5).collect()
    }

    #[test]
    fn parse_round_trip() {
        assert_eq!(Selection::parse("random").unwrap(), Selection::Random);
        assert_eq!(Selection::parse("rr").unwrap(), Selection::RoundRobin);
        assert_eq!(Selection::parse("size").unwrap(), Selection::SizeWeighted);
        assert_eq!(
            Selection::parse("fixed:1,2,3").unwrap(),
            Selection::Fixed(vec![1, 2, 3])
        );
        assert!(Selection::parse("wat").is_err());
        assert!(Selection::parse("fixed:").is_err());
    }

    #[test]
    fn all_strategies_distinct_valid_cohorts() {
        for sel in [Selection::Random, Selection::RoundRobin, Selection::SizeWeighted] {
            let picked = sel.select(3, 100, 20, &sizes(100), 7);
            assert_eq!(picked.len(), 20, "{}", sel.name());
            let mut sorted = picked.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 20, "{} produced duplicates", sel.name());
            assert!(picked.iter().all(|&c| c < 100));
        }
    }

    #[test]
    fn deterministic_per_round() {
        let s = Selection::Random;
        assert_eq!(s.select(5, 50, 10, &sizes(50), 1), s.select(5, 50, 10, &sizes(50), 1));
        assert_ne!(s.select(5, 50, 10, &sizes(50), 1), s.select(6, 50, 10, &sizes(50), 1));
    }

    #[test]
    fn round_robin_sweeps_everyone() {
        let s = Selection::RoundRobin;
        let mut seen = vec![false; 30];
        for r in 0..3 {
            for c in s.select(r, 30, 10, &sizes(30), 0) {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "3 rounds x 10 must cover 30 clients");
    }

    #[test]
    fn size_weighted_prefers_big_clients() {
        // client sizes 10..505; over many rounds the top decile should be
        // picked far more often than the bottom decile.
        let s = Selection::SizeWeighted;
        let sz = sizes(100);
        let mut counts = vec![0usize; 100];
        for r in 0..200 {
            for c in s.select(r, 100, 10, &sz, 3) {
                counts[c] += 1;
            }
        }
        let low: usize = counts[..10].iter().sum();
        let high: usize = counts[90..].iter().sum();
        assert!(high > 3 * low, "high {high} vs low {low}");
    }

    #[test]
    fn fixed_returns_exactly_the_cohort() {
        let s = Selection::Fixed(vec![4, 8, 15]);
        assert_eq!(s.select(9, 100, 10, &sizes(100), 0), vec![4, 8, 15]);
        assert_eq!(s.select(9, 100, 2, &sizes(100), 0), vec![4, 8]);
    }

    #[test]
    fn mp_clamped_to_m() {
        let picked = Selection::Random.select(0, 5, 50, &sizes(5), 1);
        assert_eq!(picked.len(), 5);
    }
}
