//! Client selection strategies (Alg. 1/2: "server selects a set of
//! clients M^r") — §3.2 lists selection among the user-customizable
//! server-side functions, so it is a first-class pluggable here.
//!
//! All strategies are deterministic in `(seed, round)` so simulation
//! and TCP deployment pick identical cohorts (the zero-code-change
//! invariant extends to selection).

use crate::util::rng::Rng;
use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Selection {
    /// Uniform without replacement (the paper's default).
    Random,
    /// Deterministic sweep: round r takes clients [r·M_p, (r+1)·M_p) mod M.
    RoundRobin,
    /// Probability ∝ dataset size (importance-style sampling; favors
    /// big-data clients, stressing the scheduler's tail).
    SizeWeighted,
    /// Fixed cohort every round (debugging / convergence studies).
    Fixed(Vec<usize>),
}

impl Selection {
    pub fn parse(s: &str) -> Result<Selection> {
        if s == "random" {
            return Ok(Selection::Random);
        }
        if s == "round_robin" || s == "rr" {
            return Ok(Selection::RoundRobin);
        }
        if s == "size_weighted" || s == "size" {
            return Ok(Selection::SizeWeighted);
        }
        if let Some(list) = s.strip_prefix("fixed:") {
            let ids = list
                .split(',')
                .map(|t| t.trim().parse::<usize>())
                .collect::<Result<Vec<_>, _>>()?;
            if ids.is_empty() {
                bail!("fixed: needs at least one client id");
            }
            return Ok(Selection::Fixed(ids));
        }
        bail!("unknown selection {s:?} (random|round_robin|size_weighted|fixed:a,b,c)")
    }

    pub fn name(&self) -> String {
        match self {
            Selection::Random => "random".into(),
            Selection::RoundRobin => "round_robin".into(),
            Selection::SizeWeighted => "size_weighted".into(),
            Selection::Fixed(ids) => format!("fixed({})", ids.len()),
        }
    }

    /// Pick M^r for `round`. `sizes[m]` is client m's dataset size.
    pub fn select(
        &self,
        round: usize,
        m_total: usize,
        m_p: usize,
        sizes: &[usize],
        seed: u64,
    ) -> Vec<usize> {
        let m_p = m_p.min(m_total);
        match self {
            Selection::Random => {
                let mut rng = Rng::new(seed ^ 0x5E1E_C702).derive(round as u64);
                rng.choose(m_total, m_p)
            }
            Selection::RoundRobin => {
                (0..m_p).map(|i| (round * m_p + i) % m_total).collect()
            }
            Selection::SizeWeighted => {
                debug_assert_eq!(sizes.len(), m_total);
                let mut rng = Rng::new(seed ^ 0x512E_D0DE).derive(round as u64);
                // Weighted sampling without replacement via exponential
                // sort keys (Efraimidis–Spirakis): key = u^(1/w).
                // Zero-size clients are *excluded* (weight 0 means "no
                // data to train on"), not silently promoted to weight 1.
                let mut keyed: Vec<(f64, usize)> = sizes
                    .iter()
                    .enumerate()
                    .filter(|&(_, &w)| w > 0)
                    .map(|(i, &w)| {
                        let u = rng.next_f64().max(1e-12);
                        (u.powf(1.0 / (w as f64)), i)
                    })
                    .collect();
                // total_cmp with an index tie-break: a NaN key (or an
                // exact tie) must never panic the sort or make the
                // cohort depend on sort internals — sim and deploy pick
                // this cohort from the same call, so it must be total.
                keyed.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                keyed.into_iter().take(m_p).map(|(_, i)| i).collect()
            }
            Selection::Fixed(ids) => ids.iter().take(m_p).cloned().collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sizes(m: usize) -> Vec<usize> {
        (0..m).map(|i| 10 + i * 5).collect()
    }

    #[test]
    fn parse_round_trip() {
        assert_eq!(Selection::parse("random").unwrap(), Selection::Random);
        assert_eq!(Selection::parse("rr").unwrap(), Selection::RoundRobin);
        assert_eq!(Selection::parse("size").unwrap(), Selection::SizeWeighted);
        assert_eq!(
            Selection::parse("fixed:1,2,3").unwrap(),
            Selection::Fixed(vec![1, 2, 3])
        );
        assert!(Selection::parse("wat").is_err());
        assert!(Selection::parse("fixed:").is_err());
    }

    #[test]
    fn all_strategies_distinct_valid_cohorts() {
        for sel in [Selection::Random, Selection::RoundRobin, Selection::SizeWeighted] {
            let picked = sel.select(3, 100, 20, &sizes(100), 7);
            assert_eq!(picked.len(), 20, "{}", sel.name());
            let mut sorted = picked.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 20, "{} produced duplicates", sel.name());
            assert!(picked.iter().all(|&c| c < 100));
        }
    }

    #[test]
    fn deterministic_per_round() {
        let s = Selection::Random;
        assert_eq!(s.select(5, 50, 10, &sizes(50), 1), s.select(5, 50, 10, &sizes(50), 1));
        assert_ne!(s.select(5, 50, 10, &sizes(50), 1), s.select(6, 50, 10, &sizes(50), 1));
    }

    #[test]
    fn round_robin_sweeps_everyone() {
        let s = Selection::RoundRobin;
        let mut seen = vec![false; 30];
        for r in 0..3 {
            for c in s.select(r, 30, 10, &sizes(30), 0) {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "3 rounds x 10 must cover 30 clients");
    }

    #[test]
    fn size_weighted_prefers_big_clients() {
        // client sizes 10..505; over many rounds the top decile should be
        // picked far more often than the bottom decile.
        let s = Selection::SizeWeighted;
        let sz = sizes(100);
        let mut counts = vec![0usize; 100];
        for r in 0..200 {
            for c in s.select(r, 100, 10, &sz, 3) {
                counts[c] += 1;
            }
        }
        let low: usize = counts[..10].iter().sum();
        let high: usize = counts[90..].iter().sum();
        assert!(high > 3 * low, "high {high} vs low {low}");
    }

    #[test]
    fn size_weighted_excludes_zero_size_clients() {
        // Regression: `w.max(1)` used to promote zero-size clients to
        // weight 1, so "no data" clients could still be selected.  They
        // must now be excluded entirely — and when fewer than M_p
        // clients have data, the cohort shrinks instead of padding with
        // empty clients.
        let s = Selection::SizeWeighted;
        let mut sz = vec![0usize; 40];
        for i in 0..8 {
            sz[i * 5] = 100; // only 8 clients have data
        }
        for r in 0..50 {
            let picked = s.select(r, 40, 10, &sz, 11);
            assert_eq!(picked.len(), 8, "round {r}: cohort must shrink to the data-holders");
            assert!(
                picked.iter().all(|&c| sz[c] > 0),
                "round {r}: zero-size client selected: {picked:?}"
            );
        }
        // Identical (seed, round, sizes) → identical cohort: the exact
        // call both the simulation driver and the deployed server make,
        // so sim and deploy keep picking the same clients.
        let a = s.select(3, 40, 10, &sz, 11);
        let b = s.select(3, 40, 10, &sz, 11);
        assert_eq!(a, b);
        // Tie-heavy weights (all equal) stay deterministic and panic-free
        // under the total_cmp + index tie-break.
        let flat = vec![7usize; 30];
        let x = s.select(0, 30, 12, &flat, 5);
        let y = s.select(0, 30, 12, &flat, 5);
        assert_eq!(x, y);
        assert_eq!(x.len(), 12);
    }

    #[test]
    fn fixed_returns_exactly_the_cohort() {
        let s = Selection::Fixed(vec![4, 8, 15]);
        assert_eq!(s.select(9, 100, 10, &sizes(100), 0), vec![4, 8, 15]);
        assert_eq!(s.select(9, 100, 2, &sizes(100), 0), vec![4, 8]);
    }

    #[test]
    fn mp_clamped_to_m() {
        let picked = Selection::Random.select(0, 5, 50, &sizes(5), 1);
        assert_eq!(picked.len(), 5);
    }
}
