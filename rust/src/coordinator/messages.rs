//! Wire protocol between the server manager and device executors.
//!
//! Two interaction styles, matching the schemes that run on real
//! compute:
//! - **Parrot**: one `Round` message down (params + task *set*), one
//!   `RoundDone` up (local aggregate G_k + runtime records) — O(K) trips.
//! - **FA Dist.** (FedScale/Flower-style): `Task` messages down one at a
//!   time, `TaskDone` up per client with the raw ClientUpdate — O(M_p)
//!   trips.  Used by the measured scheme-comparison experiments.

use crate::aggregation::{AggOp, ClientUpdate, DeviceAggregate, Payload};
use crate::algorithms::Broadcast;
use crate::compress::Codec;
use crate::model::ParamSet;
use crate::scheduler::TaskRecord;
use crate::util::codec::{Decoder, Encoder};
use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub enum Msg {
    /// Server → device: a full Parrot round.  `codec` is the upload
    /// compression negotiated for this round: the device must encode
    /// its `RoundDone` aggregate with it.
    Round { round: usize, broadcast: Broadcast, clients: Vec<usize>, codec: Codec },
    /// Server → device: one FA-style task (`codec` as in `Round`).
    Task { round: usize, broadcast: Broadcast, client: usize, codec: Codec },
    /// Server → device: FA round prologue when the device already holds
    /// this round's broadcast (params sent once per round per device).
    TaskCached { round: usize, client: usize },
    /// Server → device: end of run.
    Shutdown,
    /// Device → server: Parrot round result, aggregate tensors encoded
    /// with the round's negotiated codec.
    RoundDone {
        device: usize,
        aggregate: DeviceAggregate,
        records: Vec<TaskRecord>,
        busy_secs: f64,
        codec: Codec,
    },
    /// Device → server: FA-style single-task result (averaged-OP params
    /// encoded with the round codec; Collect entries verbatim).
    TaskDone { device: usize, update: ClientUpdate, record: TaskRecord, codec: Codec },
    /// Device → server: ready for work (FA pull model).
    Idle { device: usize },
    /// Server → owner worker: ship these clients' states (the server is
    /// about to prefetch them to the executors the round plan chose).
    StateFetch { round: usize, clients: Vec<u64> },
    /// State blobs in flight, three directions over the star topology:
    /// owner → server (fetch reply), server → executor (plan-driven
    /// prefetch, delivered before the `Round` it serves), and
    /// executor → server → owner (write-back return at round end).
    /// `None` marks a client with no state yet (first selection).
    /// Blobs ship verbatim — like §4.2's Collect entries they are raw
    /// algorithm state, outside the update-codec's scope.
    StatePut { round: usize, states: Vec<(u64, Option<Vec<u8>>)> },
    /// Bulk ownership move (device churn / resharding): everything a
    /// departing shard hosted, routed to the new owners.
    ShardTransfer { from_shard: u32, states: Vec<(u64, Vec<u8>)> },
    /// Server → device (async scheme): post-flush model refresh — the
    /// new global params and their version.  Devices compute every
    /// subsequent `AsyncTask` against this model until the next flush.
    AsyncFlush { version: u64, broadcast: Broadcast },
    /// Server → device (async scheme): one streaming task against the
    /// model version the device last received via `AsyncFlush` (echoed
    /// here as a protocol check).  The reply is a normal `TaskDone`;
    /// the server tracks the dispatch version for staleness weighting.
    AsyncTask { round: usize, client: usize, version: u64, codec: Codec },
    /// Server → device (grouped topology, `--topology groups:G`): a
    /// Parrot round addressed through the device's edge group.  The
    /// device replies `GroupDone`; the group-aggregator role merges the
    /// group's device aggregates with a
    /// [`TierAgg`](crate::aggregation::TierAgg) before anything crosses
    /// the WAN.
    GroupRound {
        round: usize,
        group: u32,
        broadcast: Broadcast,
        clients: Vec<usize>,
        codec: Codec,
    },
    /// Device → group aggregator: the grouped analogue of `RoundDone`,
    /// tagged with the device's group so the tier merge can route it.
    GroupDone {
        group: u32,
        device: usize,
        aggregate: DeviceAggregate,
        records: Vec<TaskRecord>,
        busy_secs: f64,
        codec: Codec,
    },
}

fn encode_broadcast(enc: &mut Encoder, bc: &Broadcast) -> Result<()> {
    enc.put_u32(bc.round as u32);
    bc.params.encode(enc)?;
    match &bc.extra {
        None => enc.put_u8(0),
        Some(p) => {
            enc.put_u8(1);
            p.encode(enc)?;
        }
    }
    Ok(())
}

fn decode_broadcast(dec: &mut Decoder) -> Result<Broadcast> {
    let round = dec.u32()? as usize;
    let params = ParamSet::decode(dec)?;
    let extra = match dec.u8()? {
        0 => None,
        1 => Some(ParamSet::decode(dec)?),
        t => bail!("bad extra tag {t}"),
    };
    Ok(Broadcast { round, params, extra })
}

fn encode_update(enc: &mut Encoder, u: &ClientUpdate, codec: Codec) -> Result<()> {
    enc.put_u32(u.client as u32);
    enc.put_f64(u.weight);
    enc.put_len(u.entries.len())?;
    for (name, op, p) in &u.entries {
        enc.put_str(name)?;
        enc.put_u8(match op {
            AggOp::WeightedAvg => 0,
            AggOp::Avg => 1,
            AggOp::Sum => 2,
            AggOp::Collect => 3,
        });
        // Special Params (Collect) always ship verbatim (§4.2).
        let c = if *op == AggOp::Collect { Codec::None } else { codec };
        p.encode_with(enc, c)?;
    }
    Ok(())
}

fn decode_update(dec: &mut Decoder) -> Result<ClientUpdate> {
    let client = dec.u32()? as usize;
    let weight = dec.f64()?;
    // An entry is at least name(4) + op(1) + payload tag(1) bytes.
    let n = dec.count(6)?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let name = dec.str()?;
        let op = match dec.u8()? {
            0 => AggOp::WeightedAvg,
            1 => AggOp::Avg,
            2 => AggOp::Sum,
            3 => AggOp::Collect,
            t => bail!("bad op code {t}"),
        };
        entries.push((name, op, Payload::decode(dec)?));
    }
    Ok(ClientUpdate { client, weight, entries })
}

fn encode_record(enc: &mut Encoder, r: &TaskRecord) {
    enc.put_u32(r.round as u32);
    enc.put_u32(r.device as u32);
    enc.put_u32(r.n_samples as u32);
    enc.put_f64(r.secs);
}

fn decode_record(dec: &mut Decoder) -> Result<TaskRecord> {
    Ok(TaskRecord {
        round: dec.u32()? as usize,
        device: dec.u32()? as usize,
        n_samples: dec.u32()? as usize,
        secs: dec.f64()?,
    })
}

impl Msg {
    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut enc = Encoder::new();
        match self {
            Msg::Round { round, broadcast, clients, codec } => {
                enc.put_u8(0);
                enc.put_u32(*round as u32);
                codec.encode_meta(&mut enc);
                encode_broadcast(&mut enc, broadcast)?;
                enc.put_len(clients.len())?;
                for &c in clients {
                    enc.put_u32(c as u32);
                }
            }
            Msg::Task { round, broadcast, client, codec } => {
                enc.put_u8(1);
                enc.put_u32(*round as u32);
                codec.encode_meta(&mut enc);
                encode_broadcast(&mut enc, broadcast)?;
                enc.put_u32(*client as u32);
            }
            Msg::TaskCached { round, client } => {
                enc.put_u8(2);
                enc.put_u32(*round as u32);
                enc.put_u32(*client as u32);
            }
            Msg::Shutdown => enc.put_u8(3),
            Msg::RoundDone { device, aggregate, records, busy_secs, codec } => {
                enc.put_u8(4);
                enc.put_u32(*device as u32);
                codec.encode_meta(&mut enc);
                enc.put_bytes(&aggregate.encoded_with(*codec)?)?;
                enc.put_len(records.len())?;
                for r in records {
                    encode_record(&mut enc, r);
                }
                enc.put_f64(*busy_secs);
            }
            Msg::TaskDone { device, update, record, codec } => {
                enc.put_u8(5);
                enc.put_u32(*device as u32);
                codec.encode_meta(&mut enc);
                encode_update(&mut enc, update, *codec)?;
                encode_record(&mut enc, record);
            }
            Msg::Idle { device } => {
                enc.put_u8(6);
                enc.put_u32(*device as u32);
            }
            Msg::StateFetch { round, clients } => {
                enc.put_u8(7);
                enc.put_u32(*round as u32);
                enc.put_len(clients.len())?;
                for &c in clients {
                    enc.put_u64(c);
                }
            }
            Msg::StatePut { round, states } => {
                enc.put_u8(8);
                enc.put_u32(*round as u32);
                enc.put_len(states.len())?;
                for (c, bytes) in states {
                    enc.put_u64(*c);
                    match bytes {
                        None => enc.put_u8(0),
                        Some(b) => {
                            enc.put_u8(1);
                            enc.put_bytes(b)?;
                        }
                    }
                }
            }
            Msg::ShardTransfer { from_shard, states } => {
                enc.put_u8(9);
                enc.put_u32(*from_shard);
                enc.put_len(states.len())?;
                for (c, bytes) in states {
                    enc.put_u64(*c);
                    enc.put_bytes(bytes)?;
                }
            }
            Msg::AsyncFlush { version, broadcast } => {
                enc.put_u8(10);
                enc.put_u64(*version);
                encode_broadcast(&mut enc, broadcast)?;
            }
            Msg::AsyncTask { round, client, version, codec } => {
                enc.put_u8(11);
                enc.put_u32(*round as u32);
                enc.put_u32(*client as u32);
                enc.put_u64(*version);
                codec.encode_meta(&mut enc);
            }
            Msg::GroupRound { round, group, broadcast, clients, codec } => {
                enc.put_u8(12);
                enc.put_u32(*round as u32);
                enc.put_u32(*group);
                codec.encode_meta(&mut enc);
                encode_broadcast(&mut enc, broadcast)?;
                enc.put_len(clients.len())?;
                for &c in clients {
                    enc.put_u32(c as u32);
                }
            }
            Msg::GroupDone { group, device, aggregate, records, busy_secs, codec } => {
                enc.put_u8(13);
                enc.put_u32(*group);
                enc.put_u32(*device as u32);
                codec.encode_meta(&mut enc);
                enc.put_bytes(&aggregate.encoded_with(*codec)?)?;
                enc.put_len(records.len())?;
                for r in records {
                    encode_record(&mut enc, r);
                }
                enc.put_f64(*busy_secs);
            }
        }
        Ok(enc.finish())
    }

    pub fn decode(buf: &[u8]) -> Result<Msg> {
        let mut dec = Decoder::new(buf);
        let tag = dec.u8()?;
        Ok(match tag {
            0 => {
                let round = dec.u32()? as usize;
                let codec = Codec::decode_meta(&mut dec)?;
                let broadcast = decode_broadcast(&mut dec)?;
                let n = dec.count(4)?;
                let mut clients = Vec::with_capacity(n);
                for _ in 0..n {
                    clients.push(dec.u32()? as usize);
                }
                Msg::Round { round, broadcast, clients, codec }
            }
            1 => {
                let round = dec.u32()? as usize;
                let codec = Codec::decode_meta(&mut dec)?;
                Msg::Task {
                    round,
                    broadcast: decode_broadcast(&mut dec)?,
                    client: dec.u32()? as usize,
                    codec,
                }
            }
            2 => Msg::TaskCached { round: dec.u32()? as usize, client: dec.u32()? as usize },
            3 => Msg::Shutdown,
            4 => {
                let device = dec.u32()? as usize;
                let codec = Codec::decode_meta(&mut dec)?;
                let agg_bytes = dec.bytes()?;
                let aggregate = DeviceAggregate::decode(&agg_bytes)?;
                // A task record is 4 + 4 + 4 + 8 bytes on the wire.
                let n = dec.count(20)?;
                let mut records = Vec::with_capacity(n);
                for _ in 0..n {
                    records.push(decode_record(&mut dec)?);
                }
                let busy_secs = dec.f64()?;
                Msg::RoundDone { device, aggregate, records, busy_secs, codec }
            }
            5 => {
                let device = dec.u32()? as usize;
                let codec = Codec::decode_meta(&mut dec)?;
                Msg::TaskDone {
                    device,
                    update: decode_update(&mut dec)?,
                    record: decode_record(&mut dec)?,
                    codec,
                }
            }
            6 => Msg::Idle { device: dec.u32()? as usize },
            7 => {
                let round = dec.u32()? as usize;
                // Each client id is 8 wire bytes.
                let n = dec.count(8)?;
                let mut clients = Vec::with_capacity(n);
                for _ in 0..n {
                    clients.push(dec.u64()?);
                }
                Msg::StateFetch { round, clients }
            }
            8 => {
                let round = dec.u32()? as usize;
                // An entry is at least id(8) + presence(1) bytes.
                let n = dec.count(9)?;
                let mut states = Vec::with_capacity(n);
                for _ in 0..n {
                    let client = dec.u64()?;
                    let bytes = match dec.u8()? {
                        0 => None,
                        1 => Some(dec.bytes()?),
                        t => bail!("bad state presence tag {t}"),
                    };
                    states.push((client, bytes));
                }
                Msg::StatePut { round, states }
            }
            9 => {
                let from_shard = dec.u32()?;
                // An entry is at least id(8) + length prefix(4) bytes.
                let n = dec.count(12)?;
                let mut states = Vec::with_capacity(n);
                for _ in 0..n {
                    let client = dec.u64()?;
                    states.push((client, dec.bytes()?));
                }
                Msg::ShardTransfer { from_shard, states }
            }
            10 => {
                let version = dec.u64()?;
                Msg::AsyncFlush { version, broadcast: decode_broadcast(&mut dec)? }
            }
            11 => {
                let round = dec.u32()? as usize;
                let client = dec.u32()? as usize;
                let version = dec.u64()?;
                let codec = Codec::decode_meta(&mut dec)?;
                Msg::AsyncTask { round, client, version, codec }
            }
            12 => {
                let round = dec.u32()? as usize;
                let group = dec.u32()?;
                let codec = Codec::decode_meta(&mut dec)?;
                let broadcast = decode_broadcast(&mut dec)?;
                // Each client id is 4 wire bytes.
                let n = dec.count(4)?;
                let mut clients = Vec::with_capacity(n);
                for _ in 0..n {
                    clients.push(dec.u32()? as usize);
                }
                Msg::GroupRound { round, group, broadcast, clients, codec }
            }
            13 => {
                let group = dec.u32()?;
                let device = dec.u32()? as usize;
                let codec = Codec::decode_meta(&mut dec)?;
                let agg_bytes = dec.bytes()?;
                let aggregate = DeviceAggregate::decode(&agg_bytes)?;
                // A task record is 4 + 4 + 4 + 8 bytes on the wire.
                let n = dec.count(20)?;
                let mut records = Vec::with_capacity(n);
                for _ in 0..n {
                    records.push(decode_record(&mut dec)?);
                }
                let busy_secs = dec.f64()?;
                Msg::GroupDone { group, device, aggregate, records, busy_secs, codec }
            }
            t => bail!("unknown msg tag {t}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::LocalAgg;

    fn params(v: f32) -> ParamSet {
        ParamSet { shapes: vec![vec![2, 2]], tensors: vec![vec![v; 4]] }
    }

    #[test]
    fn round_msg_round_trip() {
        let m = Msg::Round {
            round: 7,
            broadcast: Broadcast { round: 7, params: params(1.5), extra: Some(params(0.5)) },
            clients: vec![3, 1, 4, 1, 5],
            codec: Codec::TopK(0.25),
        };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::Round { round, broadcast, clients, codec } => {
                assert_eq!(round, 7);
                assert_eq!(broadcast.params, params(1.5));
                assert_eq!(broadcast.extra, Some(params(0.5)));
                assert_eq!(clients, vec![3, 1, 4, 1, 5]);
                assert!(matches!(codec, Codec::TopK(f) if (f - 0.25).abs() < 1e-6));
            }
            other => panic!("Msg::Round must round-trip to itself, decoded {other:?}"),
        }
    }

    #[test]
    fn round_done_round_trip() {
        let mut la = LocalAgg::new(3);
        la.add(&ClientUpdate {
            client: 1,
            weight: 2.0,
            entries: vec![("delta".into(), AggOp::WeightedAvg, Payload::Params(params(1.0)))],
        });
        let m = Msg::RoundDone {
            device: 3,
            aggregate: la.finish(),
            records: vec![TaskRecord { round: 1, device: 3, n_samples: 40, secs: 1.25 }],
            busy_secs: 2.5,
            codec: Codec::None,
        };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::RoundDone { device, records, busy_secs, codec, .. } => {
                assert_eq!(device, 3);
                assert_eq!(records.len(), 1);
                assert_eq!(records[0].secs, 1.25);
                assert_eq!(busy_secs, 2.5);
                assert_eq!(codec, Codec::None);
            }
            other => panic!("Msg::RoundDone must round-trip to itself, decoded {other:?}"),
        }
    }

    #[test]
    fn compressed_round_done_shrinks_and_stays_in_bound() {
        // The negotiated codec actually bites on the wire: the encoded
        // RoundDone frame shrinks, and the decoded aggregate matches
        // the original within the codec's documented bound.
        let mk = |codec: Codec| {
            let mut la = LocalAgg::new(1);
            for c in 0..3 {
                la.add(&ClientUpdate {
                    client: c,
                    weight: 2.0,
                    entries: vec![(
                        "delta".into(),
                        AggOp::WeightedAvg,
                        Payload::Params(ParamSet::init_he(&[vec![64, 32]], c as u64 + 1)),
                    )],
                });
            }
            Msg::RoundDone {
                device: 1,
                aggregate: la.finish(),
                records: vec![],
                busy_secs: 0.0,
                codec,
            }
            .encode()
            .unwrap()
        };
        let raw = mk(Codec::None);
        for codec in [Codec::Fp16, Codec::QInt8, Codec::TopK(0.1)] {
            let wire = mk(codec);
            assert!(
                wire.len() < raw.len(),
                "{codec:?}: {} !< {}",
                wire.len(),
                raw.len()
            );
            assert!(matches!(Msg::decode(&wire).unwrap(), Msg::RoundDone { .. }));
        }
    }

    #[test]
    fn task_done_round_trip() {
        let m = Msg::TaskDone {
            device: 2,
            update: ClientUpdate {
                client: 9,
                weight: 3.0,
                entries: vec![
                    ("delta".into(), AggOp::WeightedAvg, Payload::Params(params(2.0))),
                    ("tau".into(), AggOp::Collect, Payload::Scalar(5.0)),
                ],
            },
            record: TaskRecord { round: 0, device: 2, n_samples: 60, secs: 0.5 },
            codec: Codec::Fp16,
        };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::TaskDone { update, codec, .. } => {
                assert_eq!(update.client, 9);
                assert_eq!(update.entries.len(), 2);
                assert_eq!(update.entries[1].1, AggOp::Collect);
                // params(2.0) is exactly representable in fp16
                assert_eq!(update.entries[0].2, Payload::Params(params(2.0)));
                assert_eq!(codec, Codec::Fp16);
            }
            other => panic!("Msg::TaskDone must round-trip to itself, decoded {other:?}"),
        }
    }

    #[test]
    fn small_variants() {
        assert!(matches!(
            Msg::decode(&Msg::Shutdown.encode().unwrap()).unwrap(),
            Msg::Shutdown
        ));
        assert!(matches!(
            Msg::decode(&Msg::Idle { device: 4 }.encode().unwrap()).unwrap(),
            Msg::Idle { device: 4 }
        ));
        assert!(matches!(
            Msg::decode(&Msg::TaskCached { round: 2, client: 11 }.encode().unwrap()).unwrap(),
            Msg::TaskCached { round: 2, client: 11 }
        ));
    }

    #[test]
    fn state_messages_round_trip() {
        let m = Msg::StateFetch { round: 4, clients: vec![9, 1, 1 << 40] };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::StateFetch { round, clients } => {
                assert_eq!(round, 4);
                assert_eq!(clients, vec![9, 1, 1 << 40]);
            }
            other => panic!("Msg::StateFetch must round-trip to itself, decoded {other:?}"),
        }
        let m = Msg::StatePut {
            round: 7,
            states: vec![(3, Some(vec![1, 2, 3])), (11, None), (42, Some(Vec::new()))],
        };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::StatePut { round, states } => {
                assert_eq!(round, 7);
                assert_eq!(states.len(), 3);
                assert_eq!(states[0], (3, Some(vec![1, 2, 3])));
                assert_eq!(states[1], (11, None));
                assert_eq!(states[2], (42, Some(Vec::new())));
            }
            other => panic!("Msg::StatePut must round-trip to itself, decoded {other:?}"),
        }
        let m = Msg::ShardTransfer {
            from_shard: 2,
            states: vec![(5, vec![9u8; 64]), (6, vec![])],
        };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::ShardTransfer { from_shard, states } => {
                assert_eq!(from_shard, 2);
                assert_eq!(states[0].1.len(), 64);
                assert_eq!(states[1], (6, Vec::new()));
            }
            other => panic!("Msg::ShardTransfer must round-trip to itself, decoded {other:?}"),
        }
    }

    #[test]
    fn async_messages_round_trip() {
        let m = Msg::AsyncFlush {
            version: 1 << 40,
            broadcast: Broadcast { round: 3, params: params(2.5), extra: None },
        };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::AsyncFlush { version, broadcast } => {
                assert_eq!(version, 1 << 40);
                assert_eq!(broadcast.round, 3);
                assert_eq!(broadcast.params, params(2.5));
                assert_eq!(broadcast.extra, None);
            }
            other => panic!("Msg::AsyncFlush must round-trip to itself, decoded {other:?}"),
        }
        let m = Msg::AsyncTask { round: 9, client: 1234, version: 7, codec: Codec::QInt8 };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::AsyncTask { round, client, version, codec } => {
                assert_eq!((round, client, version), (9, 1234, 7));
                assert_eq!(codec, Codec::QInt8);
            }
            other => panic!("Msg::AsyncTask must round-trip to itself, decoded {other:?}"),
        }
        // Truncated async frames error cleanly (bounds-check discipline).
        let buf = m.encode().unwrap();
        for cut in 0..buf.len() {
            assert!(Msg::decode(&buf[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn group_messages_round_trip() {
        let m = Msg::GroupRound {
            round: 5,
            group: 3,
            broadcast: Broadcast { round: 5, params: params(1.0), extra: None },
            clients: vec![9, 2, 7],
            codec: Codec::QInt8,
        };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::GroupRound { round, group, broadcast, clients, codec } => {
                assert_eq!((round, group), (5, 3));
                assert_eq!(broadcast.params, params(1.0));
                assert_eq!(clients, vec![9, 2, 7]);
                assert_eq!(codec, Codec::QInt8);
            }
            other => panic!("Msg::GroupRound must round-trip to itself, decoded {other:?}"),
        }
        let mut la = LocalAgg::new(2);
        la.add(&ClientUpdate {
            client: 4,
            weight: 1.5,
            entries: vec![("delta".into(), AggOp::WeightedAvg, Payload::Params(params(2.0)))],
        });
        let m = Msg::GroupDone {
            group: 1,
            device: 2,
            aggregate: la.finish(),
            records: vec![TaskRecord { round: 5, device: 2, n_samples: 30, secs: 0.75 }],
            busy_secs: 1.5,
            codec: Codec::None,
        };
        match Msg::decode(&m.encode().unwrap()).unwrap() {
            Msg::GroupDone { group, device, aggregate, records, busy_secs, codec } => {
                assert_eq!((group, device), (1, 2));
                assert_eq!(aggregate.n_clients, 1);
                assert_eq!(records.len(), 1);
                assert_eq!(busy_secs, 1.5);
                assert_eq!(codec, Codec::None);
            }
            other => panic!("Msg::GroupDone must round-trip to itself, decoded {other:?}"),
        }
        // Truncated group frames error cleanly.
        let buf = m.encode().unwrap();
        for cut in 0..buf.len() {
            assert!(Msg::decode(&buf[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn state_messages_reject_hostile_counts() {
        // A huge entry count with no backing bytes must error before
        // any allocation (the count() bounds-check discipline).
        let mut enc = crate::util::codec::Encoder::new();
        enc.put_u8(7);
        enc.put_u32(0);
        enc.put_u32(u32::MAX);
        assert!(Msg::decode(&enc.finish()).is_err());
        let mut enc = crate::util::codec::Encoder::new();
        enc.put_u8(8);
        enc.put_u32(0);
        enc.put_u32(u32::MAX);
        assert!(Msg::decode(&enc.finish()).is_err());
        let mut enc = crate::util::codec::Encoder::new();
        enc.put_u8(9);
        enc.put_u32(0);
        enc.put_u32(u32::MAX);
        assert!(Msg::decode(&enc.finish()).is_err());
        // A blob length prefix past the frame end errors too.
        let mut enc = crate::util::codec::Encoder::new();
        enc.put_u8(8);
        enc.put_u32(0);
        enc.put_u32(1);
        enc.put_u64(3);
        enc.put_u8(1);
        enc.put_u32(u32::MAX); // blob length, no payload
        assert!(Msg::decode(&enc.finish()).is_err());
    }

    #[test]
    fn garbage_rejected() {
        assert!(Msg::decode(&[99]).is_err());
        assert!(Msg::decode(&[]).is_err());
        let mut good = Msg::Shutdown.encode().unwrap();
        good.push(42); // trailing garbage tolerated? No - decode only reads 1 byte; fine.
        assert!(Msg::decode(&good).is_ok());
    }
}
