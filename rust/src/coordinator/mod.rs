//! The Parrot coordinator (paper §3, Alg. 2): leader (server manager) +
//! sequential device executors, wired over any [`Transport`].
//!
//! - [`messages`] — the wire protocol between server and devices.
//! - [`worker`] — `Device_Executes`: sequential client training through
//!   the PJRT runtime, state-manager loads/saves, local aggregation,
//!   heterogeneity sleep injection (Appendix A).
//! - [`server`] — `Server_Executes`: client selection, Alg.-3
//!   scheduling, broadcast, global aggregation, algorithm server-update,
//!   periodic evaluation.
//! - [`metrics`] — measured per-round accounting (comm bytes/trips,
//!   busy times, utilization) feeding the Table-1/Fig-4 harnesses.
//! - [`asyncbuf`] — the buffered-flush ledger behind `--scheme async`
//!   (when to flush, staleness weights, discard decisions), shared by
//!   the streaming server loop and the sim-vs-deploy differential.

pub mod asyncbuf;
pub mod messages;
pub mod metrics;
pub mod selection;
pub mod server;
pub mod worker;

pub use asyncbuf::{FlushLedger, FlushPolicy, UpdateDecision};
pub use messages::Msg;
pub use metrics::{MemoryModel, RoundMetrics, RunMetrics};
pub use selection::Selection;
pub use server::{run_simulation, Server, TrainSummary};
pub use worker::Worker;
