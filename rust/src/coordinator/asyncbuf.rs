//! The deploy-side flush ledger for asynchronous buffered aggregation
//! (`--scheme async`): pure bookkeeping over the arrival stream of
//! client updates — when to flush, each update's staleness, its
//! discount weight, and whether it is applied or discarded.
//!
//! The real [`Server`](crate::coordinator::Server) drives this ledger
//! with live `TaskDone` arrivals; `parrot exp asyncscale --smoke`
//! replays the virtual engine's recorded arrival sequence through a
//! fresh ledger and asserts both sides agree on every flush counter —
//! the async analogue of the statescale sim-vs-deploy differential.
//! Keeping the policy here (transport-free, engine-free) is what makes
//! that differential meaningful: the engine accounts flushes
//! independently inside its event loop.

use crate::aggregation::StalenessWeight;

/// The flush policy knobs (`--buffer`, `--max-staleness`,
/// `--staleness-weight`).
#[derive(Debug, Clone, Copy)]
pub struct FlushPolicy {
    /// Client updates per flush (≥ 1; the CLI's `0 = M_p` convention is
    /// resolved by the caller).
    pub buffer: usize,
    pub max_staleness: usize,
    pub weight: StalenessWeight,
}

/// Per-update outcome of one flush, in arrival order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UpdateDecision {
    /// Model version the update was computed against.
    pub born: u64,
    /// Flushes applied between its dispatch and this flush.
    pub staleness: usize,
    /// Discount factor (0.0 when discarded).
    pub weight: f64,
    pub applied: bool,
}

/// Arrival-ordered flush bookkeeping (see module docs).
#[derive(Debug)]
pub struct FlushLedger {
    policy: FlushPolicy,
    version: u64,
    pending: Vec<u64>,
    /// Flushes applied so far.
    pub flushes: usize,
    /// Updates applied across all flushes.
    pub applied: usize,
    /// Updates discarded for exceeding `max_staleness`.
    pub stale_dropped: usize,
    /// `staleness_hist[s]` = applied updates that were s flushes old.
    pub staleness_hist: Vec<usize>,
}

impl FlushLedger {
    pub fn new(policy: FlushPolicy) -> FlushLedger {
        assert!(policy.buffer >= 1, "flush buffer must be >= 1");
        FlushLedger {
            version: 0,
            pending: Vec::new(),
            flushes: 0,
            applied: 0,
            stale_dropped: 0,
            staleness_hist: vec![0; policy.max_staleness + 1],
            policy,
        }
    }

    /// Current global model version (== flushes applied).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Updates buffered toward the next flush.
    pub fn buffered(&self) -> usize {
        self.pending.len()
    }

    /// Record one arrived update computed against model version `born`.
    /// Returns the per-update decisions when this arrival fills the
    /// buffer and a flush must run (the ledger has already advanced its
    /// version by then).
    pub fn on_update(&mut self, born: u64) -> Option<Vec<UpdateDecision>> {
        debug_assert!(born <= self.version, "updates cannot come from the future");
        self.pending.push(born);
        if self.pending.len() >= self.policy.buffer {
            return Some(self.flush());
        }
        None
    }

    /// Drain any partial buffer at end of stream (returns `None` when
    /// nothing is pending).
    pub fn finalize(&mut self) -> Option<Vec<UpdateDecision>> {
        if self.pending.is_empty() {
            return None;
        }
        Some(self.flush())
    }

    fn flush(&mut self) -> Vec<UpdateDecision> {
        let borns = std::mem::take(&mut self.pending);
        let decisions = borns
            .into_iter()
            .map(|born| {
                let staleness = (self.version - born) as usize;
                if staleness > self.policy.max_staleness {
                    self.stale_dropped += 1;
                    UpdateDecision { born, staleness, weight: 0.0, applied: false }
                } else {
                    self.staleness_hist[staleness] += 1;
                    self.applied += 1;
                    UpdateDecision {
                        born,
                        staleness,
                        weight: self.policy.weight.weight(staleness),
                        applied: true,
                    }
                }
            })
            .collect();
        self.version += 1;
        self.flushes += 1;
        decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(buffer: usize, max_staleness: usize) -> FlushPolicy {
        FlushPolicy { buffer, max_staleness, weight: StalenessWeight::Poly(0.5) }
    }

    #[test]
    fn flushes_every_buffer_arrivals_and_weights_by_staleness() {
        let mut l = FlushLedger::new(policy(3, 2));
        assert!(l.on_update(0).is_none());
        assert!(l.on_update(0).is_none());
        let d = l.on_update(0).expect("third arrival fills the buffer");
        assert_eq!(d.len(), 3);
        assert!(d.iter().all(|x| x.applied && x.staleness == 0 && x.weight == 1.0));
        assert_eq!(l.version(), 1);
        // An update born before that flush is one flush stale now.
        l.on_update(0);
        l.on_update(1);
        let d = l.on_update(1).unwrap();
        assert_eq!(d[0].staleness, 1);
        assert!((d[0].weight - (2.0f64).powf(-0.5)).abs() < 1e-12);
        assert_eq!(d[1].staleness, 0);
        assert_eq!(l.flushes, 2);
        assert_eq!(l.applied, 6);
        assert_eq!(l.staleness_hist, vec![5, 1, 0]);
    }

    #[test]
    fn stale_updates_are_discarded_not_applied() {
        let mut l = FlushLedger::new(policy(1, 0));
        l.on_update(0); // v 0 -> 1
        l.on_update(1); // v 1 -> 2
        let d = l.on_update(0).unwrap(); // staleness 2 > 0
        assert!(!d[0].applied);
        assert_eq!(d[0].weight, 0.0);
        assert_eq!(l.stale_dropped, 1);
        assert_eq!(l.applied, 2);
        assert_eq!(l.flushes, 3, "a discarded batch still advances the version");
    }

    #[test]
    fn finalize_drains_the_partial_tail() {
        let mut l = FlushLedger::new(policy(4, 1));
        assert!(l.finalize().is_none(), "nothing buffered yet");
        l.on_update(0);
        l.on_update(0);
        let d = l.finalize().expect("partial flush");
        assert_eq!(d.len(), 2);
        assert_eq!(l.flushes, 1);
        assert!(l.finalize().is_none());
    }
}
