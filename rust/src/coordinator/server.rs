//! `Server_Executes` (Alg. 2): the leader.
//!
//! Per round: select M_p clients → Task_Schedule (Alg. 3) → broadcast
//! Θ^r + task sets → collect device aggregates → GlobalAggregate →
//! algorithm server-update → (optionally) evaluate on the held-out set.
//! All communication is metered (bytes, trips) for the Table-1/Fig-5
//! measured comparisons.
//!
//! Two wire modes (see `messages`): Parrot batch mode (O(K) trips) and
//! FA pull mode (O(M_p) trips, no local aggregation) — the latter is the
//! faithful FedScale/Flower-style baseline on identical compute.

use crate::aggregation::{
    ClientUpdate, DeviceAggregate, GlobalAgg, LocalAgg, RoundAggregate, TierAgg,
};
use crate::algorithms::{Algo, Broadcast, ServerCtx, ServerState};
use crate::config::{RunConfig, Scheme};
use crate::coordinator::asyncbuf::{FlushLedger, FlushPolicy, UpdateDecision};
use crate::coordinator::messages::Msg;
use crate::coordinator::metrics::{RoundMetrics, RunMetrics};
use crate::coordinator::worker::{build_dataset, initial_params, Worker};
use crate::data::FederatedDataset;
use crate::model::params::AggPool;
use crate::model::ParamSet;
use crate::obs::{chrome, EvKind, Tracer, Track};
use crate::runtime::{Executable, Runtime};
use crate::scheduler::{AffinityCtx, Scheduler, TaskRecord};
use crate::statestore::ShardMap;
use crate::transport::{local, Transport};
use crate::util::timer::Stopwatch;
use anyhow::{bail, Context, Result};

/// Final outcome of a run.
#[derive(Debug)]
pub struct TrainSummary {
    pub metrics: RunMetrics,
    pub final_params: ParamSet,
    pub final_loss: Option<f64>,
    pub final_acc: Option<f64>,
}

/// Deferred async dispatches awaiting a state-prefetch reply:
/// client → FIFO of (device, cohort) reservations.
type PendingFetch = std::collections::HashMap<u64, std::collections::VecDeque<(usize, usize)>>;

/// Mutable dispatch state of the streaming async loop.
struct AsyncLoop {
    /// Remaining (cohort, client) stream in selection order.
    queue: std::collections::VecDeque<(usize, usize)>,
    /// Outstanding task per device: (cohort, client, born version).
    outstanding: Vec<Option<(usize, usize, u64)>>,
    pending_fetch: PendingFetch,
    /// Devices parked by the staleness gate, re-dispatched post-flush.
    idle: Vec<usize>,
    /// Dispatched-but-unapplied updates (in flight + buffered) — the
    /// same pipeline-depth gate the virtual dispatcher enforces, so
    /// deploy staleness stays within `max_staleness` by construction
    /// instead of silently discarding most of the cluster's work when
    /// K exceeds the window.
    pending: usize,
    /// Gate: `buffer · (max_staleness + 1)`.
    window: usize,
}

/// Per-flush-interval meters of the streaming async loop.
#[derive(Debug, Default)]
struct AsyncMeters {
    bytes_down: u64,
    bytes_up: u64,
    trips: u64,
    state_bytes: u64,
    state_msgs: u64,
    busy: f64,
}

pub struct Server<T: Transport> {
    transport: T,
    cfg: RunConfig,
    algo: Algo,
    global: ParamSet,
    sstate: ServerState,
    scheduler: Scheduler,
    dataset: FederatedDataset,
    eval_exe: Option<Executable>,
    /// Ownership ring of the sharded client-state store (None = legacy
    /// local state, or a stateless algorithm).
    state_shards: Option<ShardMap>,
    pub metrics: RunMetrics,
    /// Wallclock tracer (`--trace PATH`): the same typed span API the
    /// virtual engine records into, stamped in seconds since server
    /// construction.  `None` = tracing off (a branch per emission).
    tracer: Option<Tracer>,
    run_sw: Stopwatch,
    /// Running task index for trace labelling.
    task_seq: usize,
    /// Size-class buffer pool reused across rounds by the tier-fold and
    /// global merges (decoded aggregates recycle into it after merging).
    pool: AggPool,
}

impl<T: Transport> Server<T> {
    pub fn new(transport: T, cfg: RunConfig) -> Result<Server<T>> {
        anyhow::ensure!(transport.id() == 0, "server must be endpoint 0");
        let algo = Algo::parse(&cfg.algorithm, cfg.mu)?;
        let global = initial_params(&cfg)?;
        let mut scheduler = Scheduler::new(cfg.scheduler, cfg.warmup_rounds, cfg.n_devices);
        // The real coordinator reports Fig. 8 scheduling overhead in
        // wallclock seconds; the scheduler itself stays clock-free and
        // books 0.0 unless a consumer injects one.
        scheduler.set_wall_clock(crate::util::timer::wall_secs);
        let dataset = build_dataset(&cfg);
        let eval_exe = if cfg.eval_every > 0 {
            let rt = Runtime::cpu(&cfg.artifact_dir)?;
            Some(rt.load(&cfg.artifact("eval"))?)
        } else {
            None
        };
        let state_shards = (cfg.state_shards > 0 && algo.stateful())
            .then(|| ShardMap::new(cfg.state_shards.min(cfg.n_devices)));
        if let Some(map) = &state_shards {
            // Give SchedulerKind::StateAffinity its ownership view on the
            // real path too: off-owner placements cost the two-leg state
            // round trip (SCAFFOLD/FedDyn state is model-sized).
            let s_d = global.size_bytes() as f64;
            scheduler.set_affinity(Some(AffinityCtx {
                map: map.clone(),
                n_workers: cfg.n_devices,
                remote_secs: 2.0 * (cfg.cluster.latency + s_d / cfg.cluster.bandwidth),
            }));
        }
        let tracer = cfg.trace.is_some().then(Tracer::new);
        Ok(Server {
            transport,
            cfg,
            algo,
            global,
            sstate: ServerState::default(),
            scheduler,
            dataset,
            eval_exe,
            state_shards,
            metrics: RunMetrics::default(),
            tracer,
            run_sw: Stopwatch::start(),
            task_seq: 0,
            pool: AggPool::new(),
        })
    }

    /// Seconds since server construction — the wallclock trace clock.
    fn tnow(&self) -> f64 {
        self.run_sw.elapsed_secs()
    }

    /// `--trace PATH`: render the wallclock span trace plus the run's
    /// counter registry (including the transport's wire meters) to
    /// Chrome trace-event JSON — the same exporter the virtual engine
    /// uses, so both sides load in Perfetto identically.
    fn write_trace(&mut self) -> Result<()> {
        let Some(path) = self.cfg.trace.clone() else { return Ok(()) };
        let Some(tr) = self.tracer.take() else { return Ok(()) };
        let mut reg = self.metrics.registry();
        if let Some(m) = self.transport.meter() {
            m.export(&mut reg, "deploy.transport");
        }
        std::fs::write(&path, chrome::render(&tr, Some(&reg)))
            .with_context(|| format!("writing Chrome trace to {path}"))
    }

    /// Tile one returned task record onto its device's compute lane:
    /// devices run their assigned client list in order, so stacking the
    /// measured per-task seconds forward from the round start recovers
    /// the lane (records only come back batched at round end).
    fn trace_task(
        &mut self,
        r: TaskRecord,
        queues: &mut [std::collections::VecDeque<usize>],
        cursor: &mut [f64],
    ) {
        if self.tracer.is_none() {
            return;
        }
        let client = queues[r.device].pop_front().unwrap_or(0);
        let s = cursor[r.device];
        cursor[r.device] = s + r.secs;
        let task = self.task_seq;
        self.task_seq += 1;
        if let Some(tr) = self.tracer.as_mut() {
            tr.span(s, s + r.secs, Track::Device(r.device), EvKind::Task { task, client });
        }
    }

    /// Run R rounds and shut the workers down.
    pub fn run(mut self) -> Result<TrainSummary> {
        let client_sizes: Vec<usize> = (0..self.cfg.n_clients)
            .map(|c| self.dataset.client_size(c))
            .collect();
        if self.cfg.scheme == Scheme::Async {
            return self.run_async(client_sizes);
        }
        for round in 0..self.cfg.rounds {
            let selected = self.cfg.selection.select(
                round,
                self.cfg.n_clients,
                self.cfg.clients_per_round,
                &client_sizes,
                self.cfg.seed,
            );
            let t0 = self.tnow();
            let rm = match self.cfg.scheme {
                Scheme::Parrot | Scheme::SP => self.round_parrot(round, &selected)?,
                Scheme::FaDist => self.round_fa(round, &selected)?,
                s => bail!(
                    "scheme {s:?} runs on the virtual-time engine (simulation::), \
                     not on real compute"
                ),
            };
            let t1 = self.tnow();
            if let Some(tr) = self.tracer.as_mut() {
                tr.instant(t0, Track::Run, EvKind::Sched { round, placed: selected.len() });
                tr.span(t0, t1, Track::Run, EvKind::Round { round });
            }
            self.metrics.push(rm);
        }
        for k in 1..=self.cfg.n_devices {
            self.transport.send(k, Msg::Shutdown.encode()?)?;
        }
        self.write_trace()?;
        let (final_loss, final_acc) = self.metrics.final_eval();
        Ok(TrainSummary {
            metrics: self.metrics,
            final_params: self.global,
            final_loss,
            final_acc,
        })
    }

    fn broadcast(&self, round: usize) -> Broadcast {
        Broadcast {
            round,
            params: self.global.clone(),
            extra: self.algo.broadcast_extra(&self.sstate),
        }
    }

    /// Plan-driven state prefetch (sharded store only): pull the states
    /// the schedule placed off-owner from their owners, stage them at
    /// the executors BEFORE the `Round` messages, and return the
    /// metered `(state_bytes, state_msgs)`.
    fn prefetch_state(
        &mut self,
        round: usize,
        assignment: &[Vec<usize>],
    ) -> Result<(u64, u64)> {
        let Some(map) = &self.state_shards else { return Ok((0, 0)) };
        let k = self.cfg.n_devices;
        // need[d]: clients device d runs but does not own;
        // fetch[o]: clients owner o must ship.
        let mut need: Vec<Vec<u64>> = vec![Vec::new(); k];
        let mut fetch: Vec<Vec<u64>> = vec![Vec::new(); k];
        for (dev, clients) in assignment.iter().enumerate() {
            for &c in clients {
                let owner = map.owner(c as u64) as usize;
                if owner != dev {
                    need[dev].push(c as u64);
                    fetch[owner].push(c as u64);
                }
            }
        }
        let (mut state_bytes, mut state_msgs) = (0u64, 0u64);
        let mut expect = 0usize;
        for (owner, cs) in fetch.iter().enumerate() {
            if cs.is_empty() {
                continue;
            }
            let m = Msg::StateFetch { round, clients: cs.clone() }.encode()?;
            state_bytes += m.len() as u64;
            state_msgs += 1;
            self.transport.send(owner + 1, m)?;
            expect += 1;
        }
        let mut have: std::collections::HashMap<u64, Option<Vec<u8>>> = Default::default();
        for _ in 0..expect {
            let (_, raw) = self.transport.recv(None)?;
            state_bytes += raw.len() as u64;
            state_msgs += 1;
            match Msg::decode(&raw)? {
                Msg::StatePut { states, .. } => {
                    for (c, b) in states {
                        have.insert(c, b);
                    }
                }
                other => bail!("expected StatePut during state prefetch, got {other:?}"),
            }
        }
        for (dev, cs) in need.iter().enumerate() {
            if cs.is_empty() {
                continue;
            }
            // `need` lists are disjoint (one destination per client), so
            // the blobs move out of the staging map — no re-clone of a
            // model-sized state per prefetched client.
            let mut states: Vec<(u64, Option<Vec<u8>>)> = Vec::with_capacity(cs.len());
            for &c in cs {
                states.push((c, have.remove(&c).flatten()));
            }
            let m = Msg::StatePut { round, states }.encode()?;
            state_bytes += m.len() as u64;
            state_msgs += 1;
            self.transport.send(dev + 1, m)?;
        }
        let prefetched: usize = need.iter().map(|v| v.len()).sum();
        if prefetched > 0 {
            let t = self.tnow();
            if let Some(tr) = self.tracer.as_mut() {
                tr.instant(t, Track::Server, EvKind::StateLoad { clients: prefetched });
            }
        }
        Ok((state_bytes, state_msgs))
    }

    /// Route an executor's write-back `StatePut` return to the owners.
    fn route_state_returns(
        &self,
        round: usize,
        states: Vec<(u64, Option<Vec<u8>>)>,
    ) -> Result<(u64, u64)> {
        let map = self
            .state_shards
            .as_ref()
            .context("StatePut return without a sharded state store")?;
        let k = self.cfg.n_devices;
        let mut by_owner: Vec<Vec<(u64, Option<Vec<u8>>)>> = vec![Vec::new(); k];
        for (c, b) in states {
            by_owner[map.owner(c) as usize].push((c, b));
        }
        let (mut state_bytes, mut state_msgs) = (0u64, 0u64);
        for (owner, sts) in by_owner.into_iter().enumerate() {
            if sts.is_empty() {
                continue;
            }
            let m = Msg::StatePut { round, states: sts }.encode()?;
            state_bytes += m.len() as u64;
            state_msgs += 1;
            self.transport.send(owner + 1, m)?;
        }
        Ok((state_bytes, state_msgs))
    }

    /// Encode and send one streaming `AsyncTask`, metering the frame.
    fn send_async_task(
        &mut self,
        dev: usize,
        cohort: usize,
        client: usize,
        version: u64,
        met: &mut AsyncMeters,
    ) -> Result<()> {
        let msg = Msg::AsyncTask { round: cohort, client, version, codec: self.cfg.compress }
            .encode()?;
        met.bytes_down += msg.len() as u64;
        met.trips += 1;
        self.transport.send(dev + 1, msg)
    }

    /// Work-conserving dispatch: hand `dev` the next queued client —
    /// unless the staleness gate is closed (`pending` ≥ window), in
    /// which case the device parks and is re-dispatched after the next
    /// flush; without the gate, any cluster with more devices than
    /// `buffer·(S+1)` would keep every device in flight and discard
    /// most updates as stale (the virtual dispatcher gates admission
    /// identically).  With the sharded state store, a non-owned state
    /// is prefetched first (the dispatcher's rolling horizon — one
    /// fetch per dispatch decision instead of a whole-round plan): the
    /// `AsyncTask` is deferred until the owner's `StatePut` reply comes
    /// back and is forwarded ahead of it.  Deferred dispatches queue
    /// per client (the same client can be in flight for two cohorts at
    /// once) and the owner's replies release them FIFO.
    fn dispatch_async(
        &mut self,
        dev: usize,
        st: &mut AsyncLoop,
        version: u64,
        met: &mut AsyncMeters,
    ) -> Result<()> {
        if st.queue.is_empty() {
            return Ok(());
        }
        if st.pending >= st.window {
            st.idle.push(dev);
            return Ok(());
        }
        let (cohort, client) = st.queue.pop_front().expect("checked non-empty");
        st.pending += 1;
        if let Some(map) = &self.state_shards {
            let owner = map.owner(client as u64) as usize;
            if owner != dev {
                let msg =
                    Msg::StateFetch { round: cohort, clients: vec![client as u64] }.encode()?;
                met.state_bytes += msg.len() as u64;
                met.state_msgs += 1;
                self.transport.send(owner + 1, msg)?;
                // The device stays reserved (no outstanding entry) until
                // the fetch reply releases the deferred task.
                st.pending_fetch.entry(client as u64).or_default().push_back((dev, cohort));
                return Ok(());
            }
        }
        self.send_async_task(dev, cohort, client, version, met)?;
        st.outstanding[dev] = Some((cohort, client, version));
        Ok(())
    }

    /// Merge one flush batch with its staleness weights and advance the
    /// global model.
    fn apply_async_flush(
        &mut self,
        updates: &mut Vec<ClientUpdate>,
        decisions: &[UpdateDecision],
    ) -> RoundAggregate {
        debug_assert_eq!(updates.len(), decisions.len());
        let mut flat = LocalAgg::new(0);
        for (u, d) in updates.drain(..).zip(decisions) {
            if d.applied {
                flat.add_pooled(&u.staleness_scaled(d.weight), &mut self.pool);
            }
        }
        let mut agg = GlobalAgg::new();
        agg.merge_pooled(flat.finish(), &mut self.pool);
        let result = agg.finish();
        self.apply_round(&result);
        result
    }

    /// The streaming async loop (`--scheme async`): every device holds
    /// one outstanding task at a time; completed updates buffer at the
    /// server and the [`FlushLedger`] decides when to flush, each
    /// update's staleness weight, and what to discard.  One
    /// `RoundMetrics` is recorded per flush.
    fn run_async(mut self, client_sizes: Vec<usize>) -> Result<TrainSummary> {
        let k = self.cfg.n_devices;
        let buffer = if self.cfg.buffer == 0 {
            self.cfg.clients_per_round
        } else {
            self.cfg.buffer
        };
        let mut ledger = FlushLedger::new(FlushPolicy {
            buffer,
            max_staleness: self.cfg.max_staleness,
            weight: self.cfg.staleness_weight,
        });
        // The identical cohort stream the sync path would select.
        let mut queue: std::collections::VecDeque<(usize, usize)> = Default::default();
        for round in 0..self.cfg.rounds {
            for c in self.cfg.selection.select(
                round,
                self.cfg.n_clients,
                self.cfg.clients_per_round,
                &client_sizes,
                self.cfg.seed,
            ) {
                queue.push_back((round, c));
            }
        }
        let total = queue.len();
        let mut met = AsyncMeters::default();
        let mut sw = Stopwatch::start();

        // Version-0 model to every device before any task.
        let bc0 = self.broadcast(0);
        for dev in 1..=k {
            let m = Msg::AsyncFlush { version: 0, broadcast: bc0.clone() }.encode()?;
            met.bytes_down += m.len() as u64;
            met.trips += 1;
            self.transport.send(dev, m)?;
        }

        let mut st = AsyncLoop {
            queue,
            outstanding: vec![None; k],
            pending_fetch: Default::default(),
            idle: Vec::new(),
            pending: 0,
            window: buffer.saturating_mul(self.cfg.max_staleness + 1),
        };
        let mut buffered: Vec<ClientUpdate> = Vec::new();
        for dev in 0..k {
            self.dispatch_async(dev, &mut st, ledger.version(), &mut met)?;
        }

        let mut done = 0usize;
        while done < total {
            let (from, raw) = self.transport.recv(None)?;
            match Msg::decode(&raw)? {
                Msg::TaskDone { device, update, record, .. } => {
                    met.bytes_up += raw.len() as u64;
                    met.trips += 1;
                    met.busy += record.secs;
                    self.scheduler.record(record);
                    let (_, client, born) = st.outstanding[device]
                        .take()
                        .context("TaskDone from a device with no outstanding task")?;
                    done += 1;
                    buffered.push(update);
                    let t1 = self.tnow();
                    let task = self.task_seq;
                    self.task_seq += 1;
                    if let Some(tr) = self.tracer.as_mut() {
                        // One outstanding task per device: the span is
                        // the arrival minus the measured compute time.
                        tr.span(
                            (t1 - record.secs).max(0.0),
                            t1,
                            Track::Device(device),
                            EvKind::Task { task, client },
                        );
                    }
                    if let Some(decisions) = ledger.on_update(born) {
                        st.pending -= decisions.len();
                        let result = self.apply_async_flush(&mut buffered, &decisions);
                        self.broadcast_flush(&ledger, &decisions, &result, &mut met, &mut sw)?;
                        // The flush reopened the staleness gate: parked
                        // devices pull their next client now.
                        let parked: Vec<usize> = st.idle.drain(..).collect();
                        for dev in parked {
                            self.dispatch_async(dev, &mut st, ledger.version(), &mut met)?;
                        }
                    }
                    // Work-conserving: the freed device pulls its next
                    // client immediately — no barrier (parks if the
                    // staleness gate is closed).
                    self.dispatch_async(device, &mut st, ledger.version(), &mut met)?;
                }
                Msg::StatePut { round, states } => {
                    met.state_bytes += raw.len() as u64;
                    met.state_msgs += 1;
                    let t = self.tnow();
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.instant(t, Track::Server, EvKind::StateFlush {
                            bytes: raw.len() as u64,
                        });
                    }
                    let mut returns = Vec::new();
                    for (c, b) in states {
                        // A fetch *reply* comes from c's owner and
                        // matches a pending prefetch (owners never
                        // write-back their own clients); anything else
                        // is a write-back return headed for the owner.
                        let owner = self
                            .state_shards
                            .as_ref()
                            .map(|m| m.owner(c) as usize + 1)
                            .unwrap_or(0);
                        let is_reply = from == owner
                            && st.pending_fetch.get(&c).map(|q| !q.is_empty()).unwrap_or(false);
                        if is_reply {
                            let q = st.pending_fetch.get_mut(&c).expect("checked above");
                            let (dev, cohort) = q.pop_front().expect("checked above");
                            if q.is_empty() {
                                st.pending_fetch.remove(&c);
                            }
                            let fwd = Msg::StatePut { round, states: vec![(c, b)] }.encode()?;
                            met.state_bytes += fwd.len() as u64;
                            met.state_msgs += 1;
                            self.transport.send(dev + 1, fwd)?;
                            let v = ledger.version();
                            self.send_async_task(dev, cohort, c as usize, v, &mut met)?;
                            st.outstanding[dev] = Some((cohort, c as usize, v));
                        } else {
                            returns.push((c, b));
                        }
                    }
                    if !returns.is_empty() {
                        let (b, n) = self.route_state_returns(round, returns)?;
                        met.state_bytes += b;
                        met.state_msgs += n;
                    }
                }
                other => bail!("async loop expected TaskDone/StatePut, got {other:?}"),
            }
        }
        // Partial tail: whatever is still buffered flushes once.
        if let Some(decisions) = ledger.finalize() {
            let result = self.apply_async_flush(&mut buffered, &decisions);
            self.broadcast_flush(&ledger, &decisions, &result, &mut met, &mut sw)?;
        }
        for dev in 1..=k {
            self.transport.send(dev, Msg::Shutdown.encode()?)?;
        }
        self.write_trace()?;
        let (final_loss, final_acc) = self.metrics.final_eval();
        Ok(TrainSummary {
            metrics: self.metrics,
            final_params: self.global,
            final_loss,
            final_acc,
        })
    }

    /// Post-flush bookkeeping: broadcast the refreshed model to every
    /// device and record one `RoundMetrics` for the flush interval.
    fn broadcast_flush(
        &mut self,
        ledger: &FlushLedger,
        decisions: &[UpdateDecision],
        result: &RoundAggregate,
        met: &mut AsyncMeters,
        sw: &mut Stopwatch,
    ) -> Result<()> {
        let flush_idx = ledger.flushes - 1;
        let t0 = self.tnow();
        let bc = self.broadcast(flush_idx);
        for dev in 1..=self.cfg.n_devices {
            let m =
                Msg::AsyncFlush { version: ledger.version(), broadcast: bc.clone() }.encode()?;
            met.bytes_down += m.len() as u64;
            met.trips += 1;
            self.transport.send(dev, m)?;
        }
        let spent = std::mem::take(met);
        let interval_sw = std::mem::replace(sw, Stopwatch::start());
        let mut rm = self.finish_metrics(
            flush_idx,
            interval_sw,
            0.0,
            spent.busy,
            spent.bytes_down,
            spent.bytes_up,
            spent.trips,
            spent.state_bytes,
            spent.state_msgs,
            result,
        )?;
        rm.flush_updates = decisions.iter().filter(|d| d.applied).count();
        rm.stale_dropped = decisions.iter().filter(|d| !d.applied).count();
        // Per-flush staleness histogram over the APPLIED updates — the
        // deploy mirror of `VRound::staleness_hist` (applied staleness
        // is bounded by `max_staleness` by construction).
        let mut hist = vec![0usize; self.cfg.max_staleness + 1];
        for d in decisions.iter().filter(|d| d.applied) {
            hist[d.staleness] += 1;
        }
        rm.staleness_hist = hist;
        let t1 = self.tnow();
        if let Some(tr) = self.tracer.as_mut() {
            tr.span(t0, t1, Track::Server, EvKind::Flush {
                flush: flush_idx,
                applied: rm.flush_updates,
                stale: rm.stale_dropped,
            });
        }
        self.metrics.push(rm);
        Ok(())
    }

    /// Parrot batch round (SP degenerates to K=1 with the same code).
    /// On a grouped topology (`--topology groups:G | tree:SPEC`) the
    /// round runs through the group-aggregator role: devices reply
    /// `GroupDone`, each group's aggregates merge in a [`TierAgg`], and
    /// only the merged+encoded group aggregate is accounted as crossing
    /// the WAN (`RoundMetrics::cross_group_bytes`) before the global
    /// merge — the deploy-side mirror of the engine's tiered tail.
    fn round_parrot(&mut self, round: usize, selected: &[usize]) -> Result<RoundMetrics> {
        let sw = Stopwatch::start();
        let round_t0 = self.tnow();
        let topo = self.cfg.cluster.topology.clone();
        let grouped = !topo.is_flat();
        let sizes: Vec<(usize, usize)> = selected
            .iter()
            .map(|&c| (c, self.dataset.client_size(c) * self.cfg.local_epochs))
            .collect();
        let schedule = if grouped {
            let groups = topo.members(self.cfg.n_devices);
            let alive = vec![true; self.cfg.n_devices];
            self.scheduler.schedule_grouped(round, &sizes, &alive, &groups)
        } else {
            self.scheduler.schedule(round, &sizes)
        };
        let bc = self.broadcast(round);

        // Trace reconstruction state: each device executes its assigned
        // client list in order, so tiling the returned per-task seconds
        // forward from the round start recovers each compute lane.
        let mut trace_q: Vec<std::collections::VecDeque<usize>> = schedule
            .assignment
            .iter()
            .map(|cs| cs.iter().copied().collect())
            .collect();
        let mut trace_cursor = vec![round_t0; self.cfg.n_devices];

        // Plan-driven prefetch: non-owned states must be staged at the
        // executors before the Round messages land (transport FIFO).
        let (mut state_bytes, mut state_msgs) =
            self.prefetch_state(round, &schedule.assignment)?;

        let mut bytes_down = 0u64;
        let mut trips = 0u64;
        let mut cross_bytes = 0u64;
        let mut top_seen = vec![false; topo.n_top()];
        let mut active = Vec::new();
        for (k, clients) in schedule.assignment.iter().enumerate() {
            if clients.is_empty() {
                continue;
            }
            let msg = if grouped {
                Msg::GroupRound {
                    round,
                    group: topo.group_of(k) as u32,
                    broadcast: bc.clone(),
                    clients: clients.clone(),
                    codec: self.cfg.compress,
                }
                .encode()?
            } else {
                Msg::Round {
                    round,
                    broadcast: bc.clone(),
                    clients: clients.clone(),
                    codec: self.cfg.compress,
                }
                .encode()?
            };
            bytes_down += msg.len() as u64;
            trips += 1;
            if grouped {
                // One broadcast per root-adjacent site crosses the WAN;
                // the deeper relays and member replicas are intra-site.
                let t = topo.top_of(topo.group_of(k));
                if !top_seen[t] {
                    top_seen[t] = true;
                    cross_bytes += msg.len() as u64;
                }
            }
            self.transport.send(k + 1, msg)?;
            active.push(k);
        }

        let mut agg = GlobalAgg::new();
        let mut tiers: Vec<Option<TierAgg>> =
            (0..topo.n_groups()).map(|_| None).collect();
        let mut bytes_up = 0u64;
        let mut busy = 0.0f64;
        let mut done = 0usize;
        while done < active.len() {
            let (_, raw) = self.transport.recv(None)?;
            match Msg::decode(&raw)? {
                Msg::RoundDone { aggregate, records, busy_secs, .. } => {
                    anyhow::ensure!(!grouped, "flat RoundDone during a grouped round");
                    bytes_up += raw.len() as u64;
                    trips += 1;
                    agg.merge_pooled(aggregate, &mut self.pool);
                    for r in records {
                        self.scheduler.record(r);
                        self.trace_task(r, &mut trace_q, &mut trace_cursor);
                    }
                    busy += busy_secs;
                    done += 1;
                }
                Msg::GroupDone { group, aggregate, records, busy_secs, .. } => {
                    anyhow::ensure!(grouped, "GroupDone during a flat round");
                    let g = group as usize;
                    anyhow::ensure!(g < tiers.len(), "GroupDone for unknown group {g}");
                    bytes_up += raw.len() as u64;
                    trips += 1;
                    tiers[g]
                        .get_or_insert_with(|| TierAgg::new(g))
                        .merge_pooled(aggregate, &mut self.pool);
                    for r in records {
                        self.scheduler.record(r);
                        self.trace_task(r, &mut trace_q, &mut trace_cursor);
                    }
                    busy += busy_secs;
                    done += 1;
                }
                // Write-back returns interleave with round results.
                Msg::StatePut { round: r, states } => {
                    state_bytes += raw.len() as u64;
                    state_msgs += 1;
                    let t = self.tnow();
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.instant(t, Track::Server, EvKind::StateFlush {
                            bytes: raw.len() as u64,
                        });
                    }
                    let (b, m) = self.route_state_returns(r, states)?;
                    state_bytes += b;
                    state_msgs += m;
                }
                other => bail!("expected RoundDone, got {other:?}"),
            }
        }
        // Group-aggregator role: fold the leaf tiers up the topology
        // tree, one wire re-encode per tier boundary (sim and deploy
        // apply identical tier-boundary quantization at every level);
        // only the root-adjacent aggregates are metered as crossing the
        // WAN — exactly the engine's tiered-tail structure, any depth.
        let mut group_aggs = 0usize;
        let mut level_aggs = tiers;
        for level in (1..topo.depth()).rev() {
            let fan = topo.levels[level];
            let n_parents = level_aggs.len() / fan.max(1);
            let mut parents: Vec<Option<TierAgg>> = (0..n_parents).map(|_| None).collect();
            for (child, t) in level_aggs.into_iter().enumerate() {
                if let Some(t) = t {
                    let folded = t.finish();
                    let wire = folded.encoded_with(self.cfg.compress)?;
                    // The tier aggregate is re-encoded at the boundary;
                    // its buffers come back for the parent's accumulators.
                    folded.recycle_into(&mut self.pool);
                    parents[child / fan]
                        .get_or_insert_with(|| TierAgg::new(child / fan))
                        .merge_pooled(DeviceAggregate::decode(&wire)?, &mut self.pool);
                }
            }
            level_aggs = parents;
        }
        for tier in level_aggs {
            if let Some(t) = tier {
                let folded = t.finish();
                let wire = folded.encoded_with(self.cfg.compress)?;
                folded.recycle_into(&mut self.pool);
                cross_bytes += wire.len() as u64;
                group_aggs += 1;
                agg.merge_pooled(DeviceAggregate::decode(&wire)?, &mut self.pool);
            }
        }
        let result = agg.finish();
        self.apply_round(&result);
        let mut rm = self.finish_metrics(
            round,
            sw,
            schedule.overhead_secs,
            busy,
            bytes_down,
            bytes_up,
            trips,
            state_bytes,
            state_msgs,
            &result,
        )?;
        rm.group_aggs = group_aggs;
        rm.cross_group_bytes = cross_bytes;
        Ok(rm)
    }

    /// FA pull round: one task per message, params shipped per task
    /// (first task per device carries the broadcast; re-sends each task
    /// to mirror FA Dist.'s O(s_a·M_p) accounting).
    fn round_fa(&mut self, round: usize, selected: &[usize]) -> Result<RoundMetrics> {
        let sw = Stopwatch::start();
        // FedScale-style: largest jobs first into a pull queue.
        let mut queue: Vec<usize> = selected.to_vec();
        queue.sort_by_key(|&c| std::cmp::Reverse(self.dataset.client_size(c)));
        let mut queue = std::collections::VecDeque::from(queue);
        let bc = self.broadcast(round);

        let mut bytes_down = 0u64;
        let mut bytes_up = 0u64;
        let mut trips = 0u64;
        let k = self.cfg.n_devices;
        let mut outstanding = 0usize;
        for dev in 1..=k {
            if let Some(client) = queue.pop_front() {
                let msg = Msg::Task {
                    round,
                    broadcast: bc.clone(),
                    client,
                    codec: self.cfg.compress,
                }
                .encode()?;
                bytes_down += msg.len() as u64;
                trips += 1;
                self.transport.send(dev, msg)?;
                outstanding += 1;
            }
        }
        let mut flat = LocalAgg::new(0);
        let mut n_done = 0usize;
        while n_done < selected.len() {
            let (_, raw) = self.transport.recv(None)?;
            bytes_up += raw.len() as u64;
            trips += 1;
            match Msg::decode(&raw)? {
                Msg::TaskDone { device, update, record, .. } => {
                    flat.add_pooled(&update, &mut self.pool);
                    self.scheduler.record(record);
                    n_done += 1;
                    outstanding -= 1;
                    if let Some(client) = queue.pop_front() {
                        // Params re-sent per task — FA Dist.'s comm model.
                        let msg = Msg::Task {
                            round,
                            broadcast: bc.clone(),
                            client,
                            codec: self.cfg.compress,
                        }
                        .encode()?;
                        bytes_down += msg.len() as u64;
                        trips += 1;
                        self.transport.send(device + 1, msg)?;
                        outstanding += 1;
                    }
                }
                other => bail!("expected TaskDone, got {other:?}"),
            }
        }
        debug_assert_eq!(outstanding, 0);
        let mut agg = GlobalAgg::new();
        agg.merge_pooled(flat.finish(), &mut self.pool);
        let result = agg.finish();
        self.apply_round(&result);
        self.finish_metrics(round, sw, 0.0, 0.0, bytes_down, bytes_up, trips, 0, 0, &result)
    }

    fn apply_round(&mut self, result: &RoundAggregate) {
        let ctx = ServerCtx {
            m_total: self.cfg.n_clients,
            m_selected: self.cfg.clients_per_round,
        };
        self.algo
            .server_apply(&mut self.global, &mut self.sstate, result, &ctx);
    }

    #[allow(clippy::too_many_arguments)]
    fn finish_metrics(
        &mut self,
        round: usize,
        sw: Stopwatch,
        sched_secs: f64,
        busy: f64,
        bytes_down: u64,
        bytes_up: u64,
        trips: u64,
        state_bytes: u64,
        state_msgs: u64,
        result: &RoundAggregate,
    ) -> Result<RoundMetrics> {
        let mut rm = RoundMetrics {
            round,
            sched_secs,
            bytes_down,
            bytes_up,
            trips,
            state_bytes,
            state_msgs,
            busy_secs: busy,
            train_loss: result.scalars.get("loss").copied().unwrap_or(f64::NAN),
            ..Default::default()
        };
        if self.cfg.eval_every > 0 && (round + 1) % self.cfg.eval_every == 0 {
            let (l, a) = self.evaluate()?;
            rm.eval_loss = Some(l);
            rm.eval_acc = Some(a);
        }
        rm.wall_secs = sw.elapsed_secs();
        rm.utilization = if rm.wall_secs > 0.0 && self.cfg.n_devices > 0 {
            (busy / (self.cfg.n_devices as f64 * rm.wall_secs)).min(1.0)
        } else {
            0.0
        };
        Ok(rm)
    }

    /// Server-side eval over the held-out IID test stream.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let exe = self.eval_exe.as_ref().context("eval disabled")?;
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let mut preds = 0.0;
        let per_batch: usize = exe
            .manifest
            .batch_decls()
            .iter()
            .find(|d| d.name == "y")
            .map(|d| d.numel())
            .unwrap_or(crate::model::BATCH);
        for j in 0..self.cfg.eval_batches {
            let b = self.dataset.test_batch(j);
            let (l, c) = exe.eval(&self.global, &b)?;
            loss_sum += l as f64;
            correct += c as f64;
            preds += per_batch as f64;
        }
        Ok((loss_sum / self.cfg.eval_batches.max(1) as f64, correct / preds.max(1.0)))
    }

    pub fn global_params(&self) -> &ParamSet {
        &self.global
    }
}

/// One-call in-process simulation: local transport, K worker threads,
/// server in the calling thread.  This is the entrypoint the launcher,
/// the examples and the Fig-4 harness all share.
pub fn run_simulation(cfg: RunConfig) -> Result<TrainSummary> {
    cfg.validate()?;
    let mut endpoints = local(cfg.n_devices);
    // endpoints[0] = server, rest = workers (spawned back to front).
    let mut handles = Vec::new();
    for _ in 0..cfg.n_devices {
        let ep = endpoints.pop().unwrap();
        let wcfg = cfg.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            Worker::new(ep, wcfg)?.run()
        }));
    }
    let server_ep = endpoints.pop().unwrap();
    let summary = Server::new(server_ep, cfg)?.run()?;
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
    }
    Ok(summary)
}
