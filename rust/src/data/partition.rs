//! Client partitioners: how the global data pool is split across M
//! clients.
//!
//! Three laws from the paper (Table 4):
//! - **Natural** — FEMNIST-like: per-client sizes log-normal
//!   (writer-per-client heavy tail), labels mildly skewed.
//! - **Dirichlet(α)** — ImageNet(a): per-client label distribution drawn
//!   from Dirichlet(α·1_C); α=0.1 gives strong label skew. Sizes are
//!   near-uniform (label skew alone does not stress the scheduler —
//!   paper footnote 1).
//! - **QuantitySkew(s)** — ImageNet(b): sizes follow a power-ish law with
//!   skew parameter s (larger s = heavier size imbalance); labels IID.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionKind {
    /// Log-normal sizes (σ controls the tail), mild label skew.
    Natural,
    /// Dirichlet(alpha) label skew, near-uniform sizes.
    Dirichlet(f64),
    /// Quantity skew with exponent-like parameter (paper uses 5.0).
    QuantitySkew(f64),
}

/// The realized partition: per-client sizes and label mixtures.
#[derive(Debug, Clone)]
pub struct Partition {
    pub kind_name: String,
    /// Number of samples on each client (len = M).
    pub sizes: Vec<usize>,
    /// Per-client categorical label distribution (len = M, each len = C).
    pub label_mix: Vec<Vec<f64>>,
}

impl Partition {
    /// Generate a partition for `m` clients over `n_classes`, with mean
    /// per-client size `mean_size`.  The realized sizes are normalized
    /// so the pool totals *exactly* `m · mean_size` (the raw draws only
    /// hit it in expectation), with a floor of 2 samples per client —
    /// no partitioner can emit a zero-size client.
    pub fn generate(
        kind: PartitionKind,
        m: usize,
        n_classes: usize,
        mean_size: usize,
        seed: u64,
    ) -> Partition {
        assert!(m > 0 && n_classes > 0 && mean_size >= 2);
        let root = Rng::new(seed);
        let mut sizes = Vec::with_capacity(m);
        let mut label_mix = Vec::with_capacity(m);
        let uniform = vec![1.0 / n_classes as f64; n_classes];
        for c in 0..m {
            let mut rng = root.derive(c as u64);
            match kind {
                PartitionKind::Natural => {
                    // Log-normal with sigma=0.7: FEMNIST-like 10x spread.
                    let mu = (mean_size as f64).ln() - 0.5 * 0.7 * 0.7;
                    let s = rng.lognormal(mu, 0.7).round().max(2.0) as usize;
                    sizes.push(s);
                    // Mild skew: Dirichlet(2.0).
                    label_mix.push(rng.dirichlet(2.0, n_classes));
                }
                PartitionKind::Dirichlet(alpha) => {
                    // Near-uniform sizes: +-20%.
                    let s = (mean_size as f64 * rng.range_f64(0.8, 1.2))
                        .round()
                        .max(2.0) as usize;
                    sizes.push(s);
                    label_mix.push(rng.dirichlet(alpha.max(1e-3), n_classes));
                }
                PartitionKind::QuantitySkew(skew) => {
                    // Pareto-like: size ∝ U^(-1/skew̃), normalized to the
                    // requested mean; larger `skew` = heavier imbalance.
                    let tail = 1.0 + 4.0 / skew.max(0.1);
                    let u = rng.next_f64().max(1e-9);
                    let raw = u.powf(-1.0 / tail);
                    // E[U^(-1/t)] = t/(t-1) for t>1.
                    let norm = tail / (tail - 1.0);
                    let s = (mean_size as f64 * raw / norm).round().max(2.0) as usize;
                    sizes.push(s);
                    label_mix.push(uniform.clone());
                }
            }
        }
        normalize_sizes(&mut sizes, m * mean_size);
        Partition { kind_name: kind.name(), sizes, label_mix }
    }

    pub fn total_samples(&self) -> usize {
        self.sizes.iter().sum()
    }

    pub fn n_clients(&self) -> usize {
        self.sizes.len()
    }

    /// Coefficient of variation of sizes — the straggler-pressure signal.
    pub fn size_cv(&self) -> f64 {
        let n = self.sizes.len() as f64;
        let mean = self.total_samples() as f64 / n;
        let var = self
            .sizes
            .iter()
            .map(|&s| (s as f64 - mean) * (s as f64 - mean))
            .sum::<f64>()
            / n;
        var.sqrt() / mean
    }
}

/// Rescale `sizes` so they sum to exactly `target` (largest-remainder
/// rounding, ties by index) while keeping every client at ≥ 2 samples.
/// Deterministic: no randomness, stable ordering.  Requires
/// `target >= 2 * sizes.len()` (guaranteed by the `mean_size >= 2`
/// generate() precondition).
fn normalize_sizes(sizes: &mut [usize], target: usize) {
    let m = sizes.len();
    if m == 0 {
        return;
    }
    let total: usize = sizes.iter().sum();
    if total == target {
        return;
    }
    let scale = target as f64 / total.max(1) as f64;
    // Floor-scale with the fractional remainders kept for distribution.
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(m);
    let mut assigned = 0usize;
    for (i, s) in sizes.iter_mut().enumerate() {
        let scaled = *s as f64 * scale;
        let lo = scaled.floor().max(0.0) as usize;
        *s = lo;
        assigned += lo;
        fracs.push((i, scaled - lo as f64));
    }
    // Largest remainder first (ties by index) for the leftover units.
    fracs.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    let mut leftover = target.saturating_sub(assigned);
    for &(i, _) in &fracs {
        if leftover == 0 {
            break;
        }
        sizes[i] += 1;
        leftover -= 1;
    }
    // Deterministic argmax: first index holding the maximum.
    fn argmax(sizes: &[usize]) -> usize {
        let mut big = 0usize;
        for (i, &s) in sizes.iter().enumerate() {
            if s > sizes[big] {
                big = i;
            }
        }
        big
    }
    // fp pathologies only: if flooring still overshot, shave the
    // largest entries back down (never below the floor of 2).
    let mut excess = sizes.iter().sum::<usize>().saturating_sub(target);
    while excess > 0 {
        let big = argmax(sizes);
        if sizes[big] <= 2 {
            break;
        }
        sizes[big] -= 1;
        excess -= 1;
    }
    // Re-impose the ≥2 floor, paying for each raise from the largest
    // clients so the exact total is preserved.
    for i in 0..m {
        while sizes[i] < 2 {
            let big = argmax(sizes);
            if big == i || sizes[big] <= 2 {
                // Degenerate (target ~ 2m): just raise without payment.
                sizes[i] += 1;
            } else {
                sizes[i] += 1;
                sizes[big] -= 1;
            }
        }
    }
}

impl PartitionKind {
    pub fn name(&self) -> String {
        match self {
            PartitionKind::Natural => "natural".into(),
            PartitionKind::Dirichlet(a) => format!("dirichlet({a})"),
            PartitionKind::QuantitySkew(s) => format!("quantity_skew({s})"),
        }
    }

    /// Parse "natural" | "dirichlet:0.1" | "qskew:5.0".
    pub fn parse(s: &str) -> anyhow::Result<PartitionKind> {
        if s == "natural" {
            return Ok(PartitionKind::Natural);
        }
        if let Some(a) = s.strip_prefix("dirichlet:") {
            return Ok(PartitionKind::Dirichlet(a.parse()?));
        }
        if let Some(a) = s.strip_prefix("qskew:") {
            return Ok(PartitionKind::QuantitySkew(a.parse()?));
        }
        anyhow::bail!("unknown partition kind {s:?} (natural | dirichlet:A | qskew:S)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_positive_and_mean_close() {
        for kind in [
            PartitionKind::Natural,
            PartitionKind::Dirichlet(0.1),
            PartitionKind::QuantitySkew(5.0),
        ] {
            let p = Partition::generate(kind, 500, 62, 100, 1);
            assert_eq!(p.n_clients(), 500);
            assert!(p.sizes.iter().all(|&s| s >= 2));
            let mean = p.total_samples() as f64 / 500.0;
            assert!(
                (mean - 100.0).abs() / 100.0 < 0.35,
                "{}: mean={mean}",
                p.kind_name
            );
        }
    }

    #[test]
    fn natural_is_heavier_than_dirichlet_sizes() {
        let nat = Partition::generate(PartitionKind::Natural, 1000, 62, 100, 2);
        let dir = Partition::generate(PartitionKind::Dirichlet(0.1), 1000, 62, 100, 2);
        assert!(nat.size_cv() > dir.size_cv() * 2.0,
            "natural cv={} dirichlet cv={}", nat.size_cv(), dir.size_cv());
    }

    #[test]
    fn quantity_skew_is_heaviest() {
        let q = Partition::generate(PartitionKind::QuantitySkew(5.0), 1000, 62, 100, 3);
        let d = Partition::generate(PartitionKind::Dirichlet(0.1), 1000, 62, 100, 3);
        assert!(q.size_cv() > d.size_cv(), "q={} d={}", q.size_cv(), d.size_cv());
    }

    #[test]
    fn dirichlet_alpha_controls_label_skew() {
        let spiky = Partition::generate(PartitionKind::Dirichlet(0.1), 200, 10, 50, 4);
        let flat = Partition::generate(PartitionKind::Dirichlet(100.0), 200, 10, 50, 4);
        let max_mass = |p: &Partition| {
            p.label_mix
                .iter()
                .map(|mix| mix.iter().cloned().fold(0.0, f64::max))
                .sum::<f64>()
                / p.n_clients() as f64
        };
        assert!(max_mass(&spiky) > 0.5);
        assert!(max_mass(&flat) < 0.2);
    }

    #[test]
    fn label_mix_is_distribution() {
        let p = Partition::generate(PartitionKind::Natural, 50, 62, 80, 5);
        for mix in &p.label_mix {
            assert_eq!(mix.len(), 62);
            assert!((mix.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Partition::generate(PartitionKind::Natural, 100, 62, 100, 7);
        let b = Partition::generate(PartitionKind::Natural, 100, 62, 100, 7);
        assert_eq!(a.sizes, b.sizes);
        let c = Partition::generate(PartitionKind::Natural, 100, 62, 100, 8);
        assert_ne!(a.sizes, c.sizes);
    }

    #[test]
    fn sizes_sum_exactly_to_pool_for_every_kind_and_seed() {
        // The generate() contract: the realized pool is exactly
        // m · mean_size, whatever the law and the seed.
        for kind in [
            PartitionKind::Natural,
            PartitionKind::Dirichlet(0.1),
            PartitionKind::QuantitySkew(5.0),
        ] {
            for seed in [0u64, 1, 7, 42, 12345] {
                for (m, mean) in [(1usize, 50usize), (17, 3), (200, 100), (1000, 60)] {
                    let p = Partition::generate(kind, m, 10, mean, seed);
                    assert_eq!(
                        p.total_samples(),
                        m * mean,
                        "{}: m={m} mean={mean} seed={seed}",
                        p.kind_name
                    );
                }
            }
        }
    }

    #[test]
    fn no_partitioner_emits_zero_size_clients() {
        // Regression companion to the SizeWeighted zero-size exclusion:
        // selection may assume every client has data, so the
        // partitioners must never produce a 0- (or 1-) sample client —
        // even at the degenerate mean where the floor binds everywhere.
        for kind in [
            PartitionKind::Natural,
            PartitionKind::Dirichlet(0.1),
            PartitionKind::QuantitySkew(9.0), // heaviest tail
        ] {
            for seed in [3u64, 11, 99] {
                let p = Partition::generate(kind, 500, 62, 2, seed);
                assert!(
                    p.sizes.iter().all(|&s| s >= 2),
                    "{}: min size {:?}",
                    p.kind_name,
                    p.sizes.iter().min()
                );
                assert_eq!(p.total_samples(), 1000);
            }
        }
    }

    #[test]
    fn every_label_mix_row_is_a_distribution() {
        for kind in [
            PartitionKind::Natural,
            PartitionKind::Dirichlet(0.1),
            PartitionKind::Dirichlet(100.0),
            PartitionKind::QuantitySkew(5.0),
        ] {
            let p = Partition::generate(kind, 120, 17, 50, 9);
            assert_eq!(p.label_mix.len(), 120);
            for (c, mix) in p.label_mix.iter().enumerate() {
                assert_eq!(mix.len(), 17, "{}: client {c}", p.kind_name);
                let sum: f64 = mix.iter().sum();
                assert!(
                    (sum - 1.0).abs() < 1e-9,
                    "{}: client {c} mix sums to {sum}",
                    p.kind_name
                );
                assert!(mix.iter().all(|&q| (0.0..=1.0 + 1e-12).contains(&q)));
            }
        }
    }

    #[test]
    fn same_seed_identical_partition_across_kinds() {
        for kind in [
            PartitionKind::Natural,
            PartitionKind::Dirichlet(0.5),
            PartitionKind::QuantitySkew(5.0),
        ] {
            let a = Partition::generate(kind, 150, 12, 80, 31);
            let b = Partition::generate(kind, 150, 12, 80, 31);
            assert_eq!(a.sizes, b.sizes);
            assert_eq!(a.label_mix, b.label_mix, "{}", a.kind_name);
            let c = Partition::generate(kind, 150, 12, 80, 32);
            assert_ne!(a.sizes, c.sizes, "{}", a.kind_name);
        }
    }

    #[test]
    fn normalization_preserves_the_size_ordering_shape() {
        // Rescaling must not reshuffle who is big and who is small:
        // ranks are preserved up to the ±1 largest-remainder rounding.
        let p = Partition::generate(PartitionKind::QuantitySkew(5.0), 400, 10, 100, 5);
        let max = *p.sizes.iter().max().unwrap();
        let min = *p.sizes.iter().min().unwrap();
        assert!(max > 4 * min, "quantity skew must survive normalization: {max} vs {min}");
        assert_eq!(p.total_samples(), 40_000);
    }

    #[test]
    fn parse_round_trip() {
        assert_eq!(PartitionKind::parse("natural").unwrap(), PartitionKind::Natural);
        assert_eq!(
            PartitionKind::parse("dirichlet:0.1").unwrap(),
            PartitionKind::Dirichlet(0.1)
        );
        assert_eq!(
            PartitionKind::parse("qskew:5.0").unwrap(),
            PartitionKind::QuantitySkew(5.0)
        );
        assert!(PartitionKind::parse("bogus").is_err());
    }
}
