//! Client partitioners: how the global data pool is split across M
//! clients.
//!
//! Three laws from the paper (Table 4):
//! - **Natural** — FEMNIST-like: per-client sizes log-normal
//!   (writer-per-client heavy tail), labels mildly skewed.
//! - **Dirichlet(α)** — ImageNet(a): per-client label distribution drawn
//!   from Dirichlet(α·1_C); α=0.1 gives strong label skew. Sizes are
//!   near-uniform (label skew alone does not stress the scheduler —
//!   paper footnote 1).
//! - **QuantitySkew(s)** — ImageNet(b): sizes follow a power-ish law with
//!   skew parameter s (larger s = heavier size imbalance); labels IID.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionKind {
    /// Log-normal sizes (σ controls the tail), mild label skew.
    Natural,
    /// Dirichlet(alpha) label skew, near-uniform sizes.
    Dirichlet(f64),
    /// Quantity skew with exponent-like parameter (paper uses 5.0).
    QuantitySkew(f64),
}

/// The realized partition: per-client sizes and label mixtures.
#[derive(Debug, Clone)]
pub struct Partition {
    pub kind_name: String,
    /// Number of samples on each client (len = M).
    pub sizes: Vec<usize>,
    /// Per-client categorical label distribution (len = M, each len = C).
    pub label_mix: Vec<Vec<f64>>,
}

impl Partition {
    /// Generate a partition for `m` clients over `n_classes`, with mean
    /// per-client size `mean_size`.
    pub fn generate(
        kind: PartitionKind,
        m: usize,
        n_classes: usize,
        mean_size: usize,
        seed: u64,
    ) -> Partition {
        assert!(m > 0 && n_classes > 0 && mean_size >= 2);
        let root = Rng::new(seed);
        let mut sizes = Vec::with_capacity(m);
        let mut label_mix = Vec::with_capacity(m);
        let uniform = vec![1.0 / n_classes as f64; n_classes];
        for c in 0..m {
            let mut rng = root.derive(c as u64);
            match kind {
                PartitionKind::Natural => {
                    // Log-normal with sigma=0.7: FEMNIST-like 10x spread.
                    let mu = (mean_size as f64).ln() - 0.5 * 0.7 * 0.7;
                    let s = rng.lognormal(mu, 0.7).round().max(2.0) as usize;
                    sizes.push(s);
                    // Mild skew: Dirichlet(2.0).
                    label_mix.push(rng.dirichlet(2.0, n_classes));
                }
                PartitionKind::Dirichlet(alpha) => {
                    // Near-uniform sizes: +-20%.
                    let s = (mean_size as f64 * rng.range_f64(0.8, 1.2))
                        .round()
                        .max(2.0) as usize;
                    sizes.push(s);
                    label_mix.push(rng.dirichlet(alpha.max(1e-3), n_classes));
                }
                PartitionKind::QuantitySkew(skew) => {
                    // Pareto-like: size ∝ U^(-1/skew̃), normalized to the
                    // requested mean; larger `skew` = heavier imbalance.
                    let tail = 1.0 + 4.0 / skew.max(0.1);
                    let u = rng.next_f64().max(1e-9);
                    let raw = u.powf(-1.0 / tail);
                    // E[U^(-1/t)] = t/(t-1) for t>1.
                    let norm = tail / (tail - 1.0);
                    let s = (mean_size as f64 * raw / norm).round().max(2.0) as usize;
                    sizes.push(s);
                    label_mix.push(uniform.clone());
                }
            }
        }
        Partition { kind_name: kind.name(), sizes, label_mix }
    }

    pub fn total_samples(&self) -> usize {
        self.sizes.iter().sum()
    }

    pub fn n_clients(&self) -> usize {
        self.sizes.len()
    }

    /// Coefficient of variation of sizes — the straggler-pressure signal.
    pub fn size_cv(&self) -> f64 {
        let n = self.sizes.len() as f64;
        let mean = self.total_samples() as f64 / n;
        let var = self
            .sizes
            .iter()
            .map(|&s| (s as f64 - mean) * (s as f64 - mean))
            .sum::<f64>()
            / n;
        var.sqrt() / mean
    }
}

impl PartitionKind {
    pub fn name(&self) -> String {
        match self {
            PartitionKind::Natural => "natural".into(),
            PartitionKind::Dirichlet(a) => format!("dirichlet({a})"),
            PartitionKind::QuantitySkew(s) => format!("quantity_skew({s})"),
        }
    }

    /// Parse "natural" | "dirichlet:0.1" | "qskew:5.0".
    pub fn parse(s: &str) -> anyhow::Result<PartitionKind> {
        if s == "natural" {
            return Ok(PartitionKind::Natural);
        }
        if let Some(a) = s.strip_prefix("dirichlet:") {
            return Ok(PartitionKind::Dirichlet(a.parse()?));
        }
        if let Some(a) = s.strip_prefix("qskew:") {
            return Ok(PartitionKind::QuantitySkew(a.parse()?));
        }
        anyhow::bail!("unknown partition kind {s:?} (natural | dirichlet:A | qskew:S)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_positive_and_mean_close() {
        for kind in [
            PartitionKind::Natural,
            PartitionKind::Dirichlet(0.1),
            PartitionKind::QuantitySkew(5.0),
        ] {
            let p = Partition::generate(kind, 500, 62, 100, 1);
            assert_eq!(p.n_clients(), 500);
            assert!(p.sizes.iter().all(|&s| s >= 2));
            let mean = p.total_samples() as f64 / 500.0;
            assert!(
                (mean - 100.0).abs() / 100.0 < 0.35,
                "{}: mean={mean}",
                p.kind_name
            );
        }
    }

    #[test]
    fn natural_is_heavier_than_dirichlet_sizes() {
        let nat = Partition::generate(PartitionKind::Natural, 1000, 62, 100, 2);
        let dir = Partition::generate(PartitionKind::Dirichlet(0.1), 1000, 62, 100, 2);
        assert!(nat.size_cv() > dir.size_cv() * 2.0,
            "natural cv={} dirichlet cv={}", nat.size_cv(), dir.size_cv());
    }

    #[test]
    fn quantity_skew_is_heaviest() {
        let q = Partition::generate(PartitionKind::QuantitySkew(5.0), 1000, 62, 100, 3);
        let d = Partition::generate(PartitionKind::Dirichlet(0.1), 1000, 62, 100, 3);
        assert!(q.size_cv() > d.size_cv(), "q={} d={}", q.size_cv(), d.size_cv());
    }

    #[test]
    fn dirichlet_alpha_controls_label_skew() {
        let spiky = Partition::generate(PartitionKind::Dirichlet(0.1), 200, 10, 50, 4);
        let flat = Partition::generate(PartitionKind::Dirichlet(100.0), 200, 10, 50, 4);
        let max_mass = |p: &Partition| {
            p.label_mix
                .iter()
                .map(|mix| mix.iter().cloned().fold(0.0, f64::max))
                .sum::<f64>()
                / p.n_clients() as f64
        };
        assert!(max_mass(&spiky) > 0.5);
        assert!(max_mass(&flat) < 0.2);
    }

    #[test]
    fn label_mix_is_distribution() {
        let p = Partition::generate(PartitionKind::Natural, 50, 62, 80, 5);
        for mix in &p.label_mix {
            assert_eq!(mix.len(), 62);
            assert!((mix.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Partition::generate(PartitionKind::Natural, 100, 62, 100, 7);
        let b = Partition::generate(PartitionKind::Natural, 100, 62, 100, 7);
        assert_eq!(a.sizes, b.sizes);
        let c = Partition::generate(PartitionKind::Natural, 100, 62, 100, 8);
        assert_ne!(a.sizes, c.sizes);
    }

    #[test]
    fn parse_round_trip() {
        assert_eq!(PartitionKind::parse("natural").unwrap(), PartitionKind::Natural);
        assert_eq!(
            PartitionKind::parse("dirichlet:0.1").unwrap(),
            PartitionKind::Dirichlet(0.1)
        );
        assert_eq!(
            PartitionKind::parse("qskew:5.0").unwrap(),
            PartitionKind::QuantitySkew(5.0)
        );
        assert!(PartitionKind::parse("bogus").is_err());
    }
}
