//! Synthetic-but-learnable sample generators.
//!
//! Samples are generated *on demand*, deterministically from
//! `(seed, client, batch)` — a 10,000-client federation costs no storage
//! beyond the per-class prototypes, which is what lets the Table-3 /
//! Fig-5 scale experiments run at paper scale on one machine.
//!
//! - **Vision** (FEMNIST / ImageNet analogs): class prototypes drawn from
//!   N(0, I); a sample is `prototype[y] + σ·noise`.  Linearly separable
//!   enough that the MLP/CNN make real accuracy progress (Fig. 4) while
//!   noisy enough that more local steps keep helping.
//! - **Language** (Reddit analog): token streams from a client-flavored
//!   affine bigram process `next = (a·cur + b + flavor_c) mod V` with an
//!   ε-uniform mixture; the transformer learns the bigram structure, and
//!   the per-client flavor provides the non-IID-ness.

use super::partition::Partition;
use crate::util::rng::Rng;

/// Which generator a dataset uses (must match the model family's input).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskKind {
    /// `dim`-feature vectors over `n_classes` (mlp/cnn: dim=784, C=62).
    Vision { dim: usize, n_classes: usize },
    /// Token sequences over `vocab` of length `seq` (tinylm: 128, 32).
    Language { vocab: usize, seq: usize },
}

#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub task: TaskKind,
    pub batch_size: usize,
    pub noise: f32,
    pub seed: u64,
}

impl SynthConfig {
    pub fn vision(seed: u64) -> SynthConfig {
        SynthConfig {
            task: TaskKind::Vision { dim: 784, n_classes: 62 },
            batch_size: crate::model::BATCH,
            noise: 0.7,
            seed,
        }
    }

    pub fn language(seed: u64) -> SynthConfig {
        SynthConfig {
            task: TaskKind::Language { vocab: 128, seq: 32 },
            batch_size: crate::model::BATCH,
            noise: 0.15, // ε of the uniform mixture
            seed,
        }
    }
}

/// One batch in the layout the AOT artifacts expect.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Flattened f32 features (vision) — empty for language tasks.
    pub x_f32: Vec<f32>,
    /// Flattened i32 tokens (language) — empty for vision tasks.
    pub x_i32: Vec<i32>,
    /// Labels: class ids (vision, len B) or next-tokens (language, len B·T).
    pub y: Vec<i32>,
}

/// A federation: partition (who has how much of what) + generator.
pub struct FederatedDataset {
    pub cfg: SynthConfig,
    pub partition: Partition,
    /// Vision: per-class prototypes, row-major [n_classes][dim].
    prototypes: Vec<f32>,
}

impl FederatedDataset {
    pub fn new(cfg: SynthConfig, partition: Partition) -> FederatedDataset {
        let prototypes = match cfg.task {
            TaskKind::Vision { dim, n_classes } => {
                let mut rng = Rng::new(cfg.seed ^ 0x5EED_0001);
                (0..n_classes * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect()
            }
            TaskKind::Language { .. } => Vec::new(),
        };
        FederatedDataset { cfg, partition, prototypes }
    }

    pub fn n_clients(&self) -> usize {
        self.partition.n_clients()
    }

    /// Samples held by client `m` (the scheduler's N_m).
    pub fn client_size(&self, m: usize) -> usize {
        self.partition.sizes[m]
    }

    /// Batches per local epoch for client `m` (partial tail batch is
    /// padded by wrapping, matching common FL-sim practice).
    pub fn n_batches(&self, m: usize) -> usize {
        self.client_size(m).div_ceil(self.cfg.batch_size)
    }

    /// The `j`-th batch of client `m`'s fixed local dataset.
    /// Deterministic: same (client, batch) → same data every epoch.
    pub fn batch(&self, m: usize, j: usize) -> Batch {
        let mut rng = Rng::new(self.cfg.seed).derive((m as u64) << 20 | j as u64);
        self.gen_batch(&mut rng, Some(m))
    }

    /// The `j`-th batch of the held-out IID test set.
    pub fn test_batch(&self, j: usize) -> Batch {
        let mut rng = Rng::new(self.cfg.seed ^ 0x7E57_0000).derive(j as u64);
        self.gen_batch(&mut rng, None)
    }

    fn gen_batch(&self, rng: &mut Rng, client: Option<usize>) -> Batch {
        let b = self.cfg.batch_size;
        match self.cfg.task {
            TaskKind::Vision { dim, n_classes } => {
                let mut x = Vec::with_capacity(b * dim);
                let mut y = Vec::with_capacity(b);
                for _ in 0..b {
                    let label = match client {
                        Some(m) => rng.categorical(&self.partition.label_mix[m]),
                        None => rng.below(n_classes as u64) as usize,
                    };
                    y.push(label as i32);
                    let proto = &self.prototypes[label * dim..(label + 1) * dim];
                    for &p in proto {
                        x.push(p + self.cfg.noise * rng.normal_f32(0.0, 1.0));
                    }
                }
                Batch { x_f32: x, x_i32: Vec::new(), y }
            }
            TaskKind::Language { vocab, seq } => {
                // Per-client bigram flavor: shifts the affine map so the
                // federation is non-IID in transition structure.
                let flavor = client
                    .map(|m| {
                        let mix = &self.partition.label_mix[m];
                        // argmax of the client's label mixture, folded small
                        let arg = mix
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(i, _)| i)
                            .unwrap_or(0);
                        (arg % 8) as i64
                    })
                    .unwrap_or(0);
                let v = vocab as i64;
                let mut x = Vec::with_capacity(b * seq);
                let mut y = Vec::with_capacity(b * seq);
                for _ in 0..b {
                    let mut cur = rng.below(vocab as u64) as i64;
                    for _ in 0..seq {
                        x.push(cur as i32);
                        let next = if rng.next_f32() < self.cfg.noise {
                            rng.below(vocab as u64) as i64
                        } else {
                            (3 * cur + 7 + flavor).rem_euclid(v)
                        };
                        y.push(next as i32);
                        cur = next;
                    }
                }
                Batch { x_f32: Vec::new(), x_i32: x, y }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::PartitionKind;

    fn vision_ds() -> FederatedDataset {
        let p = Partition::generate(PartitionKind::Natural, 20, 62, 60, 1);
        FederatedDataset::new(SynthConfig::vision(42), p)
    }

    fn lm_ds() -> FederatedDataset {
        let p = Partition::generate(PartitionKind::Natural, 20, 62, 60, 1);
        FederatedDataset::new(SynthConfig::language(42), p)
    }

    #[test]
    fn vision_batch_shapes() {
        let ds = vision_ds();
        let b = ds.batch(3, 0);
        assert_eq!(b.x_f32.len(), 20 * 784);
        assert!(b.x_i32.is_empty());
        assert_eq!(b.y.len(), 20);
        assert!(b.y.iter().all(|&y| (0..62).contains(&y)));
    }

    #[test]
    fn language_batch_shapes() {
        let ds = lm_ds();
        let b = ds.batch(3, 0);
        assert_eq!(b.x_i32.len(), 20 * 32);
        assert!(b.x_f32.is_empty());
        assert_eq!(b.y.len(), 20 * 32);
        assert!(b.x_i32.iter().all(|&t| (0..128).contains(&t)));
        assert!(b.y.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn batches_deterministic_across_epochs() {
        let ds = vision_ds();
        let a = ds.batch(5, 2);
        let b = ds.batch(5, 2);
        assert_eq!(a.x_f32, b.x_f32);
        assert_eq!(a.y, b.y);
        let c = ds.batch(5, 3);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn clients_differ() {
        let ds = vision_ds();
        assert_ne!(ds.batch(0, 0).x_f32, ds.batch(1, 0).x_f32);
    }

    #[test]
    fn vision_classes_are_separated() {
        // Same-class samples must be closer than cross-class on average —
        // the learnability precondition for Fig. 4.
        let ds = vision_ds();
        let mut same = Vec::new();
        let mut cross = Vec::new();
        let batches: Vec<Batch> = (0..8).map(|j| ds.test_batch(j)).collect();
        let samples: Vec<(&[f32], i32)> = batches
            .iter()
            .flat_map(|b| {
                (0..b.y.len()).map(move |i| (&b.x_f32[i * 784..(i + 1) * 784], b.y[i]))
            })
            .collect();
        for i in 0..samples.len() {
            for j in (i + 1)..samples.len() {
                let d: f32 = samples[i]
                    .0
                    .iter()
                    .zip(samples[j].0)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if samples[i].1 == samples[j].1 {
                    same.push(d);
                } else {
                    cross.push(d);
                }
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
        assert!(!same.is_empty() && !cross.is_empty());
        assert!(
            mean(&same) < 0.6 * mean(&cross),
            "same={} cross={}",
            mean(&same),
            mean(&cross)
        );
    }

    #[test]
    fn lm_bigram_structure_dominates() {
        // Without noise the process is deterministic: y = 3x+7+flavor mod V.
        let ds = lm_ds();
        let b = ds.test_batch(0);
        let mut hits = 0;
        for (x, y) in b.x_i32.iter().zip(&b.y) {
            if (3 * x + 7).rem_euclid(128) == *y {
                hits += 1;
            }
        }
        let frac = hits as f64 / b.y.len() as f64;
        assert!(frac > 0.75, "bigram structure frac={frac}");
    }

    #[test]
    fn n_batches_covers_dataset() {
        let ds = vision_ds();
        for m in 0..ds.n_clients() {
            let nb = ds.n_batches(m);
            assert!(nb * 20 >= ds.client_size(m));
            assert!((nb - 1) * 20 < ds.client_size(m));
        }
    }

    #[test]
    fn label_mix_respected() {
        // A client with spiky Dirichlet mix should mostly emit its top label.
        let p = Partition::generate(PartitionKind::Dirichlet(0.05), 10, 10, 200, 9);
        let ds = FederatedDataset::new(
            SynthConfig {
                task: TaskKind::Vision { dim: 16, n_classes: 10 },
                batch_size: 50,
                noise: 0.1,
                seed: 3,
            },
            p,
        );
        for m in 0..3 {
            let top = ds
                .partition
                .label_mix[m]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            if *top.1 < 0.8 {
                continue;
            }
            let b = ds.batch(m, 0);
            let hits = b.y.iter().filter(|&&y| y == top.0 as i32).count();
            assert!(hits as f64 / b.y.len() as f64 > 0.5);
        }
    }
}
