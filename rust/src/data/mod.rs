//! Federated dataset substrate.
//!
//! The paper evaluates on FEMNIST / ImageNet / Reddit; none are
//! available in this environment, so this module builds the synthetic
//! analogs described in DESIGN.md §2: a learnable Gaussian
//! class-prototype generator (vision) and a Markov token generator (LM),
//! partitioned across clients by the paper's three partition laws —
//! natural (log-normal sizes), Dirichlet(α) label skew, and quantity
//! skew.  What the *system* experiments consume is exactly what drives
//! the paper's results: the per-client dataset-size distribution (the
//! scheduler's workload signal, Eq. 1) and the label heterogeneity (the
//! algorithms' convergence signal, Fig. 4).

pub mod partition;
pub mod synth;

pub use partition::{Partition, PartitionKind};
pub use synth::{Batch, FederatedDataset, SynthConfig, TaskKind};
