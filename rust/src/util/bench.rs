//! Criterion-style micro/meso benchmark harness (criterion itself is
//! unavailable offline — DESIGN.md §6).
//!
//! Used by the `benches/*.rs` targets (`harness = false`), which `cargo
//! bench` runs as plain binaries.  Reports mean ± std, median and p95
//! over timed iterations after a warm-up phase, plus throughput when an
//! element count is supplied.

use super::stats::{summarize, Summary};
use super::timer::fmt_secs;
use std::time::Instant;

pub struct Bencher {
    name: String,
    warmup_iters: usize,
    sample_iters: usize,
    results: Vec<(String, Summary, Option<f64>)>,
}

impl Bencher {
    pub fn new(name: &str) -> Bencher {
        // Honor the harness convention: `cargo bench -- --quick` halves work.
        let quick = std::env::args().any(|a| a == "--quick");
        Bencher {
            name: name.to_string(),
            warmup_iters: if quick { 3 } else { 10 },
            sample_iters: if quick { 15 } else { 50 },
            results: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: usize, samples: usize) -> Bencher {
        self.warmup_iters = warmup;
        self.sample_iters = samples;
        self
    }

    /// Time `f` repeatedly; `black_box` its output yourself if needed.
    pub fn bench<T>(&mut self, label: &str, mut f: impl FnMut() -> T) {
        self.bench_n(label, None, &mut f);
    }

    /// Like `bench` but reports `elems/iter / time` as throughput.
    pub fn bench_throughput<T>(&mut self, label: &str, elems: usize, mut f: impl FnMut() -> T) {
        self.bench_n(label, Some(elems as f64), &mut f);
    }

    fn bench_n<T>(&mut self, label: &str, elems: Option<f64>, f: &mut impl FnMut() -> T) {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let s = summarize(&samples);
        let tput = elems.map(|e| e / s.p50);
        println!(
            "{:<44} {:>10} ±{:>9}  p50 {:>10}  p95 {:>10}{}",
            format!("{}/{}", self.name, label),
            fmt_secs(s.mean),
            fmt_secs(s.std),
            fmt_secs(s.p50),
            fmt_secs(s.p95),
            tput.map(|t| format!("  {:.2e} elems/s", t)).unwrap_or_default(),
        );
        self.results.push((label.to_string(), s, tput));
    }

    pub fn results(&self) -> &[(String, Summary, Option<f64>)] {
        &self.results
    }
}

/// Header line for a bench binary.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>10} {:>10}  {:>14}  {:>14}",
        "benchmark", "mean", "std", "p50", "p95"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut b = Bencher::new("t").with_iters(1, 5);
        let mut acc = 0u64;
        b.bench("noop", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].1.n, 5);
    }

    #[test]
    fn throughput_positive() {
        let mut b = Bencher::new("t").with_iters(1, 3);
        b.bench_throughput("sum", 1000, || (0..1000u64).sum::<u64>());
        assert!(b.results()[0].2.unwrap() > 0.0);
    }
}
