//! Deterministic PRNG + the samplers the data/cluster substrates need.
//!
//! SplitMix64 core (Steele et al., "Fast splittable pseudorandom number
//! generators") — tiny state, passes BigCrush when used as a stream, and
//! trivially splittable so every client / device / round can derive an
//! independent, reproducible stream from `(seed, id)`.

/// Splittable 64-bit PRNG. Every simulation entity owns its own stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point and decorrelate small seeds.
        Rng { state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1) }
    }

    /// Derive an independent stream for entity `id` (client, device, round).
    pub fn derive(&self, id: u64) -> Rng {
        let mut r = Rng::new(self.state ^ id.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        r.next_u64(); // burn one output to decorrelate
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless bounded sampling.
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Log-normal with the given log-space parameters — the "natural"
    /// client-dataset-size law (FEMNIST-like heavy tail).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape >= 0 handled by boosting).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.next_f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k): the paper's label-skew partitioner
    /// (ImageNet(a) uses Dirichlet(0.1)).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            // pathological underflow: fall back to a one-hot draw
            let mut out = vec![0.0; k];
            out[self.below(k as u64) as usize] = 1.0;
            return out;
        }
        for x in v.iter_mut() {
            *x /= s;
        }
        v
    }

    /// Sample from a discrete distribution given by (unnormalized) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from [0, m) (client selection per round).
    pub fn choose(&mut self, m: usize, n: usize) -> Vec<usize> {
        assert!(n <= m, "cannot choose {n} from {m}");
        let mut idx: Vec<usize> = (0..m).collect();
        // Partial Fisher–Yates: only the first n swaps matter.
        for i in 0..n {
            let j = i + self.below((m - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(n);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_decorrelates() {
        let root = Rng::new(1);
        let mut a = root.derive(0);
        let mut b = root.derive(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn dirichlet_sums_to_one_and_skews() {
        let mut r = Rng::new(5);
        for &alpha in &[0.1, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 62);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
        // Small alpha concentrates mass: max component should dominate.
        let p = r.dirichlet(0.05, 10);
        let mx = p.iter().cloned().fold(0.0, f64::max);
        assert!(mx > 0.5, "Dirichlet(0.05) should be spiky, max={mx}");
    }

    #[test]
    fn gamma_mean_close_to_shape() {
        let mut r = Rng::new(9);
        for &shape in &[0.1, 0.5, 2.0, 7.5] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() / shape < 0.1,
                "gamma({shape}) mean={mean}"
            );
        }
    }

    #[test]
    fn lognormal_is_positive_heavy_tail() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..10_000).map(|_| r.lognormal(3.0, 1.0)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[xs.len() / 2];
        assert!(mean > median, "heavy right tail expected");
    }

    #[test]
    fn choose_distinct_and_in_range() {
        let mut r = Rng::new(17);
        for _ in 0..50 {
            let picked = r.choose(100, 30);
            assert_eq!(picked.len(), 30);
            let mut s = picked.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 30, "duplicates in selection");
            assert!(picked.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn choose_all_is_permutation() {
        let mut r = Rng::new(19);
        let mut p = r.choose(10, 10);
        p.sort_unstable();
        assert_eq!(p, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(23);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio={ratio}");
    }
}
