//! Statistics substrate: OLS linear regression (the paper's workload
//! estimator, Eq. 2) and summary statistics for the bench harness.

/// Result of fitting `y = slope * x + intercept` by ordinary least squares.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination (1 = perfect fit).
    pub r2: f64,
    pub n: usize,
}

/// OLS fit of (x, y) pairs. Returns `None` for fewer than 2 points or a
/// degenerate (constant-x) design; callers fall back to the warm-up
/// uniform schedule in that case (Alg. 3's `r <= R_w` branch).
pub fn linear_regression(xs: &[f64], ys: &[f64]) -> Option<LinearFit> {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return None;
    }
    let nf = n as f64;
    let mx = xs.iter().sum::<f64>() / nf;
    let my = ys.iter().sum::<f64>() / nf;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    if sxx < 1e-12 {
        return None;
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(&x, &y)| {
            let e = y - (slope * x + intercept);
            e * e
        })
        .sum();
    let r2 = if syy < 1e-12 { 1.0 } else { 1.0 - ss_res / syy };
    Some(LinearFit { slope, intercept, r2, n })
}

/// Summary statistics over a sample (bench reporting).
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(2) as f64;
    let mut sorted = samples.to_vec();
    // total_cmp: NaN samples sort to the top instead of panicking (the
    // SizeWeighted scheduler precedent) — a poisoned series still
    // yields a summary, with NaN visible in max/p95.
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| sorted[(((n - 1) as f64) * p).round() as usize];
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: pct(0.50),
        p95: pct(0.95),
    }
}

/// Mean absolute percentage error — Fig. 11(a)'s estimation-error metric.
pub fn mape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    assert!(!actual.is_empty());
    let mut acc = 0.0;
    for (&a, &p) in actual.iter().zip(predicted) {
        acc += ((a - p) / a.max(1e-12)).abs();
    }
    acc / actual.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.5 * x + 2.0).collect();
        let fit = linear_regression(&xs, &ys).unwrap();
        assert!((fit.slope - 3.5).abs() < 1e-9);
        assert!((fit.intercept - 2.0).abs() < 1e-9);
        assert!((fit.r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn noisy_line_approximate() {
        let mut rng = crate::util::rng::Rng::new(1);
        let xs: Vec<f64> = (0..500).map(|_| rng.range_f64(10.0, 200.0)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.02 * x + 1.0 + 0.05 * rng.normal()).collect();
        let fit = linear_regression(&xs, &ys).unwrap();
        assert!((fit.slope - 0.02).abs() < 0.002, "{fit:?}");
        assert!((fit.intercept - 1.0).abs() < 0.05, "{fit:?}");
        assert!(fit.r2 > 0.9);
    }

    #[test]
    fn degenerate_cases_none() {
        assert!(linear_regression(&[], &[]).is_none());
        assert!(linear_regression(&[1.0], &[2.0]).is_none());
        // constant x: unfittable
        assert!(linear_regression(&[5.0, 5.0, 5.0], &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn constant_y_r2_is_one() {
        let fit = linear_regression(&[1.0, 2.0, 3.0], &[4.0, 4.0, 4.0]).unwrap();
        assert!(fit.slope.abs() < 1e-12);
        assert!((fit.r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_survives_nan_samples() {
        // partial_cmp().unwrap() used to panic here; total_cmp sorts
        // NaN above every finite value.
        let s = summarize(&[3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());
    }

    #[test]
    fn mape_zero_for_perfect() {
        assert!(mape(&[1.0, 2.0], &[1.0, 2.0]) < 1e-12);
        assert!((mape(&[2.0], &[1.0]) - 0.5).abs() < 1e-12);
    }
}
