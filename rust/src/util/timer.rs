//! Wallclock timing helpers shared by the coordinator's metrics and the
//! bench harness.

use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Monotonic wallclock seconds since the first call, as a plain `fn`
/// so it can be *injected* into engine components (`fn() -> f64`
/// clock fields) from their deploy-side callers.  The engine modules
/// themselves never read ambient time — `parrot lint`'s
/// `ambient-entropy-transitive` rule enforces exactly that — so
/// overhead accounting is wired up only where a real coordinator or
/// experiment harness consumes it.
pub fn wall_secs() -> f64 {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    ANCHOR.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.elapsed_secs())
}

/// Human-readable duration for reports.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_secs() >= 0.002);
    }

    #[test]
    fn wall_secs_is_monotonic_nonnegative() {
        let a = wall_secs();
        std::thread::sleep(Duration::from_millis(2));
        let b = wall_secs();
        assert!(a >= 0.0);
        assert!(b >= a + 0.001, "wall_secs must advance: {a} -> {b}");
    }

    #[test]
    fn time_it_returns_result() {
        let (v, s) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_secs(3.2e-9).ends_with("ns"));
        assert!(fmt_secs(5.0e-5).ends_with("µs"));
        assert!(fmt_secs(0.2).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
