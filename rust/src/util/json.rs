//! Minimal JSON writer + parser for experiment reports
//! (results/*.json) and the lint report's self-validation.
//!
//! Configs are plain `key=value` files parsed by `config`, so the
//! writer half stays small (correct string escaping, stable field
//! order).  The parser half exists so tooling that *emits* JSON lines
//! (`parrot lint --out`) can assert its own output round-trips — it
//! is a strict, panic-free recursive-descent parser, not a general
//! serde replacement.

use anyhow::{bail, Result};
use std::fmt::Write as _;

/// A JSON value under construction.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or append) a field; builder-style.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), val.into()));
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Parse one complete JSON value; trailing non-whitespace is an
/// error.  Integral numbers that fit i64 come back as `Json::Int`
/// (matching what the writer emits for counters), everything else
/// numeric as `Json::Num`.
pub fn parse(s: &str) -> Result<Json> {
    let b = s.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != b.len() {
        bail!("json: trailing content at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("json: expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("json: bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("json: unexpected {:?} at byte {}", c as char, self.i),
            None => bail!("json: unexpected end of input"),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("json: expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("json: expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn hex4(&mut self) -> Result<u16> {
        if self.i + 4 > self.b.len() {
            bail!("json: truncated \\u escape at byte {}", self.i);
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| anyhow::anyhow!("json: non-ascii \\u escape at byte {}", self.i))?;
        let v = u16::from_str_radix(s, 16)
            .map_err(|_| anyhow::anyhow!("json: bad \\u escape at byte {}", self.i))?;
        self.i += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else { bail!("json: unterminated string") };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else { bail!("json: unterminated escape") };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: a following \uXXXX low half
                                if self.peek() != Some(b'\\') {
                                    bail!("json: lone high surrogate at byte {}", self.i);
                                }
                                self.i += 1;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("json: bad low surrogate at byte {}", self.i);
                                }
                                let cp = 0x10000
                                    + ((hi as u32 - 0xD800) << 10)
                                    + (lo as u32 - 0xDC00);
                                char::from_u32(cp)
                            } else if (0xDC00..0xE000).contains(&hi) {
                                None // lone low surrogate
                            } else {
                                char::from_u32(hi as u32)
                            };
                            match ch {
                                Some(ch) => out.push(ch),
                                None => bail!("json: invalid \\u escape at byte {}", self.i),
                            }
                        }
                        other => {
                            bail!("json: bad escape \\{} at byte {}", other as char, self.i)
                        }
                    }
                }
                _ => {
                    // UTF-8 continuation: step back and take the whole char
                    let start = self.i - 1;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| anyhow::anyhow!("json: invalid UTF-8 at byte {start}"))?;
                    let ch = rest.chars().next().unwrap_or('\u{fffd}');
                    if (ch as u32) < 0x20 {
                        bail!("json: unescaped control char at byte {start}");
                    }
                    out.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.i += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.i += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap_or("");
        if !is_float {
            if let Ok(v) = text.parse::<i64>() {
                return Ok(Json::Int(v));
            }
        }
        match text.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => bail!("json: bad number {text:?} at byte {start}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig5")
            .set("k", 8usize)
            .set("times", vec![1.5f64, 2.0, 3.25])
            .set("ok", true)
            .set("detail", Json::obj().set("scheme", "parrot"));
        assert_eq!(
            j.render(),
            r#"{"name":"fig5","k":8,"times":[1.5,2,3.25],"ok":true,"detail":{"scheme":"parrot"}}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.render(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let j = Json::obj()
            .set("name", "fig5")
            .set("k", 8usize)
            .set("neg", -3i64)
            .set("times", vec![1.5f64, 2.0, 3.25])
            .set("ok", true)
            .set("none", Json::Null)
            .set("msg", "a\"b\\c\nd\u{1}é — dash")
            .set("detail", Json::obj().set("scheme", "parrot"));
        let rendered = j.render();
        let back = parse(&rendered).unwrap();
        assert_eq!(back.render(), rendered);
    }

    #[test]
    fn parse_handles_escapes_and_unicode() {
        let Json::Str(s) = parse(r#""a\u0041\n\t\" \u00e9 \ud83d\ude00""#).unwrap() else {
            panic!("expected string")
        };
        assert_eq!(s, "aA\n\t\" é 😀");
        // `2` is integral (Int), `2.5` is not
        assert!(matches!(parse("2").unwrap(), Json::Int(2)));
        assert!(matches!(parse("2.5").unwrap(), Json::Num(x) if x == 2.5));
        assert!(matches!(parse("[1, 2 , 3]").unwrap(), Json::Arr(v) if v.len() == 3));
    }

    #[test]
    fn parse_rejects_garbage_and_truncation() {
        for bad in [
            "", "{", "[1,", "\"unterminated", "{\"a\":}", "{\"a\":1,}", "tru", "1 2",
            "\"\\q\"", "\"\\u12\"", "\"\\ud800x\"", "nullx",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }
}
