//! Minimal JSON *writer* for experiment reports (results/*.json).
//!
//! Only emission is needed — configs are plain `key=value` files parsed
//! by `config` — so this stays a writer with correct string escaping and
//! stable field order.

use std::fmt::Write as _;

/// A JSON value under construction.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or append) a field; builder-style.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), val.into()));
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig5")
            .set("k", 8usize)
            .set("times", vec![1.5f64, 2.0, 3.25])
            .set("ok", true)
            .set("detail", Json::obj().set("scheme", "parrot"));
        assert_eq!(
            j.render(),
            r#"{"name":"fig5","k":8,"times":[1.5,2,3.25],"ok":true,"detail":{"scheme":"parrot"}}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.render(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }
}
