//! Binary codec: length-prefixed little-endian encoding for state
//! snapshots (client state manager) and transport messages.
//!
//! Hand-rolled because no serde is available offline (DESIGN.md §6).
//! The format is versionless-simple by design: every record the system
//! persists is written and read by this same build.

use anyhow::{bail, Result};

/// Append-only encoder.
#[derive(Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Encoder { buf: Vec::with_capacity(cap) }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// f32 slice with length prefix; the workhorse for parameter tensors.
    ///
    /// Perf (EXPERIMENTS.md §Perf): on little-endian targets this is a
    /// single bulk copy — the per-element `to_le_bytes` loop measured
    /// ~4 GB/s, the memcpy path >20 GB/s, and this sits on the
    /// device-aggregate upload path of every round.
    pub fn put_f32s(&mut self, xs: &[f32]) {
        self.put_u32(xs.len() as u32);
        #[cfg(target_endian = "little")]
        {
            let raw = unsafe {
                std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
            };
            self.buf.extend_from_slice(raw);
        }
        #[cfg(target_endian = "big")]
        {
            self.buf.reserve(xs.len() * 4);
            for &x in xs {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    pub fn put_bytes(&mut self, xs: &[u8]) {
        self.put_u32(xs.len() as u32);
        self.buf.extend_from_slice(xs);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Cursor-based decoder over an encoded byte slice.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Decoder { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "decode underrun: need {n} bytes at {} of {}",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        #[cfg(target_endian = "little")]
        {
            // Bulk copy (possibly unaligned source): see put_f32s.
            let mut out = vec![0.0f32; n];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 4,
                );
            }
            Ok(out)
        }
        #[cfg(target_endian = "big")]
        {
            let mut out = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                out.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            Ok(out)
        }
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn done(&self) -> bool {
        self.remaining() == 0
    }
}

/// Read a little-endian f32 buffer straight from raw bytes (the testvec
/// `.bin` format emitted by `aot.py`).
pub fn f32s_from_le_bytes(raw: &[u8]) -> Result<Vec<f32>> {
    if raw.len() % 4 != 0 {
        bail!("raw length {} not a multiple of 4", raw.len());
    }
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub fn i32s_from_le_bytes(raw: &[u8]) -> Result<Vec<i32>> {
    if raw.len() % 4 != 0 {
        bail!("raw length {} not a multiple of 4", raw.len());
    }
    Ok(raw
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        let mut e = Encoder::new();
        e.put_u8(7);
        e.put_u32(0xDEAD_BEEF);
        e.put_u64(u64::MAX);
        e.put_f32(-1.5);
        e.put_f64(std::f64::consts::PI);
        e.put_str("parrot");
        let buf = e.finish();
        let mut d = Decoder::new(&buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX);
        assert_eq!(d.f32().unwrap(), -1.5);
        assert_eq!(d.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(d.str().unwrap(), "parrot");
        assert!(d.done());
    }

    #[test]
    fn round_trip_f32s() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 17.0).collect();
        let mut e = Encoder::new();
        e.put_f32s(&xs);
        let buf = e.finish();
        assert_eq!(buf.len(), 4 + 4 * xs.len());
        let mut d = Decoder::new(&buf);
        assert_eq!(d.f32s().unwrap(), xs);
    }

    #[test]
    fn underrun_is_error_not_panic() {
        let mut d = Decoder::new(&[1, 2]);
        assert!(d.u32().is_err());
    }

    #[test]
    fn truncated_string_is_error() {
        let mut e = Encoder::new();
        e.put_str("hello");
        let mut buf = e.finish();
        buf.truncate(6);
        let mut d = Decoder::new(&buf);
        assert!(d.str().is_err());
    }

    #[test]
    fn f32s_from_le_bytes_matches_encoder() {
        let xs = [1.0f32, -2.5, 3.25];
        let raw: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(f32s_from_le_bytes(&raw).unwrap(), xs);
        assert!(f32s_from_le_bytes(&raw[..5]).is_err());
    }

    #[test]
    fn empty_slices() {
        let mut e = Encoder::new();
        e.put_f32s(&[]);
        e.put_bytes(&[]);
        let buf = e.finish();
        let mut d = Decoder::new(&buf);
        assert!(d.f32s().unwrap().is_empty());
        assert!(d.bytes().unwrap().is_empty());
    }
}
