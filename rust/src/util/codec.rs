//! Binary codec: length-prefixed little-endian encoding for state
//! snapshots (client state manager) and transport messages.
//!
//! Hand-rolled because no serde is available offline (DESIGN.md §6).
//! The format is versionless-simple by design: every record the system
//! persists is written and read by this same build.

use anyhow::{anyhow, bail, Result};

/// Append-only encoder.
#[derive(Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Encoder { buf: Vec::with_capacity(cap) }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Checked u32 length prefix: every `len()` that crosses the wire
    /// goes through here.  A bare `as u32` cast truncates silently
    /// past 4 GiB — the decoder would then happily read a frame whose
    /// tail is misparsed as fresh records (`parrot lint` rule
    /// `unchecked-narrow` bans the cast).
    pub fn put_len(&mut self, n: usize) -> Result<()> {
        let v = u32::try_from(n)
            .map_err(|_| anyhow!("length {n} exceeds the u32 wire prefix"))?;
        self.put_u32(v);
        Ok(())
    }

    /// Checked u32 narrowing for non-length values feeding the wire
    /// (element counts, ids).
    pub fn try_put_u32(&mut self, v: usize) -> Result<()> {
        let v = u32::try_from(v).map_err(|_| anyhow!("value {v} exceeds u32 on the wire"))?;
        self.put_u32(v);
        Ok(())
    }

    pub fn put_str(&mut self, s: &str) -> Result<()> {
        self.put_len(s.len())?;
        self.buf.extend_from_slice(s.as_bytes());
        Ok(())
    }

    /// f32 slice with length prefix; the workhorse for parameter tensors.
    ///
    /// Perf (EXPERIMENTS.md §Perf): on little-endian targets this is a
    /// single bulk copy — the per-element `to_le_bytes` loop measured
    /// ~4 GB/s, the memcpy path >20 GB/s, and this sits on the
    /// device-aggregate upload path of every round.
    pub fn put_f32s(&mut self, xs: &[f32]) -> Result<()> {
        self.put_len(xs.len())?;
        #[cfg(target_endian = "little")]
        {
            let raw = unsafe {
                std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
            };
            self.buf.extend_from_slice(raw);
        }
        #[cfg(target_endian = "big")]
        {
            self.buf.reserve(xs.len() * 4);
            for &x in xs {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        Ok(())
    }

    /// u16 slice with length prefix (fp16-compressed tensors).
    pub fn put_u16s(&mut self, xs: &[u16]) -> Result<()> {
        self.put_len(xs.len())?;
        #[cfg(target_endian = "little")]
        {
            let raw = unsafe {
                std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 2)
            };
            self.buf.extend_from_slice(raw);
        }
        #[cfg(target_endian = "big")]
        {
            self.buf.reserve(xs.len() * 2);
            for &x in xs {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        Ok(())
    }

    pub fn put_bytes(&mut self, xs: &[u8]) -> Result<()> {
        self.put_len(xs.len())?;
        self.buf.extend_from_slice(xs);
        Ok(())
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Total dense f32 elements one decoder may materialize from sparse
/// (wire-unbacked) length prefixes — 64M elements ≈ 256 MB, far above
/// any legitimate frame, but a hard ceiling against amplification
/// attacks that repeat small sparse records with huge dense lengths.
pub const DENSE_ELEM_BUDGET: usize = 1 << 26;

/// Cursor-based decoder over an encoded byte slice.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
    dense_budget: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Decoder { buf, pos: 0, dense_budget: DENSE_ELEM_BUDGET }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "decode underrun: need {n} bytes at {} of {}",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Fixed-size read without a panicking conversion: `take`
    /// bounds-checks, the copy length is `N` by construction.  This
    /// keeps the whole decode path free of `unwrap`/`expect` (`parrot
    /// lint` rule `panicking-decode`).
    fn take_array<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take_array()?))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take_array()?))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take_array()?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take_array()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take_array()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let raw = self.take(n * 4)?;
        #[cfg(target_endian = "little")]
        {
            // Bulk copy (possibly unaligned source): see put_f32s.
            let mut out = vec![0.0f32; n];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 4,
                );
            }
            Ok(out)
        }
        #[cfg(target_endian = "big")]
        {
            let mut out = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            Ok(out)
        }
    }

    pub fn u16s(&mut self) -> Result<Vec<u16>> {
        let n = self.count(2)?;
        let raw = self.take(n * 2)?;
        #[cfg(target_endian = "little")]
        {
            let mut out = vec![0u16; n];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 2,
                );
            }
            Ok(out)
        }
        #[cfg(target_endian = "big")]
        {
            let mut out = Vec::with_capacity(n);
            for c in raw.chunks_exact(2) {
                out.push(u16::from_le_bytes([c[0], c[1]]));
            }
            Ok(out)
        }
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Read a u32 element-count prefix and bounds-check it against the
    /// remaining buffer (each element occupies at least
    /// `min_elem_bytes`) *before* any allocation happens.  This is the
    /// seam that keeps a corrupted or attacker-controlled length prefix
    /// from pre-allocating GBs: callers size their `Vec::with_capacity`
    /// from the checked count.
    pub fn count(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let need = n
            .checked_mul(min_elem_bytes.max(1))
            .ok_or_else(|| anyhow::anyhow!("length prefix {n} overflows"))?;
        if need > self.remaining() {
            bail!(
                "length prefix {n} needs {need} bytes but only {} remain",
                self.remaining()
            );
        }
        Ok(n)
    }

    /// Take `n` raw bytes (bounds-checked).
    pub fn raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Charge `n` elements against this decoder's cumulative budget for
    /// dense allocations that are NOT backed 1:1 by wire bytes (sparse
    /// top-k tensors).  Errors once a frame has asked for more than
    /// [`DENSE_ELEM_BUDGET`] total elements, so repeating small hostile
    /// records cannot amplify a KB-sized frame into GBs of memory.
    pub fn charge_dense(&mut self, n: usize) -> Result<()> {
        if n > self.dense_budget {
            bail!(
                "dense-allocation budget exhausted: {n} elements requested, {} left",
                self.dense_budget
            );
        }
        self.dense_budget -= n;
        Ok(())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn done(&self) -> bool {
        self.remaining() == 0
    }
}

/// Read a little-endian f32 buffer straight from raw bytes (the testvec
/// `.bin` format emitted by `aot.py`).
pub fn f32s_from_le_bytes(raw: &[u8]) -> Result<Vec<f32>> {
    if raw.len() % 4 != 0 {
        bail!("raw length {} not a multiple of 4", raw.len());
    }
    // `chunks_exact(4)` guarantees 4-byte windows, so the slice
    // pattern is irrefutable — no fallible conversion on the decode
    // path (`parrot lint` panicking-decode).
    Ok(raw
        .chunks_exact(4)
        .map(|c| match *c {
            [a, b, c2, d] => f32::from_le_bytes([a, b, c2, d]),
            _ => f32::from_le_bytes([0; 4]),
        })
        .collect())
}

pub fn i32s_from_le_bytes(raw: &[u8]) -> Result<Vec<i32>> {
    if raw.len() % 4 != 0 {
        bail!("raw length {} not a multiple of 4", raw.len());
    }
    Ok(raw
        .chunks_exact(4)
        .map(|c| match *c {
            [a, b, c2, d] => i32::from_le_bytes([a, b, c2, d]),
            _ => i32::from_le_bytes([0; 4]),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        let mut e = Encoder::new();
        e.put_u8(7);
        e.put_u32(0xDEAD_BEEF);
        e.put_u64(u64::MAX);
        e.put_f32(-1.5);
        e.put_f64(std::f64::consts::PI);
        e.put_str("parrot").unwrap();
        let buf = e.finish();
        let mut d = Decoder::new(&buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX);
        assert_eq!(d.f32().unwrap(), -1.5);
        assert_eq!(d.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(d.str().unwrap(), "parrot");
        assert!(d.done());
    }

    #[test]
    fn round_trip_f32s() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 17.0).collect();
        let mut e = Encoder::new();
        e.put_f32s(&xs).unwrap();
        let buf = e.finish();
        assert_eq!(buf.len(), 4 + 4 * xs.len());
        let mut d = Decoder::new(&buf);
        assert_eq!(d.f32s().unwrap(), xs);
    }

    #[test]
    fn underrun_is_error_not_panic() {
        let mut d = Decoder::new(&[1, 2]);
        assert!(d.u32().is_err());
    }

    #[test]
    fn round_trip_u16s() {
        let xs: Vec<u16> = (0..300).map(|i| (i * 211) as u16).collect();
        let mut e = Encoder::new();
        e.put_u16(0xBEEF);
        e.put_u16s(&xs).unwrap();
        let buf = e.finish();
        assert_eq!(buf.len(), 2 + 4 + 2 * xs.len());
        let mut d = Decoder::new(&buf);
        assert_eq!(d.u16().unwrap(), 0xBEEF);
        assert_eq!(d.u16s().unwrap(), xs);
        assert!(d.done());
    }

    #[test]
    fn hostile_length_prefix_rejected_before_allocation() {
        // A u32::MAX count with an empty tail must error immediately,
        // not allocate; same for the typed readers built on count().
        let mut e = Encoder::new();
        e.put_u32(u32::MAX);
        let buf = e.finish();
        assert!(Decoder::new(&buf).count(1).is_err());
        assert!(Decoder::new(&buf).f32s().is_err());
        assert!(Decoder::new(&buf).u16s().is_err());
        assert!(Decoder::new(&buf).bytes().is_err());
        assert!(Decoder::new(&buf).str().is_err());
        // a valid count passes and leaves the cursor on the payload
        let mut e = Encoder::new();
        e.put_u32(3);
        e.put_bytes(&[]).unwrap(); // 4 more bytes of tail
        let buf = e.finish();
        let mut d = Decoder::new(&buf);
        assert_eq!(d.count(1).unwrap(), 3);
    }

    #[test]
    fn truncated_string_is_error() {
        let mut e = Encoder::new();
        e.put_str("hello").unwrap();
        let mut buf = e.finish();
        buf.truncate(6);
        let mut d = Decoder::new(&buf);
        assert!(d.str().is_err());
    }

    #[test]
    fn f32s_from_le_bytes_matches_encoder() {
        let xs = [1.0f32, -2.5, 3.25];
        let raw: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(f32s_from_le_bytes(&raw).unwrap(), xs);
        assert!(f32s_from_le_bytes(&raw[..5]).is_err());
    }

    #[test]
    fn empty_slices() {
        let mut e = Encoder::new();
        e.put_f32s(&[]).unwrap();
        e.put_bytes(&[]).unwrap();
        let buf = e.finish();
        let mut d = Decoder::new(&buf);
        assert!(d.f32s().unwrap().is_empty());
        assert!(d.bytes().unwrap().is_empty());
    }

    #[test]
    fn put_len_rejects_lengths_past_u32() {
        // No 4 GiB allocation needed: the helper checks the *value*,
        // not a real buffer.
        let over = u32::MAX as usize + 1;
        let mut e = Encoder::new();
        assert!(e.put_len(over).is_err());
        assert!(e.try_put_u32(over).is_err());
        assert!(e.is_empty(), "a rejected prefix must write nothing");
        e.put_len(u32::MAX as usize).unwrap();
        e.try_put_u32(7).unwrap();
        let mut d = Decoder::new(&e.finish());
        assert_eq!(d.u32().unwrap(), u32::MAX);
        assert_eq!(d.u32().unwrap(), 7);
    }
}
