//! Property-testing harness (in-tree stand-in for `proptest`, which is
//! unavailable offline — DESIGN.md §6).
//!
//! Model: a property is a closure over a seeded [`Gen`]; the runner
//! executes it for `cases` random seeds and, on failure, retries the
//! failing seed with progressively smaller size hints to report the
//! smallest reproduction it finds.  Failures print the seed so any case
//! is replayable.

use super::rng::Rng;

/// Value generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    /// Size hint in [0.0, 1.0]; generators scale ranges by it so the
    /// shrink pass can search smaller inputs.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Gen {
        Gen { rng: Rng::new(seed), size }
    }

    /// Integer in [lo, hi], scaled down by the size hint.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).ceil() as u64;
        lo + self.rng.below(span.max(1)) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, lo + (hi - lo) * self.size.max(0.05))
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.int(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Default master seed; override per run with `PARROT_PROP_SEED=<u64>`
/// (decimal or 0x-hex) — scripts/ci.sh runs the suites once with the
/// fixed default and once with a random seed it prints for replay.
const DEFAULT_MASTER_SEED: u64 = 0xC0FF_EE00;

fn master_seed() -> u64 {
    match std::env::var("PARROT_PROP_SEED") {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x") {
                Some(h) => u64::from_str_radix(h, 16).ok(),
                None => s.parse().ok(),
            };
            parsed.unwrap_or_else(|| {
                panic!("PARROT_PROP_SEED must be a u64 (decimal or 0x-hex), got {s:?}")
            })
        }
        Err(_) => DEFAULT_MASTER_SEED,
    }
}

/// Run `prop` for `cases` random cases. Panics with the failing seed and
/// the smallest failing size found.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    check_seeded(name, cases, master_seed(), &mut prop)
}

pub fn check_seeded(
    name: &str,
    cases: usize,
    master_seed: u64,
    prop: &mut impl FnMut(&mut Gen) -> Result<(), String>,
) {
    let mut seeder = Rng::new(master_seed);
    for case in 0..cases {
        let seed = seeder.next_u64();
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink pass: same seed, smaller size hints.
            let mut best: Option<(f64, String)> = None;
            for &size in &[0.02, 0.05, 0.1, 0.25, 0.5, 0.75] {
                let mut g = Gen::new(seed, size);
                if let Err(m) = prop(&mut g) {
                    best = Some((size, m));
                    break;
                }
            }
            let replay =
                format!("replay the whole sequence with PARROT_PROP_SEED={master_seed:#x}");
            match best {
                Some((size, m)) => panic!(
                    "property {name:?} failed (case {case}, seed {seed:#x}): {msg}\n\
                     smallest reproduction at size={size}: {m}\n{replay}"
                ),
                None => panic!(
                    "property {name:?} failed (case {case}, seed {seed:#x}, size=1.0): {msg}\n\
                     {replay}"
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivially true", 50, |g| {
            count += 1;
            let x = g.int(0, 100);
            if x <= 100 {
                Ok(())
            } else {
                Err(format!("{x} > 100"))
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always false", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 100, |g| {
            let n = g.int(3, 17);
            if !(3..=17).contains(&n) {
                return Err(format!("int out of range: {n}"));
            }
            let x = g.f64(-2.0, 5.0);
            if !(-2.0..=5.0).contains(&x) {
                return Err(format!("f64 out of range: {x}"));
            }
            let v = g.vec_f32(n, 0.0, 1.0);
            if v.len() != n {
                return Err("bad vec len".into());
            }
            Ok(())
        });
    }

    #[test]
    fn same_seed_same_values() {
        let mut a = Gen::new(99, 1.0);
        let mut b = Gen::new(99, 1.0);
        for _ in 0..20 {
            assert_eq!(a.int(0, 1000), b.int(0, 1000));
        }
    }
}
