//! Tiny CLI argument parser for the `parrot` launcher and the examples.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free
//! positional arguments, with typed getters and a usage renderer.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — pass `std::env::args().skip(1)`.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminator: everything after is positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.opts.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: expected integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: expected integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: expected number, got {v:?}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// List of comma-separated usize values (e.g. `--devices 4,8,16,32`).
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| anyhow!("--{name}: bad list element {t:?}"))
                })
                .collect(),
        }
    }

    pub fn subcommand(&self) -> Result<&str> {
        if self.positional.is_empty() {
            bail!("missing subcommand");
        }
        Ok(&self.positional[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = parse(&["exp", "fig5", "--devices", "4,8", "--seed=42", "--verbose"]);
        assert_eq!(a.positional, vec!["exp", "fig5"]);
        assert_eq!(a.get("devices"), Some("4,8"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 42);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse(&["--k", "8", "--lr", "0.05"]);
        assert_eq!(a.usize_or("k", 1).unwrap(), 8);
        assert_eq!(a.usize_or("m", 100).unwrap(), 100);
        assert!((a.f64_or("lr", 0.1).unwrap() - 0.05).abs() < 1e-12);
        assert!(a.usize_or("lr", 0).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "x"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("x"));
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--k", "1", "--", "--not-an-opt"]);
        assert_eq!(a.positional, vec!["--not-an-opt"]);
    }

    #[test]
    fn usize_list() {
        let a = parse(&["--devices", "4, 8,16"]);
        assert_eq!(a.usize_list_or("devices", &[]).unwrap(), vec![4, 8, 16]);
        assert_eq!(a.usize_list_or("other", &[2]).unwrap(), vec![2]);
    }

    #[test]
    fn require_and_subcommand_errors() {
        let a = parse(&[]);
        assert!(a.require("x").is_err());
        assert!(a.subcommand().is_err());
    }
}
