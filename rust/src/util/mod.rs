//! Zero-dependency substrates.
//!
//! The offline build environment provides only the `xla` crate closure,
//! so the utility layer other frameworks take from crates.io is built
//! in-tree (DESIGN.md §6): PRNG, statistics/OLS, binary codec, CLI
//! parsing, a property-testing harness, a criterion-style bench harness,
//! and a minimal JSON writer for experiment reports.

pub mod bench;
pub mod cli;
pub mod codec;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
