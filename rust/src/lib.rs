//! # Parrot — scalable federated-learning simulation
//!
//! A reproduction of *"FedML Parrot: A Scalable Federated Learning System
//! via Heterogeneity-aware Scheduling on Sequential and Hierarchical
//! Training"* (Tang et al., 2023) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! - **Layer 3 (this crate)** — the Parrot coordinator: round loop,
//!   sequential device executors, hierarchical aggregation, the
//!   heterogeneity-aware task scheduler, and the client state manager.
//! - **Layer 2** — the per-client train/eval step authored in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text.
//! - **Layer 1** — Pallas kernels for the step's compute hot-spot
//!   (`python/compile/kernels/`), lowered into the same HLO.
//!
//! At runtime the Rust binary loads `artifacts/*.hlo.txt` through PJRT
//! (`runtime`); Python never runs on the simulation path.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a harness in [`exp`].

// Determinism discipline (README): `clippy.toml` disallows HashMap/
// HashSet and wallclock entropy so editors surface the core `parrot
// lint` rules live.  The ban is scoped, not global — allow at the
// crate root, deny in the determinism-critical modules (simulation,
// scheduler, aggregation, statestore, compress, cluster, obs), whose
// iteration/merge order is observable in traces.
#![allow(clippy::disallowed_types, clippy::disallowed_methods)]

pub mod analysis;
pub mod obs;
pub mod util;
pub mod compress;
pub mod config;
pub mod data;
pub mod model;
pub mod runtime;
pub mod algorithms;
pub mod aggregation;
pub mod state;
pub mod statestore;
pub mod scheduler;
pub mod cluster;
pub mod transport;
pub mod coordinator;
pub mod simulation;
pub mod exp;
