//! PJRT runtime: load AOT artifacts (HLO text), compile once per
//! process, execute from the simulation hot path.
//!
//! Wraps the `xla` crate exactly as the smoke-verified reference
//! (/opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format — xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids).
//!
//! Threading model: PJRT handles in the `xla` crate are not `Send`, so
//! each simulated device (worker thread) owns its own [`Runtime`] — which
//! also mirrors the paper's execution model where every device holds its
//! own copy of the training executable.
//!
//! Hot-path design (§Perf): [`TaskRun`] keeps the model parameters as
//! PJRT literals across the E·⌈N_m/B⌉ batch steps of one client task,
//! so per-batch marshalling is only the (x, y) batch literals; the
//! ParamSet ↔ literal conversion happens once per client task, not once
//! per batch.

use crate::data::Batch;
use crate::model::{Dtype, Manifest, ParamSet, TensorDecl};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

/// A compiled AOT artifact plus its manifest (the marshalling contract).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

/// Outputs of one train-step invocation.
#[derive(Debug)]
pub struct TrainOut {
    pub params: ParamSet,
    pub loss: f32,
    pub gsq: f32,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` / `<name>.manifest.txt`.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let hlo = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let man = self.artifact_dir.join(format!("{name}.manifest.txt"));
        let manifest = Manifest::load(&man)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("loading HLO {}: {e}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        Ok(Executable { exe, manifest })
    }
}

// ---------------------------------------------------------------- literals

pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == numel, "f32 literal: {} vs shape {:?}", data.len(), shape);
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("create f32 literal: {e}"))
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == numel, "i32 literal: {} vs shape {:?}", data.len(), shape);
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow!("create i32 literal: {e}"))
}

pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

fn batch_literals(decls: &[&TensorDecl], batch: &Batch) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(decls.len());
    for d in decls {
        let lit = match (d.name.as_str(), d.dtype) {
            ("x", Dtype::F32) => lit_f32(&batch.x_f32, &d.shape)?,
            ("x", Dtype::I32) => lit_i32(&batch.x_i32, &d.shape)?,
            ("y", Dtype::I32) => lit_i32(&batch.y, &d.shape)?,
            _ => bail!("unexpected batch decl {} {:?}", d.name, d.dtype),
        };
        out.push(lit);
    }
    Ok(out)
}

fn params_to_literals(p: &ParamSet) -> Result<Vec<xla::Literal>> {
    p.shapes
        .iter()
        .zip(&p.tensors)
        .map(|(s, t)| lit_f32(t, s))
        .collect()
}

fn literal_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))
}

fn scalar_of(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow!("scalar read: {e}"))
}

impl Executable {
    /// Execute with literal inputs; unwraps the 1-tuple root into the
    /// flat output literals (the AOT path lowers with return_tuple=True).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.manifest.inputs.len(),
            "{}: {} inputs, manifest wants {}",
            self.manifest.artifact,
            inputs.len(),
            self.manifest.inputs.len()
        );
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {}: {e}", self.manifest.artifact))?;
        let mut tuple = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let outs = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e}"))?;
        anyhow::ensure!(
            outs.len() == self.manifest.outputs.len(),
            "{}: {} outputs, manifest wants {}",
            self.manifest.artifact,
            outs.len(),
            self.manifest.outputs.len()
        );
        Ok(outs)
    }

    /// One eval step: returns (loss, n_correct).
    pub fn eval(&self, params: &ParamSet, batch: &Batch) -> Result<(f32, f32)> {
        anyhow::ensure!(self.manifest.kind == "eval");
        let mut inputs = params_to_literals(params)?;
        inputs.extend(batch_literals(&self.manifest.batch_decls(), batch)?);
        let outs = self.execute(&inputs)?;
        Ok((scalar_of(&outs[0])?, scalar_of(&outs[1])?))
    }

    /// Full-batch gradient step: returns (grads, loss).
    pub fn grad(&self, params: &ParamSet, batch: &Batch) -> Result<(ParamSet, f32)> {
        anyhow::ensure!(self.manifest.kind == "grad");
        let mut inputs = params_to_literals(params)?;
        inputs.extend(batch_literals(&self.manifest.batch_decls(), batch)?);
        let outs = self.execute(&inputs)?;
        let n = self.manifest.nparams;
        let shapes = self.manifest.param_shapes();
        let tensors = outs[..n]
            .iter()
            .map(literal_to_vec_f32)
            .collect::<Result<Vec<_>>>()?;
        Ok((ParamSet { shapes, tensors }, scalar_of(&outs[n])?))
    }

    /// Single train step (slow path; [`TaskRun`] is the hot path).
    pub fn train_once(
        &self,
        params: &ParamSet,
        anchors: &ParamSet,
        corrs: &ParamSet,
        batch: &Batch,
        lr: f32,
        mu: f32,
    ) -> Result<TrainOut> {
        let mut run = TaskRun::start(self, params, anchors, corrs, lr, mu)?;
        let (loss, gsq) = run.step(batch)?;
        Ok(TrainOut { params: run.finish()?, loss, gsq })
    }

    /// Begin a client task (sequential batches over one client's data).
    pub fn start_task(
        &self,
        params: &ParamSet,
        anchors: &ParamSet,
        corrs: &ParamSet,
        lr: f32,
        mu: f32,
    ) -> Result<TaskRun<'_>> {
        TaskRun::start(self, params, anchors, corrs, lr, mu)
    }
}

/// In-flight client task: parameters live as PJRT literals between
/// batch steps (see module docs / §Perf).
pub struct TaskRun<'e> {
    exe: &'e Executable,
    param_lits: Vec<xla::Literal>,
    anchor_lits: Vec<xla::Literal>,
    corr_lits: Vec<xla::Literal>,
    lr: xla::Literal,
    mu: xla::Literal,
}

impl<'e> TaskRun<'e> {
    fn start(
        exe: &'e Executable,
        params: &ParamSet,
        anchors: &ParamSet,
        corrs: &ParamSet,
        lr: f32,
        mu: f32,
    ) -> Result<TaskRun<'e>> {
        anyhow::ensure!(exe.manifest.kind == "train", "start_task on non-train artifact");
        Ok(TaskRun {
            exe,
            param_lits: params_to_literals(params)?,
            anchor_lits: params_to_literals(anchors)?,
            corr_lits: params_to_literals(corrs)?,
            lr: lit_scalar(lr),
            mu: lit_scalar(mu),
        })
    }

    /// One batch step; updates the in-flight parameters, returns (loss, gsq).
    pub fn step(&mut self, batch: &Batch) -> Result<(f32, f32)> {
        let m = &self.exe.manifest;
        let n = m.nparams;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(m.inputs.len());
        inputs.extend(self.param_lits.iter());
        inputs.extend(self.anchor_lits.iter());
        inputs.extend(self.corr_lits.iter());
        let batch_lits = batch_literals(&m.batch_decls(), batch)?;
        inputs.extend(batch_lits.iter());
        inputs.push(&self.lr);
        inputs.push(&self.mu);
        // Borrow-based execute avoids cloning the big param literals.
        let bufs = self
            .exe
            .exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("train step: {e}"))?;
        let mut tuple = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch train result: {e}"))?;
        let mut outs = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose train tuple: {e}"))?;
        anyhow::ensure!(outs.len() == n + 2, "train outputs {} != {}", outs.len(), n + 2);
        let gsq = scalar_of(&outs[n + 1])?;
        let loss = scalar_of(&outs[n])?;
        outs.truncate(n);
        self.param_lits = outs; // new params stay as literals — no host decode
        Ok((loss, gsq))
    }

    /// Materialize the current parameters back into a ParamSet.
    pub fn finish(self) -> Result<ParamSet> {
        let shapes = self.exe.manifest.param_shapes();
        let tensors = self
            .param_lits
            .iter()
            .map(literal_to_vec_f32)
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamSet { shapes, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let data = vec![1.0f32, -2.0, 3.5, 0.0, 7.25, -9.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(literal_to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn literal_round_trip_i32() {
        let data = vec![1i32, -2, 3];
        let lit = lit_i32(&data, &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_literal() {
        let lit = lit_scalar(4.25);
        assert_eq!(scalar_of(&lit).unwrap(), 4.25);
    }
}
