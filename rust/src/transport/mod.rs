//! Communication abstraction — the §3.2 "zero-code-change" seam.
//!
//! The coordinator is generic over [`Transport`]; simulation wires it to
//! [`local`] (in-process channels) and the deployment example wires the
//! *identical* coordinator to [`tcp`] (length-prefixed frames over real
//! sockets, workers possibly in other processes).  Endpoint 0 is always
//! the server; endpoints 1..=K are devices.
//!
//! Every byte crossing a Transport is counted by the caller — the comm
//! size/trip columns of Table 1 are measured, not asserted.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Passive wire meters an endpoint can expose for observability
/// (`--trace`): message and byte totals per direction.  `Relaxed`
/// atomics — the counts feed the exported metrics registry only, never
/// control flow, so no ordering is load-bearing.
#[derive(Debug, Default)]
pub struct TransportMeter {
    sent_msgs: AtomicU64,
    sent_bytes: AtomicU64,
    recv_msgs: AtomicU64,
    recv_bytes: AtomicU64,
}

impl TransportMeter {
    fn on_send(&self, bytes: usize) {
        self.sent_msgs.fetch_add(1, Ordering::Relaxed);
        self.sent_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn on_recv(&self, bytes: usize) {
        self.recv_msgs.fetch_add(1, Ordering::Relaxed);
        self.recv_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Snapshot as `(sent_msgs, sent_bytes, recv_msgs, recv_bytes)`.
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.sent_msgs.load(Ordering::Relaxed),
            self.sent_bytes.load(Ordering::Relaxed),
            self.recv_msgs.load(Ordering::Relaxed),
            self.recv_bytes.load(Ordering::Relaxed),
        )
    }

    /// Export the snapshot as `<prefix>.{sent,recv}_{msgs,bytes}`.
    pub fn export(&self, reg: &mut crate::obs::Registry, prefix: &str) {
        let (sm, sb, rm, rb) = self.snapshot();
        reg.add(&format!("{prefix}.sent_msgs"), sm);
        reg.add(&format!("{prefix}.sent_bytes"), sb);
        reg.add(&format!("{prefix}.recv_msgs"), rm);
        reg.add(&format!("{prefix}.recv_bytes"), rb);
    }
}

/// A bidirectional message endpoint.
pub trait Transport: Send {
    /// This endpoint's id (0 = server).
    fn id(&self) -> usize;
    /// Send `msg` to endpoint `to`.
    fn send(&self, to: usize, msg: Vec<u8>) -> Result<()>;
    /// Blocking receive; `timeout` None = wait forever.
    fn recv(&self, timeout: Option<Duration>) -> Result<(usize, Vec<u8>)>;
    /// This endpoint's wire meters, when it keeps any.
    fn meter(&self) -> Option<&TransportMeter> {
        None
    }
}

// ------------------------------------------------------------------ local

/// In-process transport over std mpsc channels.
pub struct LocalEndpoint {
    id: usize,
    inbox: Receiver<(usize, Vec<u8>)>,
    peers: HashMap<usize, Sender<(usize, Vec<u8>)>>,
    meter: TransportMeter,
}

/// Build a fully-connected local network: returns K+1 endpoints
/// (server = index 0, devices = 1..=K).
pub fn local(k: usize) -> Vec<LocalEndpoint> {
    let mut senders = Vec::with_capacity(k + 1);
    let mut inboxes = Vec::with_capacity(k + 1);
    for _ in 0..=k {
        let (tx, rx) = channel();
        senders.push(tx);
        inboxes.push(rx);
    }
    inboxes
        .into_iter()
        .enumerate()
        .map(|(id, inbox)| LocalEndpoint {
            id,
            inbox,
            peers: senders
                .iter()
                .enumerate()
                .map(|(j, tx)| (j, tx.clone()))
                .collect(),
            meter: TransportMeter::default(),
        })
        .collect()
}

impl Transport for LocalEndpoint {
    fn id(&self) -> usize {
        self.id
    }

    fn send(&self, to: usize, msg: Vec<u8>) -> Result<()> {
        self.meter.on_send(msg.len());
        self.peers
            .get(&to)
            .ok_or_else(|| anyhow!("no endpoint {to}"))?
            .send((self.id, msg))
            .map_err(|_| anyhow!("endpoint {to} hung up"))
    }

    fn recv(&self, timeout: Option<Duration>) -> Result<(usize, Vec<u8>)> {
        let got = match timeout {
            None => self.inbox.recv().map_err(|_| anyhow!("all senders hung up")),
            Some(t) => self
                .inbox
                .recv_timeout(t)
                .map_err(|e| anyhow!("recv timeout/disconnect: {e}")),
        }?;
        self.meter.on_recv(got.1.len());
        Ok(got)
    }

    fn meter(&self) -> Option<&TransportMeter> {
        Some(&self.meter)
    }
}

// -------------------------------------------------------------------- tcp

/// Frame = 4-byte LE length + 4-byte LE sender id + payload.
fn write_frame(stream: &mut TcpStream, from: usize, msg: &[u8]) -> Result<()> {
    let len = u32::try_from(msg.len()).map_err(|_| {
        anyhow!("frame payload of {} bytes exceeds the u32 length prefix", msg.len())
    })?;
    let mut hdr = [0u8; 8];
    hdr[..4].copy_from_slice(&len.to_le_bytes());
    hdr[4..].copy_from_slice(&(from as u32).to_le_bytes());
    stream.write_all(&hdr).context("write frame header")?;
    stream.write_all(msg).context("write frame payload")?;
    stream.flush()?;
    Ok(())
}

fn read_frame(stream: &mut TcpStream) -> Result<(usize, Vec<u8>)> {
    let mut hdr = [0u8; 8];
    stream.read_exact(&mut hdr).context("read frame header")?;
    let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
    let from = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]) as usize;
    if len > 1 << 30 {
        bail!("frame too large: {len}");
    }
    let mut buf = vec![0u8; len];
    stream
        .read_exact(&mut buf)
        .with_context(|| format!("frame truncated: peer {from} promised {len} bytes"))?;
    Ok((from, buf))
}

/// TCP server endpoint: accepts K workers, demuxes their frames into a
/// channel (one reader thread per connection).
pub struct TcpServerEndpoint {
    inbox: Receiver<(usize, Vec<u8>)>,
    outs: HashMap<usize, Arc<Mutex<TcpStream>>>,
    meter: TransportMeter,
}

/// A bound-but-not-yet-accepting listener.  Binding and accepting are
/// split so callers can bind port 0, read the ephemeral port the OS
/// picked, hand it to workers, and only then block in `accept` —
/// no test or example ever hardcodes a port (which collides under
/// parallel runs).
pub struct TcpListenerHandle {
    listener: TcpListener,
}

impl TcpListenerHandle {
    pub fn listen(addr: &str) -> Result<TcpListenerHandle> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(TcpListenerHandle { listener })
    }

    /// The actual bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept exactly `k` workers; each worker's first frame announces
    /// its device id (1..=k).
    pub fn accept(self, k: usize) -> Result<TcpServerEndpoint> {
        let (tx, inbox) = channel();
        let mut outs = HashMap::new();
        for _ in 0..k {
            let (mut stream, _) = self.listener.accept()?;
            stream.set_nodelay(true).ok();
            let (id, _) = read_frame(&mut stream).context("worker hello frame")?;
            outs.insert(id, Arc::new(Mutex::new(stream.try_clone()?)));
            let tx = tx.clone();
            std::thread::spawn(move || loop {
                match read_frame(&mut stream) {
                    Ok(f) => {
                        if tx.send(f).is_err() {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            });
        }
        Ok(TcpServerEndpoint { inbox, outs, meter: TransportMeter::default() })
    }
}

impl TcpServerEndpoint {
    /// Bind `addr` and accept exactly `k` workers in one call (the
    /// deployment path, where the address is fixed up front).
    pub fn bind(addr: &str, k: usize) -> Result<TcpServerEndpoint> {
        TcpListenerHandle::listen(addr)?.accept(k)
    }
}

impl Transport for TcpServerEndpoint {
    fn id(&self) -> usize {
        0
    }

    fn send(&self, to: usize, msg: Vec<u8>) -> Result<()> {
        self.meter.on_send(msg.len());
        let s = self.outs.get(&to).ok_or_else(|| anyhow!("no worker {to}"))?;
        let mut s = s.lock().map_err(|_| anyhow!("connection to worker {to} poisoned"))?;
        write_frame(&mut s, 0, &msg)
    }

    fn recv(&self, timeout: Option<Duration>) -> Result<(usize, Vec<u8>)> {
        let got = match timeout {
            None => self.inbox.recv().map_err(|_| anyhow!("workers hung up")),
            Some(t) => self.inbox.recv_timeout(t).map_err(|e| anyhow!("recv: {e}")),
        }?;
        self.meter.on_recv(got.1.len());
        Ok(got)
    }

    fn meter(&self) -> Option<&TransportMeter> {
        Some(&self.meter)
    }
}

/// TCP worker endpoint: connects to the server.
pub struct TcpWorkerEndpoint {
    id: usize,
    stream: Arc<Mutex<TcpStream>>,
    inbox: Receiver<(usize, Vec<u8>)>,
    meter: TransportMeter,
}

impl TcpWorkerEndpoint {
    pub fn connect(addr: &str, id: usize) -> Result<TcpWorkerEndpoint> {
        let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        write_frame(&mut stream, id, b"hello")?; // announce id
        let (tx, inbox) = channel();
        let mut reader = stream.try_clone()?;
        std::thread::spawn(move || loop {
            match read_frame(&mut reader) {
                Ok(f) => {
                    if tx.send(f).is_err() {
                        break;
                    }
                }
                Err(_) => break,
            }
        });
        Ok(TcpWorkerEndpoint {
            id,
            stream: Arc::new(Mutex::new(stream)),
            inbox,
            meter: TransportMeter::default(),
        })
    }
}

impl Transport for TcpWorkerEndpoint {
    fn id(&self) -> usize {
        self.id
    }

    fn send(&self, to: usize, msg: Vec<u8>) -> Result<()> {
        anyhow::ensure!(to == 0, "workers only talk to the server");
        self.meter.on_send(msg.len());
        let mut s =
            self.stream.lock().map_err(|_| anyhow!("server connection mutex poisoned"))?;
        write_frame(&mut s, self.id, &msg)
    }

    fn recv(&self, timeout: Option<Duration>) -> Result<(usize, Vec<u8>)> {
        let got = match timeout {
            None => self.inbox.recv().map_err(|_| anyhow!("server hung up")),
            Some(t) => self.inbox.recv_timeout(t).map_err(|e| anyhow!("recv: {e}")),
        }?;
        self.meter.on_recv(got.1.len());
        Ok(got)
    }

    fn meter(&self) -> Option<&TransportMeter> {
        Some(&self.meter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_round_trip() {
        let mut eps = local(2);
        let w2 = eps.pop().unwrap();
        let w1 = eps.pop().unwrap();
        let server = eps.pop().unwrap();
        server.send(1, b"task for 1".to_vec()).unwrap();
        server.send(2, b"task for 2".to_vec()).unwrap();
        let (from, msg) = w1.recv(Some(Duration::from_secs(1))).unwrap();
        assert_eq!((from, msg.as_slice()), (0, b"task for 1".as_slice()));
        let (_, msg2) = w2.recv(Some(Duration::from_secs(1))).unwrap();
        assert_eq!(msg2, b"task for 2");
        w1.send(0, b"done 1".to_vec()).unwrap();
        w2.send(0, b"done 2".to_vec()).unwrap();
        let mut got = vec![
            server.recv(Some(Duration::from_secs(1))).unwrap(),
            server.recv(Some(Duration::from_secs(1))).unwrap(),
        ];
        got.sort_by_key(|(from, _)| *from);
        assert_eq!(got[0].0, 1);
        assert_eq!(got[1].1, b"done 2");
        // The endpoint meters every frame it moved, both directions.
        let (sm, sb, rm, rb) = server.meter().unwrap().snapshot();
        assert_eq!((sm, sb), (2, 20));
        assert_eq!((rm, rb), (2, 12));
    }

    #[test]
    fn local_timeout() {
        let eps = local(1);
        assert!(eps[0].recv(Some(Duration::from_millis(10))).is_err());
    }

    #[test]
    fn local_unknown_peer() {
        let eps = local(1);
        assert!(eps[0].send(9, vec![]).is_err());
    }

    #[test]
    fn tcp_round_trip_threads() {
        // Bind port 0 and discover the ephemeral port: hardcoded ports
        // collide under parallel test runs.
        let handle = TcpListenerHandle::listen("127.0.0.1:0").unwrap();
        let addr = handle.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || {
            let server = handle.accept(2).unwrap();
            server.send(1, b"hi 1".to_vec()).unwrap();
            server.send(2, vec![7u8; 100_000]).unwrap(); // big frame
            let mut seen = Vec::new();
            for _ in 0..2 {
                let (from, msg) = server.recv(Some(Duration::from_secs(5))).unwrap();
                seen.push((from, msg));
            }
            seen.sort_by_key(|(f, _)| *f);
            assert_eq!(seen[0], (1, b"ack1".to_vec()));
            assert_eq!(seen[1].1.len(), 3);
        });
        // The listener is already bound, so connects queue in the
        // accept backlog — no startup sleep needed.
        let w1 = TcpWorkerEndpoint::connect(&addr, 1).unwrap();
        let w2 = TcpWorkerEndpoint::connect(&addr, 2).unwrap();
        let (_, m1) = w1.recv(Some(Duration::from_secs(5))).unwrap();
        assert_eq!(m1, b"hi 1");
        let (_, m2) = w2.recv(Some(Duration::from_secs(5))).unwrap();
        assert_eq!(m2.len(), 100_000);
        assert!(m2.iter().all(|&b| b == 7));
        w1.send(0, b"ack1".to_vec()).unwrap();
        w2.send(0, b"ac2".to_vec()).unwrap();
        server_thread.join().unwrap();
    }

    #[test]
    fn half_written_frame_degrades_to_error() {
        // A peer that dies mid-frame must surface as a recv error on
        // the server side — never as a short frame delivered as data.
        let handle = TcpListenerHandle::listen("127.0.0.1:0").unwrap();
        let addr = handle.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || {
            let server = handle.accept(1).unwrap();
            server.recv(Some(Duration::from_millis(500)))
        });
        {
            let mut stream = TcpStream::connect(&addr).unwrap();
            write_frame(&mut stream, 1, b"hello").unwrap(); // announce id
            // Promise 100 payload bytes, deliver 3, drop the socket.
            let mut hdr = [0u8; 8];
            hdr[..4].copy_from_slice(&100u32.to_le_bytes());
            hdr[4..].copy_from_slice(&1u32.to_le_bytes());
            stream.write_all(&hdr).unwrap();
            stream.write_all(&[1, 2, 3]).unwrap();
            stream.flush().unwrap();
        }
        let got = server_thread.join().unwrap();
        assert!(got.is_err(), "truncated frame must not surface as data: {got:?}");
    }
}
