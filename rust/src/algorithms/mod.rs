//! The six FL algorithms of the paper's evaluation (§5.1), expressed in
//! Parrot's generic API: per-algorithm **OP declarations** on the
//! communicated parameters plus device-side task preparation and
//! server-side application (paper §3.2 "the only extra things users
//! specify").
//!
//! All six share the one AOT-compiled generalized step (DESIGN.md §3):
//!
//! | algorithm | mu        | anchor   | corr        | client state | special params |
//! |-----------|-----------|----------|-------------|--------------|----------------|
//! | FedAvg    | 0         | —        | 0           | —            | —              |
//! | FedProx   | μ         | w_global | 0           | —            | —              |
//! | FedNova   | 0         | —        | 0           | —            | τ_m (Collect)  |
//! | SCAFFOLD  | 0         | —        | c − c_i     | c_i          | —              |
//! | FedDyn    | α         | w_global | −h_i        | h_i          | —              |
//! | Mime      | 0         | —        | β·m_server  | —            | full-batch g   |
//!
//! SCAFFOLD uses option-II control-variate refresh; Mime is the
//! MimeLite-style variant (server momentum applied as an additive local
//! correction) — both documented in DESIGN.md §3.

use crate::aggregation::{AggOp, ClientUpdate, Payload, RoundAggregate};
use crate::model::ParamSet;
use anyhow::{bail, Result};

/// What the server broadcasts each round (Θ^r of Alg. 1).
#[derive(Debug, Clone)]
pub struct Broadcast {
    pub round: usize,
    pub params: ParamSet,
    /// Algorithm-specific extra global quantity (SCAFFOLD c, Mime m).
    pub extra: Option<ParamSet>,
}

/// Device-side inputs for one client task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub anchors: ParamSet,
    pub corrs: ParamSet,
    pub mu: f32,
    /// Whether the worker must also run the grad artifact to produce a
    /// full-batch gradient (Mime).
    pub wants_full_grad: bool,
}

/// What local training produced for one client.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub client: usize,
    /// Aggregation weight (N_m).
    pub weight: f64,
    /// Parameters at task start (= broadcast params).
    pub initial: ParamSet,
    /// Parameters after E local epochs.
    pub finals: ParamSet,
    pub mean_loss: f32,
    /// Local SGD steps taken (τ_m for FedNova / SCAFFOLD).
    pub n_steps: usize,
    pub lr: f32,
    /// Full-batch gradient at the initial params (Mime only).
    pub full_grad: Option<ParamSet>,
}

/// Server-side mutable algorithm state.
#[derive(Debug, Clone, Default)]
pub struct ServerState {
    /// SCAFFOLD global control variate c.
    pub c: Option<ParamSet>,
    /// FedDyn h term.
    pub h: Option<ParamSet>,
    /// Mime server momentum m.
    pub m: Option<ParamSet>,
}

/// Round context for server updates.
#[derive(Debug, Clone, Copy)]
pub struct ServerCtx {
    pub m_total: usize,
    pub m_selected: usize,
}

/// The algorithm registry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algo {
    FedAvg,
    FedProx { mu: f32 },
    FedNova,
    Scaffold,
    FedDyn { alpha: f32 },
    Mime { beta: f32 },
}

impl Algo {
    /// Parse by name, taking μ/α/β from the config's `mu` knob.
    pub fn parse(name: &str, mu: f32) -> Result<Algo> {
        Ok(match name {
            "fedavg" => Algo::FedAvg,
            "fedprox" => Algo::FedProx { mu: if mu > 0.0 { mu } else { 0.01 } },
            "fednova" => Algo::FedNova,
            "scaffold" => Algo::Scaffold,
            "feddyn" => Algo::FedDyn { alpha: if mu > 0.0 { mu } else { 0.01 } },
            "mime" => Algo::Mime { beta: if mu > 0.0 { mu } else { 0.9 } },
            _ => bail!(
                "unknown algorithm {name:?} (fedavg|fedprox|fednova|scaffold|feddyn|mime)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::FedAvg => "fedavg",
            Algo::FedProx { .. } => "fedprox",
            Algo::FedNova => "fednova",
            Algo::Scaffold => "scaffold",
            Algo::FedDyn { .. } => "feddyn",
            Algo::Mime { .. } => "mime",
        }
    }

    /// Does the algorithm keep per-client state (needs the state manager)?
    pub fn stateful(&self) -> bool {
        matches!(self, Algo::Scaffold | Algo::FedDyn { .. })
    }

    /// Does it communicate Special Params (Collect entries, §4.2)?
    pub fn has_special(&self) -> bool {
        matches!(self, Algo::FedNova | Algo::Mime { .. })
    }

    // ------------------------------------------------------------ device

    /// Build the task spec for one client (Device_Executes prologue).
    pub fn prepare(
        &self,
        bc: &Broadcast,
        client_state: Option<&ParamSet>,
        shapes: &[Vec<usize>],
    ) -> TaskSpec {
        let zeros = || ParamSet::zeros(shapes);
        match self {
            Algo::FedAvg | Algo::FedNova => TaskSpec {
                anchors: zeros(),
                corrs: zeros(),
                mu: 0.0,
                wants_full_grad: false,
            },
            Algo::FedProx { mu } => TaskSpec {
                anchors: bc.params.clone(),
                corrs: zeros(),
                mu: *mu,
                wants_full_grad: false,
            },
            Algo::Scaffold => {
                // corr = c − c_i
                let mut corr = bc.extra.clone().unwrap_or_else(zeros);
                if let Some(ci) = client_state {
                    corr.add_scaled(ci, -1.0);
                }
                TaskSpec { anchors: zeros(), corrs: corr, mu: 0.0, wants_full_grad: false }
            }
            Algo::FedDyn { alpha } => {
                // corr = −h_i ; prox anchor = w_global with μ=α
                let mut corr = zeros();
                if let Some(hi) = client_state {
                    corr.add_scaled(hi, -1.0);
                }
                TaskSpec {
                    anchors: bc.params.clone(),
                    corrs: corr,
                    mu: *alpha,
                    wants_full_grad: false,
                }
            }
            Algo::Mime { beta } => {
                let mut corr = bc.extra.clone().unwrap_or_else(zeros);
                corr.scale(*beta);
                TaskSpec { anchors: zeros(), corrs: corr, mu: 0.0, wants_full_grad: true }
            }
        }
    }

    /// Build the ClientUpdate (+ new client state) from a finished task
    /// (Device_Executes epilogue: the user-declared OPs).
    pub fn client_update(
        &self,
        res: &TaskResult,
        bc: &Broadcast,
        old_state: Option<&ParamSet>,
    ) -> (ClientUpdate, Option<ParamSet>) {
        let delta = res.finals.delta(&res.initial);
        let mut entries: Vec<(String, AggOp, Payload)> = Vec::new();
        let mut new_state = None;
        match self {
            Algo::FedAvg | Algo::FedProx { .. } => {
                entries.push(("delta".into(), AggOp::WeightedAvg, Payload::Params(delta)));
            }
            Algo::FedNova => {
                // Normalized direction d_m = Δ_m / τ_m ; τ_eff via a
                // weighted-avg scalar; raw τ_m additionally collected as
                // a Special Param (the s_e path of Table 1).
                let tau = res.n_steps.max(1) as f32;
                let mut d = delta;
                d.scale(1.0 / tau);
                entries.push(("delta_norm".into(), AggOp::WeightedAvg, Payload::Params(d)));
                entries.push(("tau_eff".into(), AggOp::WeightedAvg, Payload::Scalar(tau as f64)));
                entries.push(("tau".into(), AggOp::Collect, Payload::Scalar(tau as f64)));
            }
            Algo::Scaffold => {
                // Option II refresh: c_i⁺ = c_i − c + (w0 − wE)/(τ·lr)
                let tau = res.n_steps.max(1) as f32;
                let zeros = ParamSet::zeros(&res.initial.shapes);
                let c = bc.extra.as_ref().unwrap_or(&zeros);
                let ci = old_state.unwrap_or(&zeros);
                let mut ci_new = ci.clone();
                ci_new.add_scaled(c, -1.0);
                // (w0 − wE) / (τ lr) = −Δ/(τ lr)
                let mut drift = res.finals.delta(&res.initial);
                drift.scale(-1.0 / (tau * res.lr));
                ci_new.add_scaled(&drift, 1.0);
                let delta_c = ci_new.delta(ci);
                entries.push(("delta".into(), AggOp::WeightedAvg, Payload::Params(delta)));
                entries.push(("delta_c".into(), AggOp::Avg, Payload::Params(delta_c)));
                new_state = Some(ci_new);
            }
            Algo::FedDyn { alpha } => {
                // h_i⁺ = h_i − α·Δ_m
                let zeros = ParamSet::zeros(&res.initial.shapes);
                let hi = old_state.unwrap_or(&zeros);
                let mut hi_new = hi.clone();
                hi_new.add_scaled(&delta, -*alpha);
                entries.push(("delta".into(), AggOp::Avg, Payload::Params(delta)));
                new_state = Some(hi_new);
            }
            Algo::Mime { .. } => {
                entries.push(("delta".into(), AggOp::WeightedAvg, Payload::Params(delta)));
                if let Some(g) = &res.full_grad {
                    entries.push((
                        "grad_full".into(),
                        AggOp::Collect,
                        Payload::Params(g.clone()),
                    ));
                }
            }
        }
        entries.push(("loss".into(), AggOp::WeightedAvg, Payload::Scalar(res.mean_loss as f64)));
        (
            ClientUpdate { client: res.client, weight: res.weight, entries },
            new_state,
        )
    }

    // ------------------------------------------------------------ server

    /// GlobalAggregate epilogue: fold the round aggregate into the
    /// global params + server state.
    pub fn server_apply(
        &self,
        global: &mut ParamSet,
        state: &mut ServerState,
        agg: &RoundAggregate,
        ctx: &ServerCtx,
    ) {
        match self {
            Algo::FedAvg | Algo::FedProx { .. } => {
                if let Some(d) = agg.params.get("delta") {
                    global.add_scaled(d, 1.0);
                }
            }
            Algo::FedNova => {
                if let (Some(d), Some(tau_eff)) =
                    (agg.params.get("delta_norm"), agg.scalars.get("tau_eff"))
                {
                    global.add_scaled(d, *tau_eff as f32);
                }
            }
            Algo::Scaffold => {
                if let Some(d) = agg.params.get("delta") {
                    global.add_scaled(d, 1.0);
                }
                if let Some(dc) = agg.params.get("delta_c") {
                    let c = state
                        .c
                        .get_or_insert_with(|| ParamSet::zeros(&global.shapes));
                    let frac = ctx.m_selected as f32 / ctx.m_total.max(1) as f32;
                    c.add_scaled(dc, frac);
                }
            }
            Algo::FedDyn { alpha } => {
                if let Some(d) = agg.params.get("delta") {
                    let h = state
                        .h
                        .get_or_insert_with(|| ParamSet::zeros(&global.shapes));
                    let frac = ctx.m_selected as f32 / ctx.m_total.max(1) as f32;
                    h.add_scaled(d, -alpha * frac);
                    // w_{r+1} = mean(w_m) − h_{r+1}/α, with mean(w_m) =
                    // w_r + Δ̄ because clients start from the corrected
                    // iterate. Unrolling shows the −h/α terms accumulate
                    // by construction: w_r = w_0 + Σ Δ̄_i − Σ h_i/α, which
                    // is exactly Acar et al.'s recursion.
                    global.add_scaled(d, 1.0);
                    global.add_scaled(h, -1.0 / alpha);
                }
            }
            Algo::Mime { beta } => {
                if let Some(d) = agg.params.get("delta") {
                    global.add_scaled(d, 1.0);
                }
                if let Some(grads) = agg.collected.get("grad_full") {
                    let mut mean: Option<ParamSet> = None;
                    let mut n = 0.0f32;
                    for (_, p) in grads {
                        if let Payload::Params(g) = p {
                            match &mut mean {
                                None => mean = Some(g.clone()),
                                Some(m) => m.add_scaled(g, 1.0),
                            }
                            n += 1.0;
                        }
                    }
                    if let Some(mut gbar) = mean {
                        gbar.scale(1.0 / n.max(1.0));
                        let m = state
                            .m
                            .get_or_insert_with(|| ParamSet::zeros(&global.shapes));
                        m.scale(*beta);
                        m.add_scaled(&gbar, 1.0 - *beta);
                    }
                }
            }
        }
    }

    /// What rides along with the global params in the broadcast.
    pub fn broadcast_extra(&self, state: &ServerState) -> Option<ParamSet> {
        match self {
            Algo::Scaffold => state.c.clone(),
            Algo::Mime { .. } => state.m.clone(),
            _ => None,
        }
    }
}

pub const ALL_ALGORITHMS: [&str; 6] =
    ["fedavg", "fedprox", "fednova", "scaffold", "feddyn", "mime"];

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![4], vec![2, 2]]
    }

    fn ones(v: f32) -> ParamSet {
        let mut p = ParamSet::zeros(&shapes());
        for t in p.tensors.iter_mut() {
            for x in t.iter_mut() {
                *x = v;
            }
        }
        p
    }

    fn bc(params: ParamSet, extra: Option<ParamSet>) -> Broadcast {
        Broadcast { round: 0, params, extra }
    }

    fn result(initial: ParamSet, finals: ParamSet) -> TaskResult {
        TaskResult {
            client: 0,
            weight: 10.0,
            initial,
            finals,
            mean_loss: 1.0,
            n_steps: 5,
            lr: 0.1,
            full_grad: None,
        }
    }

    #[test]
    fn parse_all() {
        for name in ALL_ALGORITHMS {
            let a = Algo::parse(name, 0.0).unwrap();
            assert_eq!(a.name(), name);
        }
        assert!(Algo::parse("sgd", 0.0).is_err());
    }

    #[test]
    fn statefulness_matches_paper_table() {
        assert!(!Algo::FedAvg.stateful());
        assert!(!Algo::FedNova.stateful());
        assert!(Algo::Scaffold.stateful());
        assert!(Algo::FedDyn { alpha: 0.1 }.stateful());
        assert!(Algo::FedNova.has_special());
        assert!(Algo::Mime { beta: 0.9 }.has_special());
        assert!(!Algo::FedProx { mu: 0.1 }.has_special());
    }

    #[test]
    fn fedavg_round_trip_moves_global_to_client_mean() {
        let algo = Algo::FedAvg;
        let global = ones(1.0);
        let b = bc(global.clone(), None);
        let spec = algo.prepare(&b, None, &shapes());
        assert_eq!(spec.mu, 0.0);
        assert_eq!(spec.corrs, ParamSet::zeros(&shapes()));
        // Two clients land at 2.0 and 4.0 with equal weights:
        let (u1, s1) = algo.client_update(&result(global.clone(), ones(2.0)), &b, None);
        let (u2, s2) = algo.client_update(&result(global.clone(), ones(4.0)), &b, None);
        assert!(s1.is_none() && s2.is_none());
        let agg = crate::aggregation::flat_aggregate(&[u1, u2]);
        let mut g = global;
        algo.server_apply(&mut g, &mut ServerState::default(), &agg,
            &ServerCtx { m_total: 10, m_selected: 2 });
        // mean delta = ((2-1) + (4-1))/2 = 2 -> g = 3
        assert!((g.tensors[0][0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn fedprox_anchor_is_global() {
        let algo = Algo::FedProx { mu: 0.5 };
        let b = bc(ones(7.0), None);
        let spec = algo.prepare(&b, None, &shapes());
        assert_eq!(spec.mu, 0.5);
        assert_eq!(spec.anchors, ones(7.0));
    }

    #[test]
    fn fednova_normalizes_by_tau() {
        let algo = Algo::FedNova;
        let b = bc(ones(0.0), None);
        let mut res = result(ones(0.0), ones(10.0));
        res.n_steps = 10;
        let (u, _) = algo.client_update(&res, &b, None);
        // delta_norm = 10/10 = 1
        let d = u.entries.iter().find(|(n, _, _)| n == "delta_norm").unwrap();
        match &d.2 {
            Payload::Params(p) => assert!((p.tensors[0][0] - 1.0).abs() < 1e-6),
            other => unreachable!("delta_norm must carry a Params payload, got {other:?}"),
        }
        // special param present
        assert!(u.entries.iter().any(|(n, op, _)| n == "tau" && *op == AggOp::Collect));
        // server scales by tau_eff
        let agg = crate::aggregation::flat_aggregate(&[u]);
        let mut g = ones(0.0);
        algo.server_apply(&mut g, &mut ServerState::default(), &agg,
            &ServerCtx { m_total: 10, m_selected: 1 });
        assert!((g.tensors[0][0] - 10.0).abs() < 1e-5, "tau_eff*d̄ = 10*1");
    }

    #[test]
    fn scaffold_correction_and_state_refresh() {
        let algo = Algo::Scaffold;
        let c = ones(0.3);
        let ci = ones(0.1);
        let b = bc(ones(1.0), Some(c));
        let spec = algo.prepare(&b, Some(&ci), &shapes());
        // corr = c − c_i = 0.2
        assert!((spec.corrs.tensors[0][0] - 0.2).abs() < 1e-6);
        // refresh: c_i+ = c_i − c + (w0−wE)/(τ·lr); τ=5, lr=0.1, Δ=1
        let res = result(ones(1.0), ones(2.0));
        let (u, new_state) = algo.client_update(&res, &b, Some(&ci));
        let ci_new = new_state.unwrap();
        let want = 0.1 - 0.3 + (-1.0) / (5.0 * 0.1);
        assert!((ci_new.tensors[0][0] - want).abs() < 1e-5, "{}", ci_new.tensors[0][0]);
        // delta_c entry is Avg op
        assert!(u.entries.iter().any(|(n, op, _)| n == "delta_c" && *op == AggOp::Avg));
        // server c moves by (Mp/M)·mean(delta_c)
        let agg = crate::aggregation::flat_aggregate(&[u]);
        let mut st = ServerState::default();
        let mut g = ones(1.0);
        algo.server_apply(&mut g, &mut st, &agg, &ServerCtx { m_total: 4, m_selected: 1 });
        let dc = want - 0.1;
        let c_expect = 0.25 * dc;
        assert!((st.c.unwrap().tensors[0][0] - c_expect).abs() < 1e-5);
    }

    #[test]
    fn feddyn_state_and_prepare() {
        let algo = Algo::FedDyn { alpha: 0.5 };
        let hi = ones(0.2);
        let b = bc(ones(1.0), None);
        let spec = algo.prepare(&b, Some(&hi), &shapes());
        assert_eq!(spec.mu, 0.5);
        assert!((spec.corrs.tensors[0][0] + 0.2).abs() < 1e-6, "corr = −h_i");
        assert_eq!(spec.anchors, ones(1.0));
        let res = result(ones(1.0), ones(3.0));
        let (_, new_state) = algo.client_update(&res, &b, Some(&hi));
        // h_i+ = 0.2 − 0.5·2 = −0.8
        assert!((new_state.unwrap().tensors[0][0] + 0.8).abs() < 1e-6);
    }

    #[test]
    fn mime_momentum_update() {
        let algo = Algo::Mime { beta: 0.5 };
        let mut res = result(ones(0.0), ones(1.0));
        res.full_grad = Some(ones(2.0));
        let b = bc(ones(0.0), None);
        let (u, _) = algo.client_update(&res, &b, None);
        assert!(u.entries.iter().any(|(n, op, _)| n == "grad_full" && *op == AggOp::Collect));
        let agg = crate::aggregation::flat_aggregate(&[u]);
        let mut st = ServerState::default();
        let mut g = ones(0.0);
        algo.server_apply(&mut g, &mut st, &agg, &ServerCtx { m_total: 4, m_selected: 1 });
        // m = (1−β)·ḡ = 0.5·2 = 1
        assert!((st.m.as_ref().unwrap().tensors[0][0] - 1.0).abs() < 1e-6);
        // broadcast extra carries m, scaled by β at prepare time
        let b2 = Broadcast { round: 1, params: g, extra: algo.broadcast_extra(&st) };
        let spec = algo.prepare(&b2, None, &shapes());
        assert!((spec.corrs.tensors[0][0] - 0.5).abs() < 1e-6);
        assert!(spec.wants_full_grad);
    }

    #[test]
    fn loss_entry_always_present() {
        for name in ALL_ALGORITHMS {
            let algo = Algo::parse(name, 0.1).unwrap();
            let b = bc(ones(0.0), Some(ones(0.0)));
            let (u, _) = algo.client_update(&result(ones(0.0), ones(1.0)), &b, None);
            assert!(u.entries.iter().any(|(n, _, _)| n == "loss"), "{name}");
        }
    }
}
