//! Model-side substrate of Layer 3: the artifact manifests emitted by
//! `python/compile/aot.py` and the flat parameter sets the coordinator
//! aggregates.
//!
//! The manifest is the L2↔L3 contract: it pins the flattened input /
//! output order of every AOT artifact, so the Rust side can marshal
//! parameter tensors, batches and scalars into PJRT literals without
//! ever re-tracing the Python.

pub mod manifest;
pub mod params;

pub use manifest::{Dtype, Manifest, Role, TensorDecl};
pub use params::ParamSet;

/// Paper batch size (Table 4) — must match `python/compile/model.py::BATCH`.
pub const BATCH: usize = 20;

/// The model families exported by the AOT pipeline.
pub const MODEL_NAMES: [&str; 3] = ["mlp", "cnn", "tinylm"];

/// Step kinds exported per model.
pub const STEP_KINDS: [&str; 3] = ["train", "eval", "grad"];
