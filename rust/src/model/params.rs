//! Flat parameter sets: the unit the coordinator moves, aggregates and
//! persists.
//!
//! A [`ParamSet`] is the list of parameter tensors of one model, in
//! manifest order, stored as flat `Vec<f32>`s.  All aggregation math
//! (hierarchical local/global averaging, SCAFFOLD control-variate
//! updates, FedDyn h-terms) happens on these via the axpy-style ops
//! below — no PJRT round-trip for aggregation, matching the paper where
//! aggregation is a server/device CPU operation.

use crate::compress::{self, Codec};
use crate::util::codec::{Decoder, Encoder};
use crate::util::rng::Rng;
use anyhow::Result;

#[derive(Debug, Clone, PartialEq)]
pub struct ParamSet {
    /// Tensor shapes, manifest order.
    pub shapes: Vec<Vec<usize>>,
    /// Flat tensor data, parallel to `shapes`.
    pub tensors: Vec<Vec<f32>>,
}

impl ParamSet {
    pub fn zeros(shapes: &[Vec<usize>]) -> ParamSet {
        ParamSet {
            shapes: shapes.to_vec(),
            tensors: shapes
                .iter()
                .map(|s| vec![0.0; s.iter().product::<usize>().max(1)])
                .collect(),
        }
    }

    pub fn zeros_like(other: &ParamSet) -> ParamSet {
        ParamSet::zeros(&other.shapes)
    }

    /// [`ParamSet::zeros`] drawing its tensor buffers from a pool
    /// instead of the allocator — the per-round aggregation scratch
    /// path (see [`AggPool`]).  Identical contents (all zeros), only
    /// the buffers' provenance differs.
    pub fn zeros_in(shapes: &[Vec<usize>], pool: &mut AggPool) -> ParamSet {
        ParamSet {
            shapes: shapes.to_vec(),
            tensors: shapes
                .iter()
                .map(|s| pool.take(s.iter().product::<usize>().max(1)))
                .collect(),
        }
    }

    /// Hand this set's tensor buffers back to `pool` for reuse.  The
    /// shapes are dropped; only the f32 backing stores are retained.
    pub fn recycle_into(self, pool: &mut AggPool) {
        for t in self.tensors {
            pool.put(t);
        }
    }

    /// He-normal init matching `ModelSpec.init` semantics on the Python
    /// side (weights ~ N(0, 2/fan_in), 1-d tensors zero).  Numerically
    /// different draws than jax's PRNG — used when Rust owns init; the
    /// testvec path checks cross-language numerics instead.
    pub fn init_he(shapes: &[Vec<usize>], seed: u64) -> ParamSet {
        let root = Rng::new(seed ^ 0x1217_5EED);
        let tensors = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut rng = root.derive(i as u64);
                let numel: usize = s.iter().product::<usize>().max(1);
                if s.len() <= 1 {
                    vec![0.0; numel]
                } else {
                    let fan_in: usize = s[..s.len() - 1].iter().product();
                    let std = (2.0 / fan_in as f32).sqrt();
                    (0..numel).map(|_| rng.normal_f32(0.0, std)).collect()
                }
            })
            .collect();
        ParamSet { shapes: shapes.to_vec(), tensors }
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * 4
    }

    /// self += alpha * other   (the aggregation primitive).
    pub fn add_scaled(&mut self, other: &ParamSet, alpha: f32) {
        debug_assert_eq!(self.shapes, other.shapes);
        for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += alpha * y;
            }
        }
    }

    /// self *= alpha.
    pub fn scale(&mut self, alpha: f32) {
        for t in self.tensors.iter_mut() {
            for x in t.iter_mut() {
                *x *= alpha;
            }
        }
    }

    /// self - other, returned (client delta Δw).
    pub fn delta(&self, other: &ParamSet) -> ParamSet {
        debug_assert_eq!(self.shapes, other.shapes);
        ParamSet {
            shapes: self.shapes.clone(),
            tensors: self
                .tensors
                .iter()
                .zip(&other.tensors)
                .map(|(a, b)| a.iter().zip(b).map(|(x, y)| x - y).collect())
                .collect(),
        }
    }

    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    pub fn max_abs_diff(&self, other: &ParamSet) -> f32 {
        self.tensors
            .iter()
            .zip(&other.tensors)
            .flat_map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x - y).abs()))
            .fold(0.0, f32::max)
    }

    /// Serialize (state-manager snapshot / transport message payload).
    /// Lossless raw-f32 tensors; see [`ParamSet::encode_with`] for the
    /// compressed wire forms.
    pub fn encode(&self, enc: &mut Encoder) -> Result<()> {
        self.encode_with(enc, Codec::None)
    }

    /// Serialize with a wire codec: each tensor is written as a
    /// self-describing compressed stream (`compress::encode_f32s`), so
    /// [`ParamSet::decode`] needs no out-of-band codec knowledge.
    /// Errs only on counts past the u32 wire prefixes.
    pub fn encode_with(&self, enc: &mut Encoder, codec: Codec) -> Result<()> {
        enc.put_len(self.tensors.len())?;
        for (shape, t) in self.shapes.iter().zip(&self.tensors) {
            enc.put_len(shape.len())?;
            for &d in shape {
                enc.try_put_u32(d)?;
            }
            compress::encode_f32s(enc, t, codec)?;
        }
        Ok(())
    }

    pub fn decode(dec: &mut Decoder) -> Result<ParamSet> {
        // Every count is bounds-checked against the remaining buffer
        // before allocation (corrupt frames error, never panic or
        // balloon): a tensor record is at least rank(4) + codec tag(1)
        // + length(4) bytes, a shape dim exactly 4.
        let n = dec.count(9)?;
        let mut shapes = Vec::with_capacity(n);
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let rank = dec.count(4)?;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(dec.u32()? as usize);
            }
            let numel = shape
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .ok_or_else(|| anyhow::anyhow!("shape {shape:?} overflows"))?;
            let t = compress::decode_f32s(dec)?;
            anyhow::ensure!(
                t.len() == numel.max(1),
                "tensor length {} != shape {:?}",
                t.len(),
                shape
            );
            shapes.push(shape);
            tensors.push(t);
        }
        Ok(ParamSet { shapes, tensors })
    }

    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut enc = Encoder::with_capacity(self.size_bytes() + 64);
        self.encode(&mut enc)?;
        Ok(enc.finish())
    }

    pub fn from_bytes(buf: &[u8]) -> Result<ParamSet> {
        ParamSet::decode(&mut Decoder::new(buf))
    }
}

/// Weighted running mean over ParamSets — the hierarchical-aggregation
/// accumulator used identically on devices (local) and server (global),
/// which is what makes the two-level scheme exactly equal to flat
/// averaging (§4.2; proven by `aggregation::tests`).
#[derive(Debug, Clone)]
pub struct WeightedAccum {
    pub sum: ParamSet,
    pub weight: f64,
}

impl WeightedAccum {
    pub fn new(shapes: &[Vec<usize>]) -> WeightedAccum {
        WeightedAccum { sum: ParamSet::zeros(shapes), weight: 0.0 }
    }

    /// [`WeightedAccum::new`] with pooled tensor buffers — the
    /// aggregator tiers' per-round accumulators reuse the previous
    /// round's buffers instead of allocating one per entry per merge.
    pub fn new_in(shapes: &[Vec<usize>], pool: &mut AggPool) -> WeightedAccum {
        WeightedAccum { sum: ParamSet::zeros_in(shapes, pool), weight: 0.0 }
    }

    pub fn add(&mut self, p: &ParamSet, w: f64) {
        self.sum.add_scaled(p, w as f32);
        self.weight += w;
    }

    /// Merge another accumulator (global step of hierarchical agg).
    pub fn merge(&mut self, other: &WeightedAccum) {
        self.sum.add_scaled(&other.sum, 1.0);
        self.weight += other.weight;
    }

    /// Weighted mean; None if nothing was accumulated.
    pub fn mean(&self) -> Option<ParamSet> {
        if self.weight <= 0.0 {
            return None;
        }
        let mut m = self.sum.clone();
        m.scale((1.0 / self.weight) as f32);
        Some(m)
    }
}

/// Size-class buffer pool for aggregation scratch: freed `Vec<f32>`
/// tensor buffers are binned by ceil-log2 capacity and handed back out
/// zeroed, so the per-round device/tier/server merges reuse the
/// previous round's allocations instead of allocating one buffer per
/// client per entry.  Exclusive ownership (one pool per aggregation
/// actor, `&mut` everywhere) — no locking, no unordered iteration, and
/// the pooled results are element-for-element identical to the
/// allocator path (property-tested in `aggregation::tests`).
#[derive(Debug, Default)]
pub struct AggPool {
    /// `classes[c]` holds free buffers of capacity in (2^(c-1), 2^c].
    classes: Vec<SizeClass>,
    /// `take` calls served from a free list.
    pub hits: u64,
    /// `take` calls that fell through to the allocator.
    pub misses: u64,
    /// Buffers handed back via `put`.
    pub recycled: u64,
}

#[derive(Debug, Default)]
struct SizeClass {
    free: Vec<Vec<f32>>,
}

impl AggPool {
    pub fn new() -> AggPool {
        AggPool::default()
    }

    /// Ceil-log2 size class of a buffer length (class 0 holds lengths
    /// 0 and 1).
    fn class_of(len: usize) -> usize {
        (usize::BITS - len.max(1).wrapping_sub(1).leading_zeros()) as usize
    }

    fn class_mut(&mut self, c: usize) -> &mut SizeClass {
        if c >= self.classes.len() {
            self.classes.resize_with(c + 1, SizeClass::default);
        }
        &mut self.classes[c]
    }

    /// A zeroed buffer of exactly `len` elements, reusing a freed
    /// buffer of the same size class when one is available.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let c = Self::class_of(len);
        match self.class_mut(c).free.pop() {
            Some(mut buf) => {
                self.hits += 1;
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => {
                self.misses += 1;
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer for reuse; contents are discarded.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let c = Self::class_of(buf.capacity());
        self.recycled += 1;
        self.class_mut(c).free.push(buf);
    }

    /// Free buffers currently parked across all size classes.
    pub fn free_buffers(&self) -> usize {
        self.classes.iter().map(|c| c.free.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![4, 3], vec![3], vec![2, 2, 2]]
    }

    #[test]
    fn zeros_layout() {
        let p = ParamSet::zeros(&shapes());
        assert_eq!(p.n_tensors(), 3);
        assert_eq!(p.numel(), 12 + 3 + 8);
        assert_eq!(p.size_bytes(), 4 * 23);
        assert!(p.tensors.iter().flatten().all(|&x| x == 0.0));
    }

    #[test]
    fn he_init_statistics() {
        let sh = vec![vec![1000, 100], vec![100]];
        let p = ParamSet::init_he(&sh, 1);
        // bias tensor zero
        assert!(p.tensors[1].iter().all(|&x| x == 0.0));
        // weight std ~ sqrt(2/1000)
        let w = &p.tensors[0];
        let mean = w.iter().sum::<f32>() / w.len() as f32;
        let var = w.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / w.len() as f32;
        let want = 2.0 / 1000.0;
        assert!(mean.abs() < 0.005, "mean={mean}");
        assert!((var - want).abs() / want < 0.15, "var={var} want={want}");
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = ParamSet::zeros(&shapes());
        let mut b = ParamSet::zeros(&shapes());
        b.tensors[0][0] = 2.0;
        b.tensors[2][7] = -4.0;
        a.add_scaled(&b, 0.5);
        assert_eq!(a.tensors[0][0], 1.0);
        assert_eq!(a.tensors[2][7], -2.0);
        a.scale(3.0);
        assert_eq!(a.tensors[0][0], 3.0);
    }

    #[test]
    fn delta_and_norms() {
        let mut a = ParamSet::zeros(&shapes());
        a.tensors[0][0] = 3.0;
        a.tensors[1][1] = 4.0;
        let b = ParamSet::zeros(&shapes());
        let d = a.delta(&b);
        assert_eq!(d.tensors[0][0], 3.0);
        assert!((d.l2_norm() - 5.0).abs() < 1e-9);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }

    #[test]
    fn codec_round_trip() {
        let p = ParamSet::init_he(&shapes(), 9);
        let q = ParamSet::from_bytes(&p.to_bytes().unwrap()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn compressed_round_trip_within_bound() {
        let p = ParamSet::init_he(&shapes(), 11);
        for codec in crate::compress::ALL_CODECS {
            let mut enc = Encoder::new();
            p.encode_with(&mut enc, codec).unwrap();
            let buf = enc.finish();
            let q = ParamSet::from_bytes(&buf).unwrap();
            assert_eq!(q.shapes, p.shapes);
            let bound: f64 = p
                .tensors
                .iter()
                .map(|t| codec.bound(t))
                .fold(0.0, f64::max);
            assert!(
                (p.max_abs_diff(&q) as f64) <= bound,
                "{codec:?}: diff {} > bound {bound}",
                p.max_abs_diff(&q)
            );
            if codec == Codec::None {
                assert_eq!(p, q);
            }
        }
    }

    #[test]
    fn codec_rejects_corrupt() {
        let p = ParamSet::init_he(&shapes(), 9);
        let mut b = p.to_bytes().unwrap();
        b.truncate(b.len() - 3);
        assert!(ParamSet::from_bytes(&b).is_err());
    }

    #[test]
    fn weighted_accum_is_weighted_mean() {
        let sh = vec![vec![2]];
        let mk = |v: f32| ParamSet { shapes: sh.clone(), tensors: vec![vec![v, 2.0 * v]] };
        let mut acc = WeightedAccum::new(&sh);
        acc.add(&mk(1.0), 1.0);
        acc.add(&mk(4.0), 3.0);
        let m = acc.mean().unwrap();
        // (1*1 + 4*3)/4 = 3.25
        assert!((m.tensors[0][0] - 3.25).abs() < 1e-6);
        assert!((m.tensors[0][1] - 6.5).abs() < 1e-6);
    }

    #[test]
    fn accum_merge_equals_flat() {
        let sh = vec![vec![3]];
        let mut rng = crate::util::rng::Rng::new(4);
        let ps: Vec<(ParamSet, f64)> = (0..10)
            .map(|_| {
                let t: Vec<f32> = (0..3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                (ParamSet { shapes: sh.clone(), tensors: vec![t] }, rng.range_f64(0.5, 2.0))
            })
            .collect();
        // flat
        let mut flat = WeightedAccum::new(&sh);
        for (p, w) in &ps {
            flat.add(p, *w);
        }
        // two-level: 3 "devices"
        let mut global = WeightedAccum::new(&sh);
        for chunk in ps.chunks(4) {
            let mut local = WeightedAccum::new(&sh);
            for (p, w) in chunk {
                local.add(p, *w);
            }
            global.merge(&local);
        }
        let a = flat.mean().unwrap();
        let b = global.mean().unwrap();
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn empty_accum_mean_none() {
        assert!(WeightedAccum::new(&shapes()).mean().is_none());
    }

    #[test]
    fn pool_reuses_and_zeroes_buffers() {
        let mut pool = AggPool::new();
        let mut a = pool.take(12);
        assert_eq!(pool.misses, 1);
        assert!(a.iter().all(|&x| x == 0.0));
        a[3] = 7.5;
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.recycled, 1);
        assert_eq!(pool.free_buffers(), 1);
        // Same size class (12 and 16 both round up to 2^4): the freed
        // buffer comes back, zeroed, with its capacity intact.
        let b = pool.take(16);
        assert_eq!(pool.hits, 1);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|&x| x == 0.0), "recycled buffer must be re-zeroed");
        assert!(b.capacity() >= cap);
        assert_eq!(pool.free_buffers(), 0);
        // Different class: allocator path again.
        let c = pool.take(1000);
        assert_eq!(pool.misses, 2);
        assert_eq!(c.len(), 1000);
    }

    #[test]
    fn zeros_in_matches_zeros() {
        let mut pool = AggPool::new();
        let a = ParamSet::zeros(&shapes());
        let b = ParamSet::zeros_in(&shapes(), &mut pool);
        assert_eq!(a, b);
        // Round-trip: recycle, re-take from the pool, still identical.
        b.recycle_into(&mut pool);
        assert_eq!(pool.free_buffers(), 3);
        let c = ParamSet::zeros_in(&shapes(), &mut pool);
        assert_eq!(a, c);
        assert_eq!(pool.hits, 3);
    }
}
