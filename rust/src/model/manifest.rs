//! Parser for the plain-text artifact manifests (`<artifact>.manifest.txt`).
//!
//! Format (one record per line, space-separated — see `aot.py`):
//!
//! ```text
//! artifact mlp_train
//! model mlp
//! kind train
//! batch 20
//! nparams 6
//! input w1 param f32 784,256
//! input lr scalar f32 -
//! output loss metric f32 -
//! ```

use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unknown dtype {s:?}"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// The role a tensor plays in the generalized step (DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Model parameter (inputs) / updated parameter or gradient (outputs).
    Param,
    /// FedProx/FedDyn anchor (w_global).
    Anchor,
    /// SCAFFOLD / Mime correction term.
    Corr,
    /// Data batch (x or y).
    BatchData,
    /// 0-d hyperparameter (lr, mu).
    Scalar,
    /// Scalar output metric (loss, gsq, correct).
    Metric,
}

impl Role {
    pub fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "anchor" => Role::Anchor,
            "corr" => Role::Corr,
            "batch" => Role::BatchData,
            "scalar" => Role::Scalar,
            "metric" => Role::Metric,
            _ => bail!("unknown role {s:?}"),
        })
    }
}

/// One declared input or output tensor.
#[derive(Debug, Clone)]
pub struct TensorDecl {
    pub name: String,
    pub role: Role,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorDecl {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }
}

/// Parsed manifest of one AOT artifact.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifact: String,
    pub model: String,
    pub kind: String,
    pub batch: usize,
    pub nparams: usize,
    pub inputs: Vec<TensorDecl>,
    pub outputs: Vec<TensorDecl>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "-" {
        return Ok(Vec::new()); // 0-d scalar
    }
    s.split(',')
        .map(|d| d.parse::<usize>().context("bad shape dim"))
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut artifact = String::new();
        let mut model = String::new();
        let mut kind = String::new();
        let mut batch = 0usize;
        let mut nparams = 0usize;
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        for (lno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("manifest line {}: {line:?}", lno + 1);
            match parts[0] {
                "artifact" => artifact = parts.get(1).with_context(ctx)?.to_string(),
                "model" => model = parts.get(1).with_context(ctx)?.to_string(),
                "kind" => kind = parts.get(1).with_context(ctx)?.to_string(),
                "batch" => batch = parts.get(1).with_context(ctx)?.parse()?,
                "nparams" => nparams = parts.get(1).with_context(ctx)?.parse()?,
                "input" | "output" => {
                    if parts.len() != 5 {
                        bail!("{}: expected 5 fields", ctx());
                    }
                    let decl = TensorDecl {
                        name: parts[1].to_string(),
                        role: Role::parse(parts[2]).with_context(ctx)?,
                        dtype: Dtype::parse(parts[3]).with_context(ctx)?,
                        shape: parse_shape(parts[4]).with_context(ctx)?,
                    };
                    if parts[0] == "input" {
                        inputs.push(decl);
                    } else {
                        outputs.push(decl);
                    }
                }
                other => bail!("unknown manifest record {other:?} at line {}", lno + 1),
            }
        }
        if artifact.is_empty() || inputs.is_empty() || outputs.is_empty() {
            bail!("incomplete manifest (artifact={artifact:?}, {} in, {} out)",
                  inputs.len(), outputs.len());
        }
        let m = Manifest { artifact, model, kind, batch, nparams, inputs, outputs };
        m.validate()?;
        Ok(m)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading manifest {}", p.display()))?;
        Manifest::parse(&text).with_context(|| format!("parsing {}", p.display()))
    }

    fn validate(&self) -> Result<()> {
        let n_in_params = self.inputs.iter().filter(|d| d.role == Role::Param).count();
        if n_in_params != self.nparams {
            bail!("nparams={} but {} param inputs", self.nparams, n_in_params);
        }
        match self.kind.as_str() {
            "train" => {
                let anchors = self.inputs.iter().filter(|d| d.role == Role::Anchor).count();
                let corrs = self.inputs.iter().filter(|d| d.role == Role::Corr).count();
                if anchors != self.nparams || corrs != self.nparams {
                    bail!("train manifest needs {} anchors+corrs, got {}/{}",
                          self.nparams, anchors, corrs);
                }
                let out_params =
                    self.outputs.iter().filter(|d| d.role == Role::Param).count();
                if out_params != self.nparams {
                    bail!("train outputs {} params, expected {}", out_params, self.nparams);
                }
            }
            "eval" | "grad" => {}
            k => bail!("unknown kind {k:?}"),
        }
        Ok(())
    }

    /// Input param declarations, in order.
    pub fn param_decls(&self) -> Vec<&TensorDecl> {
        self.inputs.iter().filter(|d| d.role == Role::Param).collect()
    }

    /// Shapes of the model parameters (the aggregation layout).
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        self.param_decls().iter().map(|d| d.shape.clone()).collect()
    }

    /// Total parameter element count (s_a in the paper's Table 1, in elems).
    pub fn param_numel(&self) -> usize {
        self.param_decls().iter().map(|d| d.numel()).sum()
    }

    /// Model size in bytes — the paper's s_a.
    pub fn param_bytes(&self) -> usize {
        self.param_decls().iter().map(|d| d.size_bytes()).sum()
    }

    /// The x/y batch declarations.
    pub fn batch_decls(&self) -> Vec<&TensorDecl> {
        self.inputs.iter().filter(|d| d.role == Role::BatchData).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact mlp_train
model mlp
kind train
batch 20
nparams 2
input w1 param f32 784,256
input b1 param f32 256
input anchor.w1 anchor f32 784,256
input anchor.b1 anchor f32 256
input corr.w1 corr f32 784,256
input corr.b1 corr f32 256
input x batch f32 20,784
input y batch i32 20
input lr scalar f32 -
input mu scalar f32 -
output new.w1 param f32 784,256
output new.b1 param f32 256
output loss metric f32 -
output gsq metric f32 -
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifact, "mlp_train");
        assert_eq!(m.batch, 20);
        assert_eq!(m.nparams, 2);
        assert_eq!(m.inputs.len(), 10);
        assert_eq!(m.outputs.len(), 4);
        assert_eq!(m.param_numel(), 784 * 256 + 256);
        assert_eq!(m.param_bytes(), 4 * (784 * 256 + 256));
        assert_eq!(m.inputs[7].dtype, Dtype::I32);
        assert!(m.inputs[8].shape.is_empty());
        assert_eq!(m.inputs[8].numel(), 1); // 0-d scalar has 1 element
    }

    #[test]
    fn rejects_bad_nparams() {
        let bad = SAMPLE.replace("nparams 2", "nparams 3");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_anchor() {
        let bad = SAMPLE.replace("input anchor.b1 anchor f32 256\n", "");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("wat 1 2 3").is_err());
        assert!(Manifest::parse("").is_err());
        let bad = SAMPLE.replace("f32 784,256", "f32 784,abc");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn eval_kind_lenient() {
        let m = Manifest::parse(
            "artifact mlp_eval\nmodel mlp\nkind eval\nbatch 20\nnparams 1\n\
             input w1 param f32 4,4\ninput x batch f32 20,4\ninput y batch i32 20\n\
             output loss metric f32 -\noutput correct metric f32 -\n",
        )
        .unwrap();
        assert_eq!(m.kind, "eval");
        assert_eq!(m.batch_decls().len(), 2);
    }

    #[test]
    fn real_artifacts_parse_if_present() {
        // Run against the actual AOT output when artifacts/ exists.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.exists() {
            return;
        }
        for model in crate::model::MODEL_NAMES {
            for kind in crate::model::STEP_KINDS {
                let p = dir.join(format!("{model}_{kind}.manifest.txt"));
                if p.exists() {
                    let m = Manifest::load(&p).unwrap();
                    assert_eq!(m.model, model);
                    assert_eq!(m.kind, kind);
                    assert_eq!(m.batch, crate::model::BATCH);
                }
            }
        }
    }
}
